//! END-TO-END VALIDATION DRIVER (required by DESIGN.md §4).
//!
//! Runs the full system on a real small workload and reports the
//! paper's headline metric: a 3-node cluster per system, a synthetic
//! tiny-corpus load (Zipf keys, 16 KB values), point + range query
//! phases, and the put/get/scan improvement of Nezha over Original —
//! proving all layers compose: Rust coordinator → KVS-Raft → ValueLog
//! / LSM → GC (with the hash index built through the AOT XLA/Pallas
//! artifact when available) → three-phase reads.
//!
//! ```bash
//! cargo run --release --example e2e_pipeline
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use nezha::engine::EngineKind;
use nezha::harness::{improvement_pct, print_header, Env, Spec};
use nezha::runtime::IndexPlanner;
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    // Confirm the AOT artifact story up front.
    match IndexPlanner::load_default() {
        Ok(_) => println!("AOT artifact: artifacts/index_build.hlo.txt loaded on PJRT CPU ✓"),
        Err(e) => {
            println!("AOT artifact unavailable ({e:#}); GC uses the bit-identical Rust backend")
        }
    }

    let value_size = 16 << 10;
    let load_bytes: u64 = 12 << 20;
    let gets = 400u64;
    let scans = 30u64;
    let scan_len = 32usize;

    print_header("E2E: load (put path)");
    let mut put_tp: HashMap<EngineKind, f64> = HashMap::new();
    let mut get_tp: HashMap<EngineKind, f64> = HashMap::new();
    let mut scan_tp: HashMap<EngineKind, f64> = HashMap::new();
    let mut get_rows = Vec::new();
    let mut scan_rows = Vec::new();

    for kind in EngineKind::ALL {
        let mut spec = Spec::new(kind, value_size);
        spec.load_bytes = load_bytes;
        let env = Env::start(spec)?;
        let put = env.load("16KB")?;
        println!("{}", put.row());
        put_tp.insert(kind, put.mib_per_sec());
        env.settle()?;
        let get = env.run_gets(gets, "16KB")?;
        get_tp.insert(kind, get.ops_per_sec());
        get_rows.push(get.row());
        let scan = env.run_scans(scans, scan_len, "16KB")?;
        scan_tp.insert(kind, scan.mib_per_sec());
        scan_rows.push(scan.row());
        env.destroy()?;
    }

    print_header("E2E: point queries (get path)");
    for r in get_rows {
        println!("{r}");
    }
    print_header("E2E: range queries (scan path)");
    for r in scan_rows {
        println!("{r}");
    }

    let o = EngineKind::Original;
    let n = EngineKind::Nezha;
    println!("\n=== E2E headline (Nezha vs Original, paper in parens) ===");
    println!(
        "put : {:+.1}%   (+460.2%)",
        improvement_pct(put_tp[&n], put_tp[&o])
    );
    println!(
        "get : {:+.1}%   (+12.5%)",
        improvement_pct(get_tp[&n], get_tp[&o])
    );
    println!(
        "scan: {:+.1}%   (+72.6%)",
        improvement_pct(scan_tp[&n], scan_tp[&o])
    );
    println!("\nordering checks:");
    let nogc = EngineKind::NezhaNoGc;
    println!(
        "  put : Nezha ≈ NoGC > Original?   {} ({:.1} vs {:.1} vs {:.1} MiB/s)",
        put_tp[&n] > put_tp[&o] && put_tp[&nogc] > put_tp[&o],
        put_tp[&n], put_tp[&nogc], put_tp[&o]
    );
    println!(
        "  get : Nezha > NoGC?              {} ({:.0} vs {:.0} ops/s)",
        get_tp[&n] > get_tp[&nogc],
        get_tp[&n], get_tp[&nogc]
    );
    println!(
        "  scan: Nezha > NoGC?              {} ({:.1} vs {:.1} MiB/s)",
        scan_tp[&n] > scan_tp[&nogc],
        scan_tp[&n], scan_tp[&nogc]
    );
    Ok(())
}
