//! GC lifecycle walkthrough: drive a single Nezha replica through
//! Pre-GC → During-GC → Post-GC, exercising the three-phase request
//! processing (Algorithms 1–3) and the crash-resume path (§III-E).
//!
//! ```bash
//! cargo run --release --example gc_lifecycle
//! ```

use nezha::coordinator::Replica;
use nezha::engine::{EngineKind, EngineOpts};
use nezha::gc::{GcConfig, GcPhase};
use nezha::raft::{Command, Config as RaftConfig};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("nezha-gclife-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut replica = Replica::open(
        1,
        vec![],
        &dir,
        EngineKind::Nezha,
        EngineOpts::new("unset", "unset"),
        RaftConfig::default(),
        GcConfig { threshold_bytes: 2 << 20, ..Default::default() },
        7,
    )?;
    while !replica.node.is_leader() {
        replica.node.tick()?;
    }

    println!("phase = {:?} (Pre-GC: only the Active Storage)", replica.engine().gc_phase());
    assert_eq!(replica.engine().gc_phase(), GcPhase::Pre);

    // Write past the threshold.
    for i in 0..256u32 {
        let cmd = Command::Put {
            key: format!("key{i:05}").into_bytes(),
            value: vec![i as u8; 16 << 10],
        };
        replica.propose_batch(vec![cmd])?;
    }
    println!("wrote 4 MiB; pumping the GC trigger...");
    replica.pump_gc(0)?;
    let phase = replica.engine().gc_phase();
    println!("phase = {phase:?} (During-GC: New + frozen Active Storage)");
    assert_eq!(replica.engine().gc_phase(), GcPhase::During);

    // Reads and writes keep flowing mid-GC.
    let put = Command::Put { key: b"during-gc".to_vec(), value: b"still writable".to_vec() };
    replica.propose_batch(vec![put])?;
    assert!(replica.engine().get(b"key00042")?.is_some());
    assert!(replica.engine().get(b"during-gc")?.is_some());
    println!("reads + writes served During-GC ✓");

    let out = replica.finish_gc()?.expect("cycle output");
    println!(
        "GC done: L0 run gen {} with {} entries — {} flush B + {} merge B ({} level merges), \
         stack {:?}, index backend `{}` ({} ms)",
        out.gen,
        out.entries,
        out.flush_bytes,
        out.merge_bytes,
        out.merges,
        out.levels,
        out.index_backend,
        out.wall_ms
    );
    let phase = replica.engine().gc_phase();
    println!("phase = {phase:?} (Post-GC: New + Final Compacted Storage)");
    assert_eq!(replica.engine().gc_phase(), GcPhase::Post);

    // Post-GC reads hit the hash-indexed sorted ValueLog.
    assert!(replica.engine().get(b"key00100")?.is_some());
    let rows = replica.engine().scan(b"key00010", b"key00020", 100)?;
    println!("post-GC scan(10) -> {} rows via sorted ValueLog ✓", rows.len());

    // Crash + recover: state machine reconstructs from snapshot +
    // live epoch (Figure 11's scenario).
    drop(replica);
    let t0 = std::time::Instant::now();
    let recovered = Replica::open(
        1,
        vec![],
        &dir,
        EngineKind::Nezha,
        EngineOpts::new("unset", "unset"),
        RaftConfig::default(),
        GcConfig::default(),
        7,
    )?;
    println!(
        "recovered in {:.1} ms; key00123 = {} bytes",
        t0.elapsed().as_secs_f64() * 1e3,
        recovered.engine().get(b"key00123")?.map_or(0, |v| v.len())
    );

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
