//! YCSB demo: run workloads A and E against Nezha and Original and
//! print the side-by-side comparison — the mixed-workload scenario
//! from the paper's §IV-E.
//!
//! ```bash
//! cargo run --release --example ycsb_demo
//! ```

use nezha::engine::EngineKind;
use nezha::harness::{print_header, Env, Spec};
use nezha::ycsb::WorkloadKind;

fn main() -> anyhow::Result<()> {
    print_header("YCSB demo: A (50/50) and E (scan-heavy), 16KB values");
    for wl in [WorkloadKind::A, WorkloadKind::E] {
        for kind in [EngineKind::Original, EngineKind::Nezha] {
            let mut spec = Spec::new(kind, 16 << 10);
            spec.load_bytes = 4 << 20;
            let env = Env::start(spec)?;
            env.load("preload")?;
            env.settle()?;
            let (m, wlat, rlat) = env.run_ycsb(wl, 200, 50)?;
            println!("{}", m.row());
            println!("    write[{}]", wlat.summary());
            println!("    read [{}]", rlat.summary());
            env.destroy()?;
        }
    }
    Ok(())
}
