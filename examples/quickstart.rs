//! Quickstart: start a 3-node Nezha cluster in-process, write, read,
//! scan, delete, and watch a GC cycle happen.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nezha::coordinator::{Cluster, ClusterConfig};
use nezha::engine::EngineKind;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("nezha-quickstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A 3-node Nezha cluster with a small GC threshold so the demo
    // actually triggers a cycle.
    let mut cfg = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    cfg.gc.threshold_bytes = 4 << 20;
    let cluster = Cluster::start(cfg)?;
    let leader = cluster.wait_for_leader(Duration::from_secs(5))?;
    println!("cluster up, leader = node {leader}");

    // Writes go through KVS-Raft: one value persist, offsets in the
    // state machine.
    cluster.put(b"greeting", b"hello, nezha!")?;
    println!("get(greeting) = {:?}", String::from_utf8_lossy(&cluster.get(b"greeting")?.unwrap()));

    // Bulk write to cross the GC threshold.
    println!("writing 6 MiB to trigger GC...");
    for chunk in 0..24 {
        let ops: Vec<_> = (0..16u32)
            .map(|i| {
                (
                    format!("bulk{:06}", chunk * 16 + i).into_bytes(),
                    vec![chunk as u8; 16 << 10],
                )
            })
            .collect();
        cluster.put_batch(ops)?;
    }
    cluster.drain_gc()?;
    let st = cluster.status(leader)?;
    println!("GC cycles completed: {} (phase now {:?})", st.gc_cycles, st.gc_phase);

    // Reads work identically across GC phases (three-phase request
    // processing).
    let rows = cluster.scan(b"bulk000100", b"bulk000110", 100)?;
    println!("scan(bulk000100..bulk000110) -> {} rows", rows.len());
    assert_eq!(rows.len(), 10);

    cluster.delete(b"greeting")?;
    assert_eq!(cluster.get(b"greeting")?, None);
    println!("delete works; shutting down");

    cluster.shutdown()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
