//! Figure 11 — recovery time after a crash in each GC state (Pre-GC /
//! During-GC / Post-GC) vs Original.  Paper headline: Nezha's phases
//! recover 34.8% / 34.5% / 32.6% faster than Original, because the
//! state machine holds only offsets (small LSM to rebuild) and an
//! interrupted GC resumes from the sorted file's last key.
//!
//! Method: build the state on a single replica per shard, "crash" by
//! dropping it, and time `Replica::open` across all shards (raft log
//! scan + LSM WAL replay + optional GC resume).  With `--shards N`
//! the same dataset is partitioned over N shard replicas, showing how
//! sharding shrinks each group's recovery unit.
//!
//! The follower-catch-up section measures the *other* recovery path
//! (DESIGN.md §8): a 3-node cluster where node 3 falls behind a
//! compacting leader and rejoins — "Nezha (run-shipping)" streams
//! sealed GC runs as chunked files, "Nezha (monolithic)" re-serializes
//! the whole engine into one `InstallSnapshot` blob.  Each row reports
//! catch-up wall time plus total and snapshot-attributed bytes on the
//! wire.  Every run also writes the tables to `BENCH_fig11.json`.
//!
//! Run: `cargo bench --bench fig11_recovery [-- --shards N]`.

use nezha::coordinator::{Cluster, ClusterConfig, ReadConsistency, Replica};
use nezha::engine::{EngineKind, EngineOpts};
use nezha::gc::{FrozenEpoch, GcConfig, GcState};
use nezha::harness::{bench_scale, bench_shards};
use nezha::raft::{Command, Config as RaftConfig, NetConfig, TransportKind};
use nezha::ycsb::Generator;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-fig11-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Per-shard replica directories under one scenario dir.
fn shard_dirs(dir: &std::path::Path, shards: usize) -> Vec<PathBuf> {
    (0..shards).map(|s| dir.join(format!("shard-{s}"))).collect()
}

fn open_replica(dir: &std::path::Path, kind: EngineKind) -> anyhow::Result<Replica> {
    let mut opts = EngineOpts::new("unset", "unset");
    opts.memtable_bytes = 1 << 20;
    Replica::open(
        1,
        vec![],
        dir,
        kind,
        opts,
        RaftConfig::default(),
        GcConfig { threshold_bytes: u64::MAX, ..Default::default() },
        7,
    )
}

fn make_leader(r: &mut Replica) {
    for _ in 0..200 {
        let _ = r.node.tick().unwrap();
        if r.node.is_leader() {
            return;
        }
    }
    panic!("no leader");
}

fn load(r: &mut Replica, records: u64, vs: usize, seed: u64) {
    let mut g = Generator::load_ops(records, vs, seed);
    let mut batch = Vec::new();
    loop {
        batch.clear();
        for _ in 0..64 {
            match g.next() {
                Some((k, v)) => batch.push(Command::Put { key: k, value: v }),
                None => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        let (_, _out) = r.propose_batch(batch.drain(..).collect()).unwrap();
    }
    r.engine().sync().unwrap();
    r.node.log.sync().unwrap();
}

/// Reopen every shard replica of a scenario; total wall time is the
/// recovery cost (recovery includes serving a first read per shard).
fn time_reopen(dirs: &[PathBuf], kind: EngineKind) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    for dir in dirs {
        let mut r = open_replica(dir, kind)?;
        let _ = r.engine().scan(b"", &[0xffu8; 16], 1)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

/// Build one loaded shard replica per dir and hand each to `crash`
/// for the scenario-specific pre-crash state.
fn build_shards(
    dirs: &[PathBuf],
    kind: EngineKind,
    records_per_shard: u64,
    vs: usize,
    crash: impl Fn(&mut Replica, &std::path::Path) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    for (s, dir) in dirs.iter().enumerate() {
        let mut r = open_replica(dir, kind)?;
        make_leader(&mut r);
        load(&mut r, records_per_shard, vs, 42 + s as u64);
        crash(&mut r, dir)?;
    }
    Ok(())
}

/// Follower catch-up on a 3-node cluster: kill node 3, write past it
/// across two GC drains (the raft log compacts beyond its position),
/// then time restart → converged and meter the wire.  Returns
/// (catchup_ms, wire_mib, snap_mib) for the rejoin window only.
fn catchup(streaming: bool, keys_n: u32, tag: &str) -> anyhow::Result<(f64, f64, f64)> {
    let dir = base(tag);
    let mut c = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.gc.threshold_bytes = 32 << 10;
    c.raft.snap_chunk_bytes = 8 << 10;
    c.raft.snap_streaming = streaming;
    c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 17 };
    c.read_consistency = ReadConsistency::Stale;
    c.transport = TransportKind::Inproc;
    let cluster = Cluster::start(c)?;
    let key = |i: u32| format!("cu{i:06}").into_bytes();
    let val = vec![0x5a_u8; 1024];
    let quarter = (keys_n / 4).max(8);
    for i in 0..quarter {
        cluster.put(&key(i), &val)?;
    }
    cluster.kill(0, 3)?;
    for i in quarter..keys_n {
        cluster.put(&key(i), &val)?;
        if i == (quarter + keys_n) / 2 {
            cluster.drain_gc_all()?;
        }
    }
    cluster.drain_gc_all()?;
    let before = cluster.wire_stats();
    let t0 = Instant::now();
    cluster.restart(0, 3)?;
    cluster.wait_converged(Duration::from_secs(60))?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let after = cluster.wire_stats();
    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    let wire = mib(after.bytes.saturating_sub(before.bytes));
    let snap = mib(after.snap_bytes.saturating_sub(before.snap_bytes));
    cluster.shutdown()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok((ms, wire, snap))
}

fn main() -> anyhow::Result<()> {
    let records = (1024.0 * bench_scale()) as u64;
    let vs = 16 << 10;
    let shards = bench_shards();
    let per_shard = (records / shards as u64).max(16);
    let mut recovery_rows: Vec<(String, f64)> = Vec::new();
    println!("\n=== Figure 11: recovery time by GC state (ms, {shards} shard(s)) ===");
    println!("{:<22} {:>12}", "state", "recovery_ms");

    // Baseline: Original (no GC states).
    {
        let dir = base("orig");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Original, per_shard, vs, |_, _| Ok(()))?;
        let ms = time_reopen(&dirs, EngineKind::Original)?;
        println!("{:<22} {:>12.1}", "Original", ms);
        recovery_rows.push(("Original".into(), ms));
    }

    // Nezha Pre-GC: loaded, no cycle yet.
    {
        let dir = base("pre");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |_, _| Ok(()))?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Pre-GC)", ms);
        recovery_rows.push(("Nezha (Pre-GC)".into(), ms));
    }

    // Nezha During-GC: frozen epoch + GC flag set, cycle interrupted
    // before completion — recovery must resume from the sorted file.
    {
        let dir = base("during");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, dir| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            GcState {
                running: true,
                min_epoch: frozen,
                frozen_epoch: frozen,
                out_gen: 1,
                min_index: 0,
                last_index,
                last_term,
                stack: vec![],
                run_tombstones: Default::default(),
            }
            .save(&nezha::coordinator::replica::engine_dir(dir))?;
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (During-GC)", ms);
        recovery_rows.push(("Nezha (During-GC)".into(), ms));
    }

    // Nezha During-GC, faulted: the cycle genuinely runs and its
    // commit point — the LEVELS manifest fsync — fails via an injected
    // disk fault, leaving real torn during-GC state on disk (partial
    // output runs, GcState running, pre-fault manifest).  Recovery
    // must adopt the old manifest and resume the cycle.  This is the
    // faulted twin of the synthetic During-GC scenario above.
    {
        let dir = base("faulted");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, dir| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            let edir = nezha::coordinator::replica::engine_dir(dir);
            nezha::fault::disk::arm(
                &[edir.to_string_lossy().into_owned(), "LEVELS".into()],
                nezha::fault::disk::DiskOp::Sync,
                1,
            );
            r.engine().begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, last_term)?;
            // The commit fails; the cycle stays interrupted (During).
            let torn = r.finish_gc().is_err() || r.gc_history.is_empty();
            nezha::fault::disk::clear();
            anyhow::ensure!(torn, "LEVELS fault did not tear the GC commit");
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (During, torn)", ms);
        recovery_rows.push(("Nezha (During, torn)".into(), ms));
    }

    // Nezha Post-GC: a completed cycle, then a crash.
    {
        let dir = base("post");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, _| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            r.engine().begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, last_term)?;
            r.finish_gc()?;
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Post-GC)", ms);
        recovery_rows.push(("Nezha (Post-GC)".into(), ms));
    }

    println!("\npaper: Pre/During/Post-GC recover 34.8%/34.5%/32.6% faster than Original");

    // Follower catch-up: the rejoin path rather than the local-reopen
    // path — run-shipping streamed transfer vs the monolithic blob
    // (DESIGN.md §8), same fall-behind workload for both.
    let keys_n = (600.0 * bench_scale()) as u32;
    println!("\n=== Figure 11b: follower catch-up after falling behind GC ({keys_n} keys) ===");
    println!("{:<22} {:>12} {:>10} {:>10}", "mode", "catchup_ms", "wire_mib", "snap_mib");
    let (run_ms, run_wire, run_snap) = catchup(true, keys_n, "catchup-stream")?;
    let (mono_ms, mono_wire, mono_snap) = catchup(false, keys_n, "catchup-mono")?;
    let cu_print = |mode: &str, ms: f64, wire: f64, snap: f64| {
        println!("{mode:<22} {ms:>12.1} {wire:>10.2} {snap:>10.2}");
    };
    cu_print("Nezha (run-shipping)", run_ms, run_wire, run_snap);
    cu_print("Nezha (monolithic)", mono_ms, mono_wire, mono_snap);

    let rec_body: Vec<String> = recovery_rows
        .iter()
        .map(|(s, ms)| format!("    {{\"state\": \"{s}\", \"recovery_ms\": {ms:.1}}}"))
        .collect();
    let cu_row = |mode: &str, ms: f64, wire: f64, snap: f64| {
        format!(
            "    {{\"mode\": \"{mode}\", \"catchup_ms\": {ms:.1}, \"wire_mib\": {wire:.3}, \
             \"snap_mib\": {snap:.3}}}"
        )
    };
    let json = format!(
        "{{\n  \"figure\": \"fig11_recovery\",\n  \"scale\": {},\n  \"shards\": {shards},\n  \
         \"recovery\": [\n{}\n  ],\n  \"catchup\": [\n{},\n{}\n  ]\n}}\n",
        bench_scale(),
        rec_body.join(",\n"),
        cu_row("run-shipping", run_ms, run_wire, run_snap),
        cu_row("monolithic", mono_ms, mono_wire, mono_snap),
    );
    std::fs::write("BENCH_fig11.json", &json)?;
    println!("wrote BENCH_fig11.json ({} recovery rows + 2 catch-up rows)", recovery_rows.len());
    Ok(())
}
