//! Figure 11 — recovery time after a crash in each GC state (Pre-GC /
//! During-GC / Post-GC) vs Original.  Paper headline: Nezha's phases
//! recover 34.8% / 34.5% / 32.6% faster than Original, because the
//! state machine holds only offsets (small LSM to rebuild) and an
//! interrupted GC resumes from the sorted file's last key.
//!
//! Method: build the state on a single replica per shard, "crash" by
//! dropping it, and time `Replica::open` across all shards (raft log
//! scan + LSM WAL replay + optional GC resume).  With `--shards N`
//! the same dataset is partitioned over N shard replicas, showing how
//! sharding shrinks each group's recovery unit.
//!
//! Run: `cargo bench --bench fig11_recovery [-- --shards N]`.

use nezha::coordinator::Replica;
use nezha::engine::{EngineKind, EngineOpts};
use nezha::gc::{FrozenEpoch, GcConfig, GcState};
use nezha::harness::{bench_scale, bench_shards};
use nezha::raft::{Command, Config as RaftConfig};
use nezha::ycsb::Generator;
use std::path::PathBuf;
use std::time::Instant;

fn base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-fig11-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Per-shard replica directories under one scenario dir.
fn shard_dirs(dir: &std::path::Path, shards: usize) -> Vec<PathBuf> {
    (0..shards).map(|s| dir.join(format!("shard-{s}"))).collect()
}

fn open_replica(dir: &std::path::Path, kind: EngineKind) -> anyhow::Result<Replica> {
    let mut opts = EngineOpts::new("unset", "unset");
    opts.memtable_bytes = 1 << 20;
    Replica::open(
        1,
        vec![],
        dir,
        kind,
        opts,
        RaftConfig::default(),
        GcConfig { threshold_bytes: u64::MAX, ..Default::default() },
        7,
    )
}

fn make_leader(r: &mut Replica) {
    for _ in 0..200 {
        let _ = r.node.tick().unwrap();
        if r.node.is_leader() {
            return;
        }
    }
    panic!("no leader");
}

fn load(r: &mut Replica, records: u64, vs: usize, seed: u64) {
    let mut g = Generator::load_ops(records, vs, seed);
    let mut batch = Vec::new();
    loop {
        batch.clear();
        for _ in 0..64 {
            match g.next() {
                Some((k, v)) => batch.push(Command::Put { key: k, value: v }),
                None => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        let (_, _out) = r.propose_batch(batch.drain(..).collect()).unwrap();
    }
    r.engine().sync().unwrap();
    r.node.log.sync().unwrap();
}

/// Reopen every shard replica of a scenario; total wall time is the
/// recovery cost (recovery includes serving a first read per shard).
fn time_reopen(dirs: &[PathBuf], kind: EngineKind) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    for dir in dirs {
        let mut r = open_replica(dir, kind)?;
        let _ = r.engine().scan(b"", &[0xffu8; 16], 1)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

/// Build one loaded shard replica per dir and hand each to `crash`
/// for the scenario-specific pre-crash state.
fn build_shards(
    dirs: &[PathBuf],
    kind: EngineKind,
    records_per_shard: u64,
    vs: usize,
    crash: impl Fn(&mut Replica, &std::path::Path) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    for (s, dir) in dirs.iter().enumerate() {
        let mut r = open_replica(dir, kind)?;
        make_leader(&mut r);
        load(&mut r, records_per_shard, vs, 42 + s as u64);
        crash(&mut r, dir)?;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let records = (1024.0 * bench_scale()) as u64;
    let vs = 16 << 10;
    let shards = bench_shards();
    let per_shard = (records / shards as u64).max(16);
    println!("\n=== Figure 11: recovery time by GC state (ms, {shards} shard(s)) ===");
    println!("{:<22} {:>12}", "state", "recovery_ms");

    // Baseline: Original (no GC states).
    {
        let dir = base("orig");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Original, per_shard, vs, |_, _| Ok(()))?;
        let ms = time_reopen(&dirs, EngineKind::Original)?;
        println!("{:<22} {:>12.1}", "Original", ms);
    }

    // Nezha Pre-GC: loaded, no cycle yet.
    {
        let dir = base("pre");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |_, _| Ok(()))?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Pre-GC)", ms);
    }

    // Nezha During-GC: frozen epoch + GC flag set, cycle interrupted
    // before completion — recovery must resume from the sorted file.
    {
        let dir = base("during");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, dir| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            GcState {
                running: true,
                min_epoch: frozen,
                frozen_epoch: frozen,
                out_gen: 1,
                min_index: 0,
                last_index,
                last_term,
                stack: vec![],
                run_tombstones: Default::default(),
            }
            .save(&nezha::coordinator::replica::engine_dir(dir))?;
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (During-GC)", ms);
    }

    // Nezha During-GC, faulted: the cycle genuinely runs and its
    // commit point — the LEVELS manifest fsync — fails via an injected
    // disk fault, leaving real torn during-GC state on disk (partial
    // output runs, GcState running, pre-fault manifest).  Recovery
    // must adopt the old manifest and resume the cycle.  This is the
    // faulted twin of the synthetic During-GC scenario above.
    {
        let dir = base("faulted");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, dir| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            let edir = nezha::coordinator::replica::engine_dir(dir);
            nezha::fault::disk::arm(
                &[edir.to_string_lossy().into_owned(), "LEVELS".into()],
                nezha::fault::disk::DiskOp::Sync,
                1,
            );
            r.engine().begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, last_term)?;
            // The commit fails; the cycle stays interrupted (During).
            let torn = r.finish_gc().is_err() || r.gc_history.is_empty();
            nezha::fault::disk::clear();
            anyhow::ensure!(torn, "LEVELS fault did not tear the GC commit");
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (During, torn)", ms);
    }

    // Nezha Post-GC: a completed cycle, then a crash.
    {
        let dir = base("post");
        let dirs = shard_dirs(&dir, shards);
        build_shards(&dirs, EngineKind::Nezha, per_shard, vs, |r, _| {
            let last_index = r.node.last_applied();
            let last_term = r.node.log.term_at(last_index).unwrap_or(1);
            let frozen = r.node.log.rotate()?;
            r.engine().begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, last_term)?;
            r.finish_gc()?;
            Ok(())
        })?;
        let ms = time_reopen(&dirs, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Post-GC)", ms);
    }

    println!("\npaper: Pre/During/Post-GC recover 34.8%/34.5%/32.6% faster than Original");
    Ok(())
}
