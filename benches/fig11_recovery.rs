//! Figure 11 — recovery time after a crash in each GC state (Pre-GC /
//! During-GC / Post-GC) vs Original.  Paper headline: Nezha's phases
//! recover 34.8% / 34.5% / 32.6% faster than Original, because the
//! state machine holds only offsets (small LSM to rebuild) and an
//! interrupted GC resumes from the sorted file's last key.
//!
//! Method: build the state on a single replica, "crash" by dropping
//! it, and time `Replica::open` (raft log scan + LSM WAL replay +
//! optional GC resume).
//!
//! Run: `cargo bench --bench fig11_recovery`.

use nezha::coordinator::Replica;
use nezha::engine::{EngineKind, EngineOpts};
use nezha::gc::{GcConfig, GcState};
use nezha::harness::bench_scale;
use nezha::raft::{Command, Config as RaftConfig};
use nezha::ycsb::Generator;
use std::path::PathBuf;
use std::time::Instant;

fn base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-fig11-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn open_replica(dir: &std::path::Path, kind: EngineKind) -> anyhow::Result<Replica> {
    let mut opts = EngineOpts::new("unset", "unset");
    opts.memtable_bytes = 1 << 20;
    Replica::open(
        1,
        vec![],
        dir,
        kind,
        opts,
        RaftConfig::default(),
        GcConfig { threshold_bytes: u64::MAX, ..Default::default() },
        7,
    )
}

fn make_leader(r: &mut Replica) {
    for _ in 0..200 {
        let _ = r.node.tick().unwrap();
        if r.node.is_leader() {
            return;
        }
    }
    panic!("no leader");
}

fn load(r: &mut Replica, records: u64, vs: usize) {
    let mut g = Generator::load_ops(records, vs, 42);
    let mut batch = Vec::new();
    loop {
        batch.clear();
        for _ in 0..64 {
            match g.next() {
                Some((k, v)) => batch.push(Command::Put { key: k, value: v }),
                None => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        let (_, _out) = r.propose_batch(batch.drain(..).collect()).unwrap();
    }
    r.engine().sync().unwrap();
    r.node.log.sync().unwrap();
}

fn time_reopen(dir: &std::path::Path, kind: EngineKind) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let mut r = open_replica(dir, kind)?;
    // Recovery includes being able to serve a read.
    let _ = r.engine().scan(b"", &[0xffu8; 16], 1)?;
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

fn main() -> anyhow::Result<()> {
    let records = (1024.0 * bench_scale()) as u64;
    let vs = 16 << 10;
    println!("\n=== Figure 11: recovery time by GC state (ms) ===");
    println!("{:<22} {:>12}", "state", "recovery_ms");

    // Baseline: Original (no GC states).
    {
        let dir = base("orig");
        let mut r = open_replica(&dir, EngineKind::Original)?;
        make_leader(&mut r);
        load(&mut r, records, vs);
        drop(r);
        let ms = time_reopen(&dir, EngineKind::Original)?;
        println!("{:<22} {:>12.1}", "Original", ms);
    }

    // Nezha Pre-GC: loaded, no cycle yet.
    {
        let dir = base("pre");
        let mut r = open_replica(&dir, EngineKind::Nezha)?;
        make_leader(&mut r);
        load(&mut r, records, vs);
        drop(r);
        let ms = time_reopen(&dir, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Pre-GC)", ms);
    }

    // Nezha During-GC: frozen epoch + GC flag set, cycle interrupted
    // before completion — recovery must resume from the sorted file.
    {
        let dir = base("during");
        let mut r = open_replica(&dir, EngineKind::Nezha)?;
        make_leader(&mut r);
        load(&mut r, records, vs);
        let last_index = r.node.last_applied();
        let last_term = r.node.log.term_at(last_index).unwrap_or(1);
        let frozen = r.node.log.rotate()?;
        GcState {
            running: true,
            min_epoch: frozen,
            frozen_epoch: frozen,
            out_gen: 1,
            min_index: 0,
            last_index,
            last_term,
            stack: vec![],
        }
        .save(&nezha::coordinator::replica::engine_dir(&dir))?;
        drop(r);
        let ms = time_reopen(&dir, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (During-GC)", ms);
    }

    // Nezha Post-GC: a completed cycle, then a crash.
    {
        let dir = base("post");
        let mut r = open_replica(&dir, EngineKind::Nezha)?;
        make_leader(&mut r);
        load(&mut r, records, vs);
        let last_index = r.node.last_applied();
        let last_term = r.node.log.term_at(last_index).unwrap_or(1);
        let frozen = r.node.log.rotate()?;
        r.engine().begin_gc(&[frozen], 0, last_index, last_term)?;
        r.finish_gc()?;
        drop(r);
        let ms = time_reopen(&dir, EngineKind::Nezha)?;
        println!("{:<22} {:>12.1}", "Nezha (Post-GC)", ms);
    }

    println!("\npaper: Pre/During/Post-GC recover 34.8%/34.5%/32.6% faster than Original");
    Ok(())
}
