//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **Index-build backend** — the GC hash-index construction through
//!    the AOT XLA/Pallas artifact vs the pure-Rust mirror (identical
//!    output, different compute path).
//! 2. **Group-commit batch size** — the coordinator's write batcher
//!    (Algorithm 1 amortization across consensus rounds).
//! 3. **Hash index vs sparse-only** — Nezha's point-query accelerator
//!    against binary-search-the-sparse-index (what a plain sorted file
//!    would give you).
//!
//! Run: `cargo bench --bench ablation`.

use nezha::engine::EngineKind;
use nezha::gc::{IndexBackend, RustBackend};
use nezha::harness::{bench_scale, Env, Spec};
use nezha::runtime::IndexPlanner;
use nezha::vlog::{Entry, HashIndex, SortedVLog, SortedVLogWriter};
use std::time::Instant;

fn ablation_index_backend() -> anyhow::Result<()> {
    println!("\n=== Ablation 1: GC index-build backend (XLA/Pallas vs Rust) ===");
    let n = (200_000.0 * bench_scale()) as usize;
    let keys: Vec<Vec<u8>> = (0..n).map(|i| format!("user{i:012}").into_bytes()).collect();
    let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
    let cap = HashIndex::capacity_for(n) as u32;

    let rust = RustBackend;
    let t0 = Instant::now();
    let (h_rust, b_rust) = rust.plan(&refs, cap)?;
    let rust_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mkeys = n as f64 / rust_ms / 1e3;
    println!("rust backend : {n} keys in {rust_ms:.1} ms ({mkeys:.1} Mkeys/s)");

    match IndexPlanner::load_default() {
        Ok(planner) => {
            // Warm-up (PJRT first-execute includes lazy init).
            let _ = planner.plan(&refs[..refs.len().min(4096)], cap)?;
            let t0 = Instant::now();
            let (h_xla, b_xla) = planner.plan(&refs, cap)?;
            let xla_ms = t0.elapsed().as_secs_f64() * 1e3;
            let mkeys = n as f64 / xla_ms / 1e3;
            println!("xla backend  : {n} keys in {xla_ms:.1} ms ({mkeys:.1} Mkeys/s)");
            assert_eq!(h_rust, h_xla, "hash parity");
            assert_eq!(b_rust, b_xla, "bucket parity");
            println!("parity       : OK (bit-identical h1/bucket streams)");
            println!(
                "note         : CPU PJRT runs the Pallas kernel in interpret mode; see \
                 DESIGN.md §1 for the real-TPU estimate"
            );
        }
        Err(e) => println!("xla backend  : skipped ({e:#})"),
    }
    Ok(())
}

fn ablation_batch_size() -> anyhow::Result<()> {
    println!("\n=== Ablation 2: group-commit batch size (Nezha, 16KB values) ===");
    println!("{:>9} {:>12} {:>10}", "batch", "MiB/s", "us/op");
    for batch in [1usize, 8, 64, 256] {
        let mut spec = Spec::new(EngineKind::Nezha, 16 << 10);
        spec.load_bytes = ((4 << 20) as f64 * bench_scale()) as u64;
        let records = spec.records();
        let env = Env::start(spec)?;
        let mut g = nezha::ycsb::Generator::load_ops(records, 16 << 10, 1);
        let t0 = Instant::now();
        let mut sent = 0u64;
        loop {
            let ops: Vec<_> = g.by_ref().take(batch).collect();
            if ops.is_empty() {
                break;
            }
            sent += ops.len() as u64;
            env.cluster.put_batch(ops)?;
        }
        let s = t0.elapsed().as_secs_f64();
        println!(
            "{:>9} {:>12.1} {:>10.0}",
            batch,
            (sent * (16 << 10)) as f64 / (1 << 20) as f64 / s,
            s * 1e6 / sent as f64
        );
        env.destroy()?;
    }
    Ok(())
}

fn ablation_hash_index() -> anyhow::Result<()> {
    println!("\n=== Ablation 3: hash-indexed vs sparse-only point lookups ===");
    let n = (20_000.0 * bench_scale()) as u64;
    let dir = std::env::temp_dir().join(format!("nezha-abl3-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("sorted.vlog");
    let mut w = SortedVLogWriter::create(&path, 1, n)?;
    for i in 0..n {
        w.add(&Entry::put(1, i + 1, format!("user{i:012}"), vec![7u8; 256]))?;
    }
    let (_, kos) = w.finish()?;
    let log = SortedVLog::open(&path)?;
    let idx = HashIndex::build(&kos);

    let queries: Vec<Vec<u8>> = (0..5_000u64)
        .map(|q| format!("user{:012}", (q * 37) % n).into_bytes())
        .collect();

    let t0 = Instant::now();
    for q in &queries {
        assert!(idx.lookup(q, &log)?.is_some());
    }
    let hash_us = t0.elapsed().as_secs_f64() * 1e6 / queries.len() as f64;

    // Sparse-only: locate via sparse index then scan forward.
    let t0 = Instant::now();
    for q in &queries {
        let start = idx.scan_start(q);
        let hits = log.scan_from(start, q, &[0xffu8; 16], 1)?;
        assert!(!hits.is_empty());
    }
    let sparse_us = t0.elapsed().as_secs_f64() * 1e6 / queries.len() as f64;

    println!("hash index   : {hash_us:.1} us/lookup");
    println!("sparse-only  : {sparse_us:.1} us/lookup");
    println!("speedup      : {:.1}x", sparse_us / hash_us.max(1e-9));
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    ablation_index_backend()?;
    ablation_batch_size()?;
    ablation_hash_index()?;
    Ok(())
}
