//! Figure 8 — YCSB workloads A–F (Table II): overall throughput,
//! write latency, read latency for all seven systems.  16 KB values,
//! preloaded dataset, 1M requests in the paper (scaled here).
//! Paper headline: Nezha +86.5% average throughput over Original.
//!
//! Run: `cargo bench --bench fig8_ycsb`.  `--read-from followers`
//! serves the read mix from all replicas (ReadIndex/lease barriers);
//! writes always go through the shard leader.

use nezha::engine::EngineKind;
use nezha::harness::{
    bench_read_from, bench_scale, engines_from_env, improvement_pct, print_header,
    read_from_label, Env, Spec,
};
use nezha::ycsb::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let load = ((4 << 20) as f64 * bench_scale()) as u64;
    let ops = (250.0 * bench_scale()) as u64;
    let read_from = bench_read_from();
    print_header(&format!("Figure 8(a): YCSB throughput (reads: {})", read_from_label(read_from)));
    let mut rows_lat: Vec<String> = Vec::new();
    let mut nezha_tp = Vec::new();
    let mut orig_tp = Vec::new();
    for wl in WorkloadKind::ALL {
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, 16 << 10);
            spec.load_bytes = load;
            spec.read_from = read_from;
            let env = Env::start(spec)?;
            env.load("preload")?;
            env.settle()?;
            // Workload E uses scan length ≤ 100 like the paper's
            // default YCSB E config.
            let (m, wlat, rlat) = env.run_ycsb(wl, ops, 100)?;
            println!("{}", m.row());
            rows_lat.push(format!(
                "{:<11} {:>3}  write[{}]  read[{}]",
                kind.name(),
                wl.name(),
                wlat.summary(),
                rlat.summary()
            ));
            if kind == EngineKind::Nezha {
                nezha_tp.push(m.ops_per_sec());
            }
            if kind == EngineKind::Original {
                orig_tp.push(m.ops_per_sec());
            }
            env.destroy()?;
        }
    }
    println!("\n=== Figure 8(b,c): per-op latencies ===");
    for r in rows_lat {
        println!("{r}");
    }
    if !nezha_tp.is_empty() && nezha_tp.len() == orig_tp.len() {
        let avg: f64 = nezha_tp
            .iter()
            .zip(&orig_tp)
            .map(|(n, o)| improvement_pct(*n, *o))
            .sum::<f64>()
            / nezha_tp.len() as f64;
        println!("\nNezha vs Original average YCSB improvement: {avg:+.1}%  (paper: +86.5%)");
    }
    Ok(())
}
