//! Figure 6 — **range-query** throughput + latency vs value size
//! (paper scans 4 GB out of the 100 GB dataset → we scan ~4% of the
//! scaled load per query batch).  Scans resolve their surviving value
//! references in one batched, readahead-cached ValueLog pass per
//! query.  Paper headline: Nezha +72.6% over Original; Nezha-NoGC
//! −39.5% (random I/O over the unsorted vLog).
//!
//! Run: `cargo bench --bench fig6_scan`.  `--read-from followers`
//! rotates each shard's scans over all replicas (ReadIndex/lease
//! barriers) instead of pinning them on the leader.

use nezha::coordinator::ReadConsistency;
use nezha::engine::EngineKind;
use nezha::harness::{
    bench_read_from, bench_scale, bench_shards, engines_from_env, improvement_pct, print_header,
    print_readahead_line, read_from_label, value_sizes, Env, Spec,
};

fn main() -> anyhow::Result<()> {
    let load = ((6 << 20) as f64 * bench_scale()) as u64;
    let scans = (40.0 * bench_scale()).max(8.0) as u64;
    let shards = bench_shards();
    let read_from = bench_read_from();
    print_header(&format!(
        "Figure 6: scan throughput/latency vs value size ({shards} shard(s), reads: {})",
        read_from_label(read_from)
    ));
    let mut nezha_tp = Vec::new();
    let mut orig_tp = Vec::new();
    for vs in value_sizes() {
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, vs);
            spec.load_bytes = load;
            spec.shards = shards;
            spec.read_from = read_from;
            let records = spec.records();
            // ~4% of the dataset per scan.
            let scan_len = ((records / 25).max(4) as usize).min(2_000);
            let env = Env::start(spec)?;
            env.load("preload")?;
            env.settle()?;
            let m = env.run_scans(scans, scan_len, &format!("{}KB", vs >> 10))?;
            println!("{}", m.row());
            print_readahead_line(&env.cluster_stats()?);
            if read_from != ReadConsistency::Leader {
                env.print_read_distribution()?;
            }
            if kind == EngineKind::Nezha {
                nezha_tp.push(m.mib_per_sec());
            }
            if kind == EngineKind::Original {
                orig_tp.push(m.mib_per_sec());
            }
            env.destroy()?;
        }
    }
    if !nezha_tp.is_empty() && nezha_tp.len() == orig_tp.len() {
        let avg: f64 = nezha_tp
            .iter()
            .zip(&orig_tp)
            .map(|(n, o)| improvement_pct(*n, *o))
            .sum::<f64>()
            / nezha_tp.len() as f64;
        println!("\nNezha vs Original average scan improvement: {avg:+.1}%  (paper: +72.6%)");
    }
    Ok(())
}
