//! Figure 10 — impact of GC on a long write run.  Writes the full
//! dataset continuously with a 10% GC threshold (≈9-10 cycles — small
//! enough to show the leveled-GC shape, unlike the paper's two 40%/80%
//! trigger points) and samples cumulative throughput + per-batch
//! latency along the way for Original, Nezha and Nezha-NoGC.
//!
//! Expected shape: Nezha ≈ Nezha-NoGC curves overlap (GC is off the
//! critical path); Original sits well below both.  The per-cycle GC
//! report shows `bytes_written` bounded by level budgets — most cycles
//! flush-only — instead of growing with the total dataset as the old
//! single-generation rewrite did.
//!
//! Run: `cargo bench --bench fig10_gc_impact`.

use nezha::engine::EngineKind;
use nezha::harness::{bench_scale, bench_shards, print_gc_cycles, Env, Spec};
use nezha::ycsb::Generator;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let load = ((12 << 20) as f64 * bench_scale()) as u64;
    let vs = 16 << 10;
    let shards = bench_shards();
    println!(
        "\n=== Figure 10: GC impact timeline (16KB values, GC every 10% of load, \
         {shards} shard(s)) ==="
    );
    let cols = ("system", "pct", "cum_MiB/s", "inst_MiB/s", "batch_us");
    println!("{:<11} {:>8} {:>12} {:>12} {:>10}", cols.0, cols.1, cols.2, cols.3, cols.4);
    for kind in [EngineKind::Original, EngineKind::NezhaNoGc, EngineKind::Nezha] {
        let mut spec = Spec::new(kind, vs);
        spec.load_bytes = load;
        spec.shards = shards;
        spec.gc_fraction = 0.1;
        let records = spec.records();
        let env = Env::start(spec)?;
        let batch = 64usize;
        let mut g = Generator::load_ops(records, vs, 42);
        let t0 = Instant::now();
        let mut written = 0u64;
        let mut next_sample = records / 20; // 5% steps
        let mut last_t = t0;
        let mut last_written = 0u64;
        loop {
            let ops: Vec<_> = g.by_ref().take(batch).collect();
            if ops.is_empty() {
                break;
            }
            let n = ops.len() as u64;
            let bt = Instant::now();
            env.cluster.put_batch(ops)?;
            let bus = bt.elapsed().as_micros() as u64;
            written += n;
            if written >= next_sample {
                let cum =
                    (written * vs as u64) as f64 / (1 << 20) as f64 / t0.elapsed().as_secs_f64();
                let inst = ((written - last_written) * vs as u64) as f64 / (1 << 20) as f64
                    / last_t.elapsed().as_secs_f64().max(1e-9);
                println!(
                    "{:<11} {:>7}% {:>12.1} {:>12.1} {:>10}",
                    kind.name(),
                    written * 100 / records,
                    cum,
                    inst,
                    bus / n.max(1)
                );
                next_sample += records / 20;
                last_t = Instant::now();
                last_written = written;
            }
        }
        let leader = env.cluster.wait_for_leader(std::time::Duration::from_secs(5))?;
        let st = env.cluster.status(leader)?;
        println!(
            "{:<11} done: {} GC cycles, phase {:?}, {} levels / {} runs",
            kind.name(),
            st.gc_cycles,
            st.gc_phase,
            st.engine.gc_levels,
            st.engine.gc_level_runs,
        );
        print_gc_cycles(&env.cluster.gc_history(leader)?);
        env.destroy()?;
    }
    Ok(())
}
