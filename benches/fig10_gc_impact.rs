//! Figure 10 — impact of GC on a long write run.  Writes the full
//! dataset continuously with a 10% GC threshold (≈9-10 cycles — small
//! enough to show the leveled-GC shape, unlike the paper's two 40%/80%
//! trigger points) and samples cumulative throughput + per-batch
//! latency along the way for Original, Nezha and Nezha-NoGC.
//!
//! Expected shape: Nezha ≈ Nezha-NoGC curves overlap (GC is off the
//! critical path); Original sits well below both.  The per-cycle GC
//! report shows `bytes_written` bounded by level budgets — most cycles
//! flush-only — instead of growing with the total dataset as the old
//! single-generation rewrite did.  With decoupled merge scheduling the
//! history interleaves flush cycles and background merge jobs; the
//! merges-overlapped line under each system reports how much merge
//! work ran concurrently with puts, the put-path stall microseconds,
//! and the GC worker-pool utilization.
//!
//! Run: `cargo bench --bench fig10_gc_impact`.  `--gc-workers N` (or
//! `NEZHA_BENCH_GC_WORKERS`) sets the merge partitions in flight per
//! level merge (1 = serial; the merged bytes are identical either
//! way).  Every run also writes the table to `BENCH_fig10.json`.

use nezha::engine::EngineKind;
use nezha::gc::pool;
use nezha::harness::{bench_gc_workers, bench_scale, bench_shards, print_gc_cycles, Env, Spec};
use nezha::ycsb::Generator;
use std::time::Instant;

/// One per-cycle `BENCH_fig10.json` row (hand-rolled JSON like fig4;
/// all fields numeric or plain ASCII, so no escaping is needed).
struct CycleRow {
    system: String,
    cycle: usize,
    kind: &'static str,
    flush_bytes: u64,
    merge_bytes: u64,
    merges: u64,
    parts: u64,
    wall_ms: u64,
}

impl CycleRow {
    fn render(&self) -> String {
        format!(
            "    {{\"system\": \"{}\", \"cycle\": {}, \"kind\": \"{}\", \"flush_bytes\": {}, \
             \"merge_bytes\": {}, \"merges\": {}, \"parts\": {}, \"wall_ms\": {}}}",
            self.system,
            self.cycle,
            self.kind,
            self.flush_bytes,
            self.merge_bytes,
            self.merges,
            self.parts,
            self.wall_ms,
        )
    }
}

/// One per-system summary row of `BENCH_fig10.json`.
struct SystemRow {
    system: String,
    mib_per_sec: f64,
    gc_cycles: u64,
    merge_jobs: u64,
    merge_queue: u64,
    stall_us: u64,
    pool_busy_us: u64,
    pool_util_pct: f64,
}

impl SystemRow {
    fn render(&self) -> String {
        format!(
            "    {{\"system\": \"{}\", \"mib_per_sec\": {:.2}, \"gc_cycles\": {}, \
             \"merge_jobs\": {}, \"merge_queue\": {}, \"stall_us\": {}, \"pool_busy_us\": {}, \
             \"pool_util_pct\": {:.2}}}",
            self.system,
            self.mib_per_sec,
            self.gc_cycles,
            self.merge_jobs,
            self.merge_queue,
            self.stall_us,
            self.pool_busy_us,
            self.pool_util_pct,
        )
    }
}

fn main() -> anyhow::Result<()> {
    let load = ((12 << 20) as f64 * bench_scale()) as u64;
    let vs = 16 << 10;
    let shards = bench_shards();
    let gc_workers = bench_gc_workers();
    println!(
        "\n=== Figure 10: GC impact timeline (16KB values, GC every 10% of load, \
         {shards} shard(s), {gc_workers} gc worker(s)) ==="
    );
    let cols = ("system", "pct", "cum_MiB/s", "inst_MiB/s", "batch_us");
    println!("{:<11} {:>8} {:>12} {:>12} {:>10}", cols.0, cols.1, cols.2, cols.3, cols.4);
    let mut cycle_rows: Vec<CycleRow> = Vec::new();
    let mut system_rows: Vec<SystemRow> = Vec::new();
    for kind in [EngineKind::Original, EngineKind::NezhaNoGc, EngineKind::Nezha] {
        let mut spec = Spec::new(kind, vs);
        spec.load_bytes = load;
        spec.shards = shards;
        spec.gc_fraction = 0.1;
        spec.gc_workers = gc_workers;
        let records = spec.records();
        let env = Env::start(spec)?;
        let batch = 64usize;
        let mut g = Generator::load_ops(records, vs, 42);
        let pool0 = pool::shared().stats();
        let t0 = Instant::now();
        let mut written = 0u64;
        let mut next_sample = records / 20; // 5% steps
        let mut last_t = t0;
        let mut last_written = 0u64;
        loop {
            let ops: Vec<_> = g.by_ref().take(batch).collect();
            if ops.is_empty() {
                break;
            }
            let n = ops.len() as u64;
            let bt = Instant::now();
            env.cluster.put_batch(ops)?;
            let bus = bt.elapsed().as_micros() as u64;
            written += n;
            if written >= next_sample {
                let cum =
                    (written * vs as u64) as f64 / (1 << 20) as f64 / t0.elapsed().as_secs_f64();
                let inst = ((written - last_written) * vs as u64) as f64 / (1 << 20) as f64
                    / last_t.elapsed().as_secs_f64().max(1e-9);
                println!(
                    "{:<11} {:>7}% {:>12.1} {:>12.1} {:>10}",
                    kind.name(),
                    written * 100 / records,
                    cum,
                    inst,
                    bus / n.max(1)
                );
                next_sample += records / 20;
                last_t = Instant::now();
                last_written = written;
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let pool1 = pool::shared().stats();
        let leader = env.cluster.wait_for_leader(std::time::Duration::from_secs(5))?;
        let st = env.cluster.status(leader)?;
        println!(
            "{:<11} done: {} GC cycles, phase {:?}, {} levels / {} runs",
            kind.name(),
            st.gc_cycles,
            st.gc_phase,
            st.engine.gc_levels,
            st.engine.gc_level_runs,
        );
        let hist = env.cluster.gc_history(leader)?;
        print_gc_cycles(&hist);
        // The decoupling headline: merge bytes that moved while puts
        // kept flowing, the put-path stall those puts still paid, and
        // how busy the shared worker pool was for the run.
        let merge_jobs = hist.iter().filter(|c| c.is_merge_job).count();
        let merge_mib: f64 =
            hist.iter().map(|c| c.merge_bytes).sum::<u64>() as f64 / (1 << 20) as f64;
        let merge_wall_ms: u64 = hist.iter().filter(|c| c.is_merge_job).map(|c| c.wall_ms).sum();
        let max_parts = hist.iter().map(|c| c.parts).max().unwrap_or(0);
        let pool_busy = pool1.busy_us.saturating_sub(pool0.busy_us);
        let pool_util = pool_busy as f64 / (pool1.workers.max(1) as f64 * wall_s * 1e6) * 100.0;
        println!(
            "            merges overlapped with puts: {merge_jobs} jobs, {merge_mib:.2} MiB \
             merged in {merge_wall_ms} ms wall (max {max_parts} parts); put stall {} us; \
             pool {:.1}% busy ({} us over {} workers)",
            st.engine.gc_stall_us, pool_util, pool_busy, pool1.workers
        );
        let cum_mib = (written * vs as u64) as f64 / (1 << 20) as f64 / wall_s.max(1e-9);
        system_rows.push(SystemRow {
            system: kind.name().into(),
            mib_per_sec: cum_mib,
            gc_cycles: st.gc_cycles,
            merge_jobs: st.engine.gc_merge_jobs,
            merge_queue: st.engine.gc_merge_queue,
            stall_us: st.engine.gc_stall_us,
            pool_busy_us: pool_busy,
            pool_util_pct: pool_util,
        });
        for (i, c) in hist.iter().enumerate() {
            cycle_rows.push(CycleRow {
                system: kind.name().into(),
                cycle: i + 1,
                kind: if c.is_merge_job { "merge" } else { "flush" },
                flush_bytes: c.flush_bytes,
                merge_bytes: c.merge_bytes,
                merges: c.merges,
                parts: c.parts,
                wall_ms: c.wall_ms,
            });
        }
        env.destroy()?;
    }
    let systems: Vec<String> = system_rows.iter().map(SystemRow::render).collect();
    let cycles: Vec<String> = cycle_rows.iter().map(CycleRow::render).collect();
    let pool_now = pool::shared().stats();
    let json = format!(
        "{{\n  \"figure\": \"fig10_gc_impact\",\n  \"gc_workers\": {gc_workers},\n  \
         \"shards\": {shards},\n  \"scale\": {},\n  \"pool_workers\": {},\n  \
         \"systems\": [\n{}\n  ],\n  \"rows\": [\n{}\n  ]\n}}\n",
        bench_scale(),
        pool_now.workers,
        systems.join(",\n"),
        cycles.join(",\n")
    );
    std::fs::write("BENCH_fig10.json", &json)?;
    println!("wrote BENCH_fig10.json ({} cycle rows)", cycle_rows.len());
    Ok(())
}
