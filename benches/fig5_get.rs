//! Figure 5 — **point-query** throughput + latency vs value size.
//! Loads the dataset, lets GC settle (paper: 100 GB load with two GC
//! cycles), then issues Zipf point queries through the batched
//! `Cluster::get_batch` path (one leader round-trip per `GET_BATCH`
//! keys, epoch-grouped ValueLog resolution behind it).  Paper
//! headline: Nezha +12.5% over Original; Nezha-NoGC −21.3%
//! (offset-lookup overhead).
//!
//! Run: `cargo bench --bench fig5_get`.  `--read-from followers`
//! routes the same query stream across *every* replica behind
//! ReadIndex/lease barriers (vs the default leader-only serving), so
//! the leader-vs-follower read scaling plots share one harness.
//! `--transport tcp` runs the same cluster over real loopback sockets
//! and the wire line reports msgs/bytes/dropped for the in-process vs
//! TCP delta (DESIGN.md §2/§4).

use nezha::coordinator::ReadConsistency;
use nezha::engine::EngineKind;
use nezha::harness::{
    bench_read_from, bench_scale, bench_shards, bench_transport, engines_from_env,
    improvement_pct, print_header, print_readahead_line, read_from_label, value_sizes, Env, Spec,
};

fn main() -> anyhow::Result<()> {
    let load = ((6 << 20) as f64 * bench_scale()) as u64;
    let gets = (400.0 * bench_scale()) as u64;
    let shards = bench_shards();
    let read_from = bench_read_from();
    let transport = bench_transport();
    print_header(&format!(
        "Figure 5: get throughput/latency vs value size ({shards} shard(s), reads: {}, \
         transport: {})",
        read_from_label(read_from),
        transport.name()
    ));
    let mut nezha_tp = Vec::new();
    let mut orig_tp = Vec::new();
    for vs in value_sizes() {
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, vs);
            spec.load_bytes = load;
            spec.shards = shards;
            spec.read_from = read_from;
            spec.transport = transport;
            let env = Env::start(spec)?;
            env.load("preload")?;
            env.settle()?;
            let m = env.run_gets(gets, &format!("{}KB", vs >> 10))?;
            println!("{}", m.row());
            // Reads land on whichever replica served them: report the
            // cluster-wide rollup, not just the leader's row.
            print_readahead_line(&env.cluster_stats()?);
            env.print_wire_line();
            if read_from != ReadConsistency::Leader {
                env.print_read_distribution()?;
            }
            if kind == EngineKind::Nezha {
                nezha_tp.push(m.ops_per_sec());
            }
            if kind == EngineKind::Original {
                orig_tp.push(m.ops_per_sec());
            }
            env.destroy()?;
        }
    }
    if !nezha_tp.is_empty() && nezha_tp.len() == orig_tp.len() {
        let avg: f64 = nezha_tp
            .iter()
            .zip(&orig_tp)
            .map(|(n, o)| improvement_pct(*n, *o))
            .sum::<f64>()
            / nezha_tp.len() as f64;
        println!("\nNezha vs Original average get improvement: {avg:+.1}%  (paper: +12.5%)");
    }
    Ok(())
}
