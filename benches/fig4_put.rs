//! Figure 4 — **put** throughput + latency vs value size (1 KB →
//! 256 KB), all seven systems, 3-node cluster, Zipf keys, GC at 40% of
//! the load.  Paper headline: Nezha ≈ Nezha-NoGC ≫ Dwisckey > PASV >
//! LSM-Raft > TiKV ≈ Original, average +460.2% over Original.
//!
//! Scaled workload: `load_bytes = 12 MiB * NEZHA_BENCH_SCALE` per
//! (system, size) cell.  Run: `cargo bench --bench fig4_put`.
//! `--transport tcp` replays the same load over real loopback sockets
//! for the in-process vs TCP delta (DESIGN.md §2/§4); the wire line
//! reports msgs/bytes/dropped either way.

use nezha::engine::EngineKind;
use nezha::harness::{
    bench_scale, bench_transport, engines_from_env, improvement_pct, print_header, value_sizes,
    Env, Spec,
};

fn main() -> anyhow::Result<()> {
    let load = ((6 << 20) as f64 * bench_scale()) as u64;
    let transport = bench_transport();
    print_header(&format!(
        "Figure 4: put throughput/latency vs value size (transport: {})",
        transport.name()
    ));
    let mut nezha_tp = Vec::new();
    let mut orig_tp = Vec::new();
    for vs in value_sizes() {
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, vs);
            spec.load_bytes = load;
            spec.transport = transport;
            let env = Env::start(spec)?;
            let m = env.load(&format!("{}KB", vs >> 10))?;
            println!("{}", m.row());
            env.print_wire_line();
            if kind == EngineKind::Nezha {
                nezha_tp.push(m.mib_per_sec());
            }
            if kind == EngineKind::Original {
                orig_tp.push(m.mib_per_sec());
            }
            env.destroy()?;
        }
    }
    if !nezha_tp.is_empty() && nezha_tp.len() == orig_tp.len() {
        let avg: f64 = nezha_tp
            .iter()
            .zip(&orig_tp)
            .map(|(n, o)| improvement_pct(*n, *o))
            .sum::<f64>()
            / nezha_tp.len() as f64;
        println!("\nNezha vs Original average put improvement: {avg:+.1}%  (paper: +460.2%)");
    }
    Ok(())
}
