//! Figure 4 — **put** throughput + latency vs value size (1 KB →
//! 256 KB), all seven systems, 3-node cluster, Zipf keys, GC at 40% of
//! the load.  Paper headline: Nezha ≈ Nezha-NoGC ≫ Dwisckey > PASV >
//! LSM-Raft > TiKV ≈ Original, average +460.2% over Original.
//!
//! Scaled workload: `load_bytes = 12 MiB * NEZHA_BENCH_SCALE` per
//! (system, size) cell.  Run: `cargo bench --bench fig4_put`.
//! `--transport tcp` replays the same load over real loopback sockets
//! for the in-process vs TCP delta (DESIGN.md §2/§4); the wire line
//! reports msgs/bytes/dropped either way.  `--clients N` drives the
//! load from N concurrent client threads so group commit has
//! overlapping proposals to batch — each row reports the resulting
//! fsyncs-per-committed-entry ratio (DESIGN.md §6) — and `--shards M`
//! hash-partitions the keyspace over M consensus groups.  Every run
//! also writes the table to `BENCH_fig4.json`.

use nezha::harness::{
    bench_clients, bench_scale, bench_shards, bench_transport, engines_from_env, improvement_pct,
    print_header, value_sizes, Env, Spec,
};

/// One `BENCH_fig4.json` row (hand-rolled JSON; all fields numeric or
/// plain ASCII, so no escaping is needed).
struct JsonRow {
    system: String,
    value_size: usize,
    ops_per_sec: f64,
    mib_per_sec: f64,
    mean_us: f64,
    p50_us: u64,
    p99_us: u64,
    log_syncs: u64,
    entries_committed: u64,
    syncs_per_entry: f64,
}

impl JsonRow {
    fn render(&self) -> String {
        format!(
            "    {{\"system\": \"{}\", \"value_size\": {}, \"ops_per_sec\": {:.1}, \
             \"mib_per_sec\": {:.2}, \"mean_us\": {:.0}, \"p50_us\": {}, \"p99_us\": {}, \
             \"log_syncs\": {}, \"entries_committed\": {}, \"syncs_per_entry\": {:.4}}}",
            self.system,
            self.value_size,
            self.ops_per_sec,
            self.mib_per_sec,
            self.mean_us,
            self.p50_us,
            self.p99_us,
            self.log_syncs,
            self.entries_committed,
            self.syncs_per_entry,
        )
    }
}

fn main() -> anyhow::Result<()> {
    let load = ((6 << 20) as f64 * bench_scale()) as u64;
    let transport = bench_transport();
    let clients = bench_clients();
    let shards = bench_shards();
    print_header(&format!(
        "Figure 4: put throughput/latency vs value size (transport: {}, {clients} client(s), \
         {shards} shard(s))",
        transport.name()
    ));
    let mut nezha_tp = Vec::new();
    let mut orig_tp = Vec::new();
    let mut rows: Vec<JsonRow> = Vec::new();
    for vs in value_sizes() {
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, vs);
            spec.load_bytes = load;
            spec.transport = transport;
            spec.clients = clients;
            spec.shards = shards;
            let env = Env::start(spec)?;
            let m = env.load(&format!("{}KB", vs >> 10))?;
            println!("{}", m.row());
            env.print_wire_line();
            // The group-commit line: with overlapping clients one
            // raft-log persist covers a batch of proposals, so the
            // ratio drops below 1 (the gate for --clients >= 8 on one
            // shard is < 0.5).
            let st = env.leader_stats()?;
            let ratio = st.log_syncs as f64 / st.entries_committed.max(1) as f64;
            println!(
                "            group commit: {} syncs / {} entries = {:.3} fsyncs per committed \
                 entry ({} batches, max {})",
                st.log_syncs,
                st.entries_committed,
                ratio,
                st.group_commit_batches,
                st.group_commit_max_batch
            );
            rows.push(JsonRow {
                system: m.system.clone(),
                value_size: vs,
                ops_per_sec: m.ops_per_sec(),
                mib_per_sec: m.mib_per_sec(),
                mean_us: m.lat.mean(),
                p50_us: m.lat.p50(),
                p99_us: m.lat.p99(),
                log_syncs: st.log_syncs,
                entries_committed: st.entries_committed,
                syncs_per_entry: ratio,
            });
            if kind == nezha::engine::EngineKind::Nezha {
                nezha_tp.push(m.mib_per_sec());
            }
            if kind == nezha::engine::EngineKind::Original {
                orig_tp.push(m.mib_per_sec());
            }
            env.destroy()?;
        }
    }
    let mut avg = None;
    if !nezha_tp.is_empty() && nezha_tp.len() == orig_tp.len() {
        let a: f64 = nezha_tp
            .iter()
            .zip(&orig_tp)
            .map(|(n, o)| improvement_pct(*n, *o))
            .sum::<f64>()
            / nezha_tp.len() as f64;
        println!("\nNezha vs Original average put improvement: {a:+.1}%  (paper: +460.2%)");
        avg = Some(a);
    }
    let body: Vec<String> = rows.iter().map(JsonRow::render).collect();
    let json = format!(
        "{{\n  \"figure\": \"fig4_put\",\n  \"transport\": \"{}\",\n  \"clients\": {clients},\n  \
         \"shards\": {shards},\n  \"scale\": {},\n  \"nezha_vs_original_avg_pct\": {},\n  \
         \"rows\": [\n{}\n  ]\n}}\n",
        transport.name(),
        bench_scale(),
        avg.map_or("null".into(), |a| format!("{a:.1}")),
        body.join(",\n")
    );
    std::fs::write("BENCH_fig4.json", &json)?;
    println!("wrote BENCH_fig4.json ({} rows)", rows.len());
    Ok(())
}
