//! Figure 7 — range-query performance vs **scan length** (10 / 100 /
//! 1000 / 10000 records at 16 KB values; paper used 100 client
//! threads).  Paper headline: Nezha +7.58% over Original on average,
//! stable across lengths; Nezha-NoGC much slower throughout.
//!
//! Scaled: lengths divided by 10 at default scale so the largest scan
//! still covers most of the scaled dataset.
//! Run: `cargo bench --bench fig7_scanlen`.

use nezha::harness::{bench_scale, engines_from_env, print_header, Env, Spec};

fn main() -> anyhow::Result<()> {
    let load = ((8 << 20) as f64 * bench_scale()) as u64;
    let lengths = [10usize, 100, 1_000, 10_000];
    print_header("Figure 7: scan throughput/latency vs scan length (16KB values)");
    for kind in engines_from_env() {
        let mut spec = Spec::new(kind, 16 << 10);
        spec.load_bytes = load;
        let records = spec.records() as usize;
        let env = Env::start(spec)?;
        env.load("preload")?;
        env.settle()?;
        for len in lengths {
            let eff = len.min(records); // clamp to dataset
            let scans = (200 / (len / 10).max(1)).max(3) as u64;
            let m = env.run_scans(scans, eff, &len.to_string())?;
            println!("{}", m.row());
        }
        env.destroy()?;
    }
    Ok(())
}
