//! Figure 9 — put performance vs **cluster size** (3 / 5 / 7 nodes,
//! 16 KB values).  Paper headline: throughput decreases with cluster
//! size for everyone (consensus coordination overhead); Nezha stays
//! 3.5×–5.3× above Original.
//!
//! Run: `cargo bench --bench fig9_scalability`.

use nezha::engine::EngineKind;
use nezha::harness::{bench_scale, engines_from_env, print_header, Env, Spec};

fn main() -> anyhow::Result<()> {
    let load = ((6 << 20) as f64 * bench_scale()) as u64;
    print_header("Figure 9: put throughput/latency vs cluster size (16KB values)");
    let mut ratio: Vec<(usize, f64, f64)> = Vec::new();
    for nodes in [3usize, 5, 7] {
        let mut nezha = 0.0;
        let mut orig = 0.0;
        for kind in engines_from_env() {
            let mut spec = Spec::new(kind, 16 << 10);
            spec.nodes = nodes;
            spec.load_bytes = load;
            let env = Env::start(spec)?;
            let m = env.load(&format!("{nodes}n"))?;
            println!("{}", m.row());
            if kind == EngineKind::Nezha {
                nezha = m.mib_per_sec();
            }
            if kind == EngineKind::Original {
                orig = m.mib_per_sec();
            }
            env.destroy()?;
        }
        if nezha > 0.0 && orig > 0.0 {
            ratio.push((nodes, nezha, orig));
        }
    }
    println!();
    for (n, nez, or) in ratio {
        println!(
            "{n} nodes: Nezha/Original = {:.1}x  (paper: 3.5x–5.3x)",
            nez / or
        );
    }
    Ok(())
}
