//! End-to-end three-layer parity: a full GC cycle whose hash index is
//! built through the AOT XLA/Pallas artifact must produce a
//! byte-identical index (and identical lookups) to the pure-Rust
//! backend.  This is the L1↔L3 contract of DESIGN.md §1.
//!
//! Skipped gracefully when `artifacts/` has not been built
//! (`make artifacts`).

use nezha::gc::{run_gc, EpochSource, FinalStorage, GcInputs, IndexBackend, RustBackend};
use nezha::runtime::IndexPlanner;
use nezha::vlog::{Entry, VLog};
use std::path::PathBuf;
use std::sync::Arc;

fn planner() -> Option<Arc<IndexPlanner>> {
    match IndexPlanner::load_default() {
        Ok(p) => Some(Arc::new(p)),
        Err(e) => {
            eprintln!("skipping xla parity test: {e:#}");
            None
        }
    }
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-xlapar-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write_epoch(dir: &PathBuf, n: u64) -> PathBuf {
    let p = dir.join("raft-000000.vlog");
    let mut v = VLog::open(&p).unwrap();
    for i in 0..n {
        // Mix of sizes + some overwrites + deletes.
        let key = format!("user{:010}", (i * 7) % (n * 3 / 4).max(1));
        if i % 17 == 3 {
            v.append(&Entry::delete(1, i + 1, key)).unwrap();
        } else {
            v.append(&Entry::put(1, i + 1, key, vec![(i % 251) as u8; 64 + (i as usize % 512)]))
                .unwrap();
        }
    }
    v.sync().unwrap();
    p
}

#[test]
fn gc_cycle_identical_under_both_backends() {
    let Some(xla) = planner() else { return };
    let n = 6_000u64;

    let dir_rust = tmpdir("rust");
    let dir_xla = tmpdir("xla");
    let vlog_rust = write_epoch(&dir_rust, n);
    let vlog_xla = write_epoch(&dir_xla, n);

    let out_rust = run_gc(&GcInputs {
        frozen: vec![EpochSource { epoch: 0, path: vlog_rust, skip_offset: 0 }],
        dir: dir_rust.clone(),
        out_gen: 1,
        stack: vec![],
        run_tombstones: Default::default(),
        min_index: 0,
        last_index: n,
        last_term: 1,
        level0_bytes: u64::MAX,
        fanout: 10,
        partitions: Vec::new(),
        partition_bytes: u64::MAX,
        workers: 1,
        resume: false,
        backend: Arc::new(RustBackend),
    })
    .unwrap();
    let out_xla = run_gc(&GcInputs {
        frozen: vec![EpochSource { epoch: 0, path: vlog_xla, skip_offset: 0 }],
        dir: dir_xla.clone(),
        out_gen: 1,
        stack: vec![],
        run_tombstones: Default::default(),
        min_index: 0,
        last_index: n,
        last_term: 1,
        level0_bytes: u64::MAX,
        fanout: 10,
        partitions: Vec::new(),
        partition_bytes: u64::MAX,
        workers: 1,
        resume: false,
        backend: xla,
    })
    .unwrap();

    assert_eq!(out_rust.entries, out_xla.entries);
    assert_eq!(out_rust.bytes_written, out_xla.bytes_written);
    assert_eq!(out_rust.index_backend, "rust");
    assert_eq!(out_xla.index_backend, "xla");

    // The data files and index files must be byte-identical.
    let d_rust = std::fs::read(nezha::gc::sorted_path(&dir_rust, 1)).unwrap();
    let d_xla = std::fs::read(nezha::gc::sorted_path(&dir_xla, 1)).unwrap();
    assert_eq!(d_rust, d_xla, "sorted vlogs differ");
    let i_rust = std::fs::read(nezha::gc::index_path(&dir_rust, 1)).unwrap();
    let i_xla = std::fs::read(nezha::gc::index_path(&dir_xla, 1)).unwrap();
    assert_eq!(i_rust, i_xla, "hash index files differ");

    // And lookups behave identically.
    let fs_rust = FinalStorage::open(&dir_rust, 1).unwrap();
    let fs_xla = FinalStorage::open(&dir_xla, 1).unwrap();
    for q in 0..500u64 {
        let key = format!("user{:010}", q * 13 % (n * 3 / 4));
        let a = fs_rust.get(key.as_bytes()).unwrap();
        let b = fs_xla.get(key.as_bytes()).unwrap();
        assert_eq!(a, b, "lookup mismatch for {key}");
    }
}

#[test]
fn planner_bucket_stream_matches_rust_for_odd_sizes() {
    let Some(xla) = planner() else { return };
    // Exercise non-multiple-of-batch sizes and odd bucket counts.
    for (n, buckets) in [(1usize, 7u32), (4095, 1021), (4097, 65536), (9000, 12345)] {
        let keys: Vec<Vec<u8>> = (0..n).map(|i| format!("key-{i}").into_bytes()).collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
        let (hx, bx) = xla.plan(&refs, buckets).unwrap();
        let (hr, br) = RustBackend.plan(&refs, buckets).unwrap();
        assert_eq!(hx, hr, "hash stream n={n}");
        assert_eq!(bx, br, "bucket stream n={n} buckets={buckets}");
    }
}
