//! Failure-injection integration tests over the full stack: cluster
//! restart recovery, torn-write tolerance, GC interruption under a
//! whole-cluster crash, and engine equivalence (all seven engines
//! agree on query results for the same committed history).

use nezha::coordinator::{shard_dir, Cluster, ClusterConfig, ReadConsistency, ShardRouter};
use nezha::engine::EngineKind;
use nezha::raft::{NetConfig, TransportKind};
use std::path::PathBuf;
use std::time::Duration;

fn base(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-faults-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn cfg(dir: &PathBuf, kind: EngineKind, nodes: usize) -> ClusterConfig {
    let mut c = ClusterConfig::new(dir, kind, nodes);
    c.engine.memtable_bytes = 64 << 10;
    c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 3 };
    c
}

#[test]
fn whole_cluster_restart_preserves_data() {
    for kind in [EngineKind::Original, EngineKind::Nezha] {
        let dir = base(&format!("restart-{}", kind.name()));
        {
            let cluster = Cluster::start(cfg(&dir, kind, 3)).unwrap();
            for i in 0..60u32 {
                cluster
                    .put(format!("key{i:03}").as_bytes(), format!("val{i}").as_bytes())
                    .unwrap();
            }
            cluster.shutdown().unwrap();
        }
        // Cold restart on the same directories.
        let cluster = Cluster::start(cfg(&dir, kind, 3)).unwrap();
        for i in (0..60u32).step_by(7) {
            assert_eq!(
                cluster.get(format!("key{i:03}").as_bytes()).unwrap(),
                Some(format!("val{i}").into_bytes()),
                "{} key{i:03}",
                kind.name()
            );
        }
        let rows = cluster.scan(b"key000", b"key999", 1000).unwrap();
        assert_eq!(rows.len(), 60, "{}", kind.name());
        cluster.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn cluster_crash_mid_gc_recovers_and_resumes() {
    // Genuinely cut a GC cycle mid-flight: arm a one-shot disk fault
    // on the leader's LEVELS-manifest fsync so its next GC commit
    // point fails (the cycle stays interrupted, phase During), then
    // crash that node abruptly — `Cluster::crash` skips the graceful
    // GC finalization that `shutdown` performs — and restart it from
    // the half-written directory.
    let dir = base("gccrash");
    let mut c = cfg(&dir, EngineKind::Nezha, 3);
    c.gc.threshold_bytes = 256 << 10; // force cycles during load
    let cluster = Cluster::start(c).unwrap();
    for i in 0..200u32 {
        cluster.put(format!("g{i:04}").as_bytes(), &[9u8; 2048]).unwrap();
    }
    // Target the current leader's data dir: its next LEVELS sync —
    // the commit point of a compaction/GC step — fails once.
    let victim = cluster.shard_leader(0).unwrap();
    let victim_dir = shard_dir(&cluster.config().base_dir, victim, 0);
    nezha::fault::disk::arm(
        &[victim_dir.to_string_lossy().into_owned(), "LEVELS".into()],
        nezha::fault::disk::DiskOp::Sync,
        1,
    );
    // Keep writing until the fault fires (GC/compaction cycles run as
    // the vlog grows), then crash the victim with the cycle torn.
    let mut i = 200u32;
    while nezha::fault::disk::fired() == 0 {
        assert!(i < 2000, "LEVELS disk fault never fired");
        cluster.put(format!("g{i:04}").as_bytes(), &[9u8; 2048]).unwrap();
        i += 1;
    }
    let total = i;
    cluster.crash(0, victim).unwrap();
    nezha::fault::disk::clear();
    // The survivors keep committing while the victim is down.
    for j in 0..40u32 {
        cluster.put(format!("h{j:04}").as_bytes(), &[6u8; 512]).unwrap();
    }
    // Restart from the interrupted directory: recovery must adopt the
    // pre-fault manifest (the failed cycle never committed), resume
    // GC, and catch up through Raft.
    cluster.restart(0, victim).unwrap();
    cluster.wait_converged(Duration::from_secs(20)).unwrap();
    cluster.drain_gc_all().unwrap();
    for i in (0..total).step_by(41) {
        assert_eq!(
            cluster.get(format!("g{i:04}").as_bytes()).unwrap(),
            Some(vec![9u8; 2048]),
            "g{i:04}"
        );
    }
    assert_eq!(cluster.get(b"h0020").unwrap(), Some(vec![6u8; 512]));
    // The restarted node's GC made progress after the torn cycle.
    let st = cluster.shard_status(victim, 0).unwrap();
    assert!(st.last_applied > 0, "restarted node never re-applied: {st:?}");
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn all_engines_agree_on_committed_history() {
    // The seven configurations must be *observably equivalent* — same
    // committed writes, same reads — differing only in persistence
    // strategy.
    let mut answers: Vec<(EngineKind, Option<Vec<u8>>, usize)> = Vec::new();
    for kind in EngineKind::ALL {
        let dir = base(&format!("equiv-{}", kind.name()));
        let cluster = Cluster::start(cfg(&dir, kind, 3)).unwrap();
        for i in 0..40u32 {
            cluster.put(format!("e{i:02}").as_bytes(), format!("v{i}").as_bytes()).unwrap();
        }
        // Overwrite + delete.
        cluster.put(b"e05", b"overwritten").unwrap();
        cluster.delete(b"e10").unwrap();
        let g = cluster.get(b"e05").unwrap();
        let gone = cluster.get(b"e10").unwrap();
        assert_eq!(gone, None, "{}", kind.name());
        let rows = cluster.scan(b"e00", b"e99", 100).unwrap();
        answers.push((kind, g, rows.len()));
        cluster.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
    let (k0, v0, n0) = &answers[0];
    for (k, v, n) in &answers[1..] {
        assert_eq!(v, v0, "{k} vs {k0}");
        assert_eq!(n, n0, "{k} vs {k0}");
    }
    assert_eq!(*n0, 39); // 40 - 1 deleted
}

#[test]
fn follower_catchup_after_isolation() {
    // A 3-node cluster with one node started late: the leader must
    // bring it up via AppendEntries or InstallSnapshot, and the
    // cluster must keep serving meanwhile.
    let dir = base("catchup");
    let cluster = Cluster::start(cfg(&dir, EngineKind::Nezha, 3)).unwrap();
    for i in 0..120u32 {
        cluster.put(format!("c{i:03}").as_bytes(), &[3u8; 1024]).unwrap();
    }
    // All replicas eventually apply the same index.
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    loop {
        let statuses: Vec<_> = cluster
            .node_ids()
            .iter()
            .map(|&id| cluster.status(id).unwrap())
            .collect();
        let max = statuses.iter().map(|s| s.last_applied).max().unwrap();
        let min = statuses.iter().map(|s| s.last_applied).min().unwrap();
        if max == min && max >= 120 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "followers never converged: {statuses:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_cluster_restart_preserves_every_shard() {
    // 2 shards × 3 nodes: a cold restart must adopt every shard
    // group's on-disk state (per-shard raft logs, engines, manifests).
    let dir = base("shard-restart");
    let mk = || {
        let mut c = cfg(&dir, EngineKind::Nezha, 3);
        c.router = ShardRouter::hash(2);
        c
    };
    {
        let cluster = Cluster::start(mk()).unwrap();
        let ops: Vec<(Vec<u8>, Vec<u8>)> = (0..80u32)
            .map(|i| (format!("sr{i:03}").into_bytes(), format!("v{i}").into_bytes()))
            .collect();
        cluster.put_batch(ops).unwrap();
        cluster.delete(b"sr040").unwrap();
        cluster.shutdown().unwrap();
    }
    let cluster = Cluster::start(mk()).unwrap();
    for i in (0..80u32).step_by(9) {
        let want = if i == 40 { None } else { Some(format!("v{i}").into_bytes()) };
        assert_eq!(cluster.get(format!("sr{i:03}").as_bytes()).unwrap(), want, "sr{i:03}");
    }
    let rows = cluster.scan(b"sr000", b"sr999", 1000).unwrap();
    assert_eq!(rows.len(), 79);
    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "merged scan out of order");
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite fault test: kill one shard group's leader mid-workload.
/// The other shard groups must keep committing immediately; the
/// orphaned group re-elects among its two survivors and catches up —
/// the client API rides through both via its per-shard retries.
#[test]
fn shard_leader_death_leaves_other_shards_committing() {
    let dir = base("shard-kill");
    let mut c = cfg(&dir, EngineKind::Nezha, 3);
    c.router = ShardRouter::hash(3);
    let cluster = Cluster::start(c).unwrap();
    let key = |i: u32| format!("yk{i:04}").into_bytes();
    // First half of a YCSB-style insert stream.
    for i in 0..60u32 {
        cluster.put(&key(i), &[7u8; 256]).unwrap();
    }
    // Kill shard 1's leader mid-stream.
    let victim = cluster.shard_leader(1).unwrap();
    cluster.kill(1, victim).unwrap();
    // The stream continues across ALL shards.  Keys routed to shards
    // 0/2 commit against their untouched leaders; shard-1 keys commit
    // once the survivors elect a new leader (put retries internally).
    let router = cluster.config().router.clone();
    let mut routed = [0u32; 3];
    for i in 60..140u32 {
        let k = key(i);
        routed[router.route(&k) as usize] += 1;
        cluster.put(&k, &[8u8; 256]).unwrap();
    }
    assert!(
        routed.iter().all(|&n| n > 0),
        "stream must exercise every shard: {routed:?}"
    );
    // Shard 1's new leader is one of the survivors.
    let new_leader = cluster.shard_leader(1).unwrap();
    assert_ne!(new_leader, victim, "a survivor took over shard 1");
    // Reads agree with the full committed history, across all shards.
    let keys: Vec<Vec<u8>> = (0..140u32).map(key).collect();
    let got = cluster.get_batch(&keys).unwrap();
    for (i, v) in got.iter().enumerate() {
        let want = if i < 60 { vec![7u8; 256] } else { vec![8u8; 256] };
        assert_eq!(v.as_ref(), Some(&want), "yk{i:04}");
    }
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite fault test (stale-read safety): a `Linearizable` read —
/// served by *any* replica behind a ReadIndex barrier — must never
/// return a value older than a previously acknowledged write, even
/// across a leader kill.  A single client alternates acknowledged
/// counter writes with reads that round-robin over the replicas; the
/// leader is killed mid-stream.  Any stale (or lost) read shows up as
/// a counter regression.
#[test]
fn linearizable_reads_never_stale_across_leader_kill() {
    let dir = base("readidx-kill");
    let mut c = cfg(&dir, EngineKind::Nezha, 3);
    c.read_consistency = ReadConsistency::Linearizable;
    let cluster = Cluster::start(c).unwrap();
    let key = b"counter";
    let read_counter = |cluster: &Cluster| -> u64 {
        let got = cluster.get(key).unwrap().expect("acknowledged counter must be visible");
        u64::from_be_bytes(got[..8].try_into().unwrap())
    };
    for v in 1..=25u64 {
        cluster.put(key, &v.to_be_bytes()).unwrap();
        // Single writer ⇒ a linearizable read returns exactly the
        // last acknowledged value.
        assert_eq!(read_counter(&cluster), v, "stale read before the fault");
    }
    // Kill the leader mid-stream.  Writes retry until the survivors
    // elect; reads must keep refusing any state older than v=25.
    let victim = cluster.shard_leader(0).unwrap();
    cluster.kill(0, victim).unwrap();
    assert!(read_counter(&cluster) >= 25, "read lost an acknowledged write across the kill");
    for v in 26..=40u64 {
        cluster.put(key, &v.to_be_bytes()).unwrap();
        assert_eq!(read_counter(&cluster), v, "stale read after leader change");
    }
    let new_leader = cluster.shard_leader(0).unwrap();
    assert_ne!(new_leader, victim, "a survivor took over");
    // The read traffic really was spread beyond the leader.
    let dist = cluster.read_distribution().unwrap();
    let readers = dist.iter().filter(|(_, gets, _)| *gets > 0).count();
    assert!(readers >= 2, "reads never left one node: {dist:?}");
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// TCP-transport mirror of the ReadIndex fault test above: the same
/// single-writer counter stream over real loopback sockets, killing
/// one node — thread stopped, **listener and connections torn down**,
/// the in-process analogue of killing its process mid-stream.  The
/// shard re-elects, the survivors' frames to the dead peer count
/// `dropped`, and linearizable reads never regress an acknowledged
/// write.
#[test]
fn tcp_linearizable_reads_survive_leader_kill() {
    let dir = base("tcp-readidx-kill");
    let mut c = cfg(&dir, EngineKind::Nezha, 3);
    c.transport = TransportKind::Tcp;
    c.read_consistency = ReadConsistency::Linearizable;
    let cluster = Cluster::start(c).unwrap();
    let key = b"counter";
    let read_counter = |cluster: &Cluster| -> u64 {
        let got = cluster.get(key).unwrap().expect("acknowledged counter must be visible");
        u64::from_be_bytes(got[..8].try_into().unwrap())
    };
    for v in 1..=15u64 {
        cluster.put(key, &v.to_be_bytes()).unwrap();
        assert_eq!(read_counter(&cluster), v, "stale read before the fault");
    }
    // Kill the shard leader: its process-equivalent (thread + TCP
    // listener + connections) disappears mid-stream.
    let victim = cluster.shard_leader(0).unwrap();
    cluster.kill(0, victim).unwrap();
    assert!(read_counter(&cluster) >= 15, "read lost an acknowledged write across the kill");
    for v in 16..=25u64 {
        cluster.put(key, &v.to_be_bytes()).unwrap();
        assert_eq!(read_counter(&cluster), v, "stale read after leader change");
    }
    let new_leader = cluster.shard_leader(0).unwrap();
    assert_ne!(new_leader, victim, "a survivor took over");
    // The survivors really were talking TCP, and their frames to the
    // dead peer were accounted as drops, not silently queued.
    let wire = cluster.wire_stats();
    assert!(wire.msgs > 0 && wire.bytes > 0, "no TCP traffic recorded: {wire:?}");
    assert!(wire.dropped > 0, "frames to the killed node must count dropped: {wire:?}");
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lossy_network_still_commits() {
    let dir = base("lossy");
    let mut c = cfg(&dir, EngineKind::Nezha, 3);
    c.net = NetConfig { latency_us: (0, 0), loss: 0.02, seed: 5 };
    let cluster = Cluster::start(c).unwrap();
    for i in 0..40u32 {
        cluster.put(format!("l{i:02}").as_bytes(), b"v").unwrap();
    }
    assert_eq!(cluster.get(b"l20").unwrap(), Some(b"v".to_vec()));
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
