//! Leveled-GC end-to-end parity (ISSUE 2 acceptance): on identical
//! committed histories — overwrites and deletes included — the leveled
//! Nezha engine must return exactly the same point and range results
//! as the Classic (Original) engine, across many forced GC cycles with
//! budget-triggered level merges, and across a crash + background
//! resume of an in-flight cycle.

use nezha::coordinator::replica::engine_dir;
use nezha::coordinator::Replica;
use nezha::engine::{EngineKind, EngineOpts};
use nezha::gc::levels::LevelManifest;
use nezha::gc::{GcConfig, GcState};
use nezha::raft::{Command, Config as RaftConfig};
use std::path::PathBuf;

fn base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-gclev-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn open_replica(dir: &std::path::Path, kind: EngineKind, threshold: u64) -> Replica {
    let mut opts = EngineOpts::new("unset", "unset");
    opts.memtable_bytes = 64 << 10;
    // Tiny budgets: every few cycles trigger real level merges.
    opts.gc_level0_bytes = 4 << 10;
    opts.gc_fanout = 4;
    Replica::open(
        1,
        vec![],
        dir,
        kind,
        opts,
        RaftConfig::default(),
        GcConfig { threshold_bytes: threshold, ..Default::default() },
        7,
    )
    .unwrap()
}

fn make_leader(r: &mut Replica) {
    for _ in 0..300 {
        r.node.tick().unwrap();
        if r.node.is_leader() {
            return;
        }
    }
    panic!("single node failed to elect itself");
}

fn apply_ops(r: &mut Replica, ops: &[Command]) {
    for chunk in ops.chunks(32) {
        let (idx, _out) = r.propose_batch(chunk.to_vec()).unwrap();
        assert!(r.node.last_applied() >= *idx.last().unwrap());
    }
}

/// Deterministic op mix: puts with heavy overwrites plus periodic
/// deletes over a key space half the op count.
fn op_stream(n: u64) -> Vec<Command> {
    let mut ops = Vec::with_capacity(n as usize);
    let mut x = 0x1234_5678_9abc_def0u64;
    for i in 0..n {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let key = format!("key{:05}", (x >> 16) % (n / 2)).into_bytes();
        if x % 11 == 3 {
            ops.push(Command::Delete { key });
        } else {
            ops.push(Command::Put { key, value: format!("value-{i}").into_bytes() });
        }
    }
    ops
}

#[test]
fn leveled_nezha_matches_classic_across_cycles_and_crash() {
    let n = 600u64;
    let ops = op_stream(n);

    let dir_c = base("classic");
    let mut classic = open_replica(&dir_c, EngineKind::Original, u64::MAX);
    make_leader(&mut classic);

    let dir_n = base("nezha");
    let mut nezha = open_replica(&dir_n, EngineKind::Nezha, 8 << 10);
    make_leader(&mut nezha);

    for (ci, chunk) in ops.chunks(100).enumerate() {
        apply_ops(&mut classic, chunk);
        apply_ops(&mut nezha, chunk);
        if ci == 2 {
            // Crash while a cycle is in flight: settle any running
            // cycle, then persist a freshly-initialized cycle whose
            // compaction thread "died" before writing anything, and
            // reopen — recovery must resume it in the background and
            // re-route replayed applies into the frozen layout.
            nezha.finish_gc().unwrap();
            let edir = engine_dir(&dir_n);
            let manifest = LevelManifest::load(&edir).unwrap().unwrap_or_default();
            let last_index = nezha.node.last_applied();
            let min_index = nezha.node.log.snap_index;
            let last_term = nezha.node.log.term_at(last_index).unwrap_or(1);
            assert!(last_index > min_index, "crash cycle must have work to do");
            nezha.node.log.rotate().unwrap();
            let epochs = nezha.node.log.frozen_epochs();
            GcState {
                running: true,
                min_epoch: *epochs.first().unwrap(),
                frozen_epoch: *epochs.last().unwrap(),
                out_gen: manifest.next_gen,
                min_index,
                last_index,
                last_term,
                stack: manifest.levels,
                run_tombstones: manifest.run_tombstones,
                partitions: manifest.partitions,
            }
            .save(&edir)
            .unwrap();
            drop(nezha);
            nezha = open_replica(&dir_n, EngineKind::Nezha, 8 << 10);
            make_leader(&mut nezha);
            let out = nezha.finish_gc().unwrap().expect("resumed cycle completes");
            assert_eq!(out.last_index, last_index, "resume kept the snapshot point");
        } else {
            nezha.pump_gc((ci as u64 + 1) * 1000).unwrap();
        }
    }
    nezha.finish_gc().unwrap();
    assert!(
        !nezha.gc_history.is_empty(),
        "forced thresholds must have produced GC cycles"
    );
    assert!(
        nezha.gc_history.iter().any(|c| c.merges > 0),
        "tiny budgets must have produced at least one level merge"
    );

    // Point parity over the whole key space (live + deleted + absent).
    let keys: Vec<Vec<u8>> = (0..n / 2 + 10)
        .map(|i| format!("key{i:05}").into_bytes())
        .collect();
    for k in &keys {
        assert_eq!(
            nezha.engine().get(k).unwrap(),
            classic.engine().get(k).unwrap(),
            "get({})",
            String::from_utf8_lossy(k)
        );
    }
    // Batched parity.
    assert_eq!(
        nezha.engine().multi_get(&keys).unwrap(),
        classic.engine().multi_get(&keys).unwrap()
    );
    // Range parity: bounded windows with limits, and the unbounded
    // full-range scan (empty end = +∞).
    assert_eq!(
        nezha.engine().scan(b"key00100", b"key00220", 37).unwrap(),
        classic.engine().scan(b"key00100", b"key00220", 37).unwrap()
    );
    let full_n = nezha.engine().scan(b"", b"", usize::MAX).unwrap();
    let full_c = classic.engine().scan(b"", b"", usize::MAX).unwrap();
    assert_eq!(full_n, full_c, "unbounded scans diverge");
    assert!(!full_n.is_empty());
}
