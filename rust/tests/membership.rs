//! Dynamic membership acceptance (DESIGN.md §9): a 3-node cluster
//! over real TCP sockets, under continuous client load, grows to 4
//! voters via learner catch-up and auto-promotion, then shrinks back
//! to 3 by removing the *leader* — and every acknowledged write stays
//! readable across both reconfigurations.
//!
//! The writer only records puts the cluster acknowledged; retried
//! duplicates are harmless because each key's value is derived from
//! the key.  An errored put is indeterminate (it may or may not have
//! committed) and is simply not asserted — the gate is *zero failed
//! acknowledged ops*, not zero client-visible retries.

use nezha::coordinator::{Cluster, ClusterConfig, ReadConsistency};
use nezha::engine::EngineKind;
use nezha::raft::{NetConfig, NodeId, TransportKind};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll until the shard-0 leader's applied config lists exactly
/// `want` as voters with no learners left in catch-up.
fn wait_voters(cluster: &Cluster, want: &[NodeId], deadline_s: u64) {
    let deadline = Instant::now() + Duration::from_secs(deadline_s);
    loop {
        // The leader's view is authoritative: it proposed the change.
        if let Ok(leader) = cluster.shard_leader(0) {
            if let Ok(s) = cluster.shard_status(leader, 0) {
                if s.voters == want && s.learners.is_empty() {
                    return;
                }
            }
        }
        assert!(Instant::now() < deadline, "voters never became {want:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn tcp_cluster_grows_and_shrinks_under_load() {
    let dir = std::env::temp_dir().join(format!("nezha-membership-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 17 };
    c.read_consistency = ReadConsistency::Leader;
    c.transport = TransportKind::Tcp;
    let cluster = Arc::new(Cluster::start(c).unwrap());

    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let cluster = Arc::clone(&cluster);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut acked: Vec<u32> = Vec::new();
            let mut i = 0u32;
            while !stop.load(Ordering::Relaxed) {
                let key = format!("load{i:06}").into_bytes();
                let val = format!("v{i}").into_bytes();
                if cluster.put(&key, &val).is_ok() {
                    acked.push(i);
                }
                i += 1;
            }
            acked
        })
    };

    assert_eq!(cluster.shard_members(0), vec![1, 2, 3]);
    // Grow: the new node joins as a learner, catches up while the
    // writer keeps committing, and is auto-promoted to voter.
    let joined = cluster.add_node(0).unwrap();
    assert_eq!(joined, 4, "first added node takes the next fresh id");
    assert_eq!(cluster.shard_members(0), vec![1, 2, 3, 4]);
    wait_voters(&cluster, &[1, 2, 3, 4], 60);

    // Shrink by removing the *leader*: it replicates its own removal,
    // steps down with a handoff, and the writer rides the NotLeader
    // redirects without losing an acknowledged op (DESIGN.md §9).
    let deposed = cluster.shard_leader(0).unwrap();
    cluster.remove_node(0, deposed).unwrap();
    let members = cluster.shard_members(0);
    assert_eq!(members.len(), 3, "membership after removal: {members:?}");
    assert!(!members.contains(&deposed), "node {deposed} still a member: {members:?}");
    wait_voters(&cluster, &members, 60);
    let new_leader = cluster.shard_leader(0).unwrap();
    assert_ne!(new_leader, deposed, "removed leader still leading");

    // Let the writer run a beat on the final configuration.
    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Ordering::Relaxed);
    let acked = writer.join().expect("writer thread panicked");
    assert!(acked.len() >= 100, "degenerate load: only {} acked writes", acked.len());

    // Zero failed acknowledged ops: every acked write reads back.
    let keys: Vec<Vec<u8>> = acked.iter().map(|i| format!("load{i:06}").into_bytes()).collect();
    let got = cluster.get_batch(&keys).unwrap();
    for (i, v) in acked.iter().zip(&got) {
        assert_eq!(v.as_deref(), Some(format!("v{i}").as_bytes()), "acked write load{i:06} lost");
    }
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
