//! Chaos suite: nemesis schedules against live clusters, checked for
//! linearizability (ISSUE 6's acceptance gate).
//!
//! Each schedule test sweeps a seed matrix (override with
//! `NEZHA_CHAOS_SEEDS=1,2,3`), pairing seeds with read-consistency
//! modes so all three modes are exercised per schedule; set
//! `NEZHA_CHAOS_FULL=1` for the full seeds × modes product (the CI
//! chaos job runs that in release).  Any violation fails with the
//! nemesis event log attached.
//!
//! The restart round-trip tests pin the `kill` → `restart` contract on
//! both transports: a node rebuilt from its data directory rejoins the
//! group, catches up, and *serves reads*.

use nezha::chaos::{run_chaos, ChaosOpts, ScheduleKind};
use nezha::coordinator::{Cluster, ClusterConfig, ReadConsistency};
use nezha::engine::EngineKind;
use nezha::raft::{NetConfig, TransportKind};
use std::path::PathBuf;
use std::time::Duration;

const MODES: [ReadConsistency; 3] =
    [ReadConsistency::Leader, ReadConsistency::Linearizable, ReadConsistency::Stale];

fn seeds() -> Vec<u64> {
    match std::env::var("NEZHA_CHAOS_SEEDS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("NEZHA_CHAOS_SEEDS must be u64s"))
            .collect(),
        Err(_) => vec![5, 7, 11, 13],
    }
}

/// The (seed, mode) pairs a schedule test runs: the full product under
/// `NEZHA_CHAOS_FULL=1`, else one mode per seed with all three modes
/// covered across the sweep.
fn matrix() -> Vec<(u64, ReadConsistency)> {
    let seeds = seeds();
    if std::env::var("NEZHA_CHAOS_FULL").is_ok_and(|v| v == "1") {
        seeds.iter().flat_map(|&s| MODES.map(|m| (s, m))).collect()
    } else {
        seeds.iter().enumerate().map(|(i, &s)| (s, MODES[i % MODES.len()])).collect()
    }
}

fn run_schedule(schedule: ScheduleKind, transport: TransportKind) {
    for (seed, mode) in matrix() {
        let mut opts = ChaosOpts::new(seed, schedule);
        opts.read_consistency = mode;
        opts.transport = transport;
        opts.run_ms = 2_200;
        let report = run_chaos(&opts)
            .unwrap_or_else(|e| panic!("{} seed {seed} {mode:?}: harness: {e:#}", schedule.name()));
        assert!(
            report.writes > 0 && report.reads > 0,
            "{} seed {seed} {mode:?}: degenerate run: {report:?}",
            schedule.name()
        );
        if let Some(v) = &report.violation {
            panic!(
                "{} seed {seed} {mode:?}: {v}\n  {} writes ({} indeterminate), {} reads\n  \
                 nemesis log:\n    {}",
                schedule.name(),
                report.writes,
                report.indeterminate,
                report.reads,
                report.nemesis_log.join("\n    ")
            );
        }
    }
}

#[test]
fn chaos_partition_heal() {
    run_schedule(ScheduleKind::PartitionHeal, TransportKind::Inproc);
}

#[test]
fn chaos_crash_restart_mid_gc() {
    run_schedule(ScheduleKind::CrashRestartMidGc, TransportKind::Inproc);
}

#[test]
fn chaos_flapping_links() {
    run_schedule(ScheduleKind::FlappingLinks, TransportKind::Inproc);
}

#[test]
fn chaos_torn_group_commit() {
    run_schedule(ScheduleKind::TornGroupCommit, TransportKind::Inproc);
}

#[test]
fn chaos_torn_partitioned_merge() {
    run_schedule(ScheduleKind::TornPartitionedMerge, TransportKind::Inproc);
}

/// The torn-snapshot-stream drill (DESIGN.md §8): a follower crashes,
/// falls behind a compacting leader, and its restart needs a
/// run-shipping catch-up transfer that is torn three ways — a staging
/// disk fault mid-stream, a receiver crash, and finally a sender
/// (leader) crash.  Resume-or-restart must leave an installed state
/// that serves every acknowledged write; a torn transfer must never be
/// read as installed.
#[test]
fn chaos_torn_snapshot_stream() {
    run_schedule(ScheduleKind::TornSnapshotStream, TransportKind::Inproc);
}

/// The torn-group-commit drill over real sockets: the leader dies with
/// its raft-log fsync failed *after* the pipelined broadcast left via
/// TCP, and acknowledged writes must survive its recovery.
#[test]
fn chaos_torn_group_commit_over_tcp() {
    let mut opts = ChaosOpts::new(11, ScheduleKind::TornGroupCommit);
    opts.read_consistency = ReadConsistency::Linearizable;
    opts.transport = TransportKind::Tcp;
    opts.run_ms = 2_200;
    let report = run_chaos(&opts).expect("tcp torn-group-commit harness");
    assert!(report.writes > 0 && report.reads > 0, "degenerate run: {report:?}");
    if let Some(v) = &report.violation {
        panic!(
            "tcp torn-group-commit: {v}\n  nemesis log:\n    {}",
            report.nemesis_log.join("\n    ")
        );
    }
}

/// The torn-partitioned-merge drill over real sockets: a disk fault
/// lands in one partition's sorted-run output mid-merge, the leader
/// crashes and restarts, and recovery must resume (or deterministically
/// replan) the merge without losing acknowledged writes.
#[test]
fn chaos_torn_partitioned_merge_over_tcp() {
    let mut opts = ChaosOpts::new(13, ScheduleKind::TornPartitionedMerge);
    opts.read_consistency = ReadConsistency::Linearizable;
    opts.transport = TransportKind::Tcp;
    opts.run_ms = 2_200;
    let report = run_chaos(&opts).expect("tcp torn-partitioned-merge harness");
    assert!(report.writes > 0 && report.reads > 0, "degenerate run: {report:?}");
    if let Some(v) = &report.violation {
        panic!(
            "tcp torn-partitioned-merge: {v}\n  nemesis log:\n    {}",
            report.nemesis_log.join("\n    ")
        );
    }
}

/// The torn-snapshot-stream drill over real sockets: the catch-up
/// chunks cross TCP framing, the receiver's staging tears on a disk
/// fault, both ends die mid-transfer at different points, and the
/// history must stay linearizable.
#[test]
fn chaos_torn_snapshot_stream_over_tcp() {
    let mut opts = ChaosOpts::new(5, ScheduleKind::TornSnapshotStream);
    opts.read_consistency = ReadConsistency::Linearizable;
    opts.transport = TransportKind::Tcp;
    opts.run_ms = 2_200;
    let report = run_chaos(&opts).expect("tcp torn-snapshot-stream harness");
    assert!(report.writes > 0 && report.reads > 0, "degenerate run: {report:?}");
    if let Some(v) = &report.violation {
        panic!(
            "tcp torn-snapshot-stream: {v}\n  nemesis log:\n    {}",
            report.nemesis_log.join("\n    ")
        );
    }
}

/// The membership-churn drill (DESIGN.md §9): a brand-new node joins
/// as a learner mid-load, is crashed mid-catch-up and restarted, and
/// the *leader itself* is removed from the group — all while clients
/// hammer the cluster.  Every acknowledged write must stay
/// linearizable across the 3 → 4 → 3 reconfiguration.
#[test]
fn chaos_membership_churn() {
    run_schedule(ScheduleKind::MembershipChurn, TransportKind::Inproc);
}

/// Membership churn over real sockets: the joining learner's catch-up
/// stream, its crash/restart, and the leader's self-removal handoff
/// all cross TCP framing and listener rebinds.
#[test]
fn chaos_membership_churn_over_tcp() {
    for seed in [5u64, 7, 11] {
        let mut opts = ChaosOpts::new(seed, ScheduleKind::MembershipChurn);
        opts.read_consistency = ReadConsistency::Linearizable;
        opts.transport = TransportKind::Tcp;
        opts.run_ms = 2_200;
        let report = run_chaos(&opts).expect("tcp membership-churn harness");
        assert!(report.writes > 0 && report.reads > 0, "degenerate run: {report:?}");
        if let Some(v) = &report.violation {
            panic!(
                "tcp membership-churn seed {seed}: {v}\n  nemesis log:\n    {}",
                report.nemesis_log.join("\n    ")
            );
        }
    }
}

/// One TCP-transport chaos run: the fault plan drops frames at the
/// send edge and kill/restart tears down and rebinds real listeners.
#[test]
fn chaos_partition_heal_over_tcp() {
    let mut opts = ChaosOpts::new(7, ScheduleKind::PartitionHeal);
    opts.read_consistency = ReadConsistency::Linearizable;
    opts.transport = TransportKind::Tcp;
    opts.run_ms = 2_200;
    let report = run_chaos(&opts).expect("tcp chaos harness");
    assert!(report.writes > 0 && report.reads > 0, "degenerate run: {report:?}");
    if let Some(v) = &report.violation {
        panic!("tcp partition-heal: {v}\n  nemesis log:\n    {}", report.nemesis_log.join("\n    "));
    }
}

// ---------------------------------------------------------------------
// kill → restart round-trip (both transports)
// ---------------------------------------------------------------------

fn base(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-chaos-rt-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Kill node 3 mid-stream, keep committing, restart it from its data
/// dir, and require that the rejoined node both caught up and serves
/// reads (Stale mode round-robins reads over every live replica, so a
/// node that never shows up in the read distribution never rejoined).
fn restart_roundtrip(transport: TransportKind, tag: &str) {
    let dir = base(tag);
    let mut c = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 9 };
    c.read_consistency = ReadConsistency::Stale;
    c.transport = transport;
    let cluster = Cluster::start(c).unwrap();
    let key = |i: u32| format!("rt{i:03}").into_bytes();
    for i in 0..20u32 {
        cluster.put(&key(i), format!("v{i}").as_bytes()).unwrap();
    }
    cluster.kill(0, 3).unwrap();
    assert!(!cluster.node_ids().contains(&3), "node 3 still listed after kill");
    // The survivors keep committing while 3 is down.
    for i in 20..40u32 {
        cluster.put(&key(i), format!("v{i}").as_bytes()).unwrap();
    }
    cluster.restart(0, 3).unwrap();
    assert!(cluster.node_ids().contains(&3), "node 3 missing after restart");
    cluster.wait_converged(Duration::from_secs(20)).unwrap();
    // Enough reads that the round-robin provably reaches node 3,
    // including keys committed while it was down.
    let keys: Vec<Vec<u8>> = (0..40u32).map(key).collect();
    for _ in 0..3 {
        let got = cluster.get_batch(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(format!("v{i}").as_bytes()), "rt{i:03}");
        }
    }
    let dist = cluster.read_distribution().unwrap();
    let n3 = dist.iter().find(|(id, _, _)| *id == 3).expect("node 3 in distribution");
    assert!(n3.1 > 0, "rejoined node served no reads: {dist:?}");
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restart_roundtrip_over_bus() {
    restart_roundtrip(TransportKind::Inproc, "bus");
}

#[test]
fn restart_roundtrip_over_tcp() {
    restart_roundtrip(TransportKind::Tcp, "tcp");
}
