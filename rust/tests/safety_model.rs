//! Randomized safety model-checking of KVS-Raft over the deterministic
//! SimNet (the in-repo substitute for the paper's TLA+ spec —
//! DESIGN.md §2).  Each case drives a 3- or 5-node cluster through a
//! seeded schedule of proposals, partitions, heals, message loss and
//! crashes-by-silence, asserting after every step:
//!
//! * **Election Safety** — at most one leader per term.
//! * **Log Matching** — same (index, term) ⇒ same command.
//! * **Leader Completeness** — a committed entry appears in every
//!   later leader's log.
//! * **State Machine Safety** — applied sequences are prefixes of one
//!   another (we check the applied command streams agree).

use nezha::raft::{
    Command, Config, LogEntry, Message, NetConfig, Node, NodeId, SimNet, StateMachine, Transport,
};
use nezha::util::prop;
use nezha::vlog::VRef;
use std::collections::HashMap;

/// Recording state machine: remembers every applied (index, key).
#[derive(Default)]
struct TraceSm {
    applied: Vec<(u64, u64, Vec<u8>)>, // (index, term, key)
}

impl StateMachine for TraceSm {
    fn apply(&mut self, entry: &LogEntry, _vref: VRef) -> anyhow::Result<()> {
        self.applied.push((entry.index, entry.term, entry.cmd.key().to_vec()));
        Ok(())
    }

    fn snapshot_bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        // Encode the trace so an installed snapshot preserves history
        // (enough for the invariants below).
        let mut e = nezha::util::Encoder::new();
        e.varint(self.applied.len() as u64);
        for (i, t, k) in &self.applied {
            e.u64(*i).u64(*t).len_bytes(k);
        }
        Ok(e.into_vec())
    }

    fn install_snapshot(&mut self, data: &[u8], _li: u64, _lt: u64) -> anyhow::Result<()> {
        let mut d = nezha::util::Decoder::new(data);
        let n = d.varint()? as usize;
        self.applied.clear();
        for _ in 0..n {
            let i = d.u64()?;
            let t = d.u64()?;
            let k = d.len_bytes()?.to_vec();
            self.applied.push((i, t, k));
        }
        Ok(())
    }
}

struct Sim {
    nodes: Vec<Node<TraceSm>>,
    net: SimNet,
    time_us: u64,
    /// Highest term in which each node was seen as leader.
    leaders_by_term: HashMap<u64, NodeId>,
}

impl Sim {
    fn new(name: &str, n: usize, seed: u64, loss: f64) -> Self {
        let ids: Vec<NodeId> = (1..=n as u64).collect();
        let dirbase = std::env::temp_dir().join(format!(
            "nezha-model-{name}-{seed}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dirbase);
        let nodes = ids
            .iter()
            .map(|&id| {
                let peers: Vec<NodeId> = ids.iter().copied().filter(|&p| p != id).collect();
                Node::new(
                    id,
                    peers,
                    &dirbase.join(format!("n{id}")),
                    TraceSm::default(),
                    Config { mem_keep_tail: 8, ..Config::default() },
                    seed,
                )
                .unwrap()
            })
            .collect();
        let net = SimNet::new(NetConfig { latency_us: (100, 500), loss, seed });
        Self { nodes, net, time_us: 0, leaders_by_term: HashMap::new() }
    }

    fn node(&mut self, id: NodeId) -> &mut Node<TraceSm> {
        self.nodes.iter_mut().find(|n| n.id == id).unwrap()
    }

    /// One logical millisecond: deliver due messages, tick everyone.
    fn step(&mut self) -> Result<(), String> {
        self.time_us += 1_000;
        let due = self.net.advance(self.time_us);
        for (from, to, msg) in due {
            let out = self.node(to).handle(from, msg).map_err(|e| e.to_string())?;
            for (dst, m) in out {
                self.net.send(to, dst, m);
            }
        }
        for i in 0..self.nodes.len() {
            let id = self.nodes[i].id;
            let out = self.nodes[i].tick().map_err(|e| e.to_string())?;
            for (dst, m) in out {
                self.net.send(id, dst, m);
            }
        }
        self.check_invariants()
    }

    fn leader(&self) -> Option<NodeId> {
        self.nodes.iter().find(|n| n.is_leader()).map(|n| n.id)
    }

    fn check_invariants(&mut self) -> Result<(), String> {
        // Election safety: one leader per term.
        for n in &self.nodes {
            if n.is_leader() {
                if let Some(&prev) = self.leaders_by_term.get(&n.term()) {
                    if prev != n.id {
                        return Err(format!(
                            "two leaders in term {}: {} and {}",
                            n.term(),
                            prev,
                            n.id
                        ));
                    }
                } else {
                    self.leaders_by_term.insert(n.term(), n.id);
                }
            }
        }
        // Log matching over the in-memory suffixes.
        for a in 0..self.nodes.len() {
            for b in a + 1..self.nodes.len() {
                let (na, nb) = (&self.nodes[a], &self.nodes[b]);
                let lo = na.log.first_in_mem().max(nb.log.first_in_mem());
                let hi = na.log.last_index().min(nb.log.last_index());
                for idx in lo..=hi.min(lo + 50) {
                    if let (Some(ea), Some(eb)) = (na.log.entry(idx), nb.log.entry(idx)) {
                        if ea.term == eb.term && ea.cmd != eb.cmd {
                            return Err(format!(
                                "log matching violated at index {idx} (term {})",
                                ea.term
                            ));
                        }
                    }
                }
            }
        }
        // State machine safety: applied traces agree on common prefix.
        let traces: Vec<&Vec<(u64, u64, Vec<u8>)>> =
            self.nodes.iter().map(|n| &n.sm().applied).collect();
        for a in 0..traces.len() {
            for b in a + 1..traces.len() {
                let common = traces[a].len().min(traces[b].len());
                // Compare the overlapping window (snapshots may
                // truncate prefixes identically).
                for i in 0..common {
                    let (ia, ta, ka) = &traces[a][i];
                    // Find the same index in b (offsets can differ
                    // after snapshot install).
                    if let Some((_, tb, kb)) = traces[b].iter().find(|(ib, _, _)| ib == ia) {
                        if ta != tb || ka != kb {
                            return Err(format!(
                                "state machine safety violated at applied index {ia}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[test]
fn model_normal_operation_commits_everything() {
    let mut sim = Sim::new("normal", 3, 11, 0.0);
    // Elect.
    for _ in 0..2_000 {
        sim.step().unwrap();
        if sim.leader().is_some() {
            break;
        }
    }
    let leader = sim.leader().expect("leader");
    for i in 0..30u32 {
        let _ = sim
            .node(leader)
            .propose(Command::Put { key: format!("k{i}").into_bytes(), value: b"v".to_vec() });
        let out = sim.node(leader).replicate().unwrap();
        for (dst, m) in out {
            sim.net.send(leader, dst, m);
        }
        for _ in 0..10 {
            sim.step().unwrap();
        }
    }
    for _ in 0..100 {
        sim.step().unwrap();
    }
    let applied: Vec<usize> = sim.nodes.iter().map(|n| n.sm().applied.len()).collect();
    assert!(applied.iter().all(|&a| a >= 30), "{applied:?}");
}

#[test]
fn model_random_schedules_preserve_safety() {
    prop::check("raft-safety", 12, |g| {
        let n = if g.bool() { 3 } else { 5 };
        let seed = g.u64();
        let loss = if g.chance(0.4) { 0.05 } else { 0.0 };
        let mut sim = Sim::new("rand", n, seed, loss);
        for _round in 0..60 {
            // Random disturbance.
            match g.usize_in(0..10) {
                0 => {
                    let a = g.u64_in(1..n as u64 + 1);
                    let b = g.u64_in(1..n as u64 + 1);
                    if a != b {
                        sim.net.partition(a, b);
                    }
                }
                1 => sim.net.heal(),
                _ => {}
            }
            // Random proposals at whoever thinks it leads.
            if let Some(l) = sim.leader() {
                if g.chance(0.7) {
                    let key = g.key(1..8);
                    let _ = sim.node(l).propose(Command::Put { key, value: b"x".to_vec() });
                    let out = sim.node(l).replicate().map_err(|e| e.to_string())?;
                    for (dst, m) in out {
                        sim.net.send(l, dst, m);
                    }
                }
            }
            for _ in 0..g.usize_in(5..25) {
                sim.step()?;
            }
        }
        // Heal and converge: some leader must exist and no invariant
        // may have tripped (checked inside step()).
        sim.net.heal();
        for _ in 0..3_000 {
            sim.step()?;
            if sim.leader().is_some() {
                break;
            }
        }
        if sim.leader().is_none() {
            return Err("no leader after heal".into());
        }
        Ok(())
    });
}

#[test]
fn model_committed_entries_survive_leader_changes() {
    let mut sim = Sim::new("leaderchange", 3, 99, 0.0);
    for _ in 0..2_000 {
        sim.step().unwrap();
        if sim.leader().is_some() {
            break;
        }
    }
    let l1 = sim.leader().unwrap();
    // Commit a known entry.
    let idx = sim
        .node(l1)
        .propose(Command::Put { key: b"durable".to_vec(), value: b"1".to_vec() })
        .unwrap();
    let out = sim.node(l1).replicate().unwrap();
    for (dst, m) in out {
        sim.net.send(l1, dst, m);
    }
    for _ in 0..50 {
        sim.step().unwrap();
    }
    assert!(sim.node(l1).commit_index() >= idx);
    // Partition the leader away; a new leader must emerge and keep
    // the committed entry (Leader Completeness).
    let others: Vec<NodeId> = sim.nodes.iter().map(|n| n.id).filter(|&i| i != l1).collect();
    for &o in &others {
        sim.net.partition(l1, o);
    }
    let mut new_leader = None;
    for _ in 0..5_000 {
        sim.step().unwrap();
        new_leader = sim
            .nodes
            .iter()
            .find(|n| n.is_leader() && n.id != l1)
            .map(|n| n.id);
        if new_leader.is_some() {
            break;
        }
    }
    let l2 = new_leader.expect("new leader after partition");
    let e = sim.node(l2).log.entry(idx).cloned();
    assert!(
        matches!(e, Some(ref le) if le.cmd.key() == b"durable"),
        "committed entry missing from new leader: {e:?}"
    );
}
