//! Streamed snapshot catch-up at the cluster level (DESIGN.md §8): a
//! follower that falls behind a compacting leader rejoins via the
//! run-shipping `SnapMeta`/`SnapChunk`/`SnapAck` transfer, and the
//! streamed path must install exactly the state the legacy monolithic
//! `InstallSnapshot` blob would — same keys, same values, same reads.
//!
//! The knobs force the interesting shape: a small memtable and a low
//! GC threshold so the leader seals runs and compacts its raft log
//! while node 3 is down, and small chunks so the transfer spans many
//! ack windows instead of fitting in one.

use nezha::coordinator::{Cluster, ClusterConfig, ReadConsistency};
use nezha::engine::EngineKind;
use nezha::raft::{NetConfig, TransportKind};
use std::path::PathBuf;
use std::time::Duration;

fn base(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nezha-snapstream-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Fall-behind → rejoin cycle: 30 puts with everyone up, kill node 3,
/// 120 more puts across two full GC drains (so the raft log compacts
/// past 3's position), restart 3, converge, then read everything back
/// three times over.  Returns the values served so callers can compare
/// the streamed and legacy paths byte for byte.
fn streamed_catchup(transport: TransportKind, streaming: bool, tag: &str) -> Vec<Option<Vec<u8>>> {
    let dir = base(tag);
    let mut c = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.gc.threshold_bytes = 32 << 10;
    c.raft.snap_chunk_bytes = 8 << 10;
    c.raft.snap_streaming = streaming;
    c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 21 };
    c.read_consistency = ReadConsistency::Stale;
    c.transport = transport;
    let cluster = Cluster::start(c).unwrap();
    let key = |i: u32| format!("snap{i:03}").into_bytes();
    let val = |i: u32| vec![(i % 251) as u8; 1024];
    for i in 0..30u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }
    cluster.kill(0, 3).unwrap();
    // The survivors keep writing while 3 is down; each drained GC
    // cycle seals runs and marks a raft snapshot, dropping the log
    // prefix a rejoining follower would otherwise replay.
    for i in 30..90u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }
    cluster.drain_gc_all().unwrap();
    for i in 90..150u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }
    cluster.drain_gc_all().unwrap();
    cluster.restart(0, 3).unwrap();
    cluster.wait_converged(Duration::from_secs(30)).unwrap();

    // Stale mode round-robins reads over every live replica, so three
    // passes provably reach the rejoined node for some keys.
    let keys: Vec<Vec<u8>> = (0..150u32).map(key).collect();
    let mut got = Vec::new();
    for _ in 0..3 {
        got = cluster.get_batch(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(val(i as u32).as_slice()), "{tag}: key {i}");
        }
    }
    // Transfer accounting (`Status::snap`): the rejoined node streamed
    // chunks — or, with streaming off, provably took the legacy path.
    let s3 = cluster.status(3).unwrap();
    if streaming {
        assert!(s3.snap.chunks_recv > 0, "{tag}: no chunks received: {:?}", s3.snap);
        assert!(s3.snap.streams_done >= 1, "{tag}: no stream completed: {:?}", s3.snap);
        let sent: u64 =
            [1u64, 2].iter().map(|&id| cluster.status(id).unwrap().snap.chunks_sent).sum();
        assert!(sent > 0, "{tag}: neither survivor recorded sent chunks");
    } else {
        assert_eq!(s3.snap.chunks_recv, 0, "{tag}: legacy run must not stream: {:?}", s3.snap);
    }
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    got
}

#[test]
fn streamed_catchup_over_bus() {
    streamed_catchup(TransportKind::Inproc, true, "bus-streamed");
}

/// The tentpole parity gate: run-shipping catch-up and the monolithic
/// blob install end in byte-identical served state.
#[test]
fn streamed_matches_legacy_install() {
    let streamed = streamed_catchup(TransportKind::Inproc, true, "parity-streamed");
    let legacy = streamed_catchup(TransportKind::Inproc, false, "parity-legacy");
    assert_eq!(streamed, legacy, "streamed and legacy catch-up served different state");
}

/// The same transfer over real sockets: chunks cross TCP framing and
/// reconnects instead of in-process mailboxes.
#[test]
fn streamed_catchup_over_tcp() {
    streamed_catchup(TransportKind::Tcp, true, "tcp-streamed");
}

/// DESIGN.md §9 meets §8: a node added via [`Cluster::add_node`]
/// joins as a learner whose entire state must arrive over the
/// run-shipping stream — the leader compacted its log long before the
/// learner existed, so there is no replay path.  Once chunks are
/// flowing, the original sender (the leader) is crashed.  The
/// re-elected leader must restart or resume the catch-up, and the
/// learner still ends up a promoted voter serving every preloaded key.
#[test]
fn added_learner_survives_sender_crash() {
    let dir = base("learner-sender-crash");
    let mut c = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.gc.threshold_bytes = 32 << 10;
    c.raft.snap_chunk_bytes = 2 << 10;
    c.raft.snap_window = 2;
    // A little wire latency stretches the transfer so the sender crash
    // usually lands mid-stream rather than after a sub-millisecond
    // sprint; nothing below *depends* on catching it mid-flight.
    c.net = NetConfig { latency_us: (200, 600), loss: 0.0, seed: 33 };
    c.read_consistency = ReadConsistency::Stale;
    let cluster = Cluster::start(c).unwrap();
    let key = |i: u32| format!("mem{i:03}").into_bytes();
    let val = |i: u32| vec![(i % 251) as u8; 1024];
    // Preload across two GC drains so the log prefix is gone: the
    // learner can only catch up via a streamed snapshot.
    for i in 0..75u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }
    cluster.drain_gc_all().unwrap();
    for i in 75..150u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }
    cluster.drain_gc_all().unwrap();

    let sender = cluster.shard_leader(0).unwrap();
    let joined = cluster.add_node(0).unwrap();
    assert_eq!(joined, 4, "first added node takes the next fresh id");
    assert_eq!(cluster.shard_members(0), vec![1, 2, 3, 4]);

    // Wait until the stream to the learner is demonstrably under way,
    // then crash the sender.  If the transfer already committed the
    // crash simply tests plain post-install catch-up — still valid.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = cluster.shard_status(joined, 0) {
            if s.snap.chunks_recv >= 1 {
                break;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "learner never started receiving snapshot chunks"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    cluster.crash(0, sender).unwrap();

    // The survivors re-elect and keep committing; the new leader owns
    // the learner's catch-up from here.
    for i in 150..180u32 {
        cluster.put(&key(i), &val(i)).unwrap();
    }

    // The learner must finish installing and be auto-promoted: its own
    // applied config eventually lists it as a voter (DESIGN.md §9).
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        if let Ok(s) = cluster.shard_status(joined, 0) {
            if s.voters.contains(&joined) {
                assert_eq!(s.voters, vec![1, 2, 3, 4], "promotion changed the wrong config");
                assert!(s.learners.is_empty(), "promoted learner still listed: {:?}", s.learners);
                break;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "added learner was never promoted to voter"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    cluster.wait_converged(Duration::from_secs(30)).unwrap();

    // Stale mode round-robins over live replicas, so three passes
    // provably reach the promoted newcomer for some keys.
    let keys: Vec<Vec<u8>> = (0..180u32).map(key).collect();
    for _ in 0..3 {
        let got = cluster.get_batch(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(val(i as u32).as_slice()), "key {i}");
        }
    }
    let s4 = cluster.shard_status(joined, 0).unwrap();
    assert!(s4.snap.chunks_recv > 0, "learner caught up without streaming: {:?}", s4.snap);
    assert!(s4.snap.streams_done >= 1, "no stream ran to commit: {:?}", s4.snap);
    cluster.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
