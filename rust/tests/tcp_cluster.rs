//! Multi-process-deployment integration test: three [`Server`]
//! instances — the exact objects `nezha serve` runs, one per process
//! in production — each hosting one node's replica of every shard,
//! with **all** raft traffic and **all** client traffic crossing real
//! TCP sockets on loopback.  The thin [`Client`] drives writes, point
//! reads, batched reads, scans, deletes and status; one server is
//! stopped and later restarted on the same data dir to prove the
//! remaining majority keeps serving and the returnee rejoins.

use nezha::coordinator::{Client, ClusterConfig, Server, ServerOpts, ShardRouter};
use nezha::engine::EngineKind;
use nezha::raft::NodeId;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::path::Path;
use std::time::Duration;

/// Reserve `len` consecutive free loopback ports by briefly binding
/// them all, and return the base.  The tiny race between releasing
/// and the servers re-binding is acceptable for a test.
fn alloc_port_block(len: u16) -> u16 {
    let mut base = 21000 + (std::process::id() % 10000) as u16;
    loop {
        let mut held = Vec::new();
        let mut ok = true;
        for off in 0..len {
            match TcpListener::bind(("127.0.0.1", base + off)) {
                Ok(l) => held.push(l),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            return base;
        }
        base = base.wrapping_add(len + 1).max(1024);
    }
}

fn server_opts(
    node: NodeId,
    peers: &BTreeMap<NodeId, SocketAddr>,
    dir: &Path,
    shards: u32,
) -> ServerOpts {
    let mut c = ClusterConfig::new(dir.join(format!("proc-{node}")), EngineKind::Nezha, 3);
    c.engine.memtable_bytes = 64 << 10;
    c.router = ShardRouter::hash(shards);
    ServerOpts { node, peers: peers.clone(), cluster: c, learner: false }
}

#[test]
fn three_servers_over_real_tcp_serve_and_survive_restart() {
    let shards = 2u32;
    // Per node: 1 client port + `shards` raft ports, contiguous.
    let block = 1 + shards as u16;
    let base_port = alloc_port_block(3 * block);
    let dir = std::env::temp_dir().join(format!("nezha-tcp-cluster-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let peers: BTreeMap<NodeId, SocketAddr> = (1..=3u64)
        .map(|n| {
            let port = base_port + (n as u16 - 1) * block;
            (n, SocketAddr::from(([127, 0, 0, 1], port)))
        })
        .collect();

    let s1 = Server::start(server_opts(1, &peers, &dir, shards)).unwrap();
    let s2 = Server::start(server_opts(2, &peers, &dir, shards)).unwrap();
    let s3 = Server::start(server_opts(3, &peers, &dir, shards)).unwrap();

    let mut client = Client::connect(peers.clone(), shards);
    // Writes route to each shard's leader (the client discovers it via
    // NotLeader redirects across the three processes).
    for i in 0..40u32 {
        client.put(format!("mp{i:03}").as_bytes(), format!("val{i}").as_bytes()).unwrap();
    }
    client.delete(b"mp007").unwrap();
    assert_eq!(client.get(b"mp025").unwrap(), Some(b"val25".to_vec()));
    assert_eq!(client.get(b"mp007").unwrap(), None);
    assert_eq!(client.get(b"absent").unwrap(), None);
    // Batched read in input order across shards.
    let keys: Vec<Vec<u8>> = (0..45u32).map(|i| format!("mp{i:03}").into_bytes()).collect();
    let got = client.get_batch(&keys).unwrap();
    for (i, v) in got.iter().enumerate() {
        let want = if i == 7 || i >= 40 { None } else { Some(format!("val{i}").into_bytes()) };
        assert_eq!(*v, want, "mp{i:03}");
    }
    // Cross-shard merged scan.
    let rows = client.scan(b"mp000", b"mp999", 1000).unwrap();
    assert_eq!(rows.len(), 39);
    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "merged scan out of order");
    // Every server answers status for every shard.
    for node in 1..=3u64 {
        let rows = client.status(node).unwrap();
        assert_eq!(rows.len(), shards as usize, "node {node} status rows");
    }
    // Raft frames really crossed sockets on every process.
    for (srv, name) in [(&s1, "s1"), (&s2, "s2"), (&s3, "s3")] {
        let w = srv.wire_stats();
        assert!(w.msgs > 0 && w.bytes > 0, "{name} moved no raft frames: {w:?}");
    }

    // Stop node 3's process-equivalent.  The remaining majority keeps
    // committing and serving (re-electing if node 3 led a shard).
    s3.shutdown().unwrap();
    for i in 40..60u32 {
        client.put(format!("mp{i:03}").as_bytes(), format!("val{i}").as_bytes()).unwrap();
    }
    assert_eq!(client.get(b"mp055").unwrap(), Some(b"val55".to_vec()));

    // Restart node 3 on its data dir: it rebinds the same ports,
    // rejoins both shard groups and answers status again.
    let s3 = Server::start(server_opts(3, &peers, &dir, shards)).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    loop {
        if let Ok(rows) = client.status(3) {
            if rows.len() == shards as usize {
                break;
            }
        }
        assert!(std::time::Instant::now() < deadline, "restarted node 3 never answered status");
        std::thread::sleep(Duration::from_millis(100));
    }
    // The cluster still answers the full history after the rejoin.
    assert_eq!(client.get(b"mp059").unwrap(), Some(b"val59".to_vec()));
    assert_eq!(client.get(b"mp007").unwrap(), None);

    s1.shutdown().unwrap();
    s2.shutdown().unwrap();
    s3.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
