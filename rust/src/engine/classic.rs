//! The "classic" Raft-on-LSM engines: Original, PASV, TiKV, LSM-Raft.
//!
//! All four re-persist the full value through the storage engine after
//! consensus (the redundancy Nezha removes); they differ in *which*
//! redundant writes they keep:
//!
//! * **Original** — LSM with WAL: value hits disk ≥3 times (raft log,
//!   WAL, SSTable flush; more via compaction).
//! * **PASV** [28] — drops the storage-engine WAL (passive data
//!   persistence): ≥2 value writes, recovery replays the raft log.
//! * **TiKV** [31] — Original plus per-batch apply-state metadata
//!   writes (the raft-cf bookkeeping real TiKV does), so slightly more
//!   write volume than Original.
//! * **LSM-Raft** [30] — leaders behave exactly like Original (the
//!   paper's point: "leaders still experience full redundant writes");
//!   followers skip WAL + individual applies and bulk-ingest sorted
//!   runs, modelling compacted-SSTable shipping.

use super::common::{decode_kv_snapshot, encode_kv_snapshot, lsm_options};
use super::{EngineKind, EngineOpts, EngineStats, KvEngine};
use crate::lsm::Db;
use crate::raft::rpc::{Command, LogEntry, LogIndex, Term};
use crate::raft::StateMachine;
use crate::vlog::VRef;
use anyhow::Result;

/// Follower-side ingest batch for LSM-Raft (entries, not bytes, to
/// stay deterministic across value sizes).
const LSMRAFT_INGEST_EVERY: usize = 256;

pub struct ClassicEngine {
    kind: EngineKind,
    opts: EngineOpts,
    db: Db,
    /// LSM-Raft follower: buffered applies awaiting bulk ingest.
    ingest_buf: Vec<(Vec<u8>, Vec<u8>)>,
    gets: u64,
    scans: u64,
}

impl ClassicEngine {
    pub fn open(kind: EngineKind, opts: EngineOpts) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)?;
        let wal = match kind {
            EngineKind::Pasv => false,
            EngineKind::LsmRaft if opts.follower => false,
            _ => true,
        };
        let db = Db::open(lsm_options(&opts.dir.join("db"), &opts, wal))?;
        Ok(Self { kind, opts, db, ingest_buf: Vec::new(), gets: 0, scans: 0 })
    }

    fn follower_fastpath(&self) -> bool {
        self.kind == EngineKind::LsmRaft && self.opts.follower
    }

    fn flush_ingest(&mut self) -> Result<()> {
        if self.ingest_buf.is_empty() {
            return Ok(());
        }
        // Model SSTable shipping: the follower receives an already
        // sorted, compacted run and links it in (single write).
        // Reverse before the stable sort so dedup keeps the *newest*
        // apply for each key.
        let mut batch = std::mem::take(&mut self.ingest_buf);
        batch.reverse();
        batch.sort_by(|a, b| a.0.cmp(&b.0));
        batch.dedup_by(|a, b| a.0 == b.0);
        self.db.ingest_sorted(&batch)?;
        Ok(())
    }
}

impl StateMachine for ClassicEngine {
    fn apply(&mut self, entry: &LogEntry, _vref: VRef) -> Result<()> {
        match &entry.cmd {
            Command::Put { key, value } => {
                if self.follower_fastpath() {
                    self.ingest_buf.push((key.clone(), value.clone()));
                    if self.ingest_buf.len() >= LSMRAFT_INGEST_EVERY {
                        self.flush_ingest()?;
                    }
                } else {
                    self.db.put(key, value)?; // WAL (+ flush + compaction)
                }
            }
            Command::Delete { key } => {
                if self.follower_fastpath() {
                    self.ingest_buf.retain(|(k, _)| k != key);
                }
                self.db.delete(key)?;
            }
            Command::Noop | Command::ConfChange(_) => {}
        }
        // TiKV writes apply-state metadata alongside each applied
        // entry (raft-cf bookkeeping).
        if self.kind == EngineKind::Tikv {
            let meta_key = b"\x00meta/apply_state".to_vec();
            self.db.put(&meta_key, &entry.index.to_le_bytes())?;
        }
        Ok(())
    }

    fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        self.flush_ingest()?;
        // Empty end = unbounded: keys above any sentinel still ship.
        let pairs = self.db.scan(&[], &[], usize::MAX)?;
        Ok(encode_kv_snapshot(&pairs))
    }

    fn install_snapshot(&mut self, data: &[u8], _li: LogIndex, _lt: Term) -> Result<()> {
        let pairs = decode_kv_snapshot(data)?;
        let dir = self.opts.dir.join("db");
        // Rebuild the LSM from scratch with the snapshot contents.
        Db::destroy(&dir)?;
        let wal = self.db.options().wal_enabled;
        self.db = Db::open(lsm_options(&dir, &self.opts, wal))?;
        self.db.ingest_sorted(&pairs)?;
        Ok(())
    }
}

impl KvEngine for ClassicEngine {
    fn kind(&self) -> EngineKind {
        self.kind
    }

    fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.gets += 1;
        if self.follower_fastpath() {
            if let Some((_, v)) = self.ingest_buf.iter().rev().find(|(k, _)| k == key) {
                return Ok(Some(v.clone()));
            }
        }
        self.db.get(key)
    }

    // No `multi_get` override: values are stored inline in the LSM, so
    // there is no reference resolution to batch — the trait default
    // (get per key) is exact, and the win for the classic engines is
    // the single coordinator channel crossing.

    fn scan(&mut self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        self.scans += 1;
        if self.follower_fastpath() {
            self.flush_ingest()?;
        }
        self.db.scan(start, end, limit)
    }

    fn sync(&mut self) -> Result<()> {
        self.db.sync_wal()
    }

    fn stats(&self) -> EngineStats {
        let s = self.db.stats().snapshot();
        EngineStats {
            wal_bytes: s.wal_bytes,
            flush_bytes: s.flush_bytes,
            compact_bytes: s.compact_bytes,
            gets: self.gets,
            scans: self.scans,
            log_syncs: s.log_syncs,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn opts(name: &str) -> EngineOpts {
        let base: PathBuf =
            std::env::temp_dir().join(format!("nezha-classic-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut o = EngineOpts::new(base.join("engine"), base.join("raft"));
        o.memtable_bytes = 64 << 10;
        o.level_base_bytes = 512 << 10;
        o
    }

    fn put(i: u64, k: &str, v: &[u8]) -> LogEntry {
        LogEntry { term: 1, index: i, cmd: Command::Put { key: k.into(), value: v.to_vec() } }
    }

    fn vref() -> VRef {
        VRef::new(0, 0)
    }

    #[test]
    fn original_applies_and_reads() {
        let mut e = ClassicEngine::open(EngineKind::Original, opts("orig")).unwrap();
        for i in 0..500u64 {
            e.apply(&put(i + 1, &format!("k{i:04}"), b"val"), vref()).unwrap();
        }
        assert_eq!(e.get(b"k0123").unwrap(), Some(b"val".to_vec()));
        assert_eq!(e.scan(b"k0000", b"k0010", 100).unwrap().len(), 10);
        // Value written through WAL — write amplification visible.
        assert!(e.stats().wal_bytes > 0);
    }

    #[test]
    fn pasv_skips_wal() {
        let mut e = ClassicEngine::open(EngineKind::Pasv, opts("pasv")).unwrap();
        for i in 0..100u64 {
            e.apply(&put(i + 1, &format!("k{i}"), &[9u8; 256]), vref()).unwrap();
        }
        assert_eq!(e.stats().wal_bytes, 0);
        assert_eq!(e.get(b"k42").unwrap(), Some(vec![9u8; 256]));
    }

    #[test]
    fn tikv_writes_more_than_original() {
        let mut o = ClassicEngine::open(EngineKind::Original, opts("wa-orig")).unwrap();
        let mut t = ClassicEngine::open(EngineKind::Tikv, opts("wa-tikv")).unwrap();
        for i in 0..200u64 {
            let e = put(i + 1, &format!("k{i}"), &[1u8; 128]);
            o.apply(&e, vref()).unwrap();
            t.apply(&e, vref()).unwrap();
        }
        assert!(t.stats().wal_bytes > o.stats().wal_bytes);
    }

    #[test]
    fn lsmraft_follower_ingests_without_wal() {
        let mut op = opts("lsmr");
        op.follower = true;
        let mut e = ClassicEngine::open(EngineKind::LsmRaft, op).unwrap();
        for i in 0..600u64 {
            e.apply(&put(i + 1, &format!("k{i:04}"), &[3u8; 64]), vref()).unwrap();
        }
        assert_eq!(e.stats().wal_bytes, 0);
        // Reads see both ingested and buffered entries.
        assert_eq!(e.get(b"k0001").unwrap(), Some(vec![3u8; 64]));
        assert_eq!(e.get(b"k0599").unwrap(), Some(vec![3u8; 64]));
        // Later write to the same key wins after ingest.
        e.apply(&put(601, "k0001", b"new"), vref()).unwrap();
        assert_eq!(e.get(b"k0001").unwrap(), Some(b"new".to_vec()));
    }

    #[test]
    fn lsmraft_leader_equals_original_path() {
        let mut e = ClassicEngine::open(EngineKind::LsmRaft, opts("lsml")).unwrap();
        for i in 0..100u64 {
            e.apply(&put(i + 1, &format!("k{i}"), &[1u8; 128]), vref()).unwrap();
        }
        assert!(e.stats().wal_bytes > 0, "leader keeps full redundancy");
    }

    #[test]
    fn snapshot_roundtrip_between_engines() {
        let mut a = ClassicEngine::open(EngineKind::Original, opts("snap-a")).unwrap();
        for i in 0..300u64 {
            a.apply(&put(i + 1, &format!("k{i:04}"), format!("v{i}").as_bytes()), vref()).unwrap();
        }
        let snap = a.snapshot_bytes().unwrap();
        let mut b = ClassicEngine::open(EngineKind::Original, opts("snap-b")).unwrap();
        b.install_snapshot(&snap, 300, 1).unwrap();
        assert_eq!(b.get(b"k0150").unwrap(), Some(b"v150".to_vec()));
        assert_eq!(b.scan(b"k", b"l", 1000).unwrap().len(), 300);
    }

    #[test]
    fn delete_masks_value() {
        let mut e = ClassicEngine::open(EngineKind::Original, opts("del")).unwrap();
        e.apply(&put(1, "a", b"1"), vref()).unwrap();
        e.apply(
            &LogEntry { term: 1, index: 2, cmd: Command::Delete { key: b"a".to_vec() } },
            vref(),
        )
        .unwrap();
        assert_eq!(e.get(b"a").unwrap(), None);
    }
}
