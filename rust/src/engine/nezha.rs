//! Nezha / Nezha-NoGC — the paper's system (§III).
//!
//! **Write path (Algorithm 1).**  The value was already persisted
//! exactly once when Raft appended the entry to the epoch ValueLog;
//! `apply` receives that [`VRef`] and stores the 12-byte reference in
//! the current LSM (`currentDB`).  Deletes store a reference to the
//! tombstone entry so lookups stop at the newest version instead of
//! falling through to older storage modules.
//!
//! **Read path (Algorithms 2 & 3).**  A chained lookup over the
//! storage modules of Table I — `currentDB` (New/Active Storage) →
//! `oldDB` (frozen Active Storage, During-GC only) → the leveled Final
//! Compacted Storage (hash-indexed sorted runs, consulted newest-first;
//! a retained tombstone in an upper run masks every older run).  The
//! paper issues the two lookups concurrently and prefers the new one;
//! on this single-socket testbed a prioritized chain is the same
//! decision procedure (documented in DESIGN.md §2).
//!
//! **GC lifecycle (§III-C/§III-D).**  `begin_gc` freezes `currentDB`
//! into `oldDB`, opens a fresh LSM, persists the [`GcState`] flag and
//! spawns the compaction thread, which flushes the frozen epochs into
//! a new L0 run and performs budget-triggered level merges; `poll_gc`
//! commits the new [`LevelManifest`] (the atomic visibility point) and
//! reports the snapshot point back to the replica.  On crash, `open`
//! resumes an interrupted cycle — flush and merges are deterministic,
//! so each partial output continues from its last sorted key (§III-E).

use super::common::{decode_kv_snapshot, encode_kv_snapshot, lsm_options};
use super::{EngineKind, EngineOpts, EngineStats, KvEngine};
use crate::fault;
use crate::gc::{
    self,
    levels::{self, LevelManifest, LeveledStorage, PartitionGroup},
    sorted_path, EpochSource, FinalStorage, FrozenEpoch, GcInputs, GcOutput, GcPhase, GcState,
    GcStep, MergeJob,
};
use crate::lsm::Db;
use crate::raft::rpc::{Command, LogEntry, LogIndex, Term};
use crate::raft::{PlanItem, PlanSource, SnapManifest, SnapPlan, StateMachine};
use crate::util::{key_before_end, Decoder, Encoder};
use crate::vlog::{EpochReaders, SortedVLogWriter, VRef};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

/// Flag file marking an in-progress streamed-snapshot staging area
/// (DESIGN.md §8).  Holds the CRC of the transfer's encoded manifest so
/// a restart can tell "resume this transfer" from "stale staging of a
/// different transfer" — the latter is wiped at `snap_sink_begin`.
const SNAP_STATE: &str = "SNAP_STATE";

/// Name of the residual-tail plan item: the not-yet-compacted
/// `currentDB`/`oldDB` state, shipped as one small in-memory blob while
/// the sealed runs ship as files.
const RESIDUAL_ITEM: &str = "residual.tail";

/// Lower the GC thread's scheduling priority so background compaction
/// stays off the critical write path even on low-core-count hosts
/// (the paper's 12-core nodes absorb this for free — DESIGN.md §2;
/// §IV-G: "GC operations execute asynchronously ... effectively
/// decoupling GC overhead from the critical write path").
fn deprioritize_gc_thread() {
    // SAFETY: nice(2) on the calling thread only; failure is harmless.
    unsafe {
        let _ = libc::nice(10);
    }
}

pub struct NezhaEngine {
    opts: EngineOpts,
    gc_enabled: bool,
    readers: Arc<EpochReaders>,
    /// `currentDB`: key → VRef (Active / New Storage index).
    cur_db: Db,
    cur_db_seq: u64,
    /// `oldDB`: frozen Active Storage index (During-GC only).
    old_db: Option<(Db, u64)>,
    /// Committed description of the leveled Final Compacted Storage.
    manifest: LevelManifest,
    /// Open run handles for `manifest.levels` (Post-GC reads).
    levels: LeveledStorage,
    gc_rx: Option<mpsc::Receiver<Result<GcOutput>>>,
    gc_join: Option<std::thread::JoinHandle<()>>,
    /// Newest epoch frozen by the running cycle (readahead
    /// invalidation point).
    gc_frozen_epoch: Option<u32>,
    /// Snapshot point of the in-flight cycle.  Crash recovery replays
    /// applies from the previous snapshot; entries at or below this
    /// point belong to the *frozen* layout (oldDB), not currentDB —
    /// otherwise their re-applied VRefs dangle once the cycle
    /// completes and the frozen epochs are deleted.
    gc_floor: Option<u64>,
    /// Completed-but-unreported outputs (flush cycles and merge jobs,
    /// delivered in completion order via `poll_gc`).
    pending: VecDeque<GcOutput>,
    /// In-flight decoupled merge job: the persisted plan plus its
    /// worker thread.  Mutually exclusive with a flush cycle (both
    /// allocate generations from `manifest.next_gen` and commit the
    /// manifest).
    merge_rx: Option<mpsc::Receiver<Result<Vec<(u64, u64, u64)>>>>,
    merge_join: Option<std::thread::JoinHandle<()>>,
    merge_job: Option<MergeJob>,
    merge_t0: Option<std::time::Instant>,
    /// The committed stack changed since the planner last ran; gates
    /// `maybe_start_merge_job` so idle pumps don't stat run files.
    merge_plan_dirty: bool,
    gc_bytes: u64,
    gc_cycles: u64,
    merge_jobs_done: u64,
    merge_queue_hw: u64,
    /// Apply-path microseconds spent while a flush held the engine in
    /// `GcPhase::During` (fig10's stall column).
    gc_stall_us: u64,
    gets: u64,
    scans: u64,
    /// Sender side of streamed snapshots: plan id → the run
    /// generations that plan pinned (DESIGN.md §8).  A pinned run's
    /// file must outlive the transfer even if GC supersedes it.
    snap_pins: HashMap<u64, HashSet<u64>>,
    snap_plan_seq: u64,
    /// Generations superseded by GC while pinned by a transfer;
    /// deleted once the last pinning plan ends.
    snap_deferred: HashSet<u64>,
    /// Receiver side: staging cursor of the in-flight streamed
    /// install (`None` between transfers — the staged *bytes* persist
    /// on disk as the resume point).
    snap_sink: Option<StageCursor>,
}

/// Receiver-side staging cursor for one streamed snapshot transfer.
struct StageCursor {
    manifest: SnapManifest,
    /// Global byte offset staged so far (== the next offset wanted).
    staged: u64,
    /// Open handle for the item currently being written.
    cur: Option<(usize, std::fs::File)>,
}

/// Residual-tail codec: latest version per key with tombstones
/// *retained* — a shipped tombstone in the residual must keep masking
/// the shipped lower runs, or deleted keys would resurrect on the
/// receiver (unlike `encode_kv_snapshot`, which is a live-pairs-only
/// full image).
fn encode_residual(entries: &[(Vec<u8>, Option<Vec<u8>>)]) -> Vec<u8> {
    let mut e = Encoder::new();
    e.varint(entries.len() as u64);
    for (k, v) in entries {
        match v {
            Some(v) => {
                e.u8(0);
                e.len_bytes(k);
                e.len_bytes(v);
            }
            None => {
                e.u8(1);
                e.len_bytes(k);
            }
        }
    }
    e.into_vec()
}

fn decode_residual(buf: &[u8]) -> Result<Vec<(Vec<u8>, Option<Vec<u8>>)>> {
    let mut d = Decoder::new(buf);
    let n = d.varint()? as usize;
    anyhow::ensure!(n <= buf.len(), "residual: entry count {n} exceeds payload");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let tag = d.u8()?;
        let k = d.len_bytes()?.to_vec();
        let v = match tag {
            0 => Some(d.len_bytes()?.to_vec()),
            1 => None,
            t => anyhow::bail!("residual: bad entry tag {t}"),
        };
        out.push((k, v));
    }
    anyhow::ensure!(d.remaining() == 0, "residual: trailing bytes");
    Ok(out)
}

/// The transfer's `shape` blob: the sender's committed level stack,
/// per-run tombstone counts, and partition groups — everything the
/// receiver needs to reassemble the shipped runs into an equivalent
/// `LEVELS` manifest (generation numbers are remapped at install).
fn encode_shape(m: &LevelManifest) -> Vec<u8> {
    let mut e = Encoder::new();
    e.u64(m.next_gen);
    levels::encode_levels(&mut e, &m.levels);
    levels::encode_tombstone_counts(&mut e, &m.run_tombstones);
    levels::encode_partitions(&mut e, &m.partitions);
    e.into_vec()
}

fn decode_shape(buf: &[u8]) -> Result<LevelManifest> {
    let mut d = Decoder::new(buf);
    let next_gen = d.u64()?;
    let lv = levels::decode_levels(&mut d)?;
    let rt = levels::decode_tombstone_counts(&mut d)?;
    let pt = levels::decode_partitions(&mut d)?;
    Ok(LevelManifest { levels: lv, next_gen, run_tombstones: rt, partitions: pt })
}

/// Stream a file computing `(length, crc32)` without materializing it.
fn crc_file(path: &Path) -> Result<(u64, u32)> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("snap: open {}", path.display()))?;
    let mut h = crc32fast::Hasher::new();
    let mut buf = vec![0u8; 1 << 20];
    let mut len = 0u64;
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
        len += n as u64;
    }
    Ok((len, h.finalize()))
}

/// Parse a shipped run item name (`sorted-NNNNNN.vlog`) back to its
/// sender-side generation number.
fn run_item_gen(name: &str) -> Option<u64> {
    name.strip_prefix("sorted-")?.strip_suffix(".vlog")?.parse().ok()
}

fn db_path(dir: &std::path::Path, seq: u64) -> PathBuf {
    dir.join(format!("db-{seq:06}"))
}

/// Outcome of resolving a key in one storage module.
enum Hit {
    /// Found a reference (may be a tombstone once resolved).
    Ref(VRef),
    /// Not in this module; try the next.
    Miss,
}

impl NezhaEngine {
    pub fn open(opts: EngineOpts, gc_enabled: bool) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)?;
        let readers = Arc::new(EpochReaders::new(&opts.raft_dir));

        // Discover LSM generations.
        let mut seqs: Vec<u64> = Vec::new();
        for e in std::fs::read_dir(&opts.dir)? {
            let name = e?.file_name();
            let name = name.to_string_lossy();
            if let Some(n) = name.strip_prefix("db-") {
                if let Ok(s) = n.parse::<u64>() {
                    seqs.push(s);
                }
            }
        }
        seqs.sort_unstable();
        let mut state = GcState::load(&opts.dir)?;

        // Level manifest: the committed run stack.  A directory from
        // the pre-leveled layout has runs but no manifest — adopt the
        // newest complete generation as the bottom level.
        let had_manifest = LevelManifest::load(&opts.dir)?;
        let manifest = match &had_manifest {
            Some(m) => m.clone(),
            None => match FinalStorage::latest_gen(&opts.dir)? {
                // Adopted legacy run: tombstone count unknown (treated
                // as tombstone-carrying until a rewrite recounts it).
                Some(g) => LevelManifest {
                    levels: vec![vec![g]],
                    next_gen: g + 1,
                    run_tombstones: Default::default(),
                    partitions: Vec::new(),
                },
                None => LevelManifest::default(),
            },
        };

        // A cycle that committed its manifest but crashed before
        // clearing the flag is already durable: don't re-run it.
        if let Some(st) = &state {
            if st.running && manifest.next_gen > st.out_gen {
                GcState::clear(&opts.dir)?;
                state = None;
            }
        }

        // Decoupled merge job in flight at crash time?  Validate it
        // before the orphan sweep so its partial outputs survive.
        let mut merge_job = MergeJob::load(&opts.dir)?;
        if let Some(job) = &merge_job {
            let committed: std::collections::HashSet<u64> =
                manifest.all_gens().into_iter().collect();
            if job.out_gens.iter().all(|g| committed.contains(g)) {
                // Crash between manifest commit and flag clear: the
                // job is already durable, don't re-run it.
                MergeJob::clear(&opts.dir)?;
                merge_job = None;
            } else if job.srcs.iter().any(|g| !sorted_path(&opts.dir, *g).exists())
                || state.as_ref().is_some_and(|s| s.running)
            {
                // Unexecutable (a source is gone) or inconsistent with
                // a running flush cycle — the two are mutually
                // exclusive in a healthy log.  Drop the job and its
                // partial outputs; the planner re-derives the same
                // merge from the committed stack once it settles.
                for g in &job.out_gens {
                    if !committed.contains(g) {
                        FinalStorage::remove_gen(&opts.dir, *g);
                    }
                }
                MergeJob::clear(&opts.dir)?;
                merge_job = None;
            }
        }

        // Garbage-collect run files outside the manifest (crash window
        // between manifest commit and file deletion).  A running flush
        // cycle's single output and a kept merge job's outputs are
        // in-flight — the resumes below finish them.  Stray higher
        // generations (pre-decoupling partial merges) are swept: a
        // flush-only resume would collide with them.  Skip entirely
        // for just-adopted legacy layouts (no manifest on disk yet).
        if had_manifest.is_some() {
            let mut keep: std::collections::HashSet<u64> =
                manifest.all_gens().into_iter().collect();
            if let Some(s) = state.as_ref().filter(|s| s.running) {
                keep.insert(s.out_gen);
            }
            if let Some(job) = &merge_job {
                keep.extend(job.out_gens.iter().copied());
            }
            for g in FinalStorage::list_all_gens(&opts.dir)? {
                if !keep.contains(&g) {
                    FinalStorage::remove_gen(&opts.dir, g);
                }
            }
        }

        let running = state.as_ref().is_some_and(|s| s.running);
        let (cur_seq, old_db) = if running && seqs.len() >= 2 {
            let old_seq = seqs[seqs.len() - 2];
            (
                *seqs.last().unwrap(),
                Some((Db::open(lsm_options(&db_path(&opts.dir, old_seq), &opts, true))?, old_seq)),
            )
        } else if running {
            // Crashed between GcState::save and the LSM rotation:
            // complete the rotation now, demoting the existing LSM to
            // oldDB (it holds exactly the pre-freeze references).
            let old_seq = *seqs.last().unwrap_or(&0);
            (
                old_seq + 1,
                Some((Db::open(lsm_options(&db_path(&opts.dir, old_seq), &opts, true))?, old_seq)),
            )
        } else {
            (*seqs.last().unwrap_or(&0), None)
        };
        let cur_db = Db::open(lsm_options(&db_path(&opts.dir, cur_seq), &opts, true))?;
        // LSM dirs older than the ones in use are leftovers from a
        // crash between manifest commit and cleanup.
        let keep: [Option<u64>; 2] = [Some(cur_seq), old_db.as_ref().map(|(_, s)| *s)];
        let keep_dbs: std::collections::HashSet<u64> = keep
            .into_iter()
            .flatten()
            .collect();
        for &s in &seqs {
            if !keep_dbs.contains(&s) {
                let _ = Db::destroy(&db_path(&opts.dir, s));
            }
        }

        let levels =
            LeveledStorage::open_partitioned(&opts.dir, &manifest.levels, &manifest.partitions)?;
        if had_manifest.is_none() && !manifest.is_empty() {
            // Persist the legacy adoption so the next open is uniform.
            manifest.save(&opts.dir)?;
        }

        let mut eng = Self {
            gc_enabled,
            readers,
            cur_db,
            cur_db_seq: cur_seq,
            old_db,
            manifest,
            levels,
            gc_rx: None,
            gc_join: None,
            gc_frozen_epoch: None,
            gc_floor: None,
            pending: VecDeque::new(),
            merge_rx: None,
            merge_join: None,
            merge_job: None,
            merge_t0: None,
            // An adopted or freshly-loaded stack may already be over
            // budget: let the first pump plan.
            merge_plan_dirty: true,
            gc_bytes: 0,
            gc_cycles: 0,
            merge_jobs_done: 0,
            merge_queue_hw: 0,
            gc_stall_us: 0,
            gets: 0,
            scans: 0,
            snap_pins: HashMap::new(),
            snap_plan_seq: 0,
            snap_deferred: HashSet::new(),
            snap_sink: None,
            opts,
        };

        // Interrupted cycle? Resume it *in the background* (paper
        // §III-E: recovery "only requires an additional step of
        // reading the interrupt point ... to complete the remaining GC
        // process" — the node serves requests in the During-GC mode
        // meanwhile).  Flush and merges are deterministic given the
        // recorded stack, so partial outputs continue from their last
        // sorted key.
        if let Some(mut st) = state {
            if st.running {
                if st.stack != eng.manifest.levels {
                    // Pre-leveled in-flight cycle: its partial output
                    // interleaved previous-generation data under the
                    // old full-merge semantics, which a leveled flush
                    // cannot resume.  Discard the partial output and
                    // redo the cycle against the adopted legacy stack
                    // (all inputs — frozen epochs + old generation —
                    // are still on disk until the cycle commits).
                    // Persist the corrected flag file immediately: a
                    // second crash must resume with THIS stack, or
                    // finish_cycle would delete the adopted bottom run
                    // that the empty-stack replay never merged in.
                    FinalStorage::remove_gen(&eng.opts.dir, st.out_gen);
                    st.stack = eng.manifest.levels.clone();
                    st.save(&eng.opts.dir)?;
                }
                let inputs = GcInputs {
                    // Resume reads each epoch from byte 0 (skip
                    // offsets are a volatile optimization; the flush
                    // filters by index, so the output is identical).
                    frozen: (st.min_epoch..=st.frozen_epoch)
                        .map(|e| EpochSource {
                            epoch: e,
                            path: crate::raft::log::epoch_path(&eng.opts.raft_dir, e),
                            skip_offset: 0,
                        })
                        .filter(|s| s.path.exists())
                        .collect(),
                    dir: eng.opts.dir.clone(),
                    out_gen: st.out_gen,
                    stack: st.stack.clone(),
                    run_tombstones: st.run_tombstones.clone(),
                    min_index: st.min_index,
                    last_index: st.last_index,
                    last_term: st.last_term,
                    level0_bytes: eng.opts.gc_level0_bytes,
                    fanout: eng.opts.gc_fanout,
                    partitions: st.partitions.clone(),
                    partition_bytes: eng.opts.gc_partition_bytes,
                    workers: eng.opts.gc_workers,
                    resume: true,
                    backend: Arc::clone(&eng.opts.index_backend),
                };
                let (tx, rx) = mpsc::channel();
                let join = std::thread::Builder::new()
                    .name(format!("nezha-gc-resume-{}", st.out_gen))
                    .spawn(move || {
                        deprioritize_gc_thread();
                        let _ = tx.send(gc::run_flush(&inputs).context("gc resume"));
                    })?;
                eng.gc_rx = Some(rx);
                eng.gc_join = Some(join);
                eng.gc_frozen_epoch = Some(st.frozen_epoch);
                eng.gc_floor = Some(st.last_index);
            }
        }
        // Resume an interrupted merge job with its PERSISTED plan —
        // sources, bounds and output gens are the crash-time ones even
        // if the partitioning knobs changed across the restart, so
        // every partition continues its own partial output and the
        // committed stack comes out byte-identical.
        if let Some(job) = merge_job {
            eng.spawn_merge(job, true)?;
        }
        Ok(eng)
    }

    /// Chained module lookup (Algorithm 2's decision procedure).
    fn lookup_ref(db: &Db, key: &[u8]) -> Result<Hit> {
        match db.get(key)? {
            Some(bytes) => Ok(Hit::Ref(VRef::decode(&bytes)?)),
            None => Ok(Hit::Miss),
        }
    }

    fn resolve(&self, vref: VRef) -> Result<Option<Vec<u8>>> {
        Ok(self.readers.read(vref)?.value)
    }

    fn stage_dir(&self) -> PathBuf {
        self.opts.dir.join("snap-stage")
    }

    /// Delete a superseded run — unless a streamed transfer has it
    /// pinned, in which case deletion is deferred to
    /// `snap_stream_end` (DESIGN.md §8: shipped files are immutable
    /// for the life of the plan).
    fn remove_or_defer(&mut self, g: u64) {
        if self.snap_pins.values().any(|p| p.contains(&g)) {
            self.snap_deferred.insert(g);
        } else {
            FinalStorage::remove_gen(&self.opts.dir, g);
        }
    }

    /// Commit a completed flush cycle.  This is the cycle's whole
    /// critical path now: as soon as the manifest lands the epochs
    /// reclaim and the put path unblocks — over-budget level merges
    /// are planned afterwards as decoupled background jobs.
    fn finish_cycle(&mut self, out: GcOutput) -> Result<()> {
        let old_gens = self.manifest.all_gens();
        // Open the new stack before committing, reusing the handles of
        // runs that survived unchanged.  open_reusing touches
        // self.levels only once every new run opened successfully, so
        // a failure here leaves the committed stack serving reads.
        let new_levels = LeveledStorage::open_reusing(
            &self.opts.dir,
            &out.levels,
            &out.partitions,
            &mut self.levels,
        )?;
        self.levels = new_levels;
        self.manifest.levels = out.levels.clone();
        self.manifest.partitions = out.partitions.clone();
        let max_written = out.written_gens.iter().copied().max().unwrap_or(0);
        self.manifest.next_gen = self.manifest.next_gen.max(max_written + 1);
        // Tombstone bookkeeping: adopt the counts of every run this
        // cycle wrote, drop counts of runs leaving the stack.
        let live: std::collections::HashSet<u64> = self.manifest.all_gens().into_iter().collect();
        for &(g, t) in &out.run_tombstones {
            self.manifest.run_tombstones.insert(g, t);
        }
        self.manifest.run_tombstones.retain(|g, _| live.contains(g));
        self.manifest.retain_live_partitions();
        // Commit point: the manifest makes the new runs visible.
        self.manifest.save(&self.opts.dir)?;
        GcState::clear(&self.opts.dir)?;
        // Delete runs superseded by this cycle (old stack members and
        // intermediate outputs that did not survive into the stack) —
        // deferred for runs a streamed transfer still ships.
        let dead: Vec<u64> = old_gens
            .iter()
            .chain(out.written_gens.iter())
            .filter(|g| !live.contains(g))
            .copied()
            .collect();
        for g in dead {
            self.remove_or_defer(g);
        }
        if let Some((db, seq)) = self.old_db.take() {
            let dir = db_path(&self.opts.dir, seq);
            drop(db);
            Db::destroy(&dir)?;
        }
        // The compacted epochs' files may be dropped by the replica:
        // release the reader handles + readahead segments (retained
        // epoch files are simply reopened on demand).
        if let Some(frozen) = self.gc_frozen_epoch.take() {
            self.readers.invalidate_below(frozen + 1);
        }
        self.gc_floor = None;
        self.gc_bytes += out.bytes_written;
        self.gc_cycles += 1;
        self.merge_plan_dirty = true;
        self.pending.push_back(out);
        Ok(())
    }

    fn try_finish(&mut self, blocking: bool) -> Result<()> {
        let Some(rx) = &self.gc_rx else { return Ok(()) };
        let res = if blocking {
            match rx.recv() {
                Ok(r) => r,
                Err(_) => return Ok(()),
            }
        } else {
            match rx.try_recv() {
                Ok(r) => r,
                Err(mpsc::TryRecvError::Empty) => return Ok(()),
                Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        };
        self.gc_rx = None;
        if let Some(j) = self.gc_join.take() {
            let _ = j.join();
        }
        match res {
            Ok(out) => self.finish_cycle(out),
            Err(e) => {
                // A failed cycle (e.g. snapshot install raced the
                // compaction input away) must not take the node down:
                // the frozen modules keep serving reads (During-mode
                // layering stays correct) and the persisted GcState
                // retries the cycle on the next restart.
                eprintln!("nezha: gc cycle failed, staying in During mode: {e:#}");
                Ok(())
            }
        }
    }

    /// Launch a merge job's worker thread (`resume = true` when it
    /// adopts crash-time partial outputs).
    fn spawn_merge(&mut self, job: MergeJob, resume: bool) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        let dir = self.opts.dir.clone();
        let backend = Arc::clone(&self.opts.index_backend);
        let workers = self.opts.gc_workers;
        let j = job.clone();
        let join = std::thread::Builder::new()
            .name(format!("nezha-merge-{}", job.out_gens[0]))
            .spawn(move || {
                deprioritize_gc_thread();
                let _ = tx.send(j.execute(&dir, resume, &backend, workers));
            })?;
        self.merge_rx = Some(rx);
        self.merge_join = Some(join);
        self.merge_job = Some(job);
        self.merge_t0 = Some(std::time::Instant::now());
        self.merge_queue_hw = self.merge_queue_hw.max(1);
        Ok(())
    }

    /// Plan the next background maintenance step for the committed
    /// stack: trivial moves commit inline (metadata only), the first
    /// rewrite merge becomes an independently scheduled job.  No-op
    /// while a flush cycle or another merge is in flight — both
    /// allocate generations from `manifest.next_gen`.
    fn maybe_start_merge_job(&mut self) -> Result<()> {
        // `old_db` also covers a FAILED flush cycle (During mode with
        // no thread): its persisted GcState still owns `next_gen` for
        // the restart retry, so planning would double-allocate gens.
        if !self.gc_enabled
            || !self.merge_plan_dirty
            || self.merge_rx.is_some()
            || self.gc_rx.is_some()
            || self.old_db.is_some()
        {
            return Ok(());
        }
        loop {
            let step = gc::plan_step(
                &self.opts.dir,
                &self.manifest.levels,
                &self.manifest.partitions,
                &self.manifest.run_tombstones,
                self.opts.gc_level0_bytes,
                self.opts.gc_fanout,
                self.opts.gc_partition_bytes,
                self.manifest.next_gen,
            )?;
            match step {
                GcStep::Done => {
                    self.merge_plan_dirty = false;
                    return Ok(());
                }
                GcStep::Trivial { stack_after } => {
                    self.levels = LeveledStorage::open_reusing(
                        &self.opts.dir,
                        &stack_after,
                        &self.manifest.partitions,
                        &mut self.levels,
                    )?;
                    self.manifest.levels = stack_after;
                    self.manifest.save(&self.opts.dir)?;
                }
                GcStep::Merge(job) => {
                    // Persist the plan BEFORE the first output byte:
                    // crash recovery resumes the identical job.
                    job.save(&self.opts.dir)?;
                    return self.spawn_merge(*job, false);
                }
            }
        }
    }

    /// Commit a completed merge job: its own manifest commit point,
    /// independent of any flush cycle.
    fn finish_merge_job(&mut self, job: MergeJob, parts: Vec<(u64, u64, u64)>) -> Result<()> {
        let old_gens = self.manifest.all_gens();
        let new_levels = LeveledStorage::open_reusing(
            &self.opts.dir,
            &job.stack_after,
            &job.parts_after,
            &mut self.levels,
        )?;
        self.levels = new_levels;
        self.manifest.levels = job.stack_after.clone();
        self.manifest.partitions = job.parts_after.clone();
        let max_out = job.out_gens.iter().copied().max().expect("merge outputs");
        self.manifest.next_gen = self.manifest.next_gen.max(max_out + 1);
        let live: std::collections::HashSet<u64> = self.manifest.all_gens().into_iter().collect();
        for (&g, &(_, _, t)) in job.out_gens.iter().zip(parts.iter()) {
            self.manifest.run_tombstones.insert(g, t);
        }
        self.manifest.run_tombstones.retain(|g, _| live.contains(g));
        self.manifest.retain_live_partitions();
        self.manifest.save(&self.opts.dir)?;
        MergeJob::clear(&self.opts.dir)?;
        let dead: Vec<u64> = old_gens
            .iter()
            .chain(job.out_gens.iter())
            .filter(|g| !live.contains(g))
            .copied()
            .collect();
        for g in dead {
            self.remove_or_defer(g);
        }
        let merge_bytes: u64 = parts.iter().map(|p| p.0).sum();
        self.gc_bytes += merge_bytes;
        self.merge_jobs_done += 1;
        // The next level may now be over budget: cascades continue as
        // successive independent jobs.
        self.merge_plan_dirty = true;
        self.pending.push_back(GcOutput {
            gen: job.out_gens[0],
            entries: parts.iter().map(|p| p.1).sum(),
            flush_bytes: 0,
            merge_bytes,
            bytes_written: merge_bytes,
            merges: 1,
            levels: self.manifest.levels.clone(),
            written_gens: job.out_gens.clone(),
            run_tombstones: job.out_gens.iter().zip(parts.iter()).map(|(&g, p)| (g, p.2)).collect(),
            skip_offsets: Vec::new(),
            last_index: job.last_index,
            last_term: job.last_term,
            wall_ms: self.merge_t0.take().map_or(0, |t| t.elapsed().as_millis() as u64),
            index_backend: self.opts.index_backend.name(),
            partitions: self.manifest.partitions.clone(),
            parts: job.out_gens.len() as u64,
            is_merge_job: true,
        });
        Ok(())
    }

    fn try_finish_merge(&mut self, blocking: bool) -> Result<()> {
        let Some(rx) = &self.merge_rx else { return Ok(()) };
        let res = if blocking {
            match rx.recv() {
                Ok(r) => r,
                Err(_) => return Ok(()),
            }
        } else {
            match rx.try_recv() {
                Ok(r) => r,
                Err(mpsc::TryRecvError::Empty) => return Ok(()),
                Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        };
        self.merge_rx = None;
        if let Some(j) = self.merge_join.take() {
            let _ = j.join();
        }
        let job = self.merge_job.take().expect("merge job recorded");
        match res {
            Ok(parts) => self.finish_merge_job(job, parts),
            Err(e) => {
                // A failed merge (e.g. an injected disk fault) leaves
                // the committed stack fully intact — drop the job and
                // its partial outputs; the planner re-derives the
                // SAME deterministic plan after the next flush commit
                // (or restart), so retry costs nothing in correctness.
                eprintln!("nezha: merge job failed, stack unchanged: {e:#}");
                self.merge_t0 = None;
                let committed: std::collections::HashSet<u64> =
                    self.manifest.all_gens().into_iter().collect();
                let dead: Vec<u64> =
                    job.out_gens.iter().filter(|g| !committed.contains(g)).copied().collect();
                for g in dead {
                    self.remove_or_defer(g);
                }
                MergeJob::clear(&self.opts.dir)?;
                self.merge_plan_dirty = false;
                Ok(())
            }
        }
    }
}

impl StateMachine for NezhaEngine {
    /// Algorithm 1, line 7: `ApplyStateMachine(currentDB, k, offset)` —
    /// only the lightweight reference is stored.  During crash
    /// recovery Raft replays applies from the previous snapshot;
    /// entries at or below the in-flight cycle's snapshot point are
    /// routed to the frozen `oldDB` (their pre-crash home) so
    /// `currentDB` never accumulates references that dangle once the
    /// cycle completes and the frozen epochs are deleted.
    fn apply(&mut self, entry: &LogEntry, vref: VRef) -> Result<()> {
        // Stall accounting: time spent applying while a flush holds
        // the engine in During mode (fig10's stall column — decoupled
        // merges deliberately do NOT count, they no longer gate puts).
        let t0 = self.old_db.is_some().then(std::time::Instant::now);
        match &entry.cmd {
            Command::Put { key, .. } | Command::Delete { key } => {
                match (&mut self.old_db, self.gc_floor) {
                    (Some((db, _)), Some(floor)) if entry.index <= floor => {
                        db.put(key, &vref.encode())?;
                    }
                    _ => self.cur_db.put(key, &vref.encode())?,
                }
            }
            Command::Noop | Command::ConfChange(_) => {}
        }
        if let Some(t) = t0 {
            self.gc_stall_us += t.elapsed().as_micros() as u64;
        }
        Ok(())
    }

    fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        // Unbounded full-range scan: an empty end bound means +∞, so
        // keys sorting above any sentinel still reach the snapshot.
        let pairs = self.scan(&[], &[], usize::MAX)?;
        Ok(encode_kv_snapshot(&pairs))
    }

    /// Conflict truncation rewrote epoch files `>= live_epoch` in
    /// place: drop reader handles + readahead segments for them so no
    /// pre-truncation bytes can be served for post-truncation entries.
    fn on_log_truncated(&mut self, live_epoch: u32) {
        self.readers.invalidate_from(live_epoch);
    }

    fn install_snapshot(&mut self, data: &[u8], li: LogIndex, lt: Term) -> Result<()> {
        // Abort any cycle or merge job in flight; the snapshot
        // supersedes them.  (A successful in-flight cycle commits
        // below us first — harmless, the snapshot replaces the whole
        // stack either way.)  The merge thread must settle BEFORE the
        // generation sweep below, or it would recreate deleted files.
        self.try_finish(true)?;
        self.try_finish_merge(true)?;
        MergeJob::clear(&self.opts.dir)?;
        // A cycle that completed just now must not be reported to the
        // replica: its snapshot point predates `li` and would regress
        // the Raft snapshot mark.
        self.pending.clear();
        // Every old VRef is about to become invalid and the raft log
        // resets its epochs: drop all cached ValueLog state.
        self.readers.invalidate_from(0);
        self.gc_frozen_epoch = None;
        self.gc_floor = None;
        let pairs = decode_kv_snapshot(data)?;
        // Materialize the snapshot as a fresh bottom-level run — a
        // complete, tombstone-free image, so the single run IS the
        // snapshot (§III-E) and the new stack has exactly one level.
        let gen = self.manifest.next_gen;
        let mut w = SortedVLogWriter::create(&sorted_path(&self.opts.dir, gen), lt, li)?;
        for (k, v) in &pairs {
            w.add(&crate::vlog::Entry::put(lt, li, k.clone(), v.clone()))?;
        }
        gc::seal_run(&self.opts.dir, gen, w, &self.opts.index_backend)?;
        self.manifest.levels = vec![vec![gen]];
        self.manifest.next_gen = gen + 1;
        // The snapshot run is a complete, tombstone-free image.
        self.manifest.run_tombstones = std::iter::once((gen, 0)).collect();
        self.manifest.partitions = Vec::new();
        self.manifest.save(&self.opts.dir)?;
        // The aborted cycle is superseded even if it failed: without
        // this, a stale `running` flag would make the next restart
        // resume a GC that writes into (or past) the snapshot's
        // generation range.
        GcState::clear(&self.opts.dir)?;
        self.levels = LeveledStorage::open(&self.opts.dir, &self.manifest.levels)?;
        self.merge_plan_dirty = true;
        // Remove every other on-disk generation — the old stack AND any
        // partial output a failed cycle left behind.  Generation
        // numbers are reused after this point, so a stale partial file
        // would otherwise be adopted by a later cycle's resume.
        let dead: Vec<u64> = FinalStorage::list_all_gens(&self.opts.dir)?
            .into_iter()
            .filter(|g| *g != gen)
            .collect();
        for g in dead {
            self.remove_or_defer(g);
        }
        // A monolithic install supersedes any half-staged streamed
        // transfer: its bytes describe pre-snapshot state.
        self.snap_sink = None;
        let _ = std::fs::remove_dir_all(self.stage_dir());
        let _ = std::fs::remove_file(self.opts.dir.join(SNAP_STATE));
        // Fresh currentDB (all old references are now invalid).
        let old_seq = self.cur_db_seq;
        self.cur_db_seq += 1;
        self.cur_db =
            Db::open(lsm_options(&db_path(&self.opts.dir, self.cur_db_seq), &self.opts, true))?;
        Db::destroy(&db_path(&self.opts.dir, old_seq))?;
        if let Some((db, seq)) = self.old_db.take() {
            let dir = db_path(&self.opts.dir, seq);
            drop(db);
            Db::destroy(&dir)?;
        }
        Ok(())
    }

    /// DESIGN.md §8, sender side: plan a run-shipping transfer.  The
    /// committed sealed runs ship as files; everything not yet
    /// compacted (`currentDB` + `oldDB`, references resolved to full
    /// entries, tombstones retained) ships as one small in-memory
    /// residual item.  Every shipped generation is pinned until
    /// `snap_stream_end` so concurrent GC commits defer its deletion.
    fn snap_stream_begin(&mut self, li: LogIndex, lt: Term) -> Result<Option<SnapPlan>> {
        // Settle (but never block on) finished background work so the
        // manifest is current before we enumerate it.
        self.try_finish(false)?;
        self.try_finish_merge(false)?;
        let mut items = Vec::new();
        let mut pinned: HashSet<u64> = HashSet::new();
        for g in self.manifest.all_gens() {
            let path = sorted_path(&self.opts.dir, g);
            let (len, crc) = crc_file(&path)?;
            items.push(PlanItem {
                name: format!("sorted-{g:06}.vlog"),
                len,
                crc,
                src: PlanSource::File(path),
            });
            pinned.insert(g);
        }
        // Residual tail: newest reference per key across both LSMs
        // (currentDB wins), resolved in one batched ValueLog pass.
        let mut merged: BTreeMap<Vec<u8>, VRef> = BTreeMap::new();
        if let Some((db, _)) = &self.old_db {
            for (k, r) in db.scan(&[], &[], usize::MAX)? {
                merged.insert(k, VRef::decode(&r)?);
            }
        }
        for (k, r) in self.cur_db.scan(&[], &[], usize::MAX)? {
            merged.insert(k, VRef::decode(&r)?);
        }
        let refs: Vec<VRef> = merged.values().copied().collect();
        let resolved = self.readers.read_vrefs_batched(&refs)?;
        let entries: Vec<(Vec<u8>, Option<Vec<u8>>)> =
            merged.into_keys().zip(resolved.into_iter().map(|e| e.value)).collect();
        let residual = encode_residual(&entries);
        items.push(PlanItem {
            name: RESIDUAL_ITEM.to_string(),
            len: residual.len() as u64,
            crc: crc32fast::hash(&residual),
            src: PlanSource::Bytes(residual),
        });
        self.snap_plan_seq += 1;
        let id = self.snap_plan_seq;
        self.snap_pins.insert(id, pinned);
        Ok(Some(SnapPlan {
            id,
            last_index: li,
            last_term: lt,
            items,
            shape: encode_shape(&self.manifest),
        }))
    }

    fn snap_stream_end(&mut self, plan_id: u64) {
        self.snap_pins.remove(&plan_id);
        // Flush deferred deletions whose last pin just went away.
        let live: HashSet<u64> = self.manifest.all_gens().into_iter().collect();
        let ready: Vec<u64> = self
            .snap_deferred
            .iter()
            .filter(|g| !self.snap_pins.values().any(|p| p.contains(g)))
            .copied()
            .collect();
        for g in ready {
            self.snap_deferred.remove(&g);
            if !live.contains(&g) {
                FinalStorage::remove_gen(&self.opts.dir, g);
            }
        }
    }

    /// DESIGN.md §8, receiver side: open (or resume) the staging area
    /// for one transfer and report how many bytes are already staged.
    /// `SNAP_STATE` carries the manifest CRC so a restart resumes the
    /// *same* transfer and wipes any other one's leftovers.
    fn snap_sink_begin(&mut self, manifest: &SnapManifest) -> Result<u64> {
        for it in &manifest.items {
            anyhow::ensure!(
                !it.name.is_empty()
                    && !it.name.contains(['/', '\\'])
                    && it.name != "."
                    && it.name != "..",
                "snap sink: unsafe item name {:?}",
                it.name
            );
        }
        let stage = self.stage_dir();
        std::fs::create_dir_all(&stage)?;
        self.snap_sink = None;
        let mbytes = manifest.encode();
        let mcrc = crc32fast::hash(&mbytes);
        let same = match levels::load_framed(&self.opts.dir, SNAP_STATE)? {
            Some(prev) => Decoder::new(&prev).u32().ok() == Some(mcrc),
            None => false,
        };
        if !same {
            // Stale staging of a different transfer (or none): restart
            // from offset 0 under the new manifest's identity.
            std::fs::remove_dir_all(&stage)?;
            std::fs::create_dir_all(&stage)?;
            let mut e = Encoder::with_capacity(4);
            e.u32(mcrc);
            levels::save_framed(&self.opts.dir, SNAP_STATE, &e.into_vec())?;
        }
        // Resume offset: completed items count in full, the first
        // incomplete one counts its on-disk prefix, anything after it
        // is out-of-order debris and is dropped.
        let mut staged = 0u64;
        let mut intact = true;
        for it in &manifest.items {
            let p = stage.join(&it.name);
            let have = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            if !intact {
                if have > 0 {
                    let _ = std::fs::remove_file(&p);
                }
                continue;
            }
            if have >= it.len {
                if have > it.len {
                    // Torn tail past the item's end: trim it.
                    let f = std::fs::OpenOptions::new().write(true).open(&p)?;
                    f.set_len(it.len)?;
                }
                staged += it.len;
            } else {
                staged += have;
                intact = false;
            }
        }
        self.snap_sink = Some(StageCursor { manifest: manifest.clone(), staged, cur: None });
        Ok(staged)
    }

    fn snap_sink_write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let stage = self.stage_dir();
        let sink = self.snap_sink.as_mut().context("snap sink: no transfer staged")?;
        anyhow::ensure!(
            offset == sink.staged,
            "snap sink: offset {offset} != cursor {}",
            sink.staged
        );
        anyhow::ensure!(!data.is_empty(), "snap sink: empty chunk");
        // Locate the item owning `offset`; the sender clips chunks at
        // item boundaries, so the whole slice lands in one file.
        let mut base = 0u64;
        let mut found = None;
        for (i, it) in sink.manifest.items.iter().enumerate() {
            if offset < base + it.len {
                found = Some((i, offset - base, it.len - (offset - base)));
                break;
            }
            base += it.len;
        }
        let (idx, rel, room) = found.context("snap sink: offset beyond manifest")?;
        anyhow::ensure!(data.len() as u64 <= room, "snap sink: chunk crosses item boundary");
        let path = stage.join(&sink.manifest.items[idx].name);
        if sink.cur.as_ref().map(|(i, _)| *i) != Some(idx) {
            if let Some((_, f)) = sink.cur.take() {
                f.sync_data()?;
            }
            let mut f = std::fs::OpenOptions::new().create(true).write(true).open(&path)?;
            // Trim any torn tail past the cursor, then append from it.
            f.set_len(rel)?;
            f.seek(SeekFrom::Start(rel))?;
            sink.cur = Some((idx, f));
        }
        fault::disk::check(&path, fault::disk::DiskOp::Write)?;
        let (_, f) = sink.cur.as_mut().expect("cursor just set");
        f.write_all(data)?;
        sink.staged += data.len() as u64;
        if rel + data.len() as u64 == sink.manifest.items[idx].len {
            // Item complete: make it durable so a crash resumes past it.
            if let Some((_, f)) = sink.cur.take() {
                fault::disk::check(&path, fault::disk::DiskOp::Sync)?;
                f.sync_data()?;
            }
        }
        Ok(())
    }

    /// DESIGN.md §8: the streamed-install commit.  Every staged item is
    /// re-verified (length + CRC) BEFORE any committed state changes,
    /// so a torn transfer can never be read as installed; the shipped
    /// runs are renamed into place under fresh local generation
    /// numbers (never clobbering a live run), indexes are rebuilt
    /// locally, the residual becomes a new top level, and one CRC'd
    /// `LEVELS` manifest save is the atomic cut-over — exactly the
    /// legacy `install_snapshot` commit point, without replay or
    /// re-compaction.
    fn snap_sink_commit(&mut self, li: LogIndex, lt: Term) -> Result<()> {
        let stage = self.stage_dir();
        let sink = self.snap_sink.take().context("snap sink: no transfer staged")?;
        if let Some((_, f)) = sink.cur {
            f.sync_data()?;
        }
        let poison = |stage: &Path, dir: &Path| {
            let _ = std::fs::remove_dir_all(stage);
            let _ = std::fs::remove_file(dir.join(SNAP_STATE));
        };
        anyhow::ensure!(
            sink.staged == sink.manifest.total_len,
            "snap sink: commit of incomplete transfer ({} of {})",
            sink.staged,
            sink.manifest.total_len
        );
        anyhow::ensure!(
            sink.manifest.last_index == li && sink.manifest.last_term == lt,
            "snap sink: commit point ({li},{lt}) != manifest ({},{})",
            sink.manifest.last_index,
            sink.manifest.last_term
        );
        for it in &sink.manifest.items {
            let (len, crc) = crc_file(&stage.join(&it.name))?;
            if len != it.len || crc != it.crc {
                poison(&stage, &self.opts.dir);
                anyhow::bail!(
                    "snap sink: item {} failed verification (len {len}/{}) — staging wiped",
                    it.name,
                    it.len
                );
            }
        }
        let shape = match decode_shape(&sink.manifest.shape) {
            Ok(s) => s,
            Err(e) => {
                poison(&stage, &self.opts.dir);
                return Err(e.context("snap sink: bad shape blob — staging wiped"));
            }
        };
        // Every run the shape references must have shipped.
        let item_gens: HashSet<u64> =
            sink.manifest.items.iter().filter_map(|i| run_item_gen(&i.name)).collect();
        for g in shape.all_gens() {
            if !item_gens.contains(&g) {
                poison(&stage, &self.opts.dir);
                anyhow::bail!("snap sink: shape references unshipped run {g} — staging wiped");
            }
        }

        // Same supersession preamble as the legacy install path.
        self.try_finish(true)?;
        self.try_finish_merge(true)?;
        MergeJob::clear(&self.opts.dir)?;
        self.pending.clear();
        self.readers.invalidate_from(0);
        self.gc_frozen_epoch = None;
        self.gc_floor = None;

        // Remap shipped generations onto fresh local ones so the
        // renames below can never clobber a live run: a crash between
        // here and the manifest save leaves only orphans, which the
        // next open's sweep reclaims.
        let mut base = self.manifest.next_gen;
        for g in FinalStorage::list_all_gens(&self.opts.dir)? {
            base = base.max(g + 1);
        }
        let mut map: BTreeMap<u64, u64> = BTreeMap::new();
        let mut next = base;
        for g in shape.all_gens() {
            map.insert(g, next);
            next += 1;
        }
        let mut run_tombstones: BTreeMap<u64, u64> = BTreeMap::new();
        for it in &sink.manifest.items {
            let Some(g) = run_item_gen(&it.name) else { continue };
            // A shipped run the shape never references stays in
            // staging and is wiped with it below.
            let Some(&lg) = map.get(&g) else { continue };
            std::fs::rename(stage.join(&it.name), sorted_path(&self.opts.dir, lg))?;
            // Indexes are receiver-local artifacts: rebuild, don't ship.
            let (_, tombs) =
                gc::rebuild_index_for_gen(&self.opts.dir, lg, &self.opts.index_backend)?;
            run_tombstones.insert(lg, tombs);
        }
        // The residual tail becomes a brand-new top level (it masks
        // every shipped run, same precedence it had in the LSMs).
        let residual_gen = next;
        let entries = match sink.manifest.items.iter().find(|i| i.name == RESIDUAL_ITEM) {
            Some(it) => decode_residual(&std::fs::read(stage.join(&it.name))?)?,
            None => Vec::new(),
        };
        let mut w = SortedVLogWriter::create(&sorted_path(&self.opts.dir, residual_gen), lt, li)?;
        for (k, v) in &entries {
            let e = match v {
                Some(v) => crate::vlog::Entry::put(lt, li, k.clone(), v.clone()),
                None => crate::vlog::Entry::delete(lt, li, k.clone()),
            };
            w.add(&e)?;
        }
        let (_, _, res_tombs) =
            gc::seal_run(&self.opts.dir, residual_gen, w, &self.opts.index_backend)?;
        run_tombstones.insert(residual_gen, res_tombs);
        let mut new_levels: Vec<Vec<u64>> = vec![vec![residual_gen]];
        for level in &shape.levels {
            new_levels.push(level.iter().map(|g| map[g]).collect());
        }
        let partitions: Vec<PartitionGroup> = shape
            .partitions
            .iter()
            .map(|p| PartitionGroup {
                gens: p.gens.iter().map(|g| map[g]).collect(),
                bounds: p.bounds.clone(),
            })
            .collect();
        self.manifest.levels = new_levels;
        self.manifest.next_gen = residual_gen + 1;
        self.manifest.run_tombstones = run_tombstones;
        self.manifest.partitions = partitions;
        // Atomic cut-over: the CRC'd manifest save makes the whole
        // shipped stack visible at once.
        self.manifest.save(&self.opts.dir)?;
        GcState::clear(&self.opts.dir)?;
        self.levels = LeveledStorage::open_partitioned(
            &self.opts.dir,
            &self.manifest.levels,
            &self.manifest.partitions,
        )?;
        self.merge_plan_dirty = true;
        // Sweep superseded generations and the now-empty staging area.
        let live: HashSet<u64> = self.manifest.all_gens().into_iter().collect();
        let dead: Vec<u64> = FinalStorage::list_all_gens(&self.opts.dir)?
            .into_iter()
            .filter(|g| !live.contains(g))
            .collect();
        for g in dead {
            self.remove_or_defer(g);
        }
        poison(&stage, &self.opts.dir);
        // Fresh currentDB — every old reference is now invalid.
        let old_seq = self.cur_db_seq;
        self.cur_db_seq += 1;
        self.cur_db =
            Db::open(lsm_options(&db_path(&self.opts.dir, self.cur_db_seq), &self.opts, true))?;
        Db::destroy(&db_path(&self.opts.dir, old_seq))?;
        if let Some((db, seq)) = self.old_db.take() {
            let dir = db_path(&self.opts.dir, seq);
            drop(db);
            Db::destroy(&dir)?;
        }
        Ok(())
    }

    fn snap_sink_abort(&mut self) {
        // Drop the in-memory cursor ONLY: the staged bytes on disk are
        // the resume point a reconnecting sender will be told about.
        // A different transfer wipes them at its own `snap_sink_begin`
        // via the SNAP_STATE manifest-CRC check.
        self.snap_sink = None;
    }
}

impl KvEngine for NezhaEngine {
    fn kind(&self) -> EngineKind {
        if self.gc_enabled {
            EngineKind::Nezha
        } else {
            EngineKind::NezhaNoGc
        }
    }

    /// Algorithm 2 — phase-aware point query.
    fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.gets += 1;
        self.try_finish(false)?;
        // New/Active Storage first (most recent data).
        if let Hit::Ref(r) = Self::lookup_ref(&self.cur_db, key)? {
            return self.resolve(r);
        }
        // During-GC: frozen Active Storage.
        if let Some((db, _)) = &self.old_db {
            if let Hit::Ref(r) = Self::lookup_ref(db, key)? {
                return self.resolve(r);
            }
        }
        // Post-GC: the leveled sorted runs, newest first.  The first
        // hit wins — a retained tombstone masks every older run.
        if let Some(e) = self.levels.get(key)? {
            return Ok(e.value);
        }
        Ok(None)
    }

    /// Algorithm 2, batched: run the chained module lookup per key
    /// (cheap — 12-byte references), then resolve every collected
    /// [`VRef`] in one epoch-grouped, offset-sorted ValueLog pass and
    /// every leveled-storage key through one offset-ordered batched
    /// verification pass per run (newest-first, misses carry deeper).
    fn multi_get(&mut self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        self.gets += keys.len() as u64;
        self.try_finish(false)?;
        /// Where one key landed before value resolution.
        enum Pend {
            /// LSM hit — next entry of the batched VRef resolution.
            Ref,
            /// Missed both LSMs — next entry of the leveled batch.
            Fin,
            /// No module can hold it.
            Absent,
        }
        let mut pend: Vec<Pend> = Vec::with_capacity(keys.len());
        let mut refs: Vec<VRef> = Vec::new();
        let mut fin_keys: Vec<&[u8]> = Vec::new();
        for key in keys {
            if let Hit::Ref(r) = Self::lookup_ref(&self.cur_db, key)? {
                refs.push(r);
                pend.push(Pend::Ref);
                continue;
            }
            if let Some((db, _)) = &self.old_db {
                if let Hit::Ref(r) = Self::lookup_ref(db, key)? {
                    refs.push(r);
                    pend.push(Pend::Ref);
                    continue;
                }
            }
            if !self.levels.is_empty() {
                fin_keys.push(key);
                pend.push(Pend::Fin);
            } else {
                pend.push(Pend::Absent);
            }
        }
        let resolved = self.readers.read_vrefs_batched(&refs)?;
        let fin_hits = if fin_keys.is_empty() {
            Vec::new()
        } else {
            self.levels.multi_get(&fin_keys)?
        };
        let mut rit = resolved.into_iter();
        let mut fit = fin_hits.into_iter();
        Ok(pend
            .into_iter()
            .map(|p| match p {
                // A tombstone reference resolves to None here, masking
                // older modules exactly like the single-key path.
                Pend::Ref => rit.next().expect("vref batch aligned").value,
                Pend::Fin => fit.next().expect("fin batch aligned").and_then(|e| e.value),
                Pend::Absent => None,
            })
            .collect())
    }

    /// Algorithm 3 — phase-aware range query with versioned merge.
    /// Candidates are gathered in batched passes: each pass merges at
    /// most `limit - rows_so_far` keys from the storage modules,
    /// resolves the surviving references in one batched,
    /// readahead-served ValueLog call, drops tombstones, then refills
    /// from just past the last consumed key until `limit` live rows
    /// are found or the range is exhausted.  Tombstones therefore do
    /// not consume scan budget (row-count parity with Classic, whose
    /// LSM drops tombstones before limiting), and no value is ever
    /// resolved only to be discarded by the limit.  An empty `end`
    /// means unbounded.
    fn scan(&mut self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        self.scans += 1;
        self.try_finish(false)?;
        enum Src {
            Val(Vec<u8>),
            Ref(VRef),
            /// Tombstone from a sorted run: occupies its merge slot
            /// (keeping each pass's coverage window exact) but yields
            /// no row and resolves nothing.
            Tomb,
        }
        let mut out = Vec::new();
        let mut lo = start.to_vec();
        while out.len() < limit && key_before_end(&lo, end) {
            let need = limit - out.len();
            // Priority: deeper/older runs < shallower/newer runs <
            // oldDB < currentDB; the BTreeMap insert order implements
            // MergeResults' precedence.
            let mut merged: BTreeMap<Vec<u8>, Src> = BTreeMap::new();
            for run in self.levels.runs_oldest_first() {
                for e in run.scan(&lo, end, need)? {
                    merged.insert(e.key, e.value.map_or(Src::Tomb, Src::Val));
                }
            }
            if let Some((db, _)) = &self.old_db {
                for (k, r) in db.scan(&lo, end, need)? {
                    merged.insert(k, Src::Ref(VRef::decode(&r)?));
                }
            }
            for (k, r) in self.cur_db.scan(&lo, end, need)? {
                merged.insert(k, Src::Ref(VRef::decode(&r)?));
            }
            if merged.is_empty() {
                break; // no module has anything left in [lo, end)
            }
            // Fewer than `need` merged keys means every module
            // returned short of its per-pass budget, i.e. the range is
            // exhausted after this pass.
            let exhausted = merged.len() < need;
            // Only the first `need` merged keys lie inside every
            // module's covered window this pass; resolve exactly those.
            let picked: Vec<(Vec<u8>, Src)> = merged.into_iter().take(need).collect();
            let mut next_lo = picked.last().expect("merged non-empty").0.clone();
            next_lo.push(0); // smallest key strictly past the last candidate
            let refs: Vec<VRef> = picked
                .iter()
                .filter_map(|(_, s)| match s {
                    Src::Ref(r) => Some(*r),
                    Src::Val(_) | Src::Tomb => None,
                })
                .collect();
            let resolved = self.readers.read_vrefs_batched(&refs)?;
            let mut rit = resolved.into_iter();
            for (k, src) in picked {
                match src {
                    Src::Val(v) => out.push((k, v)),
                    Src::Tomb => {}
                    Src::Ref(_) => {
                        // Tombstone references resolve to None and
                        // drop out.
                        if let Some(v) = rit.next().expect("scan batch aligned").value {
                            out.push((k, v));
                        }
                    }
                }
            }
            if exhausted {
                break;
            }
            lo = next_lo;
        }
        Ok(out)
    }

    fn sync(&mut self) -> Result<()> {
        self.cur_db.sync_wal()
    }

    fn stats(&self) -> EngineStats {
        let s = self.cur_db.stats().snapshot();
        let olds = self
            .old_db
            .as_ref()
            .map(|(db, _)| db.stats().snapshot())
            .unwrap_or_default();
        let vlog_io = self.readers.io_stats().snapshot();
        EngineStats {
            wal_bytes: s.wal_bytes + olds.wal_bytes,
            flush_bytes: s.flush_bytes + olds.flush_bytes,
            compact_bytes: s.compact_bytes + olds.compact_bytes,
            engine_vlog_bytes: 0,
            gc_bytes: self.gc_bytes,
            gc_cycles: self.gc_cycles,
            gc_levels: self.levels.level_count() as u64,
            gc_level_runs: self.levels.run_count() as u64,
            gets: self.gets,
            scans: self.scans,
            vlog_reads: vlog_io.vlog_reads,
            vlog_read_bytes: vlog_io.vlog_read_bytes,
            readahead_hits: vlog_io.readahead_hits,
            readahead_misses: vlog_io.readahead_misses,
            log_syncs: s.log_syncs + olds.log_syncs,
            gc_stall_us: self.gc_stall_us,
            gc_merge_queue: self.merge_queue_hw,
            gc_merge_jobs: self.merge_jobs_done,
            readahead_seg_bytes: vlog_io.readahead_seg_bytes,
            ..Default::default()
        }
    }

    fn gc_phase(&self) -> GcPhase {
        if self.old_db.is_some() || self.gc_rx.is_some() {
            GcPhase::During
        } else if !self.levels.is_empty() {
            GcPhase::Post
        } else {
            GcPhase::Pre
        }
    }

    /// §III-C step 1-2: freeze the Active Storage, open the New
    /// Storage, kick off asynchronous compaction over every retained
    /// frozen epoch (earlier cycles' uncompacted tails included).
    fn begin_gc(
        &mut self,
        frozen_epochs: &[FrozenEpoch],
        min_index: u64,
        last_index: u64,
        last_term: u64,
    ) -> Result<()> {
        anyhow::ensure!(self.gc_enabled, "Nezha-NoGC never garbage-collects");
        anyhow::ensure!(self.gc_rx.is_none() && self.old_db.is_none(), "GC already running");
        // Flush cycles and merge jobs are mutually exclusive: both
        // allocate generations from `manifest.next_gen` and commit the
        // manifest.  The replica gates its trigger on `gc_busy()`.
        anyhow::ensure!(self.merge_rx.is_none(), "merge job in flight");
        anyhow::ensure!(!frozen_epochs.is_empty(), "GC needs at least one frozen epoch");

        let min_epoch = frozen_epochs.iter().map(|f| f.epoch).min().unwrap();
        let frozen_epoch = frozen_epochs.iter().map(|f| f.epoch).max().unwrap();
        let out_gen = self.manifest.next_gen;
        GcState {
            running: true,
            min_epoch,
            frozen_epoch,
            out_gen,
            min_index,
            last_index,
            last_term,
            stack: self.manifest.levels.clone(),
            run_tombstones: self.manifest.run_tombstones.clone(),
            partitions: self.manifest.partitions.clone(),
        }
        .save(&self.opts.dir)?;

        // Rotate the LSM: currentDB freezes into oldDB.
        let new_seq = self.cur_db_seq + 1;
        let new_db = Db::open(lsm_options(&db_path(&self.opts.dir, new_seq), &self.opts, true))?;
        let frozen_db = std::mem::replace(&mut self.cur_db, new_db);
        let frozen_seq = std::mem::replace(&mut self.cur_db_seq, new_seq);
        self.old_db = Some((frozen_db, frozen_seq));

        let mut epochs: Vec<FrozenEpoch> = frozen_epochs.to_vec();
        epochs.sort_unstable_by_key(|f| f.epoch);
        let inputs = GcInputs {
            frozen: epochs
                .iter()
                .map(|f| EpochSource {
                    epoch: f.epoch,
                    path: crate::raft::log::epoch_path(&self.opts.raft_dir, f.epoch),
                    skip_offset: f.skip_offset,
                })
                .collect(),
            dir: self.opts.dir.clone(),
            out_gen,
            stack: self.manifest.levels.clone(),
            run_tombstones: self.manifest.run_tombstones.clone(),
            min_index,
            last_index,
            last_term,
            level0_bytes: self.opts.gc_level0_bytes,
            fanout: self.opts.gc_fanout,
            partitions: self.manifest.partitions.clone(),
            partition_bytes: self.opts.gc_partition_bytes,
            workers: self.opts.gc_workers,
            resume: false,
            backend: Arc::clone(&self.opts.index_backend),
        };
        let (tx, rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name(format!("nezha-gc-{out_gen}"))
            .spawn(move || {
                deprioritize_gc_thread();
                let _ = tx.send(gc::run_flush(&inputs));
            })?;
        self.gc_rx = Some(rx);
        self.gc_join = Some(join);
        self.gc_frozen_epoch = Some(frozen_epoch);
        self.gc_floor = Some(last_index);
        Ok(())
    }

    fn poll_gc(&mut self) -> Result<Option<GcOutput>> {
        self.try_finish(false)?;
        self.try_finish_merge(false)?;
        self.maybe_start_merge_job()?;
        Ok(self.pending.pop_front())
    }

    fn wait_gc(&mut self) -> Result<Option<GcOutput>> {
        self.try_finish(true)?;
        // Drive the merge cascade to quiescence: each commit may put
        // the next level over budget.
        loop {
            self.try_finish_merge(true)?;
            self.maybe_start_merge_job()?;
            if self.merge_rx.is_none() {
                break;
            }
        }
        Ok(self.pending.pop_front())
    }

    fn gc_busy(&self) -> bool {
        self.gc_rx.is_some()
            || self.merge_rx.is_some()
            || !self.pending.is_empty()
            || (self.gc_enabled && self.merge_plan_dirty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::raft::log::RaftLog;
    use crate::raft::rpc::Command;

    /// Harness pairing a RaftLog (value persistence) with the engine,
    /// standing in for the replica layer.
    struct Rig {
        base: PathBuf,
        log: RaftLog,
        eng: NezhaEngine,
        next_index: u64,
    }

    impl Rig {
        fn new(name: &str, gc: bool) -> Self {
            Self::with_opts(name, gc, |_| {})
        }

        fn with_opts(name: &str, gc: bool, tweak: impl Fn(&mut EngineOpts)) -> Self {
            let base =
                std::env::temp_dir().join(format!("nezha-eng-{name}-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&base);
            let log = RaftLog::open(&base.join("raft")).unwrap();
            let mut opts = EngineOpts::new(base.join("engine"), base.join("raft"));
            opts.memtable_bytes = 64 << 10;
            tweak(&mut opts);
            let eng = NezhaEngine::open(opts, gc).unwrap();
            Self { base, log, eng, next_index: 1 }
        }

        fn reopen(self, gc: bool) -> Self {
            self.reopen_with(gc, |_| {})
        }

        fn reopen_with(mut self, gc: bool, tweak: impl Fn(&mut EngineOpts)) -> Self {
            // Simulate crash+restart: drop engine, reopen everything.
            let base = self.base.clone();
            drop(std::mem::replace(
                &mut self.eng,
                NezhaEngine::open(EngineOpts::new(base.join("engine2"), base.join("raft")), false)
                    .unwrap(),
            ));
            let log = RaftLog::open(&base.join("raft")).unwrap();
            let mut opts = EngineOpts::new(base.join("engine"), base.join("raft"));
            opts.memtable_bytes = 64 << 10;
            tweak(&mut opts);
            let eng = NezhaEngine::open(opts, gc).unwrap();
            let next_index = self.next_index;
            Self { base, log, eng, next_index }
        }

        fn put(&mut self, k: &str, v: &[u8]) {
            let idx = self.next_index;
            self.next_index += 1;
            let cmd = Command::Put { key: k.into(), value: v.to_vec() };
            let e = LogEntry { term: 1, index: idx, cmd };
            let vref = self.log.append(e.clone()).unwrap();
            self.log.flush().unwrap();
            self.eng.apply(&e, vref).unwrap();
        }

        fn del(&mut self, k: &str) {
            let idx = self.next_index;
            self.next_index += 1;
            let e = LogEntry { term: 1, index: idx, cmd: Command::Delete { key: k.into() } };
            let vref = self.log.append(e.clone()).unwrap();
            self.log.flush().unwrap();
            self.eng.apply(&e, vref).unwrap();
        }

        /// Trigger a full GC cycle synchronously (with the recorded
        /// prefix-skip offsets, like the replica does).
        fn gc(&mut self) -> GcOutput {
            let last_index = self.next_index - 1;
            let min_index = self.log.snap_index;
            self.log.rotate().unwrap();
            let epochs: Vec<FrozenEpoch> = self
                .log
                .frozen_epoch_inputs()
                .into_iter()
                .map(|(epoch, skip_offset)| FrozenEpoch { epoch, skip_offset })
                .collect();
            self.eng.begin_gc(&epochs, min_index, last_index, 1).unwrap();
            // Drain the flush AND every cascading background merge
            // job; return the flush output (the replica routes merge
            // outputs separately — they carry no epochs to reclaim).
            let mut flush = None;
            while let Some(o) = self.eng.wait_gc().unwrap() {
                if !o.is_merge_job {
                    flush = Some(o);
                }
            }
            let out = flush.expect("gc output");
            self.log.mark_snapshot(out.last_index, out.last_term).unwrap();
            for &(e, off) in &out.skip_offsets {
                self.log.set_epoch_skip(e, off);
            }
            self.log.drop_epochs_covered_by(out.last_index).unwrap();
            out
        }
    }

    #[test]
    fn pre_gc_put_get_scan() {
        let mut r = Rig::new("pre", true);
        for i in 0..200u32 {
            r.put(&format!("k{i:04}"), format!("v{i}").as_bytes());
        }
        assert_eq!(r.eng.gc_phase(), GcPhase::Pre);
        assert_eq!(r.eng.get(b"k0042").unwrap(), Some(b"v42".to_vec()));
        assert_eq!(r.eng.get(b"zzz").unwrap(), None);
        let rows = r.eng.scan(b"k0000", b"k0010", 100).unwrap();
        assert_eq!(rows.len(), 10);
    }

    #[test]
    fn single_value_write_engine_side() {
        let mut r = Rig::new("onewrite", false);
        let val = vec![5u8; 8192];
        for i in 0..100u32 {
            r.put(&format!("k{i}"), &val);
        }
        // Engine persists only 12-byte refs: its write volume must be
        // tiny compared to the 800KB of values.
        let s = r.eng.stats();
        assert!(
            s.engine_write_bytes() < 200 * 1024,
            "engine writes too big: {}",
            s.engine_write_bytes()
        );
    }

    #[test]
    fn post_gc_reads_hit_sorted_storage() {
        let mut r = Rig::new("post", true);
        for i in 0..300u32 {
            r.put(&format!("key{i:05}"), format!("val{i}").as_bytes());
        }
        let out = r.gc();
        assert!(out.entries == 300, "entries={}", out.entries);
        assert_eq!(out.levels, vec![vec![1]]);
        assert_eq!(r.eng.gc_phase(), GcPhase::Post);
        // Old epoch file dropped; reads must come from Final storage.
        assert_eq!(r.eng.get(b"key00123").unwrap(), Some(b"val123".to_vec()));
        let rows = r.eng.scan(b"key00100", b"key00110", 100).unwrap();
        assert_eq!(rows.len(), 10);
        assert_eq!(rows[0].0, b"key00100".to_vec());
    }

    #[test]
    fn during_gc_reads_both_modules() {
        let mut r = Rig::new("during", true);
        for i in 0..100u32 {
            r.put(&format!("old{i:03}"), b"from-active");
        }
        let last_index = r.next_index - 1;
        let frozen = r.log.rotate().unwrap();
        r.eng.begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, 1).unwrap();
        assert_eq!(r.eng.gc_phase(), GcPhase::During);
        // New writes land in the New Storage while GC runs.
        r.put("new001", b"from-new");
        r.put("old050", b"overwritten");
        assert_eq!(r.eng.get(b"new001").unwrap(), Some(b"from-new".to_vec()));
        assert_eq!(r.eng.get(b"old050").unwrap(), Some(b"overwritten".to_vec()));
        assert_eq!(r.eng.get(b"old042").unwrap(), Some(b"from-active".to_vec()));
        // Scan merges with newest winning.
        let rows = r.eng.scan(b"old049", b"old052", 10).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].1, b"overwritten".to_vec());
        // Finish the cycle.
        let out = r.eng.wait_gc().unwrap().unwrap();
        r.log.mark_snapshot(out.last_index, out.last_term).unwrap();
        r.log.drop_epochs_covered_by(out.last_index).unwrap();
        assert_eq!(r.eng.gc_phase(), GcPhase::Post);
        assert_eq!(r.eng.get(b"old042").unwrap(), Some(b"from-active".to_vec()));
        assert_eq!(r.eng.get(b"old050").unwrap(), Some(b"overwritten".to_vec()));
    }

    #[test]
    fn deletes_respected_across_phases() {
        let mut r = Rig::new("del", true);
        r.put("a", b"1");
        r.put("b", b"2");
        r.del("a");
        assert_eq!(r.eng.get(b"a").unwrap(), None);
        r.gc();
        // After GC the tombstone annihilated the value (first cycle's
        // run is the bottom level).
        assert_eq!(r.eng.get(b"a").unwrap(), None);
        assert_eq!(r.eng.get(b"b").unwrap(), Some(b"2".to_vec()));
        // Delete of a GC'd key: tombstone in currentDB must mask the
        // sorted run.
        r.del("b");
        assert_eq!(r.eng.get(b"b").unwrap(), None);
        let rows = r.eng.scan(b"", b"z", 100).unwrap();
        assert!(rows.is_empty(), "{rows:?}");
    }

    #[test]
    fn multiple_gc_cycles_stack_levels() {
        let mut r = Rig::new("multi", true);
        for i in 0..100u32 {
            r.put(&format!("k{i:03}"), b"gen1");
        }
        r.gc();
        for i in 50..150u32 {
            r.put(&format!("k{i:03}"), b"gen2");
        }
        let out = r.gc();
        assert_eq!(out.gen, 2);
        // No merge at default budgets: the second run stacks on L0.
        assert_eq!(out.entries, 100);
        assert_eq!(out.levels, vec![vec![2, 1]]);
        assert_eq!(r.eng.get(b"k010").unwrap(), Some(b"gen1".to_vec()));
        assert_eq!(r.eng.get(b"k075").unwrap(), Some(b"gen2".to_vec()));
        assert_eq!(r.eng.get(b"k149").unwrap(), Some(b"gen2".to_vec()));
        assert_eq!(r.eng.scan(b"k", b"l", 1000).unwrap().len(), 150);
        let s = r.eng.stats();
        assert_eq!(s.gc_level_runs, 2);
        assert_eq!(s.gc_levels, 1);
    }

    /// Tiny budgets force a merge every cycle; deletes annihilate only
    /// once their tombstones reach the bottom, and reads stay correct
    /// throughout.
    #[test]
    fn leveled_merges_with_deletes_roundtrip() {
        let mut r = Rig::with_opts("levmerge", true, |o| {
            o.gc_level0_bytes = 1 << 10;
            o.gc_fanout = 4;
        });
        for cycle in 0..4u32 {
            for i in 0..40u32 {
                r.put(&format!("k{:03}", cycle * 10 + i), format!("c{cycle}").as_bytes());
            }
            r.del(&format!("k{:03}", cycle));
            r.gc();
        }
        // k000..k003 deleted in their own cycles; k000 was re-written
        // by later cycles? (cycle c writes k{c*10}..k{c*10+39}).
        // cycle0 wrote k000..k039 then deleted k000.
        // cycle1 re-wrote k010..k049 (k010 lives, value c1), deleted k001.
        // cycle2 wrote k020..k059, deleted k002; cycle3 k030..k069, del k003.
        assert_eq!(r.eng.get(b"k000").unwrap(), None);
        assert_eq!(r.eng.get(b"k001").unwrap(), None);
        assert_eq!(r.eng.get(b"k002").unwrap(), None);
        assert_eq!(r.eng.get(b"k003").unwrap(), None);
        assert_eq!(r.eng.get(b"k004").unwrap(), Some(b"c0".to_vec()));
        assert_eq!(r.eng.get(b"k015").unwrap(), Some(b"c1".to_vec()));
        assert_eq!(r.eng.get(b"k069").unwrap(), Some(b"c3".to_vec()));
        // 70 distinct keys minus 4 deleted.
        assert_eq!(r.eng.scan(b"k", b"l", 1000).unwrap().len(), 66);
        // And the same after a crash + reopen.
        let mut r = r.reopen_with(true, |o| {
            o.gc_level0_bytes = 1 << 10;
            o.gc_fanout = 4;
        });
        assert_eq!(r.eng.get(b"k000").unwrap(), None);
        assert_eq!(r.eng.get(b"k015").unwrap(), Some(b"c1".to_vec()));
        assert_eq!(r.eng.scan(b"k", b"l", 1000).unwrap().len(), 66);
    }

    #[test]
    fn nogc_variant_refuses_gc() {
        let mut r = Rig::new("nogc", false);
        r.put("k", b"v");
        assert!(r.eng.begin_gc(&[FrozenEpoch::new(0)], 0, 1, 1).is_err());
        assert_eq!(r.eng.kind(), EngineKind::NezhaNoGc);
    }

    #[test]
    fn recovery_pre_gc_replays_wal() {
        let mut r = Rig::new("rec-pre", true);
        for i in 0..50u32 {
            r.put(&format!("k{i:02}"), b"v");
        }
        r.eng.sync().unwrap();
        r.log.sync().unwrap();
        let r = r.reopen(true);
        let mut eng = r.eng;
        assert_eq!(eng.get(b"k25").unwrap(), Some(b"v".to_vec()));
    }

    #[test]
    fn recovery_post_gc_uses_sorted_storage() {
        let mut r = Rig::new("rec-post", true);
        for i in 0..120u32 {
            r.put(&format!("k{i:03}"), format!("v{i}").as_bytes());
        }
        r.gc();
        r.put("extra", b"after-gc");
        r.eng.sync().unwrap();
        r.log.sync().unwrap();
        let r = r.reopen(true);
        let mut eng = r.eng;
        assert_eq!(eng.gc_phase(), GcPhase::Post);
        assert_eq!(eng.get(b"k060").unwrap(), Some(b"v60".to_vec()));
        assert_eq!(eng.get(b"extra").unwrap(), Some(b"after-gc".to_vec()));
    }

    #[test]
    fn recovery_during_gc_resumes_cycle() {
        let mut r = Rig::new("rec-during", true);
        for i in 0..150u32 {
            r.put(&format!("k{i:03}"), format!("v{i}").as_bytes());
        }
        // Freeze + write the GC state flag, but "crash" before the
        // compaction thread runs (simulate by never starting it).
        let last_index = r.next_index - 1;
        let frozen = r.log.rotate().unwrap();
        GcState {
            running: true,
            min_epoch: frozen,
            frozen_epoch: frozen,
            out_gen: 1,
            min_index: 0,
            last_index,
            last_term: 1,
            stack: vec![],
            run_tombstones: Default::default(),
            partitions: vec![],
        }
        .save(&r.base.join("engine"))
        .unwrap();
        r.eng.sync().unwrap();
        r.log.sync().unwrap();
        // Reopen: recovery is fast (resume runs in the background);
        // the cycle must still complete and report its output.
        let r = r.reopen(true);
        let mut eng = r.eng;
        assert_eq!(eng.gc_phase(), GcPhase::During);
        let out = eng.wait_gc().unwrap().expect("resumed cycle reports output");
        assert_eq!(out.entries, 150);
        assert_eq!(eng.gc_phase(), GcPhase::Post);
        assert_eq!(eng.get(b"k100").unwrap(), Some(b"v100".to_vec()));
    }

    /// A committed cycle whose crash landed between the manifest write
    /// and the GC_STATE clear must NOT be re-run on reopen, and the
    /// stale flag must be cleared.
    #[test]
    fn recovery_skips_already_committed_cycle() {
        let mut r = Rig::new("rec-committed", true);
        for i in 0..60u32 {
            r.put(&format!("k{i:02}"), b"v");
        }
        let out = r.gc();
        // Re-create the pre-clear crash window by hand.
        GcState {
            running: true,
            min_epoch: 0,
            frozen_epoch: 0,
            out_gen: out.gen,
            min_index: 0,
            last_index: out.last_index,
            last_term: out.last_term,
            stack: vec![],
            run_tombstones: Default::default(),
            partitions: vec![],
        }
        .save(&r.base.join("engine"))
        .unwrap();
        let r = r.reopen(true);
        let mut eng = r.eng;
        assert_eq!(eng.gc_phase(), GcPhase::Post, "no spurious resume");
        assert_eq!(GcState::load(&r.base.join("engine")).unwrap(), None);
        assert_eq!(eng.get(b"k30").unwrap(), Some(b"v".to_vec()));
    }

    /// Acceptance: single-key `get` is byte-identical to `multi_get` of
    /// one key, in every GC phase.
    #[test]
    fn multi_get_of_one_key_identical_to_get() {
        let mut r = Rig::new("mget-ident", true);
        for i in 0..150u32 {
            r.put(&format!("k{i:03}"), format!("v{i}").as_bytes());
        }
        r.del("k010");
        let check = |eng: &mut NezhaEngine, keys: &[&str]| {
            for k in keys {
                let single = eng.get(k.as_bytes()).unwrap();
                let batched = eng.multi_get(&[k.as_bytes().to_vec()]).unwrap();
                assert_eq!(batched, vec![single], "{k}");
            }
        };
        let keys = ["k000", "k010", "k075", "k149", "absent"];
        check(&mut r.eng, &keys); // Pre-GC
        r.gc();
        check(&mut r.eng, &keys); // Post-GC
        r.put("k200", b"late");
        check(&mut r.eng, &["k200", "k075", "k010"]);
    }

    /// Batched resolution across an epoch rotation: values written in
    /// epoch N, rotate (GC begins), more written in epoch N+1, then one
    /// multi_get spanning both epochs plus deletes returns exactly the
    /// surviving values.
    #[test]
    fn multi_get_spans_epoch_rotation() {
        let mut r = Rig::new("mget-epochs", true);
        for i in 0..60u32 {
            r.put(&format!("old{i:03}"), format!("epoch0-{i}").as_bytes());
        }
        // Rotate: epoch 0 freezes, epoch 1 becomes the live log.
        let last_index = r.next_index - 1;
        let frozen = r.log.rotate().unwrap();
        r.eng.begin_gc(&[FrozenEpoch::new(frozen)], 0, last_index, 1).unwrap();
        for i in 0..60u32 {
            r.put(&format!("new{i:03}"), format!("epoch1-{i}").as_bytes());
        }
        r.put("old020", b"overwritten-in-epoch1");
        r.del("old030");
        r.del("new040");
        // One batch spanning both epochs, including deleted + absent keys.
        let keys: Vec<Vec<u8>> = [
            "old000", "old020", "old030", "old059", "new000", "new040", "new059", "ghost",
        ]
        .iter()
        .map(|k| k.as_bytes().to_vec())
        .collect();
        let got = r.eng.multi_get(&keys).unwrap();
        assert_eq!(got[0], Some(b"epoch0-0".to_vec()));
        assert_eq!(got[1], Some(b"overwritten-in-epoch1".to_vec()));
        assert_eq!(got[2], None, "tombstone masks the frozen epoch");
        assert_eq!(got[3], Some(b"epoch0-59".to_vec()));
        assert_eq!(got[4], Some(b"epoch1-0".to_vec()));
        assert_eq!(got[5], None, "tombstone in the live epoch");
        assert_eq!(got[6], Some(b"epoch1-59".to_vec()));
        assert_eq!(got[7], None);
        // Both epochs were actually read.
        let s = r.eng.stats();
        assert!(s.vlog_reads >= 7, "vlog_reads={}", s.vlog_reads);
        // Let the cycle finish and re-check the same batch Post-GC
        // (tombstoned keys must stay gone after compaction).
        let out = r.eng.wait_gc().unwrap().unwrap();
        r.log.mark_snapshot(out.last_index, out.last_term).unwrap();
        r.log.drop_epochs_covered_by(out.last_index).unwrap();
        let post = r.eng.multi_get(&keys).unwrap();
        assert_eq!(post, got);
    }

    /// Tombstones do not consume scan budget: the scan refills past
    /// them until `limit` live rows are found (row-count parity with
    /// Classic's LSM, which drops tombstones before limiting).
    #[test]
    fn scan_refills_past_tombstones() {
        let mut r = Rig::new("scan-tomb", true);
        for i in 0..20u32 {
            r.put(&format!("k{i:03}"), format!("v{i}").as_bytes());
        }
        for i in (0..20u32).step_by(2) {
            r.del(&format!("k{i:03}"));
        }
        let rows = r.eng.scan(b"k", b"l", 8).unwrap();
        let keys: Vec<&[u8]> = rows.iter().map(|(k, _)| k.as_slice()).collect();
        let want: Vec<Vec<u8>> = (0..20u32)
            .filter(|i| i % 2 == 1)
            .take(8)
            .map(|i| format!("k{i:03}").into_bytes())
            .collect();
        assert_eq!(keys, want.iter().map(Vec::as_slice).collect::<Vec<_>>());
        // Range exhaustion: asking for more live rows than exist
        // returns exactly the survivors.
        assert_eq!(r.eng.scan(b"k", b"l", 100).unwrap().len(), 10);
    }

    /// Satellite: scan truncates the merged key set to `limit` before
    /// resolving, so only `limit` values are ever fetched per pass.
    #[test]
    fn scan_resolves_only_limit_values() {
        let mut r = Rig::new("scan-limit", true);
        for i in 0..200u32 {
            r.put(&format!("k{i:04}"), &[9u8; 128]);
        }
        let before = r.eng.stats().vlog_reads;
        let rows = r.eng.scan(b"k0000", b"k0200", 10).unwrap();
        assert_eq!(rows.len(), 10);
        let after = r.eng.stats().vlog_reads;
        assert_eq!(after - before, 10, "resolved exactly limit values");
    }

    /// Acceptance: the readahead cache shows a non-zero hit rate on a
    /// scan workload (adjacent values share 64 KiB segments).
    #[test]
    fn scan_hits_readahead_cache() {
        let mut r = Rig::new("scan-ra", true);
        for i in 0..300u32 {
            r.put(&format!("k{i:04}"), &[3u8; 256]);
        }
        let rows = r.eng.scan(b"k0000", b"k0300", 300).unwrap();
        assert_eq!(rows.len(), 300);
        let s = r.eng.stats();
        assert!(s.readahead_hits > 0, "hits={}", s.readahead_hits);
        assert!(
            s.readahead_hit_rate() > 0.5,
            "hit rate {:.2} (hits={} misses={})",
            s.readahead_hit_rate(),
            s.readahead_hits,
            s.readahead_misses
        );
        assert!(s.vlog_read_bytes >= 300 * 256);
    }

    /// Satellite: an unbounded scan (empty end) reaches keys that sort
    /// above the old `[0xff; 32]` sentinel, so snapshots carry them.
    #[test]
    fn snapshot_includes_keys_above_old_sentinel() {
        let mut r = Rig::new("snap-ff", true);
        r.put("normal", b"1");
        // A 40-byte key of 0xff sorts above the old [0xff; 32] bound.
        let idx = r.next_index;
        r.next_index += 1;
        let e = LogEntry {
            term: 1,
            index: idx,
            cmd: Command::Put { key: vec![0xff; 40], value: b"high".to_vec() },
        };
        let vref = r.log.append(e.clone()).unwrap();
        r.log.flush().unwrap();
        r.eng.apply(&e, vref).unwrap();
        let snap = r.eng.snapshot_bytes().unwrap();
        let pairs = decode_kv_snapshot(&snap).unwrap();
        assert_eq!(pairs.len(), 2, "snapshot dropped the 0xff-heavy key");
        assert!(pairs.iter().any(|(k, v)| k == &vec![0xffu8; 40] && v == b"high"));
    }

    #[test]
    fn snapshot_install_roundtrip() {
        let mut a = Rig::new("snap-src", true);
        for i in 0..80u32 {
            a.put(&format!("k{i:02}"), format!("v{i}").as_bytes());
        }
        a.gc();
        a.put("post", b"1");
        let snap = a.eng.snapshot_bytes().unwrap();

        let mut b = Rig::new("snap-dst", true);
        b.eng.install_snapshot(&snap, 81, 1).unwrap();
        assert_eq!(b.eng.get(b"k40").unwrap(), Some(b"v40".to_vec()));
        assert_eq!(b.eng.get(b"post").unwrap(), Some(b"1".to_vec()));
        assert_eq!(b.eng.scan(b"", b"z", 1000).unwrap().len(), 81);
        // The installed snapshot is a single bottom-level run.
        let s = b.eng.stats();
        assert_eq!(s.gc_level_runs, 1);
    }

    /// Satellite regression: when the in-flight cycle ABORTS with an
    /// error during `install_snapshot`, the persisted `running` flag
    /// must not survive — otherwise the next restart resumes a GC that
    /// writes into (or past) the snapshot's generation range.
    #[test]
    fn install_snapshot_clears_failed_cycle_state() {
        let mut a = Rig::new("snap-clean-src", true);
        for i in 0..50u32 {
            a.put(&format!("k{i:02}"), b"v");
        }
        let snap = a.eng.snapshot_bytes().unwrap();

        let mut b = Rig::new("snap-clean-dst", true);
        for i in 0..30u32 {
            b.put(&format!("x{i:02}"), b"v");
        }
        let last_index = b.next_index - 1;
        let frozen = b.log.rotate().unwrap();
        // Sabotage the cycle: point it at a missing epoch so run_gc
        // fails and the engine stays During with GcState persisted.
        b.eng.begin_gc(&[FrozenEpoch::new(frozen + 7)], 0, last_index, 1).unwrap();
        assert!(b.eng.wait_gc().unwrap().is_none(), "cycle must fail");
        assert_eq!(b.eng.gc_phase(), GcPhase::During);
        assert!(GcState::load(&b.base.join("engine")).unwrap().unwrap().running);

        b.eng.install_snapshot(&snap, 50, 1).unwrap();
        assert_eq!(
            GcState::load(&b.base.join("engine")).unwrap(),
            None,
            "stale GcState survived install_snapshot"
        );
        assert_eq!(b.eng.get(b"k25").unwrap(), Some(b"v".to_vec()));
        assert_eq!(b.eng.get(b"x01").unwrap(), None, "pre-snapshot state wiped");
        // A reopen must not resume the dead cycle.
        let r = b.reopen(true);
        let mut eng = r.eng;
        assert_eq!(eng.gc_phase(), GcPhase::Post);
        assert_eq!(eng.get(b"k25").unwrap(), Some(b"v".to_vec()));
    }

    /// Flatten a plan's bytes (as the wire would carry them).
    fn plan_flat(plan: &SnapPlan) -> Vec<u8> {
        let mut flat = Vec::new();
        for it in &plan.items {
            match &it.src {
                PlanSource::Bytes(v) => flat.extend_from_slice(v),
                PlanSource::File(p) => flat.extend_from_slice(&std::fs::read(p).unwrap()),
            }
        }
        flat
    }

    /// Feed `[off, to)` of a transfer into the sink in ≤512-byte
    /// chunks clipped at item boundaries (the sender's contract).
    fn feed(eng: &mut NezhaEngine, manifest: &SnapManifest, flat: &[u8], mut off: u64, to: u64) {
        let mut bounds = Vec::new();
        let mut base = 0u64;
        for it in &manifest.items {
            base += it.len;
            bounds.push(base);
        }
        while off < to {
            let end_item = *bounds.iter().find(|b| **b > off).unwrap();
            let n = (to.min(end_item) - off).min(512) as usize;
            eng.snap_sink_write(off, &flat[off as usize..off as usize + n]).unwrap();
            off += n as u64;
        }
    }

    /// Tentpole: a streamed install (plan → staged chunks → commit) is
    /// observably identical to the legacy monolithic path, and the
    /// shipped run files land byte-identical on the receiver.
    #[test]
    fn streamed_install_parity_with_legacy() {
        let mut a = Rig::new("stream-src", true);
        for i in 0..120u32 {
            a.put(&format!("k{i:03}"), format!("v{i}").as_bytes());
        }
        a.gc();
        for i in 60..90u32 {
            a.put(&format!("k{i:03}"), b"v2");
        }
        a.del("k010");
        let li = a.next_index - 1;
        let plan = a.eng.snap_stream_begin(li, 1).unwrap().expect("nezha plans streams");
        let manifest = plan.manifest();
        let blob = a.eng.snapshot_bytes().unwrap();

        let mut b = Rig::new("stream-dst", true);
        // Non-empty receiver: install must remap shipped generations
        // instead of clobbering its live runs mid-transfer.
        for i in 0..20u32 {
            b.put(&format!("x{i:02}"), b"old");
        }
        b.gc();
        assert_eq!(b.eng.snap_sink_begin(&manifest).unwrap(), 0);
        let flat = plan_flat(&plan);
        assert_eq!(flat.len() as u64, manifest.total_len);
        feed(&mut b.eng, &manifest, &flat, 0, manifest.total_len);
        b.eng.snap_sink_commit(li, 1).unwrap();
        a.eng.snap_stream_end(plan.id);

        let mut c = Rig::new("stream-legacy", true);
        c.eng.install_snapshot(&blob, li, 1).unwrap();

        let via_stream = b.eng.scan(&[], &[], usize::MAX).unwrap();
        let via_legacy = c.eng.scan(&[], &[], usize::MAX).unwrap();
        assert_eq!(via_stream, via_legacy, "streamed and legacy installs disagree");
        assert_eq!(b.eng.get(b"k010").unwrap(), None, "shipped tombstone lost");
        assert_eq!(b.eng.get(b"k075").unwrap(), Some(b"v2".to_vec()));
        assert_eq!(b.eng.get(b"x05").unwrap(), None, "pre-install state survived");

        // Shipped run files are byte-identical after install (modulo
        // the local generation remap; indexes are rebuilt, not shipped).
        let digest =
            |dir: &Path, g: u64| crc32fast::hash(&std::fs::read(sorted_path(dir, g)).unwrap());
        let src: std::collections::BTreeSet<u32> =
            a.eng.manifest.all_gens().iter().map(|g| digest(&a.eng.opts.dir, *g)).collect();
        let residual_level: HashSet<u64> = b.eng.manifest.levels[0].iter().copied().collect();
        let dst: std::collections::BTreeSet<u32> = b
            .eng
            .manifest
            .all_gens()
            .iter()
            .filter(|g| !residual_level.contains(g))
            .map(|g| digest(&b.eng.opts.dir, *g))
            .collect();
        assert_eq!(src, dst, "installed run files differ from the shipped ones");

        // Crash + reopen: the committed cut-over is durable.
        let mut b = b.reopen(true);
        assert_eq!(b.eng.get(b"k075").unwrap(), Some(b"v2".to_vec()));
        assert_eq!(b.eng.scan(&[], &[], usize::MAX).unwrap(), via_legacy);
    }

    /// A transfer interrupted mid-item resumes from its staged byte
    /// count — across a full engine restart — while a *different*
    /// transfer's leftovers are wiped, never resumed into.
    #[test]
    fn sink_resume_and_cross_transfer_wipe() {
        let mut a = Rig::new("resume-src", true);
        for i in 0..80u32 {
            a.put(&format!("k{i:02}"), &[i as u8; 200]);
        }
        a.gc();
        let li = a.next_index - 1;
        let plan = a.eng.snap_stream_begin(li, 1).unwrap().unwrap();
        let manifest = plan.manifest();
        let flat = plan_flat(&plan);
        let half = manifest.total_len / 2;

        let mut b = Rig::new("resume-dst", true);
        assert_eq!(b.eng.snap_sink_begin(&manifest).unwrap(), 0);
        feed(&mut b.eng, &manifest, &flat, 0, half);
        b.eng.snap_sink_abort();
        // Full restart: the staging directory and SNAP_STATE survive.
        let mut b = b.reopen(true);
        let resume = b.eng.snap_sink_begin(&manifest).unwrap();
        assert_eq!(resume, half, "resume offset must equal the staged bytes");
        feed(&mut b.eng, &manifest, &flat, resume, manifest.total_len);
        b.eng.snap_sink_commit(li, 1).unwrap();
        assert_eq!(b.eng.get(b"k40").unwrap(), Some(vec![40u8; 200]));
        a.eng.snap_stream_end(plan.id);

        // Staging keyed to a different manifest is wiped at begin.
        let mut c = Rig::new("resume-other", true);
        assert_eq!(c.eng.snap_sink_begin(&manifest).unwrap(), 0);
        feed(&mut c.eng, &manifest, &flat, 0, half);
        c.eng.snap_sink_abort();
        let mut other = manifest.clone();
        other.shape.push(0xEE);
        assert_eq!(c.eng.snap_sink_begin(&other).unwrap(), 0, "cross-transfer staging not wiped");
    }

    /// Sender-side pinning: runs superseded by GC mid-transfer stay on
    /// disk until the plan ends, then the deferred deletion runs.
    #[test]
    fn stream_pins_runs_until_plan_ends() {
        let mut r = Rig::with_opts("stream-pin", true, |o| {
            o.gc_level0_bytes = 1 << 10;
            o.gc_fanout = 2;
        });
        for i in 0..60u32 {
            r.put(&format!("k{i:03}"), &[7u8; 64]);
        }
        r.gc();
        let li = r.next_index - 1;
        let plan = r.eng.snap_stream_begin(li, 1).unwrap().unwrap();
        let pinned: Vec<u64> = plan.items.iter().filter_map(|i| run_item_gen(&i.name)).collect();
        assert!(!pinned.is_empty());
        // Tiny budgets: the next cycles merge the pinned runs away.
        for c in 0..2u32 {
            for i in 0..60u32 {
                r.put(&format!("k{i:03}"), &[c; 64]);
            }
            r.gc();
        }
        let live: HashSet<u64> = r.eng.manifest.all_gens().into_iter().collect();
        assert!(
            pinned.iter().any(|g| !live.contains(g)),
            "no pinned run was superseded — test is vacuous"
        );
        for g in &pinned {
            assert!(
                sorted_path(&r.eng.opts.dir, *g).exists(),
                "pinned gen {g} deleted mid-transfer"
            );
        }
        r.eng.snap_stream_end(plan.id);
        for g in &pinned {
            if !live.contains(g) {
                assert!(
                    !sorted_path(&r.eng.opts.dir, *g).exists(),
                    "deferred gen {g} never reclaimed"
                );
            }
        }
    }
}
