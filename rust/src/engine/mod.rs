//! The seven evaluation configurations (paper §IV-B) behind one trait.
//!
//! | Kind        | Raft log           | Engine persistence                | Value writes |
//! |-------------|--------------------|-----------------------------------|--------------|
//! | `Original`  | full value (VLog)  | LSM + WAL, full values            | ≥3           |
//! | `Tikv`      | full value         | LSM + WAL + apply-state records   | ≥3 (+meta)   |
//! | `Pasv`      | full value         | LSM, **no WAL**                   | ≥2           |
//! | `Dwisckey`  | full value         | engine vLog + LSM(key→ptr) + WAL  | 2            |
//! | `LsmRaft`   | full value         | leader: as Original; followers    | ≥3 leader,   |
//! |             |                    | ingest sorted runs (SST shipping) | ~1 follower  |
//! | `NezhaNoGc` | full value = THE   | LSM(key→VRef), no value rewrite   | **1**        |
//! | `Nezha`     | single value write | + Raft-aware GC (sorted + index)  | **1** (+GC)  |
//!
//! Every engine implements [`crate::raft::StateMachine`] (the apply
//! path) plus the read/scan/GC hooks of [`KvEngine`].  The replica
//! (coordinator::replica) wires an engine into a Raft node.  The
//! `Nezha` engine additionally implements the streaming-snapshot
//! plan/sink hooks (DESIGN.md §8) so follower catch-up ships its
//! sealed sorted runs as files; every other engine falls back to the
//! monolithic `snapshot_bytes`/`install_snapshot` blob.

pub mod classic;
pub mod common;
pub mod dwisckey;
pub mod nezha;

use crate::gc::{GcOutput, GcPhase};
use crate::raft::StateMachine;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Original,
    Pasv,
    Tikv,
    Dwisckey,
    LsmRaft,
    NezhaNoGc,
    Nezha,
}

impl EngineKind {
    pub const ALL: [EngineKind; 7] = [
        EngineKind::Original,
        EngineKind::Pasv,
        EngineKind::Tikv,
        EngineKind::Dwisckey,
        EngineKind::LsmRaft,
        EngineKind::NezhaNoGc,
        EngineKind::Nezha,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Original => "Original",
            EngineKind::Pasv => "PASV",
            EngineKind::Tikv => "TiKV",
            EngineKind::Dwisckey => "Dwisckey",
            EngineKind::LsmRaft => "LSM-Raft",
            EngineKind::NezhaNoGc => "Nezha-NoGC",
            EngineKind::Nezha => "Nezha",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let norm = s.to_ascii_lowercase().replace(['-', '_'], "");
        Some(match norm.as_str() {
            "original" => EngineKind::Original,
            "pasv" => EngineKind::Pasv,
            "tikv" => EngineKind::Tikv,
            "dwisckey" | "wisckey" => EngineKind::Dwisckey,
            "lsmraft" => EngineKind::LsmRaft,
            "nezhanogc" => EngineKind::NezhaNoGc,
            "nezha" => EngineKind::Nezha,
            _ => return None,
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Construction options shared by all engines.
#[derive(Clone)]
pub struct EngineOpts {
    /// Engine-private directory (LSM dirs, sorted generations, flags).
    pub dir: PathBuf,
    /// Raft directory holding the epoch ValueLogs this engine reads.
    pub raft_dir: PathBuf,
    /// LSM memtable flush trigger.
    pub memtable_bytes: usize,
    /// LSM L0 compaction trigger.
    pub l0_trigger: usize,
    /// LSM level-size budget base.
    pub level_base_bytes: u64,
    /// This replica is a follower (LSM-Raft's asymmetric path).
    pub follower: bool,
    /// Hash/bucket backend for Nezha's GC index build.
    pub index_backend: Arc<dyn crate::gc::IndexBackend>,
    /// L0 size budget of Nezha's leveled Final Compacted Storage;
    /// level `d` gets `gc_level0_bytes * gc_fanout^d`.
    pub gc_level0_bytes: u64,
    /// Leveled-GC fanout (size ratio between adjacent levels).
    pub gc_fanout: u64,
    /// Max merge partitions in flight per level merge (the
    /// `--gc-workers` knob; 1 = serial merges, identical bytes either
    /// way).  Executes on the process-wide [`crate::gc::pool`].
    pub gc_workers: usize,
    /// Target source bytes per merge partition; a level merge splits
    /// into `ceil(total / gc_partition_bytes)` key ranges (≤
    /// [`crate::gc::MAX_PARTS`]).  `u64::MAX` disables partitioning.
    pub gc_partition_bytes: u64,
}

impl EngineOpts {
    pub fn new(dir: impl Into<PathBuf>, raft_dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            raft_dir: raft_dir.into(),
            memtable_bytes: 4 << 20,
            l0_trigger: 4,
            level_base_bytes: 32 << 20,
            follower: false,
            index_backend: Arc::new(crate::gc::RustBackend),
            gc_level0_bytes: 8 << 20,
            gc_fanout: 10,
            gc_workers: 1,
            gc_partition_bytes: 4 << 20,
        }
    }
}

/// Byte counters aggregated for the write-amplification tables.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// LSM WAL bytes (2nd value write in Original).
    pub wal_bytes: u64,
    /// LSM flush bytes (3rd value write).
    pub flush_bytes: u64,
    /// LSM compaction bytes (3rd+ writes).
    pub compact_bytes: u64,
    /// Engine-private vLog bytes (Dwisckey's extra value persist).
    pub engine_vlog_bytes: u64,
    /// GC output bytes (Nezha's background rewrite).
    pub gc_bytes: u64,
    pub gc_cycles: u64,
    /// Levels currently holding at least one sorted run (Nezha's
    /// leveled Final Compacted Storage; zero elsewhere).
    pub gc_levels: u64,
    /// Total sorted runs across all levels.
    pub gc_level_runs: u64,
    pub gets: u64,
    pub scans: u64,
    /// ValueLog entries resolved on the read path.
    pub vlog_reads: u64,
    /// Payload bytes those resolutions returned.
    pub vlog_read_bytes: u64,
    /// Readahead-cache hits/misses on the ValueLog read path (Nezha's
    /// batched resolution; zero for engines without value separation).
    pub readahead_hits: u64,
    pub readahead_misses: u64,
    /// Persistence barriers on the raft log + engine WAL (overlaid by
    /// the cluster from `NodeMetrics`/`IoStats`; the group-commit win
    /// shows up as `log_syncs / entries_committed` < 1).
    pub log_syncs: u64,
    /// Entries committed by consensus (overlaid from `NodeMetrics`).
    pub entries_committed: u64,
    /// Group-commit flush batches (overlaid from `NodeMetrics`).
    pub group_commit_batches: u64,
    /// Entries those flushes covered (sum).
    pub group_commit_entries: u64,
    /// Largest single group-commit batch.
    pub group_commit_max_batch: u64,
    /// Apply-lane queue depth high-water mark (0 without a lane).
    pub apply_queue_depth: u64,
    /// Put-path microseconds spent applying while the engine sat in
    /// `GcPhase::During` (flush in flight) — the stall window the
    /// decoupled merge scheduling shrinks (fig10's stall column).
    pub gc_stall_us: u64,
    /// High-water mark of background merge jobs queued or in flight.
    pub gc_merge_queue: u64,
    /// Decoupled background merge jobs committed.
    pub gc_merge_jobs: u64,
    /// Largest readahead segment the adaptive sizing chose (bytes; 0
    /// when the readahead cache was never touched).
    pub readahead_seg_bytes: u64,
}

impl EngineStats {
    /// Total engine-side write volume (excludes the raft ValueLog,
    /// which the replica accounts separately).
    pub fn engine_write_bytes(&self) -> u64 {
        self.wal_bytes + self.flush_bytes + self.compact_bytes + self.engine_vlog_bytes
    }

    /// Fold another shard replica's counters into this one — the
    /// rolled-up view of a node hosting one engine per shard group.
    pub fn absorb(&mut self, o: &EngineStats) {
        self.wal_bytes += o.wal_bytes;
        self.flush_bytes += o.flush_bytes;
        self.compact_bytes += o.compact_bytes;
        self.engine_vlog_bytes += o.engine_vlog_bytes;
        self.gc_bytes += o.gc_bytes;
        self.gc_cycles += o.gc_cycles;
        self.gc_levels += o.gc_levels;
        self.gc_level_runs += o.gc_level_runs;
        self.gets += o.gets;
        self.scans += o.scans;
        self.vlog_reads += o.vlog_reads;
        self.vlog_read_bytes += o.vlog_read_bytes;
        self.readahead_hits += o.readahead_hits;
        self.readahead_misses += o.readahead_misses;
        self.log_syncs += o.log_syncs;
        self.entries_committed += o.entries_committed;
        self.group_commit_batches += o.group_commit_batches;
        self.group_commit_entries += o.group_commit_entries;
        self.gc_stall_us += o.gc_stall_us;
        self.gc_merge_jobs += o.gc_merge_jobs;
        // High-water marks: the rolled-up view keeps the worst shard.
        self.group_commit_max_batch = self.group_commit_max_batch.max(o.group_commit_max_batch);
        self.apply_queue_depth = self.apply_queue_depth.max(o.apply_queue_depth);
        self.gc_merge_queue = self.gc_merge_queue.max(o.gc_merge_queue);
        self.readahead_seg_bytes = self.readahead_seg_bytes.max(o.readahead_seg_bytes);
    }

    /// Readahead cache hit rate in `[0, 1]` (0 when the cache was never
    /// touched).
    pub fn readahead_hit_rate(&self) -> f64 {
        let total = self.readahead_hits + self.readahead_misses;
        if total == 0 {
            0.0
        } else {
            self.readahead_hits as f64 / total as f64
        }
    }
}

/// A storage engine pluggable under a Raft node.
pub trait KvEngine: StateMachine {
    fn kind(&self) -> EngineKind;

    /// Linearizable-at-the-leader point read (Algorithm 2).
    fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>>;

    /// Batched point read: one result per key, in input order.  Must be
    /// observably identical to calling [`get`] per key; engines with
    /// value separation override it to resolve all references in one
    /// epoch-grouped, offset-sorted ValueLog pass.
    ///
    /// [`get`]: KvEngine::get
    fn multi_get(&mut self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        keys.iter().map(|k| self.get(k)).collect()
    }

    /// Range scan (Algorithm 3): `[start, end)`, at most `limit` rows.
    /// An **empty** `end` means unbounded (scan to the last key), so
    /// full-range dumps (snapshots) cannot silently drop keys that
    /// sort above a sentinel.  `limit` counts *live* rows only —
    /// tombstoned keys in the range never consume it (engines refill
    /// past them), so fewer than `limit` rows means the range is
    /// exhausted.  This keeps row-count parity across engines for the
    /// YCSB-E comparisons.
    fn scan(&mut self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>>;

    /// Group-commit durability point for engine-side files.
    fn sync(&mut self) -> Result<()>;

    fn stats(&self) -> EngineStats;

    /// Current request-processing phase (Table I).
    fn gc_phase(&self) -> GcPhase {
        GcPhase::Pre
    }

    /// Start a GC cycle over the frozen raft epochs (every retained
    /// frozen epoch, oldest first — earlier cycles' uncompacted tails
    /// ride along, each with the byte offset its already-compacted
    /// prefix ends at).  Entries with `index <= min_index` are already
    /// in the level stack and are skipped.  Only Nezha implements
    /// this; the replica calls it right after `RaftLog::rotate()`.
    fn begin_gc(
        &mut self,
        _frozen_epochs: &[crate::gc::FrozenEpoch],
        _min_index: u64,
        _last_index: u64,
        _last_term: u64,
    ) -> Result<()> {
        anyhow::bail!("{} does not garbage-collect", self.kind())
    }

    /// Poll for cycle completion.  When `Some`, the replica marks the
    /// Raft snapshot at the returned point and drops old epochs.
    /// Decoupled background merge jobs report here too, tagged
    /// `is_merge_job` (no epochs to reclaim).
    fn poll_gc(&mut self) -> Result<Option<GcOutput>> {
        Ok(None)
    }

    /// True while any GC work — flush cycle or background merge job —
    /// is in flight or has unreported output.  The replica throttles
    /// new cycles and drains shutdown on this.
    fn gc_busy(&self) -> bool {
        false
    }

    /// Block until a running GC cycle finishes (tests/benches).
    fn wait_gc(&mut self) -> Result<Option<GcOutput>> {
        self.poll_gc()
    }
}

impl StateMachine for Box<dyn KvEngine> {
    fn apply(&mut self, entry: &crate::raft::LogEntry, vref: crate::vlog::VRef) -> Result<()> {
        (**self).apply(entry, vref)
    }

    fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        (**self).snapshot_bytes()
    }

    fn install_snapshot(&mut self, data: &[u8], li: u64, lt: u64) -> Result<()> {
        (**self).install_snapshot(data, li, lt)
    }

    fn on_log_truncated(&mut self, live_epoch: u32) {
        (**self).on_log_truncated(live_epoch)
    }

    // Streamed-snapshot hooks must forward explicitly — the trait
    // defaults would otherwise silently disable streaming for every
    // boxed engine (DESIGN.md §8).
    fn snap_stream_begin(&mut self, li: u64, lt: u64) -> Result<Option<crate::raft::SnapPlan>> {
        (**self).snap_stream_begin(li, lt)
    }

    fn snap_stream_end(&mut self, plan_id: u64) {
        (**self).snap_stream_end(plan_id)
    }

    fn snap_sink_begin(&mut self, manifest: &crate::raft::SnapManifest) -> Result<u64> {
        (**self).snap_sink_begin(manifest)
    }

    fn snap_sink_write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        (**self).snap_sink_write(offset, data)
    }

    fn snap_sink_commit(&mut self, li: u64, lt: u64) -> Result<()> {
        (**self).snap_sink_commit(li, lt)
    }

    fn snap_sink_abort(&mut self) {
        (**self).snap_sink_abort()
    }
}

/// Shared-engine state machine: the engine behind a lock, so a
/// replica's consensus loop (snapshots, truncation) and its apply-lane
/// applier can both reach it.  Reads and GC lock it the same way via
/// `Replica::engine()`.  Lock discipline: never taken while holding
/// the apply-lane queue lock, so the pair cannot deadlock.
#[derive(Clone)]
pub struct EngineCell(pub Arc<std::sync::Mutex<Box<dyn KvEngine>>>);

impl EngineCell {
    pub fn new(engine: Box<dyn KvEngine>) -> Self {
        Self(Arc::new(std::sync::Mutex::new(engine)))
    }

    pub fn lock(&self) -> std::sync::MutexGuard<'_, Box<dyn KvEngine>> {
        self.0.lock().unwrap()
    }
}

impl StateMachine for EngineCell {
    fn apply(&mut self, entry: &crate::raft::LogEntry, vref: crate::vlog::VRef) -> Result<()> {
        self.0.lock().unwrap().apply(entry, vref)
    }

    fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        self.0.lock().unwrap().snapshot_bytes()
    }

    fn install_snapshot(&mut self, data: &[u8], li: u64, lt: u64) -> Result<()> {
        self.0.lock().unwrap().install_snapshot(data, li, lt)
    }

    fn on_log_truncated(&mut self, live_epoch: u32) {
        self.0.lock().unwrap().on_log_truncated(live_epoch)
    }

    fn snap_stream_begin(&mut self, li: u64, lt: u64) -> Result<Option<crate::raft::SnapPlan>> {
        self.0.lock().unwrap().snap_stream_begin(li, lt)
    }

    fn snap_stream_end(&mut self, plan_id: u64) {
        self.0.lock().unwrap().snap_stream_end(plan_id)
    }

    fn snap_sink_begin(&mut self, manifest: &crate::raft::SnapManifest) -> Result<u64> {
        self.0.lock().unwrap().snap_sink_begin(manifest)
    }

    fn snap_sink_write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.0.lock().unwrap().snap_sink_write(offset, data)
    }

    fn snap_sink_commit(&mut self, li: u64, lt: u64) -> Result<()> {
        self.0.lock().unwrap().snap_sink_commit(li, lt)
    }

    fn snap_sink_abort(&mut self) {
        self.0.lock().unwrap().snap_sink_abort()
    }
}

/// Build an engine of the given kind.
pub fn build(kind: EngineKind, opts: EngineOpts) -> Result<Box<dyn KvEngine>> {
    Ok(match kind {
        EngineKind::Original | EngineKind::Pasv | EngineKind::Tikv | EngineKind::LsmRaft => {
            Box::new(classic::ClassicEngine::open(kind, opts)?)
        }
        EngineKind::Dwisckey => Box::new(dwisckey::DwisckeyEngine::open(opts)?),
        EngineKind::NezhaNoGc => Box::new(nezha::NezhaEngine::open(opts, false)?),
        EngineKind::Nezha => Box::new(nezha::NezhaEngine::open(opts, true)?),
    })
}
