//! Shared engine helpers: KV snapshot wire format + LSM option
//! derivation.

use crate::lsm;
use crate::util::{Decoder, Encoder};
use anyhow::Result;
use std::path::Path;

/// Serialize a full KV state for InstallSnapshot (sorted by key — the
/// scan already is).
pub fn encode_kv_snapshot(pairs: &[(Vec<u8>, Vec<u8>)]) -> Vec<u8> {
    let payload: usize = pairs.iter().map(|(k, v)| k.len() + v.len() + 8).sum();
    let mut e = Encoder::with_capacity(64 + payload);
    e.varint(pairs.len() as u64);
    for (k, v) in pairs {
        e.len_bytes(k).len_bytes(v);
    }
    e.into_vec()
}

pub fn decode_kv_snapshot(data: &[u8]) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
    let mut d = Decoder::new(data);
    let n = d.varint()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let k = d.len_bytes()?.to_vec();
        let v = d.len_bytes()?.to_vec();
        out.push((k, v));
    }
    Ok(out)
}

/// LSM options from engine options.
pub fn lsm_options(dir: &Path, opts: &super::EngineOpts, wal: bool) -> lsm::Options {
    let mut o = lsm::Options::new(dir);
    o.wal_enabled = wal;
    o.memtable_bytes = opts.memtable_bytes;
    o.l0_compaction_trigger = opts.l0_trigger;
    o.level_base_bytes = opts.level_base_bytes;
    o.output_split_bytes = (opts.level_base_bytes / 4).max(1 << 20);
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_roundtrip() {
        let pairs = vec![
            (b"a".to_vec(), vec![1u8; 100]),
            (b"b".to_vec(), Vec::new()),
            (vec![0xff; 20], vec![7u8; 3]),
        ];
        let enc = encode_kv_snapshot(&pairs);
        assert_eq!(decode_kv_snapshot(&enc).unwrap(), pairs);
        assert_eq!(decode_kv_snapshot(&encode_kv_snapshot(&[])).unwrap(), vec![]);
    }

    #[test]
    fn snapshot_rejects_truncation() {
        let pairs = vec![(b"k".to_vec(), vec![9u8; 50])];
        let enc = encode_kv_snapshot(&pairs);
        assert!(decode_kv_snapshot(&enc[..enc.len() - 5]).is_err());
    }
}
