//! Dwisckey — distributed WiscKey baseline (paper §IV-B).
//!
//! Key-value separation lives *inside the storage engine*, below the
//! consensus layer: the raft log still persists the full value (1st
//! write), then apply appends the value to an engine-private vLog (2nd
//! write) and stores `key → engine-vlog offset` in the LSM (with WAL).
//! Hence the paper's observation: "performance close to Nezha-NoGC but
//! slightly lower due to its additional value persistence operation".
//!
//! Reads pay the WiscKey penalty Nezha's GC removes: point queries do
//! an extra offset hop, scans degrade to random I/O over the vLog.
//! The batched point path (`multi_get`) sorts pointers by offset and
//! serves them through a [`ReadaheadCache`], so adjacent values share
//! one aligned segment `pread` — scans deliberately stay on the raw
//! random-read path so the Figure 6 degradation remains visible.

use super::common::{decode_kv_snapshot, encode_kv_snapshot, lsm_options};
use super::{EngineKind, EngineOpts, EngineStats, KvEngine};
use crate::lsm::{Db, IoStats};
use crate::raft::rpc::{Command, LogEntry, LogIndex, Term};
use crate::raft::StateMachine;
use crate::vlog::{readahead, Entry as VEntry, ReadaheadCache, VLog, VRef};
use anyhow::Result;
use std::sync::Arc;

pub struct DwisckeyEngine {
    opts: EngineOpts,
    db: Db,
    vlog: VLog,
    /// Segments of `engine.vlog`, keyed under pseudo-epoch 0 (the
    /// engine vLog is a single append-only file).
    cache: ReadaheadCache,
    gets: u64,
    scans: u64,
    vlog_reads: u64,
    vlog_read_bytes: u64,
}

impl DwisckeyEngine {
    pub fn open(opts: EngineOpts) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)?;
        let db = Db::open(lsm_options(&opts.dir.join("db"), &opts, true))?;
        let vlog = VLog::open(&opts.dir.join("engine.vlog"))?;
        let cache = ReadaheadCache::new(readahead::DEFAULT_SEGMENTS, Arc::new(IoStats::default()));
        Ok(Self { opts, db, vlog, cache, gets: 0, scans: 0, vlog_reads: 0, vlog_read_bytes: 0 })
    }

    fn decode_off(off_bytes: &[u8]) -> Result<u64> {
        Ok(u64::from_le_bytes(
            off_bytes
                .try_into()
                .map_err(|_| anyhow::anyhow!("dwisckey: bad offset width"))?,
        ))
    }

    fn read_off(&mut self, off: u64) -> Result<Option<Vec<u8>>> {
        let v = self.vlog.read(off)?.value;
        self.vlog_reads += 1;
        self.vlog_read_bytes += v.as_ref().map_or(0, |v| v.len() as u64);
        Ok(v)
    }

    fn resolve(&mut self, off_bytes: &[u8]) -> Result<Option<Vec<u8>>> {
        let off = Self::decode_off(off_bytes)?;
        self.read_off(off)
    }
}

impl StateMachine for DwisckeyEngine {
    fn apply(&mut self, entry: &LogEntry, _vref: VRef) -> Result<()> {
        match &entry.cmd {
            Command::Put { key, value } => {
                // 2nd value persist: the engine's own vLog.
                let off = self
                    .vlog
                    .append(&VEntry::put(entry.term, entry.index, key.clone(), value.clone()))?;
                self.db.put(key, &off.to_le_bytes())?;
            }
            Command::Delete { key } => {
                self.db.delete(key)?;
            }
            Command::Noop | Command::ConfChange(_) => {}
        }
        Ok(())
    }

    fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        let pairs = self.scan_all()?;
        Ok(encode_kv_snapshot(&pairs))
    }

    fn install_snapshot(&mut self, data: &[u8], li: LogIndex, lt: Term) -> Result<()> {
        let pairs = decode_kv_snapshot(data)?;
        Db::destroy(&self.opts.dir.join("db"))?;
        let _ = std::fs::remove_file(self.opts.dir.join("engine.vlog"));
        self.db = Db::open(lsm_options(&self.opts.dir.join("db"), &self.opts, true))?;
        self.vlog = VLog::open(&self.opts.dir.join("engine.vlog"))?;
        // The vLog file was deleted and rewritten: resident segments
        // no longer match the file.
        self.cache.invalidate_from(0);
        let mut offsets = Vec::with_capacity(pairs.len());
        for (k, v) in &pairs {
            let off = self.vlog.append(&VEntry::put(lt, li, k.clone(), v.clone()))?;
            offsets.push((k.clone(), off.to_le_bytes().to_vec()));
        }
        self.vlog.sync()?;
        self.db.ingest_sorted(&offsets)?;
        Ok(())
    }
}

impl DwisckeyEngine {
    fn scan_all(&mut self) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        // Empty end = unbounded full-range scan.
        let ptrs = self.db.scan(&[], &[], usize::MAX)?;
        let mut out = Vec::with_capacity(ptrs.len());
        for (k, off) in ptrs {
            if let Some(v) = self.resolve(&off)? {
                out.push((k, v));
            }
        }
        Ok(out)
    }
}

impl KvEngine for DwisckeyEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Dwisckey
    }

    fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.gets += 1;
        match self.db.get(key)? {
            Some(off) => self.resolve(&off),
            None => Ok(None),
        }
    }

    /// Batched point read: look up every pointer first, then read the
    /// engine vLog in offset order so the value pass walks the file
    /// forward instead of seeking per arrival order.  The ordered walk
    /// is served through the readahead cache — adjacent entries share
    /// one aligned segment `pread` instead of two raw reads each.
    fn multi_get(&mut self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        self.gets += keys.len() as u64;
        let mut offs: Vec<(usize, u64)> = Vec::with_capacity(keys.len());
        for (i, k) in keys.iter().enumerate() {
            if let Some(off_bytes) = self.db.get(k)? {
                offs.push((i, Self::decode_off(&off_bytes)?));
            }
        }
        offs.sort_unstable_by_key(|&(_, off)| off);
        let mut out: Vec<Option<Vec<u8>>> = vec![None; keys.len()];
        if offs.len() <= 1 {
            for (i, off) in offs {
                out[i] = self.read_off(off)?;
            }
            return Ok(out);
        }
        self.vlog.flush()?;
        let reader = self.vlog.reader()?;
        for (i, off) in offs {
            let e = reader.read_cached(off, 0, &self.cache)?;
            self.vlog_reads += 1;
            self.vlog_read_bytes += e.value.as_ref().map_or(0, |v| v.len() as u64);
            out[i] = e.value;
        }
        Ok(out)
    }

    fn scan(&mut self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        self.scans += 1;
        // Offsets come back key-ordered, but each value fetch is a
        // random read into the arrival-ordered vLog (the degradation
        // Figure 6 shows).
        let ptrs = self.db.scan(start, end, limit)?;
        let mut out = Vec::with_capacity(ptrs.len());
        for (k, off) in ptrs {
            if let Some(v) = self.resolve(&off)? {
                out.push((k, v));
            }
        }
        Ok(out)
    }

    fn sync(&mut self) -> Result<()> {
        self.vlog.sync()?;
        self.db.sync_wal()
    }

    fn stats(&self) -> EngineStats {
        let s = self.db.stats().snapshot();
        let ra = self.cache.io_stats().snapshot();
        EngineStats {
            wal_bytes: s.wal_bytes,
            flush_bytes: s.flush_bytes,
            compact_bytes: s.compact_bytes,
            engine_vlog_bytes: self.vlog.len_bytes(),
            gets: self.gets,
            scans: self.scans,
            vlog_reads: self.vlog_reads,
            vlog_read_bytes: self.vlog_read_bytes,
            readahead_hits: ra.readahead_hits,
            readahead_misses: ra.readahead_misses,
            readahead_seg_bytes: ra.readahead_seg_bytes,
            log_syncs: s.log_syncs,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn opts(name: &str) -> EngineOpts {
        let base: PathBuf =
            std::env::temp_dir().join(format!("nezha-dwk-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut o = EngineOpts::new(base.join("engine"), base.join("raft"));
        o.memtable_bytes = 64 << 10;
        o
    }

    fn put(i: u64, k: &str, v: &[u8]) -> LogEntry {
        LogEntry { term: 1, index: i, cmd: Command::Put { key: k.into(), value: v.to_vec() } }
    }

    #[test]
    fn put_get_scan_roundtrip() {
        let mut e = DwisckeyEngine::open(opts("rt")).unwrap();
        for i in 0..300u64 {
            e.apply(&put(i + 1, &format!("k{i:04}"), format!("v{i}").as_bytes()), VRef::new(0, 0))
                .unwrap();
        }
        assert_eq!(e.get(b"k0042").unwrap(), Some(b"v42".to_vec()));
        assert_eq!(e.get(b"missing").unwrap(), None);
        let rows = e.scan(b"k0000", b"k0010", 100).unwrap();
        assert_eq!(rows.len(), 10);
        assert_eq!(rows[3].1, b"v3".to_vec());
    }

    #[test]
    fn values_persisted_twice_engine_side_once() {
        // Engine-side: LSM stores only 8-byte pointers, vLog holds the
        // values — pointer writes are small, vLog carries the bulk.
        let mut e = DwisckeyEngine::open(opts("wa")).unwrap();
        let value = vec![7u8; 4096];
        for i in 0..100u64 {
            e.apply(&put(i + 1, &format!("k{i}"), &value), VRef::new(0, 0)).unwrap();
        }
        let s = e.stats();
        assert!(s.engine_vlog_bytes > 100 * 4096);
        assert!(s.wal_bytes < s.engine_vlog_bytes / 10, "LSM writes only pointers");
    }

    #[test]
    fn multi_get_matches_gets_and_uses_readahead() {
        let mut e = DwisckeyEngine::open(opts("mget")).unwrap();
        for i in 0..200u64 {
            e.apply(&put(i + 1, &format!("k{i:04}"), format!("v{i}").as_bytes()), VRef::new(0, 0))
                .unwrap();
        }
        let keys: Vec<Vec<u8>> = (0..200u64)
            .rev()
            .map(|i| format!("k{i:04}").into_bytes())
            .chain([b"missing".to_vec()])
            .collect();
        let batched = e.multi_get(&keys).unwrap();
        for (k, got) in keys.iter().zip(&batched) {
            assert_eq!(*got, e.get(k).unwrap(), "key {:?}", String::from_utf8_lossy(k));
        }
        let s = e.stats();
        // 200 small frames share a handful of segments: hits dominate.
        assert!(s.readahead_hits > s.readahead_misses, "hits={s:?}");
        assert!(s.readahead_seg_bytes >= 64 << 10);
    }

    #[test]
    fn overwrite_visible() {
        let mut e = DwisckeyEngine::open(opts("ow")).unwrap();
        e.apply(&put(1, "a", b"one"), VRef::new(0, 0)).unwrap();
        e.apply(&put(2, "a", b"two"), VRef::new(0, 0)).unwrap();
        assert_eq!(e.get(b"a").unwrap(), Some(b"two".to_vec()));
    }

    #[test]
    fn delete_and_snapshot() {
        let mut e = DwisckeyEngine::open(opts("snap")).unwrap();
        for i in 0..50u64 {
            e.apply(&put(i + 1, &format!("k{i:02}"), b"v"), VRef::new(0, 0)).unwrap();
        }
        e.apply(
            &LogEntry { term: 1, index: 51, cmd: Command::Delete { key: b"k10".to_vec() } },
            VRef::new(0, 0),
        )
        .unwrap();
        let snap = e.snapshot_bytes().unwrap();
        let mut f = DwisckeyEngine::open(opts("snap2")).unwrap();
        f.install_snapshot(&snap, 51, 1).unwrap();
        assert_eq!(f.get(b"k10").unwrap(), None);
        assert_eq!(f.get(b"k11").unwrap(), Some(b"v".to_vec()));
        assert_eq!(f.scan(b"k", b"l", 100).unwrap().len(), 49);
    }
}
