//! Cluster coordinator: the Application/Consensus-layer runtime.
//!
//! * [`router`] — deterministic key→shard partitioning and the pure
//!   split/merge helpers behind the cluster's batch semantics.
//! * [`replica`] — one (shard, node) replica's composition: Raft node +
//!   engine + GC lifecycle pump.
//! * [`cluster`] — thread-per-(shard, node) cluster hosting one
//!   independent Raft group per shard, with per-shard leader routing,
//!   group-commit batching, concurrent cross-shard fan-out, a
//!   [`cluster::ReadConsistency`] knob routing reads across *all*
//!   replicas (ReadIndex/lease barriers for linearizable follower
//!   reads), and a blocking client API.  The shard groups run over an
//!   in-process bus or real TCP sockets
//!   (`ClusterConfig::transport` — DESIGN.md §2).
//! * [`nemesis`] — deterministic fault-schedule driver (partitions,
//!   link flapping, crash/restart, disk faults) walked against a live
//!   cluster by the chaos harness ([`crate::chaos`]).
//! * [`server`] — the multi-process deployment: one [`server::Server`]
//!   per process hosting one node's replica of every shard
//!   (`nezha serve`), plus the framed TCP [`server::Client`].

pub mod cluster;
pub mod nemesis;
pub mod replica;
pub mod router;
pub mod server;

pub use cluster::{shard_dir, Cluster, ClusterConfig, ReadConsistency, SnapProgress, Status};
pub use nemesis::{Nemesis, NemesisEvent, NemesisOp};
pub use replica::Replica;
pub use router::{ShardId, ShardRouter};
pub use server::{Client, Server, ServerOpts, StatusRow};
