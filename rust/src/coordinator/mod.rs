//! Cluster coordinator: the Application/Consensus-layer runtime.
//!
//! * [`replica`] — one node's composition: Raft node + engine + GC
//!   lifecycle pump.
//! * [`cluster`] — thread-per-node cluster with leader routing, group
//!   commit batching and a blocking client API.

pub mod cluster;
pub mod replica;

pub use cluster::{Cluster, ClusterConfig, Status};
pub use replica::Replica;
