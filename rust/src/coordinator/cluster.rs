//! Multi-node cluster runtime: one thread per replica, an in-process
//! [`Bus`] carrying encoded Raft frames, and a client handle that
//! routes requests to the leader (retrying on stale hints) — the
//! paper's Application→Consensus request path.
//!
//! Writes go through the group-commit batcher: a `PutBatch` is
//! proposed as a block, persisted with one ValueLog flush, replicated
//! with one AppendEntries fan-out, and acknowledged when the leader
//! applies it (majority-committed).  Reads execute at the leader
//! against the engine's three-phase read path.

use super::replica::Replica;
use crate::engine::{EngineKind, EngineOpts, EngineStats};
use crate::gc::{GcConfig, GcOutput};
use crate::raft::node::Outbox;
use crate::raft::{Bus, Command, Config as RaftConfig, NetConfig, NodeId, Role};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Client/admin requests into a node thread.
pub enum Req {
    PutBatch {
        ops: Vec<(Vec<u8>, Vec<u8>)>,
        resp: SyncSender<Result<()>>,
    },
    Delete {
        key: Vec<u8>,
        resp: SyncSender<Result<()>>,
    },
    Get {
        key: Vec<u8>,
        resp: SyncSender<Result<Option<Vec<u8>>>>,
    },
    /// Batched point read: the whole batch crosses the replica channel
    /// once and resolves through the engine's batched read path.
    MultiGet {
        keys: Vec<Vec<u8>>,
        resp: SyncSender<Result<Vec<Option<Vec<u8>>>>>,
    },
    Scan {
        start: Vec<u8>,
        end: Vec<u8>,
        limit: usize,
        resp: SyncSender<Result<Vec<(Vec<u8>, Vec<u8>)>>>,
    },
    Status {
        resp: SyncSender<Status>,
    },
    /// Block until any in-flight GC cycle completes.
    DrainGc {
        resp: SyncSender<Result<()>>,
    },
    /// Completed GC cycles on this node (fig10's per-cycle report).
    GcHistory {
        resp: SyncSender<Vec<GcOutput>>,
    },
    Stop,
}

#[derive(Clone, Debug)]
pub struct Status {
    pub id: NodeId,
    pub role: Role,
    pub term: u64,
    pub leader_hint: Option<NodeId>,
    pub last_applied: u64,
    pub raft_vlog_bytes: u64,
    pub engine: EngineStats,
    pub gc_phase: crate::gc::GcPhase,
    pub gc_cycles: u64,
}

/// Cluster-level configuration.
#[derive(Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub base_dir: PathBuf,
    pub kind: EngineKind,
    pub engine: EngineOpts,
    pub raft: RaftConfig,
    pub gc: GcConfig,
    pub net: NetConfig,
    /// Wall-clock per raft tick.
    pub tick: Duration,
    pub seed: u64,
}

impl ClusterConfig {
    pub fn new(base_dir: impl Into<PathBuf>, kind: EngineKind, nodes: usize) -> Self {
        let base: PathBuf = base_dir.into();
        // Wall-clock raft timing (1 tick = 1 ms).  The election band
        // is wider than the textbook 150–300 ms because on this
        // single-core testbed a leader can legitimately stall for
        // hundreds of ms inside a storage-engine apply burst (flush +
        // compaction), and that must not read as a dead leader.
        let raft = RaftConfig {
            election_timeout_min: 500,
            election_timeout_max: 900,
            heartbeat_interval: 40,
            ..RaftConfig::default()
        };
        Self {
            nodes,
            kind,
            engine: EngineOpts::new(base.join("unset"), base.join("unset")),
            raft,
            gc: GcConfig::default(),
            net: NetConfig::default(),
            tick: Duration::from_millis(1),
            seed: 42,
            base_dir: base,
        }
    }
}

struct NodeThread {
    tx: Sender<Req>,
    /// Doorbell handle: wakes the node loop when a request is queued.
    mailbox: Arc<crate::raft::transport::Mailbox>,
    join: std::thread::JoinHandle<()>,
}

/// A running cluster.
pub struct Cluster {
    cfg: ClusterConfig,
    threads: HashMap<NodeId, NodeThread>,
    pub bus: Bus,
    leader_cache: std::sync::Mutex<Option<NodeId>>,
}

impl Cluster {
    /// Start `cfg.nodes` replicas and wait for a leader.
    pub fn start(cfg: ClusterConfig) -> Result<Self> {
        let bus = Bus::new(cfg.net.clone());
        let ids: Vec<NodeId> = (1..=cfg.nodes as u64).collect();
        let mut threads = HashMap::new();
        for &id in &ids {
            let peers: Vec<NodeId> = ids.iter().copied().filter(|&p| p != id).collect();
            let mailbox = bus.register(id);
            let mailbox2 = Arc::clone(&mailbox);
            let (tx, rx) = mpsc::channel::<Req>();
            let cfg2 = cfg.clone();
            let bus2 = bus.clone();
            let join = std::thread::Builder::new()
                .name(format!("nezha-node-{id}"))
                .spawn(move || {
                    if let Err(e) = node_loop(id, peers, cfg2, bus2, mailbox2, rx) {
                        eprintln!("node {id} crashed: {e:#}");
                    }
                })?;
            threads.insert(id, NodeThread { tx, mailbox, join });
        }
        let cluster = Self { cfg, threads, bus, leader_cache: std::sync::Mutex::new(None) };
        cluster.wait_for_leader(Duration::from_secs(10))?;
        Ok(cluster)
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn node_ids(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.threads.keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn req(&self, id: NodeId, req: Req) -> Result<()> {
        let t = self.threads.get(&id).ok_or_else(|| anyhow!("no node {id}"))?;
        t.tx.send(req).map_err(|_| anyhow!("node {id} stopped"))?;
        t.mailbox.notify(); // wake the node loop immediately
        Ok(())
    }

    pub fn status(&self, id: NodeId) -> Result<Status> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.req(id, Req::Status { resp: tx })?;
        Ok(rx.recv_timeout(Duration::from_secs(10))?)
    }

    pub fn wait_for_leader(&self, timeout: Duration) -> Result<NodeId> {
        let t0 = Instant::now();
        loop {
            for id in self.node_ids() {
                if let Ok(st) = self.status(id) {
                    if st.role == Role::Leader {
                        *self.leader_cache.lock().unwrap() = Some(id);
                        return Ok(id);
                    }
                }
            }
            if t0.elapsed() > timeout {
                bail!("no leader within {timeout:?}");
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    fn leader(&self) -> Result<NodeId> {
        if let Some(l) = *self.leader_cache.lock().unwrap() {
            return Ok(l);
        }
        self.wait_for_leader(Duration::from_secs(10))
    }

    /// Route a request to the leader with one retry on stale cache.
    fn at_leader<T>(
        &self,
        make: impl Fn() -> (Req, Receiver<Result<T>>),
    ) -> Result<T> {
        for _attempt in 0..3 {
            let l = self.leader()?;
            let (req, rx) = make();
            self.req(l, req)?;
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(v)) => return Ok(v),
                Ok(Err(e)) => {
                    // NotLeader → refresh cache and retry.
                    *self.leader_cache.lock().unwrap() = None;
                    let msg = format!("{e:#}");
                    if !msg.contains("not leader") {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => {
                    // CONSENSUS_TIMEOUT: leadership likely moved while
                    // the batch was pending.  Refresh and re-submit —
                    // puts/deletes are idempotent re-proposals.
                    *self.leader_cache.lock().unwrap() = None;
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        bail!("request timed out (CONSENSUS_TIMEOUT)")
    }

    pub fn put(&self, key: &[u8], value: &[u8]) -> Result<()> {
        self.put_batch(vec![(key.to_vec(), value.to_vec())])
    }

    /// Group-commit write batch (Algorithm 1 semantics per op).
    pub fn put_batch(&self, ops: Vec<(Vec<u8>, Vec<u8>)>) -> Result<()> {
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::PutBatch { ops: ops.clone(), resp: tx }, rx)
        })
    }

    pub fn delete(&self, key: &[u8]) -> Result<()> {
        let key = key.to_vec();
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::Delete { key: key.clone(), resp: tx }, rx)
        })
    }

    pub fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        let key = key.to_vec();
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::Get { key: key.clone(), resp: tx }, rx)
        })
    }

    /// Batched point read: one leader round-trip for the whole batch,
    /// one result per key in input order.
    pub fn get_batch(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let keys = keys.to_vec();
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::MultiGet { keys: keys.clone(), resp: tx }, rx)
        })
    }

    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let (start, end) = (start.to_vec(), end.to_vec());
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::Scan { start: start.clone(), end: end.clone(), limit, resp: tx }, rx)
        })
    }

    /// Completed GC cycles on one node, in completion order.
    pub fn gc_history(&self, id: NodeId) -> Result<Vec<GcOutput>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.req(id, Req::GcHistory { resp: tx })?;
        Ok(rx.recv_timeout(Duration::from_secs(10))?)
    }

    /// Wait for any running GC on the leader to finish (benches).
    pub fn drain_gc(&self) -> Result<()> {
        self.at_leader(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::DrainGc { resp: tx }, rx)
        })
    }

    /// Block until every replica has applied the same log prefix.
    pub fn wait_converged(&self, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            let statuses: Result<Vec<Status>> =
                self.node_ids().iter().map(|&id| self.status(id)).collect();
            if let Ok(sts) = statuses {
                let max = sts.iter().map(|s| s.last_applied).max().unwrap_or(0);
                let min = sts.iter().map(|s| s.last_applied).min().unwrap_or(0);
                if max == min {
                    return Ok(());
                }
            }
            if t0.elapsed() > timeout {
                bail!("replicas did not converge within {timeout:?}");
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Drain GC on *every* node.  On the paper's testbed follower GC
    /// runs on other machines; on this single-core box it would
    /// otherwise compete with the leader's read service (DESIGN.md §2).
    pub fn drain_gc_all(&self) -> Result<()> {
        let mut waits = Vec::new();
        for id in self.node_ids() {
            let (tx, rx) = mpsc::sync_channel(1);
            self.req(id, Req::DrainGc { resp: tx })?;
            waits.push((id, rx));
        }
        for (id, rx) in waits {
            rx.recv_timeout(Duration::from_secs(120))
                .map_err(|_| anyhow!("drain_gc timed out on node {id}"))??;
        }
        Ok(())
    }

    pub fn shutdown(mut self) -> Result<()> {
        for (_, t) in self.threads.iter() {
            let _ = t.tx.send(Req::Stop);
        }
        self.bus.shutdown();
        for (_, t) in self.threads.drain() {
            let _ = t.join.join();
        }
        Ok(())
    }
}

/// Max client write commands folded into one consensus round.
const MAX_FOLD: usize = 512;

fn node_loop(
    id: NodeId,
    peers: Vec<NodeId>,
    cfg: ClusterConfig,
    bus: Bus,
    mailbox: Arc<crate::raft::transport::Mailbox>,
    rx: Receiver<Req>,
) -> Result<()> {
    let base = cfg.base_dir.join(format!("node-{id}"));
    let mut opts = cfg.engine.clone();
    // LSM-Raft's asymmetric persistence: node 1 takes the leader path,
    // the rest the follower (SSTable-shipping) path.  Node 1 also gets
    // a shorter election timeout so the role assignment holds (bench
    // simplification, DESIGN.md §2).
    let mut raft_cfg = cfg.raft.clone();
    if id == 1 {
        raft_cfg.election_timeout_min = raft_cfg.election_timeout_min / 2;
        raft_cfg.election_timeout_max = raft_cfg.election_timeout_min + 2;
    }
    opts.follower = cfg.kind == EngineKind::LsmRaft && id != 1;
    let mut replica = Replica::open(
        id,
        peers,
        &base,
        cfg.kind,
        opts,
        raft_cfg,
        cfg.gc.clone(),
        cfg.seed,
    )?;

    let started = Instant::now();
    let mut last_tick = Duration::ZERO;
    // (commit index awaited, responder)
    let mut pending: Vec<(u64, SyncSender<Result<()>>)> = Vec::new();

    let send_out = |out: Outbox| {
        for (dst, msg) in out {
            bus.send(id, dst, &msg);
        }
    };

    loop {
        // 1. Network input.
        let Some(msgs) = mailbox.drain(Duration::from_micros(300)) else {
            return Ok(()); // bus shut down
        };
        for (from, msg) in msgs {
            let out = replica.node.handle(from, msg)?;
            send_out(out);
        }

        // 2. Logical time.  Catch-up is capped: a thread stalled in a
        // slow engine apply must not burn its whole election budget in
        // one burst (busy ≠ dead) — it ticks at most twice per loop and
        // forgives the rest of the stall.
        let now = started.elapsed();
        let mut caught_up = 0;
        while now.saturating_sub(last_tick) >= cfg.tick {
            last_tick += cfg.tick;
            caught_up += 1;
            if caught_up > 2 {
                last_tick = now;
                break;
            }
            let out = replica.node.tick()?;
            send_out(out);
        }

        // 3. Client requests (fold writes into one consensus round).
        let mut write_cmds: Vec<Command> = Vec::new();
        let mut write_resps: Vec<(usize, SyncSender<Result<()>>)> = Vec::new();
        let mut stop = false;
        while let Ok(req) = rx.try_recv() {
            match req {
                Req::PutBatch { ops, resp } => {
                    if !replica.node.is_leader() {
                        let _ = resp.send(Err(anyhow!("not leader (hint {:?})", replica.node.leader_hint())));
                        continue;
                    }
                    for (k, v) in ops {
                        write_cmds.push(Command::Put { key: k, value: v });
                    }
                    write_resps.push((write_cmds.len(), resp));
                }
                Req::Delete { key, resp } => {
                    if !replica.node.is_leader() {
                        let _ = resp.send(Err(anyhow!("not leader (hint {:?})", replica.node.leader_hint())));
                        continue;
                    }
                    write_cmds.push(Command::Delete { key });
                    write_resps.push((write_cmds.len(), resp));
                }
                Req::Get { key, resp } => {
                    let r = if replica.node.is_leader() {
                        replica.engine().get(&key)
                    } else {
                        Err(anyhow!("not leader (hint {:?})", replica.node.leader_hint()))
                    };
                    let _ = resp.send(r);
                }
                Req::MultiGet { keys, resp } => {
                    let r = if replica.node.is_leader() {
                        replica.engine().multi_get(&keys)
                    } else {
                        Err(anyhow!("not leader (hint {:?})", replica.node.leader_hint()))
                    };
                    let _ = resp.send(r);
                }
                Req::Scan { start, end, limit, resp } => {
                    let r = if replica.node.is_leader() {
                        replica.engine().scan(&start, &end, limit)
                    } else {
                        Err(anyhow!("not leader (hint {:?})", replica.node.leader_hint()))
                    };
                    let _ = resp.send(r);
                }
                Req::Status { resp } => {
                    let s = replica.stats();
                    let _ = resp.send(Status {
                        id,
                        role: replica.node.role(),
                        term: replica.node.term(),
                        leader_hint: replica.node.leader_hint(),
                        last_applied: replica.node.last_applied(),
                        raft_vlog_bytes: replica.raft_vlog_bytes(),
                        engine: s,
                        gc_phase: replica.engine_ref().gc_phase(),
                        gc_cycles: s.gc_cycles,
                    });
                }
                Req::DrainGc { resp } => {
                    // Run every pending trigger to completion so the
                    // caller observes a fully settled Post-GC state
                    // (the paper's "loaded, two GC cycles done" setup).
                    let now_ms = started.elapsed().as_millis() as u64;
                    let r = (|| -> Result<()> {
                        for _ in 0..8 {
                            replica.pump_gc(now_ms)?;
                            if replica.engine_ref().gc_phase() == crate::gc::GcPhase::During {
                                replica.finish_gc()?;
                            } else {
                                break;
                            }
                        }
                        Ok(())
                    })();
                    let _ = resp.send(r);
                }
                Req::GcHistory { resp } => {
                    let _ = resp.send(replica.gc_history.clone());
                }
                Req::Stop => stop = true,
            }
            if write_cmds.len() >= MAX_FOLD {
                break;
            }
        }

        if !write_cmds.is_empty() {
            match replica.propose_batch(write_cmds) {
                Ok((indexes, out)) => {
                    send_out(out);
                    for (upto, resp) in write_resps {
                        // Command i completes when its index applies.
                        let idx = indexes[upto - 1];
                        pending.push((idx, resp));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, resp) in write_resps {
                        let _ = resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }

        // 4. Completions.
        if !pending.is_empty() {
            let applied = replica.node.last_applied();
            pending.retain(|(idx, resp)| {
                if *idx <= applied {
                    let _ = resp.send(Ok(()));
                    false
                } else {
                    true
                }
            });
        }

        // 5. GC lifecycle.  A GC hiccup degrades (retried after
        // restart via the persisted GcState) but never kills the node.
        let now_ms = started.elapsed().as_millis() as u64;
        if let Err(e) = replica.pump_gc(now_ms) {
            eprintln!("node {id}: gc pump error (degraded): {e:#}");
        }

        if stop {
            // Finish any GC so files are consistent on disk.
            let _ = replica.finish_gc();
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str, kind: EngineKind, nodes: usize) -> ClusterConfig {
        let base = std::env::temp_dir().join(format!("nezha-cluster-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut c = ClusterConfig::new(base, kind, nodes);
        c.engine.memtable_bytes = 64 << 10;
        c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 1 };
        c
    }

    #[test]
    fn three_node_nezha_put_get_scan() {
        let cluster = Cluster::start(cfg("basic", EngineKind::Nezha, 3)).unwrap();
        for i in 0..50u32 {
            cluster.put(format!("key{i:03}").as_bytes(), format!("val{i}").as_bytes()).unwrap();
        }
        assert_eq!(cluster.get(b"key025").unwrap(), Some(b"val25".to_vec()));
        assert_eq!(cluster.get(b"nothere").unwrap(), None);
        let rows = cluster.scan(b"key010", b"key020", 100).unwrap();
        assert_eq!(rows.len(), 10);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batch_writes_commit_atomically_visible() {
        let cluster = Cluster::start(cfg("batch", EngineKind::Original, 3)).unwrap();
        let ops: Vec<_> = (0..100u32)
            .map(|i| (format!("b{i:03}").into_bytes(), vec![i as u8; 32]))
            .collect();
        cluster.put_batch(ops).unwrap();
        assert_eq!(cluster.get(b"b099").unwrap(), Some(vec![99u8; 32]));
        cluster.shutdown().unwrap();
    }

    #[test]
    fn get_batch_matches_single_gets() {
        let cluster = Cluster::start(cfg("mget", EngineKind::Nezha, 3)).unwrap();
        for i in 0..40u32 {
            cluster.put(format!("m{i:03}").as_bytes(), format!("v{i}").as_bytes()).unwrap();
        }
        cluster.delete(b"m007").unwrap();
        let keys: Vec<Vec<u8>> = ["m000", "m007", "m025", "m039", "nope"]
            .iter()
            .map(|k| k.as_bytes().to_vec())
            .collect();
        let batched = cluster.get_batch(&keys).unwrap();
        assert_eq!(batched.len(), keys.len());
        for (k, b) in keys.iter().zip(&batched) {
            assert_eq!(*b, cluster.get(k).unwrap(), "{}", String::from_utf8_lossy(k));
        }
        assert!(cluster.get_batch(&[]).unwrap().is_empty());
        cluster.shutdown().unwrap();
    }

    #[test]
    fn delete_roundtrip() {
        let cluster = Cluster::start(cfg("delete", EngineKind::Nezha, 3)).unwrap();
        cluster.put(b"k", b"v").unwrap();
        assert_eq!(cluster.get(b"k").unwrap(), Some(b"v".to_vec()));
        cluster.delete(b"k").unwrap();
        assert_eq!(cluster.get(b"k").unwrap(), None);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn replicas_converge() {
        let cluster = Cluster::start(cfg("converge", EngineKind::Original, 3)).unwrap();
        for i in 0..30u32 {
            cluster.put(format!("c{i}").as_bytes(), b"x").unwrap();
        }
        // Wait for followers to apply everything.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let statuses: Vec<Status> =
                cluster.node_ids().iter().map(|&id| cluster.status(id).unwrap()).collect();
            let max = statuses.iter().map(|s| s.last_applied).max().unwrap();
            let min = statuses.iter().map(|s| s.last_applied).min().unwrap();
            if max == min && max >= 30 {
                break;
            }
            if Instant::now() > deadline {
                panic!("replicas did not converge: {statuses:?}");
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn gc_under_load_preserves_reads() {
        let base = std::env::temp_dir().join(format!("nezha-cluster-gcload-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut c = ClusterConfig::new(base, EngineKind::Nezha, 3);
        c.engine.memtable_bytes = 64 << 10;
        c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 1 };
        c.gc.threshold_bytes = 128 << 10; // tiny: force cycles
        let cluster = Cluster::start(c).unwrap();
        for i in 0..300u32 {
            cluster.put(format!("g{i:04}").as_bytes(), &[5u8; 1024]).unwrap();
        }
        cluster.drain_gc().unwrap();
        let st = cluster.status(cluster.wait_for_leader(Duration::from_secs(5)).unwrap()).unwrap();
        assert!(st.gc_cycles >= 1, "expected at least one GC cycle, got {}", st.gc_cycles);
        for i in (0..300u32).step_by(37) {
            assert_eq!(
                cluster.get(format!("g{i:04}").as_bytes()).unwrap(),
                Some(vec![5u8; 1024]),
                "g{i:04}"
            );
        }
        cluster.shutdown().unwrap();
    }
}
