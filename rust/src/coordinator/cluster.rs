//! Multi-shard, multi-node cluster runtime.
//!
//! A `Cluster` hosts `shards × nodes` replicas: the keyspace is
//! partitioned by the deterministic [`ShardRouter`] recorded in
//! [`ClusterConfig`], and each shard is an **independent Raft group**
//! with its own transport, its own leader, its own raft ValueLog and
//! its own engine + GC lifecycle — the Bizur-style scale-out structure
//! on top of the paper's per-replica Nezha write path.  Every replica
//! runs as two cooperatively-scheduled tasks on one small shared
//! [`Reactor`] worker pool (DESIGN.md §6): a consensus task (network
//! input, ticks, client requests, group commit, GC) and an apply-lane
//! applier that feeds committed entries into the shard's engine — so
//! a 64-shard × 3-node cluster needs a handful of threads, not 192.
//! Mailbox and client-request doorbells wake the consensus task the
//! moment input arrives; tick and group-commit deadlines come from the
//! reactor's timer wheel.  Per shard, a [`Net`] carries encoded Raft
//! frames — the in-process [`Bus`] by default, or real TCP sockets
//! ([`TcpNet`], `ClusterConfig::transport = TransportKind::Tcp`) so
//! the same cluster code runs over loopback sockets in one process or
//! across processes under `nezha serve` (DESIGN.md §2).
//!
//! The client handle splits `put_batch`/`get_batch` by shard, issues
//! the per-shard sub-batches concurrently (every sub-request is in
//! flight at once; stale-leader failures retry per shard), and merges
//! results in input order.  Scans fan out to every shard and k-way
//! merge by key up to `limit`.  **No cross-shard atomicity**: a batch
//! spanning shards is linearizable per shard only — a failure may
//! leave some shards' sub-batches committed.
//!
//! Writes go through the group-commit batcher per shard: a sub-batch
//! is proposed as a block, persisted with one ValueLog flush,
//! replicated with one AppendEntries fan-out, and acknowledged when
//! the shard leader applies it.  Reads route by the configured
//! [`ReadConsistency`]: at each shard's leader (the pre-follower-read
//! behavior), or across *every* replica behind a ReadIndex/lease
//! barrier (`Linearizable`) or from local applied state (`Stale`) —
//! a batch's keys are split over the shard's live replicas so
//! aggregate read bandwidth scales with the replica count, not just
//! the shard count.
//!
//! Single-shard clusters keep the pre-sharding on-disk layout
//! (`node-N/{raft,engine}`) byte-for-byte, so existing data dirs are
//! adopted unchanged.

use super::replica::{ReadLane, Replica};
use super::router::{merge_sorted, split_keys, split_ops, ShardId, ShardRouter};
use crate::engine::{EngineCell, EngineKind, EngineOpts, EngineStats};
use crate::fault::FaultPlan;
use crate::gc::{GcConfig, GcOutput, GcPhase};
use crate::raft::node::Outbox;
use crate::raft::{
    ApplyLane, Bus, Command, Config as RaftConfig, ConfChange, Net, NetConfig, NodeId, Role,
    StateMachine, TcpNet, TransportKind, WireSnapshot,
};
use crate::runtime::reactor::{self, PollOutcome, Reactor, Task, TaskId};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How reads are served.  The write path is unaffected.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadConsistency {
    /// Serve at the shard leader from its applied state (the
    /// pre-follower-read behavior; one node carries every read).
    #[default]
    Leader,
    /// Serve at *any* replica behind a ReadIndex barrier: the leader
    /// confirms its term (heartbeat quorum round, or its clock-bound
    /// lease for free) and hands out a `(read_index, term)`; the
    /// replica waits until `last_applied >= read_index` before reading
    /// locally.  Linearizable, and reads scale with the replica count.
    Linearizable,
    /// Serve at any replica from local applied state, no barrier.
    /// Monotonic per replica but may lag acknowledged writes (bounded
    /// by replication lag).
    Stale,
}

/// Client/admin requests into a (shard, node) thread.
pub enum Req {
    PutBatch {
        ops: Vec<(Vec<u8>, Vec<u8>)>,
        resp: SyncSender<Result<()>>,
    },
    Delete {
        key: Vec<u8>,
        resp: SyncSender<Result<()>>,
    },
    Get {
        key: Vec<u8>,
        consistency: ReadConsistency,
        resp: SyncSender<Result<Option<Vec<u8>>>>,
    },
    /// Batched point read: the whole batch crosses the replica channel
    /// once and resolves through the engine's batched read path.
    MultiGet {
        keys: Vec<Vec<u8>>,
        consistency: ReadConsistency,
        resp: SyncSender<Result<Vec<Option<Vec<u8>>>>>,
    },
    Scan {
        start: Vec<u8>,
        end: Vec<u8>,
        limit: usize,
        consistency: ReadConsistency,
        resp: SyncSender<Result<Vec<(Vec<u8>, Vec<u8>)>>>,
    },
    Status {
        resp: SyncSender<Status>,
    },
    /// Leader-side membership change (DESIGN.md §9): propose one
    /// `ConfChange` entry and answer once it is applied locally.
    /// Non-leaders reject with the standard `not leader` redirect; a
    /// second change while one is in flight rejects with an
    /// `in flight` error the caller retries.
    ConfChange {
        cc: ConfChange,
        resp: SyncSender<Result<()>>,
    },
    /// Block until any in-flight GC cycle completes.
    DrainGc {
        resp: SyncSender<Result<()>>,
    },
    /// Completed GC cycles on this shard replica (fig10's report).
    GcHistory {
        resp: SyncSender<Vec<GcOutput>>,
    },
    Stop,
    /// Abrupt stop (nemesis): exit the node loop immediately, WITHOUT
    /// finishing in-flight GC or answering queued requests — the
    /// in-process analogue of `kill -9`.  Recovery must cope with
    /// whatever the disk holds.
    Crash,
}

/// One (shard, node) replica's status row.  [`Cluster::status`] rolls
/// the per-shard rows of a node up into one aggregate row: counters
/// (`last_applied`, `raft_vlog_bytes`, `engine`, `gc_cycles`) sum
/// across shards, `role`/`term`/`leader_hint` are shard 0's, and
/// `gc_phase` reports During if any shard is mid-cycle (else Post if
/// any shard compacted, else Pre).
#[derive(Clone, Debug)]
pub struct Status {
    pub id: NodeId,
    pub shard: ShardId,
    pub role: Role,
    pub term: u64,
    pub leader_hint: Option<NodeId>,
    pub last_applied: u64,
    pub raft_vlog_bytes: u64,
    pub engine: EngineStats,
    pub gc_phase: GcPhase,
    pub gc_cycles: u64,
    /// Streamed-snapshot transfer progress (DESIGN.md §8).
    pub snap: SnapProgress,
    /// Voting members of this replica's active Raft config (its own
    /// view — during a change, views may briefly differ across nodes).
    pub voters: Vec<NodeId>,
    /// Non-voting learners still catching up (DESIGN.md §9).
    pub learners: Vec<NodeId>,
}

/// One replica's run-shipping catch-up counters (DESIGN.md §8): chunk
/// and byte volume moved as snapshot sender, chunks accepted as
/// receiver, transfers that re-entered mid-stream after a reconnect,
/// and transfers that ran to commit.  Summed across shards in the
/// [`Cluster::status`] rollup.
#[derive(Clone, Copy, Debug, Default)]
pub struct SnapProgress {
    pub chunks_sent: u64,
    pub bytes_sent: u64,
    pub chunks_recv: u64,
    pub resumes: u64,
    pub streams_done: u64,
}

impl SnapProgress {
    fn absorb(&mut self, o: SnapProgress) {
        self.chunks_sent += o.chunks_sent;
        self.bytes_sent += o.bytes_sent;
        self.chunks_recv += o.chunks_recv;
        self.resumes += o.resumes;
        self.streams_done += o.streams_done;
    }
}

/// Cluster-level configuration.
#[derive(Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub base_dir: PathBuf,
    pub kind: EngineKind,
    pub engine: EngineOpts,
    pub raft: RaftConfig,
    pub gc: GcConfig,
    pub net: NetConfig,
    /// Wall-clock per raft tick.
    pub tick: Duration,
    pub seed: u64,
    /// Deterministic key→shard map.  Recorded here so every client and
    /// node agrees on placement; must stay stable once a cluster holds
    /// data (a re-routed key would strand its old shard's copy).
    pub router: ShardRouter,
    /// How `get`/`get_batch`/`scan` are served (see
    /// [`ReadConsistency`]); writes always go through the leader.
    pub read_consistency: ReadConsistency,
    /// Which wire carries Raft frames between replicas: in-process
    /// mailboxes (the default, the PR-1..4 simulation path) or real
    /// TCP sockets over loopback.  Multi-process clusters
    /// (`nezha serve`) always run TCP with explicit peer addresses.
    pub transport: TransportKind,
    /// Shared network fault plan, threaded into every shard's
    /// transport.  Inert by default; the nemesis driver mutates it at
    /// runtime ([`Cluster::fault_plan`]).  One plan covers all shards
    /// — node ids are identical across shard groups, so a partition
    /// of node 2 cuts node 2's links in every group, which is exactly
    /// the machine-level fault a real partition is.
    pub faults: Arc<FaultPlan>,
}

impl ClusterConfig {
    pub fn new(base_dir: impl Into<PathBuf>, kind: EngineKind, nodes: usize) -> Self {
        let base: PathBuf = base_dir.into();
        // Wall-clock raft timing (1 tick = 1 ms).  The election band
        // is wider than the textbook 150–300 ms because on this
        // single-core testbed a leader can legitimately stall for
        // hundreds of ms inside a storage-engine apply burst (flush +
        // compaction), and that must not read as a dead leader.
        let raft = RaftConfig {
            election_timeout_min: 500,
            election_timeout_max: 900,
            heartbeat_interval: 40,
            ..RaftConfig::default()
        };
        Self {
            nodes,
            kind,
            engine: EngineOpts::new(base.join("unset"), base.join("unset")),
            raft,
            gc: GcConfig::default(),
            net: NetConfig::default(),
            tick: Duration::from_millis(1),
            seed: 42,
            router: ShardRouter::hash(1),
            read_consistency: ReadConsistency::default(),
            transport: TransportKind::default(),
            faults: Arc::new(FaultPlan::new(0xFA17)),
            base_dir: base,
        }
    }

    pub fn shards(&self) -> u32 {
        self.router.shards()
    }
}

/// Per-(node, shard) data directory.  Shard 0 keeps the pre-sharding
/// layout (`node-N/`) so a single-shard cluster adopts existing data
/// dirs byte-for-byte; higher shards nest under the node dir.
pub fn shard_dir(base: &Path, id: NodeId, shard: ShardId) -> PathBuf {
    let node = base.join(format!("node-{id}"));
    if shard == 0 {
        node
    } else {
        node.join(format!("shard-{shard}"))
    }
}

/// One (shard, node) replica's handles: the request channel, its
/// doorbell, and the two reactor tasks it runs as.
pub(crate) struct NodeSlot {
    pub(crate) tx: Sender<Req>,
    /// Doorbell handle: wakes the consensus task when a request or a
    /// network frame is queued.
    pub(crate) mailbox: Arc<crate::raft::transport::Mailbox>,
    /// Consensus task (network input, ticks, requests, group commit).
    pub(crate) task: TaskId,
    /// Apply-lane applier task (committed entries → engine).
    pub(crate) applier: TaskId,
}

/// A running cluster.
pub struct Cluster {
    cfg: ClusterConfig,
    /// Live replica slots.  Behind a mutex so fault injection
    /// ([`Self::kill`]/[`Self::crash`]/[`Self::restart`]) works
    /// through `&self` — a chaos run shares one `Arc<Cluster>` between
    /// client threads and the nemesis driver.
    slots: Mutex<HashMap<(ShardId, NodeId), NodeSlot>>,
    /// One network per shard group ([`Bus`] or [`TcpNet`] per
    /// [`ClusterConfig::transport`]).
    nets: Vec<Net>,
    /// Per-shard cached leader hint.
    leader_cache: Vec<Mutex<Option<NodeId>>>,
    /// Per-shard round-robin cursor for replica-served reads.
    read_rr: Vec<AtomicUsize>,
    /// Per-shard membership bookkeeping for dynamic add/remove
    /// (DESIGN.md §9).
    membership: Vec<Mutex<Membership>>,
    /// The shared worker pool every replica task runs on.
    reactor: Reactor,
}

/// The coordinator's per-shard membership view: which node ids it
/// currently runs (voters plus any still-catching-up learner) and the
/// next fresh id.  Ids are never reused — a removed node's stale data
/// directory must never resurrect under a live id.
struct Membership {
    members: Vec<NodeId>,
    next_id: NodeId,
}

/// Open one (shard, node) replica and schedule its consensus task and
/// apply-lane applier on the reactor.  Shared by [`Cluster::start`],
/// [`Cluster::restart`] and the multi-process server
/// (`coordinator::server`) so a restarted node is configured
/// identically to its first life.
pub(crate) fn spawn_replica(
    reactor: &Reactor,
    cfg: &ClusterConfig,
    net: &Net,
    shard: ShardId,
    id: NodeId,
    members: &[NodeId],
    learner: bool,
    mailbox: Arc<crate::raft::transport::Mailbox>,
) -> Result<NodeSlot> {
    let peers: Vec<NodeId> = members.iter().copied().filter(|&p| p != id).collect();
    let base = shard_dir(&cfg.base_dir, id, shard);
    let mut opts = cfg.engine.clone();
    // Asymmetric role assignment, rotated per shard: shard `s` prefers
    // node `(s % nodes) + 1` as leader (shorter election timeout), so
    // a multi-shard cluster spreads its leaders across the nodes
    // instead of serializing every group on node 1.  LSM-Raft's
    // follower (SSTable-shipping) path follows the same preference
    // (bench simplification, DESIGN.md §2).
    let preferred = (shard as u64 % cfg.nodes.max(1) as u64) + 1;
    let mut raft_cfg = cfg.raft.clone();
    if id == preferred {
        raft_cfg.election_timeout_min /= 2;
        raft_cfg.election_timeout_max = raft_cfg.election_timeout_min + 2;
    }
    opts.follower = cfg.kind == EngineKind::LsmRaft && id != preferred;
    // Distinct election jitter per shard group (shard 0 keeps the
    // configured seed, preserving single-shard determinism).
    let seed = cfg.seed.wrapping_add(shard as u64 * 7919);
    let mut replica = if learner {
        // A joining node starts as a non-voting learner of the current
        // voter set; the persisted members sidecar takes over from the
        // constructor args on every later restart (DESIGN.md §9).
        let voters: Vec<NodeId> = members.to_vec();
        Replica::open_learner(id, voters, &base, cfg.kind, opts, raft_cfg, cfg.gc.clone(), seed)?
    } else {
        Replica::open(id, peers, &base, cfg.kind, opts, raft_cfg, cfg.gc.clone(), seed)?
    };
    let lane = ApplyLane::new();
    replica.node.attach_apply_lane(Arc::clone(&lane));
    let engine = replica.engine_cell();
    let (tx, rx) = mpsc::channel::<Req>();
    let task = reactor.spawn(Box::new(ReplicaTask {
        id,
        shard,
        tick: cfg.tick,
        group_commit_us: cfg.raft.group_commit_us,
        net: net.clone(),
        mailbox: Arc::clone(&mailbox),
        rx,
        replica,
        lane: Arc::clone(&lane),
        started: Instant::now(),
        last_tick: Duration::ZERO,
        pending: Vec::new(),
        reads: ReadLane::default(),
        flush_deadline: None,
    }));
    let applier = reactor.spawn(Box::new(ApplierTask {
        id,
        shard,
        lane: Arc::clone(&lane),
        engine,
        mailbox: Arc::clone(&mailbox),
    }));
    // Doorbells: network frames and client requests ring the mailbox
    // (waking the consensus task); committed handoffs ring the lane
    // (waking the applier).  Ring both once after wiring to cover
    // anything that arrived before the wakers existed.
    let h = reactor.handle();
    mailbox.set_waker(Box::new(move || h.wake(task)));
    let h = reactor.handle();
    lane.set_waker(Box::new(move || h.wake(applier)));
    reactor.wake(task);
    reactor.wake(applier);
    Ok(NodeSlot { tx, mailbox, task, applier })
}

impl Cluster {
    /// Start `shards × nodes` replicas and wait for every shard to
    /// elect a leader.
    pub fn start(cfg: ClusterConfig) -> Result<Self> {
        let shards = cfg.shards();
        let ids: Vec<NodeId> = (1..=cfg.nodes as u64).collect();
        let reactor = Reactor::new(reactor::default_workers());
        let mut nets = Vec::with_capacity(shards as usize);
        let mut slots = HashMap::new();
        for shard in 0..shards {
            let net = match cfg.transport {
                TransportKind::Inproc => {
                    Net::Bus(Bus::with_faults(cfg.net.clone(), Arc::clone(&cfg.faults)))
                }
                // Loopback TCP with OS-assigned ports; peers discover
                // each other through the shared address map.
                TransportKind::Tcp => Net::Tcp(TcpNet::with_faults(Arc::clone(&cfg.faults))),
            };
            // Register every node before scheduling any task so the
            // first elections don't race listener/mailbox setup.
            let mut mailboxes = Vec::with_capacity(ids.len());
            for &id in &ids {
                mailboxes.push(net.register(id)?);
            }
            for (&id, mailbox) in ids.iter().zip(mailboxes) {
                slots.insert(
                    (shard, id),
                    spawn_replica(&reactor, &cfg, &net, shard, id, &ids, false, mailbox)?,
                );
            }
            nets.push(net);
        }
        let cluster = Self {
            leader_cache: (0..shards).map(|_| Mutex::new(None)).collect(),
            read_rr: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            membership: (0..shards)
                .map(|_| {
                    Mutex::new(Membership {
                        members: ids.clone(),
                        next_id: cfg.nodes as NodeId + 1,
                    })
                })
                .collect(),
            cfg,
            slots: Mutex::new(slots),
            nets,
            reactor,
        };
        cluster.wait_for_leader(Duration::from_secs(10 * shards as u64))?;
        Ok(cluster)
    }

    /// The shared network fault plan — mutate it to inject partitions,
    /// duplication, reordering, or link overrides at runtime.
    pub fn fault_plan(&self) -> Arc<FaultPlan> {
        Arc::clone(&self.cfg.faults)
    }

    /// Aggregate wire counters across every shard's transport —
    /// msgs/bytes/dropped as counted by [`crate::raft::WireStats`].
    pub fn wire_stats(&self) -> WireSnapshot {
        let mut agg = WireSnapshot::default();
        for net in &self.nets {
            agg.absorb(net.stats());
        }
        agg
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn node_ids(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.slots.lock().unwrap().keys().map(|&(_, id)| id).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    fn shard_of(&self, key: &[u8]) -> ShardId {
        self.cfg.router.route(key)
    }

    fn req(&self, shard: ShardId, id: NodeId, req: Req) -> Result<()> {
        let (tx, mailbox) = {
            let slots = self.slots.lock().unwrap();
            let t = slots
                .get(&(shard, id))
                .ok_or_else(|| anyhow!("no node {id} for shard {shard}"))?;
            (t.tx.clone(), Arc::clone(&t.mailbox))
        };
        tx.send(req).map_err(|_| anyhow!("node {id} shard {shard} stopped"))?;
        mailbox.notify(); // doorbell: wakes the consensus task immediately
        Ok(())
    }

    /// One (shard, node) replica's status.
    pub fn shard_status(&self, id: NodeId, shard: ShardId) -> Result<Status> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.req(shard, id, Req::Status { resp: tx })?;
        Ok(rx.recv_timeout(Duration::from_secs(10))?)
    }

    /// All shard rows of one node (shard-addressed view).
    pub fn node_status(&self, id: NodeId) -> Result<Vec<Status>> {
        (0..self.cfg.shards()).map(|s| self.shard_status(id, s)).collect()
    }

    /// Rolled-up status of one node (see [`Status`] for the rollup
    /// semantics).  For a single-shard cluster this is the plain
    /// per-replica status.
    pub fn status(&self, id: NodeId) -> Result<Status> {
        let mut rows = self.node_status(id)?;
        let mut agg = rows.remove(0);
        for s in rows {
            agg.last_applied += s.last_applied;
            agg.raft_vlog_bytes += s.raft_vlog_bytes;
            agg.engine.absorb(&s.engine);
            agg.gc_cycles += s.gc_cycles;
            agg.snap.absorb(s.snap);
            agg.gc_phase = match (agg.gc_phase, s.gc_phase) {
                (GcPhase::During, _) | (_, GcPhase::During) => GcPhase::During,
                (GcPhase::Post, _) | (_, GcPhase::Post) => GcPhase::Post,
                _ => GcPhase::Pre,
            };
        }
        Ok(agg)
    }

    /// Cluster-wide engine stats: every live (shard, node) replica's
    /// counters absorbed into one aggregate.  With replica-served
    /// reads the traffic lands on whichever node executed it, so this
    /// rollup — not the leader's row alone — is the honest read
    /// accounting.
    pub fn cluster_stats(&self) -> Result<EngineStats> {
        let mut agg = EngineStats::default();
        let mut keys: Vec<(ShardId, NodeId)> = self.slots.lock().unwrap().keys().copied().collect();
        keys.sort_unstable();
        for (shard, id) in keys {
            agg.absorb(&self.shard_status(id, shard)?.engine);
        }
        Ok(agg)
    }

    /// Per-node read counters `(node, gets, scans)` with shard rows
    /// rolled up — shows where read traffic actually landed (all on
    /// the leader under `ReadConsistency::Leader`, spread across
    /// replicas otherwise).
    pub fn read_distribution(&self) -> Result<Vec<(NodeId, u64, u64)>> {
        let mut per_node: BTreeMap<NodeId, (u64, u64)> = BTreeMap::new();
        let mut keys: Vec<(ShardId, NodeId)> = self.slots.lock().unwrap().keys().copied().collect();
        keys.sort_unstable();
        for (shard, id) in keys {
            let st = self.shard_status(id, shard)?;
            let e = per_node.entry(id).or_default();
            e.0 += st.engine.gets;
            e.1 += st.engine.scans;
        }
        Ok(per_node.into_iter().map(|(id, (g, s))| (id, g, s)).collect())
    }

    /// Wait until *every* shard has a leader; returns shard 0's leader
    /// (the pre-sharding contract for callers that just need "the"
    /// leader of a single-shard cluster).
    pub fn wait_for_leader(&self, timeout: Duration) -> Result<NodeId> {
        let deadline = Instant::now() + timeout;
        let mut first = None;
        for shard in 0..self.cfg.shards() {
            let l = self.wait_for_shard_leader(shard, deadline)?;
            if shard == 0 {
                first = Some(l);
            }
        }
        Ok(first.expect("at least one shard"))
    }

    fn wait_for_shard_leader(&self, shard: ShardId, deadline: Instant) -> Result<NodeId> {
        loop {
            for id in self.node_ids() {
                if let Ok(st) = self.shard_status(id, shard) {
                    if st.role == Role::Leader {
                        *self.leader_cache[shard as usize].lock().unwrap() = Some(id);
                        return Ok(id);
                    }
                }
            }
            if Instant::now() > deadline {
                bail!("no leader for shard {shard} within the deadline");
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Current leader of one shard group (cached; re-discovered on a
    /// stale hint).
    pub fn shard_leader(&self, shard: ShardId) -> Result<NodeId> {
        if let Some(l) = *self.leader_cache[shard as usize].lock().unwrap() {
            return Ok(l);
        }
        self.wait_for_shard_leader(shard, Instant::now() + Duration::from_secs(10))
    }

    /// Route a request to one shard's leader with retries on stale
    /// cache / leadership moves.
    fn at_leader<T>(
        &self,
        shard: ShardId,
        make: impl Fn() -> (Req, Receiver<Result<T>>),
    ) -> Result<T> {
        for _attempt in 0..3 {
            let l = self.shard_leader(shard)?;
            let (req, rx) = make();
            self.req(shard, l, req)?;
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(v)) => return Ok(v),
                Ok(Err(e)) => {
                    // NotLeader → refresh cache and retry.
                    *self.leader_cache[shard as usize].lock().unwrap() = None;
                    let msg = format!("{e:#}");
                    if !msg.contains("not leader") {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => {
                    // CONSENSUS_TIMEOUT: leadership likely moved while
                    // the batch was pending.  Refresh and re-submit —
                    // puts/deletes are idempotent re-proposals.
                    *self.leader_cache[shard as usize].lock().unwrap() = None;
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        bail!("request timed out on shard {shard} (CONSENSUS_TIMEOUT)")
    }

    /// Issue one request per listed shard **concurrently**: every
    /// sub-request is put in flight against its shard's cached leader
    /// before any response is awaited, so the per-shard consensus
    /// rounds overlap.  Shards whose leader moved (or was unknown) are
    /// retried through the serial [`Self::at_leader`] path.  `make`
    /// must produce a fresh request each call; it may be called more
    /// than once per slot on retry.
    fn at_shard_leaders<T>(
        &self,
        shards: &[ShardId],
        make: impl Fn(usize) -> (Req, Receiver<Result<T>>),
    ) -> Result<Vec<T>> {
        if shards.len() == 1 {
            return Ok(vec![self.at_leader(shards[0], || make(0))?]);
        }
        let mut out: Vec<Option<T>> = Vec::with_capacity(shards.len());
        out.resize_with(shards.len(), || None);
        let mut inflight: Vec<(usize, Receiver<Result<T>>)> = Vec::new();
        let mut retry: Vec<usize> = Vec::new();
        for (i, &s) in shards.iter().enumerate() {
            let cached = *self.leader_cache[s as usize].lock().unwrap();
            match cached.map_or_else(|| self.shard_leader(s), Ok) {
                Ok(l) => {
                    let (req, rx) = make(i);
                    match self.req(s, l, req) {
                        Ok(()) => inflight.push((i, rx)),
                        Err(_) => retry.push(i),
                    }
                }
                Err(_) => retry.push(i),
            }
        }
        for (i, rx) in inflight {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(v)) => out[i] = Some(v),
                Ok(Err(e)) => {
                    // Same policy as `at_leader`: only a stale-leader
                    // rejection is retried; a genuine engine/propose
                    // error surfaces immediately instead of being
                    // re-proposed.
                    *self.leader_cache[shards[i] as usize].lock().unwrap() = None;
                    if !format!("{e:#}").contains("not leader") {
                        return Err(e);
                    }
                    retry.push(i);
                }
                Err(_) => {
                    // Timeout: leadership likely moved mid-batch;
                    // re-resolve and re-submit (idempotent ops).
                    *self.leader_cache[shards[i] as usize].lock().unwrap() = None;
                    retry.push(i);
                }
            }
        }
        for i in retry {
            out[i] = Some(self.at_leader(shards[i], || make(i))?);
        }
        Ok(out.into_iter().map(|v| v.expect("every shard slot filled")).collect())
    }

    /// One shard's live replicas (killed nodes excluded), sorted.
    fn shard_nodes(&self, shard: ShardId) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self
            .slots
            .lock()
            .unwrap()
            .keys()
            .filter(|&&(s, _)| s == shard)
            .map(|&(_, id)| id)
            .collect();
        v.sort_unstable();
        v
    }

    /// Route a read to one of a shard's replicas, round-robin, marching
    /// through the membership on failure and ending at the leader.
    /// Reads are side-effect-free, so *any* failure (dead node, barrier
    /// timeout, no leader known) just retries the next replica.
    fn at_replica<T>(
        &self,
        shard: ShardId,
        make: impl Fn() -> (Req, Receiver<Result<T>>),
    ) -> Result<T> {
        let nodes = self.shard_nodes(shard);
        if nodes.is_empty() {
            bail!("no live replicas for shard {shard}");
        }
        let start = self.read_rr[shard as usize].fetch_add(1, Ordering::Relaxed);
        let mut last_err = None;
        for i in 0..=nodes.len() {
            // Last attempt goes to the (re-resolved) leader, which can
            // always satisfy any consistency level.
            let target = if i < nodes.len() {
                nodes[(start + i) % nodes.len()]
            } else {
                match self.shard_leader(shard) {
                    Ok(l) => l,
                    Err(e) => return Err(last_err.unwrap_or(e)),
                }
            };
            let (req, rx) = make();
            if self.req(shard, target, req).is_err() {
                continue;
            }
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(v)) => return Ok(v),
                Ok(Err(e)) => last_err = Some(e),
                Err(_) => {
                    last_err = Some(anyhow!("read timed out on shard {shard} node {target}"))
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("read failed on shard {shard}")))
    }

    /// Fan one read request out per listed shard, each to a
    /// round-robin-chosen replica, all concurrently; failed slots
    /// retry through the serial [`Self::at_replica`] path.
    fn at_shard_replicas<T>(
        &self,
        shards: &[ShardId],
        make: impl Fn(usize) -> (Req, Receiver<Result<T>>),
    ) -> Result<Vec<T>> {
        let mut out: Vec<Option<T>> = Vec::with_capacity(shards.len());
        out.resize_with(shards.len(), || None);
        let mut inflight = Vec::new();
        for (i, &s) in shards.iter().enumerate() {
            let nodes = self.shard_nodes(s);
            if nodes.is_empty() {
                continue; // retried (and failed properly) below
            }
            let start = self.read_rr[s as usize].fetch_add(1, Ordering::Relaxed);
            let target = nodes[start % nodes.len()];
            let (req, rx) = make(i);
            if self.req(s, target, req).is_ok() {
                inflight.push((i, rx));
            }
        }
        for (i, rx) in inflight {
            if let Ok(Ok(v)) = rx.recv_timeout(Duration::from_secs(30)) {
                out[i] = Some(v);
            }
        }
        for i in 0..shards.len() {
            if out[i].is_none() {
                out[i] = Some(self.at_replica(shards[i], || make(i))?);
            }
        }
        Ok(out.into_iter().map(|v| v.expect("every shard slot filled")).collect())
    }

    /// Replica-served batched point read: each shard's key list is
    /// split into chunks spread round-robin over the shard's live
    /// replicas, every chunk is in flight at once, and the chunk
    /// results reassemble in input order.  This is what lets aggregate
    /// get bandwidth scale with `nodes`, not just `shards`.
    fn spread_get_batch(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        /// Below this many keys a chunk is not worth its round-trip.
        const MIN_CHUNK: usize = 4;
        let consistency = self.cfg.read_consistency;
        let (per, slots) = split_keys(&self.cfg.router, keys);
        // Plan: per shard, contiguous chunks in shard-list order.
        struct Plan {
            shard: usize,
            target: NodeId,
            keys: Vec<Vec<u8>>,
        }
        let mut plans: Vec<Plan> = Vec::new();
        for (s, list) in per.iter().enumerate() {
            if list.is_empty() {
                continue;
            }
            let nodes = self.shard_nodes(s as ShardId);
            if nodes.is_empty() {
                bail!("no live replicas for shard {s}");
            }
            let spread = nodes.len().min(list.len().div_ceil(MIN_CHUNK)).max(1);
            let chunk = list.len().div_ceil(spread);
            let start = self.read_rr[s].fetch_add(spread, Ordering::Relaxed);
            for (i, c) in list.chunks(chunk).enumerate() {
                plans.push(Plan {
                    shard: s,
                    target: nodes[(start + i) % nodes.len()],
                    keys: c.to_vec(),
                });
            }
        }
        // Fire every chunk, then collect; a failed chunk retries
        // serially through the replica rotation.
        let mut chunk_res: Vec<_> = plans.iter().map(|_| None).collect();
        let mut inflight = Vec::new();
        for (pi, plan) in plans.iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel(1);
            let req = Req::MultiGet { keys: plan.keys.clone(), consistency, resp: tx };
            if self.req(plan.shard as ShardId, plan.target, req).is_ok() {
                inflight.push((pi, rx));
            }
        }
        for (pi, rx) in inflight {
            if let Ok(Ok(v)) = rx.recv_timeout(Duration::from_secs(30)) {
                if v.len() == plans[pi].keys.len() {
                    chunk_res[pi] = Some(v);
                }
            }
        }
        for (pi, plan) in plans.iter().enumerate() {
            if chunk_res[pi].is_none() {
                chunk_res[pi] = Some(self.at_replica(plan.shard as ShardId, || {
                    let (tx, rx) = mpsc::sync_channel(1);
                    (Req::MultiGet { keys: plan.keys.clone(), consistency, resp: tx }, rx)
                })?);
            }
        }
        // Chunks were planned in per-shard order, so concatenation
        // rebuilds each shard's list; `slots` maps back to input order.
        let mut per_out: Vec<Vec<Option<Vec<u8>>>> = per.iter().map(|_| Vec::new()).collect();
        for (pi, plan) in plans.iter().enumerate() {
            per_out[plan.shard].extend(chunk_res[pi].take().expect("chunk filled"));
        }
        Ok(slots.into_iter().map(|(s, p)| per_out[s][p].take()).collect())
    }

    pub fn put(&self, key: &[u8], value: &[u8]) -> Result<()> {
        self.put_batch(vec![(key.to_vec(), value.to_vec())])
    }

    /// Group-commit write batch (Algorithm 1 semantics per op).  Split
    /// by shard; per-shard sub-batches commit concurrently and
    /// independently — per-shard linearizability, no cross-shard
    /// atomicity.
    pub fn put_batch(&self, ops: Vec<(Vec<u8>, Vec<u8>)>) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        if self.cfg.shards() == 1 {
            return self.at_leader(0, move || {
                let (tx, rx) = mpsc::sync_channel(1);
                (Req::PutBatch { ops: ops.clone(), resp: tx }, rx)
            });
        }
        let per = split_ops(&self.cfg.router, ops);
        let parts: Vec<(ShardId, Vec<(Vec<u8>, Vec<u8>)>)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(s, v)| (s as ShardId, v))
            .collect();
        let ids: Vec<ShardId> = parts.iter().map(|(s, _)| *s).collect();
        self.at_shard_leaders(&ids, |i| {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::PutBatch { ops: parts[i].1.clone(), resp: tx }, rx)
        })?;
        Ok(())
    }

    pub fn delete(&self, key: &[u8]) -> Result<()> {
        let shard = self.shard_of(key);
        let key = key.to_vec();
        self.at_leader(shard, move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::Delete { key: key.clone(), resp: tx }, rx)
        })
    }

    pub fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        let consistency = self.cfg.read_consistency;
        let shard = self.shard_of(key);
        let key = key.to_vec();
        let make = move || {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::Get { key: key.clone(), consistency, resp: tx }, rx)
        };
        if consistency == ReadConsistency::Leader {
            self.at_leader(shard, make)
        } else {
            self.at_replica(shard, make)
        }
    }

    /// Batched point read: one round-trip per involved shard (issued
    /// concurrently), one result per key in input order.  Under
    /// `Linearizable`/`Stale` consistency each shard's sub-batch is
    /// additionally spread over the shard's replicas.
    pub fn get_batch(&self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let consistency = self.cfg.read_consistency;
        if consistency != ReadConsistency::Leader {
            return self.spread_get_batch(keys);
        }
        if self.cfg.shards() == 1 {
            let keys = keys.to_vec();
            return self.at_leader(0, move || {
                let (tx, rx) = mpsc::sync_channel(1);
                (Req::MultiGet { keys: keys.clone(), consistency, resp: tx }, rx)
            });
        }
        let (per, slots) = split_keys(&self.cfg.router, keys);
        let parts: Vec<(ShardId, Vec<Vec<u8>>)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(s, v)| (s as ShardId, v))
            .collect();
        let ids: Vec<ShardId> = parts.iter().map(|(s, _)| *s).collect();
        let results = self.at_shard_leaders(&ids, |i| {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::MultiGet { keys: parts[i].1.clone(), consistency, resp: tx }, rx)
        })?;
        let mut by_shard: HashMap<usize, Vec<Option<Vec<u8>>>> =
            ids.iter().map(|&s| s as usize).zip(results).collect();
        Ok(slots
            .into_iter()
            .map(|(s, p)| by_shard.get_mut(&s).expect("answered shard")[p].take())
            .collect())
    }

    /// Range scan `[start, end)` up to `limit` rows: fans out to every
    /// shard concurrently and k-way merges the key-sorted sub-results.
    /// Replica-served consistency levels rotate each shard's scan over
    /// its replicas instead of pinning it on the leader.
    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let consistency = self.cfg.read_consistency;
        let (start, end) = (start.to_vec(), end.to_vec());
        if self.cfg.shards() == 1 {
            let make = move || {
                let (tx, rx) = mpsc::sync_channel(1);
                let (start, end) = (start.clone(), end.clone());
                (Req::Scan { start, end, limit, consistency, resp: tx }, rx)
            };
            return if consistency == ReadConsistency::Leader {
                self.at_leader(0, make)
            } else {
                self.at_replica(0, make)
            };
        }
        let ids: Vec<ShardId> = (0..self.cfg.shards()).collect();
        let make = |_i: usize| {
            let (tx, rx) = mpsc::sync_channel(1);
            let (start, end) = (start.clone(), end.clone());
            (Req::Scan { start, end, limit, consistency, resp: tx }, rx)
        };
        let per = if consistency == ReadConsistency::Leader {
            self.at_shard_leaders(&ids, make)?
        } else {
            self.at_shard_replicas(&ids, make)?
        };
        Ok(merge_sorted(per, limit))
    }

    /// Completed GC cycles on one (shard, node) replica, in completion
    /// order.
    pub fn shard_gc_history(&self, id: NodeId, shard: ShardId) -> Result<Vec<GcOutput>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.req(shard, id, Req::GcHistory { resp: tx })?;
        Ok(rx.recv_timeout(Duration::from_secs(10))?)
    }

    /// Completed GC cycles on one node, concatenated shard by shard.
    pub fn gc_history(&self, id: NodeId) -> Result<Vec<GcOutput>> {
        let mut all = Vec::new();
        for shard in 0..self.cfg.shards() {
            all.extend(self.shard_gc_history(id, shard)?);
        }
        Ok(all)
    }

    /// Wait for any running GC on every shard's leader to finish
    /// (benches).
    pub fn drain_gc(&self) -> Result<()> {
        let ids: Vec<ShardId> = (0..self.cfg.shards()).collect();
        self.at_shard_leaders(&ids, |_| {
            let (tx, rx) = mpsc::sync_channel(1);
            (Req::DrainGc { resp: tx }, rx)
        })?;
        Ok(())
    }

    /// Block until, per shard, every replica has applied the same log
    /// prefix.
    pub fn wait_converged(&self, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        'shards: for shard in 0..self.cfg.shards() {
            loop {
                let statuses: Result<Vec<Status>> = self
                    .node_ids()
                    .iter()
                    .map(|&id| self.shard_status(id, shard))
                    .collect();
                if let Ok(sts) = statuses {
                    let max = sts.iter().map(|s| s.last_applied).max().unwrap_or(0);
                    let min = sts.iter().map(|s| s.last_applied).min().unwrap_or(0);
                    if max == min {
                        continue 'shards;
                    }
                }
                if t0.elapsed() > timeout {
                    bail!("shard {shard} replicas did not converge within {timeout:?}");
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        Ok(())
    }

    /// Drain GC on *every* (shard, node) replica.  On the paper's
    /// testbed follower GC runs on other machines; on this single-core
    /// box it would otherwise compete with the leaders' read service
    /// (DESIGN.md §2).
    pub fn drain_gc_all(&self) -> Result<()> {
        let keys: Vec<(ShardId, NodeId)> = self.slots.lock().unwrap().keys().copied().collect();
        let mut waits = Vec::new();
        for (shard, id) in keys {
            let (tx, rx) = mpsc::sync_channel(1);
            self.req(shard, id, Req::DrainGc { resp: tx })?;
            waits.push((shard, id, rx));
        }
        for (shard, id, rx) in waits {
            rx.recv_timeout(Duration::from_secs(120))
                .map_err(|_| anyhow!("drain_gc timed out on shard {shard} node {id}"))??;
        }
        Ok(())
    }

    /// Fault injection: stop one (shard, node) replica thread
    /// gracefully (in-flight GC finishes — the clean-stop analogue).
    /// The shard's surviving members re-elect once the election
    /// timeout lapses; every other shard group is untouched.
    pub fn kill(&self, shard: ShardId, id: NodeId) -> Result<()> {
        self.stop_node(shard, id, Req::Stop)
    }

    /// Fault injection: stop one replica thread **abruptly** — the
    /// node loop exits without finishing in-flight GC or answering
    /// queued requests (`kill -9`).  Use with [`Self::restart`] to
    /// exercise recovery from genuinely interrupted on-disk state.
    pub fn crash(&self, shard: ShardId, id: NodeId) -> Result<()> {
        self.stop_node(shard, id, Req::Crash)
    }

    fn stop_node(&self, shard: ShardId, id: NodeId, req: Req) -> Result<()> {
        let t = self
            .slots
            .lock()
            .unwrap()
            .remove(&(shard, id))
            .ok_or_else(|| anyhow!("no node {id} for shard {shard}"))?;
        let _ = t.tx.send(req);
        t.mailbox.notify();
        // Wait out both tasks: `wait_done` returning means the task box
        // — and with it the Replica's files — is dropped, so a restart
        // may reopen the data directory immediately.
        if !self.reactor.wait_done(t.task, Duration::from_secs(30)) {
            bail!("node {id} shard {shard} did not stop within 30s");
        }
        if !self.reactor.wait_done(t.applier, Duration::from_secs(30)) {
            bail!("node {id} shard {shard} applier did not stop within 30s");
        }
        // Unregister from the shard's transport: the survivors keep
        // sending heartbeats to the dead node, and those frames must
        // count as dropped rather than queueing forever in a mailbox
        // nobody drains.  Over TCP this also closes the node's
        // listener and connections — the process-kill analogue.
        self.nets[shard as usize].unregister(id);
        *self.leader_cache[shard as usize].lock().unwrap() = None;
        Ok(())
    }

    /// Fault injection: the inverse of [`Self::kill`]/[`Self::crash`].
    /// Re-registers `(shard, id)` on the shard's transport (over TCP
    /// this binds a fresh listener and republishes the address so
    /// peers re-dial) and rebuilds the replica thread from whatever
    /// its data directory holds — raft log replay, engine recovery,
    /// and any interrupted GC cycle's resumption included.
    pub fn restart(&self, shard: ShardId, id: NodeId) -> Result<()> {
        let members = self.shard_members(shard);
        if !members.contains(&id) {
            bail!("node {id} is not a member of shard {shard} ({members:?})");
        }
        {
            let slots = self.slots.lock().unwrap();
            if slots.contains_key(&(shard, id)) {
                bail!("node {id} shard {shard} is still running");
            }
        }
        let net = &self.nets[shard as usize];
        let mailbox = net.register(id)?;
        // Constructor membership is only a hint here: the replica's
        // persisted members sidecar (written on every config change)
        // outranks it, so a node restarted mid-change resumes with
        // exactly the config it last persisted.
        let t = spawn_replica(&self.reactor, &self.cfg, net, shard, id, &members, false, mailbox)?;
        self.slots.lock().unwrap().insert((shard, id), t);
        *self.leader_cache[shard as usize].lock().unwrap() = None;
        Ok(())
    }

    /// The coordinator's membership view of one shard: every node id
    /// it currently operates there (voters plus any still-catching-up
    /// learner), sorted.  This is the roster nemesis drivers and
    /// repair loops should iterate — NOT `1..=nodes`, which is only
    /// the boot-time roster.
    pub fn shard_members(&self, shard: ShardId) -> Vec<NodeId> {
        let mut v = self.membership[shard as usize].lock().unwrap().members.clone();
        v.sort_unstable();
        v
    }

    /// Propose one membership change at the shard's leader, retrying
    /// through leadership moves and the one-in-flight gate, and
    /// treating "already done" rejections as success so a retry after
    /// an indeterminate first attempt converges (DESIGN.md §9).
    fn conf_change(&self, shard: ShardId, cc: ConfChange) -> Result<()> {
        let mut last = String::new();
        for _attempt in 0..40 {
            let Ok(l) = self.shard_leader(shard) else {
                std::thread::sleep(Duration::from_millis(100));
                continue;
            };
            let (tx, rx) = mpsc::sync_channel(1);
            if self.req(shard, l, Req::ConfChange { cc, resp: tx }).is_err() {
                *self.leader_cache[shard as usize].lock().unwrap() = None;
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            match rx.recv_timeout(Duration::from_secs(10)) {
                Ok(Ok(())) => return Ok(()),
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    // Idempotent outcomes: a previous (indeterminate)
                    // attempt already took effect.
                    if msg.contains("already a member")
                        || msg.contains("already a voter")
                        || msg.contains("is not a member")
                        || msg.contains("is not a learner")
                    {
                        return Ok(());
                    }
                    if msg.contains("in flight") {
                        // One change at a time: wait for the pending
                        // entry to commit, then retry.
                        std::thread::sleep(Duration::from_millis(200));
                    } else if msg.contains("not leader") {
                        *self.leader_cache[shard as usize].lock().unwrap() = None;
                        std::thread::sleep(Duration::from_millis(50));
                    } else {
                        return Err(e);
                    }
                    last = msg;
                }
                Err(_) => {
                    // Indeterminate: the change may or may not have
                    // committed.  Refresh the leader and retry — the
                    // idempotent-success arms above absorb a duplicate.
                    *self.leader_cache[shard as usize].lock().unwrap() = None;
                    std::thread::sleep(Duration::from_millis(100));
                    last = format!("timed out awaiting {cc:?} on shard {shard}");
                }
            }
        }
        bail!("conf change {cc:?} did not commit on shard {shard}: {last}")
    }

    /// Grow one shard's Raft group by a brand-new node (DESIGN.md §9):
    /// allocate a fresh id, spawn it as a non-voting learner with an
    /// empty data directory, and propose `AddLearner` at the leader.
    /// The learner catches up through normal replication (or the
    /// streamed snapshot path when the leader already compacted) and
    /// the leader auto-promotes it to voter once its match index is
    /// within `Config::promote_lag` of the log head.  Returns the new
    /// node's id as soon as the `AddLearner` entry commits — poll
    /// [`Self::shard_status`] to observe the promotion.
    pub fn add_node(&self, shard: ShardId) -> Result<NodeId> {
        let (id, members) = {
            let mut m = self.membership[shard as usize].lock().unwrap();
            let id = m.next_id;
            m.next_id += 1;
            (id, m.members.clone())
        };
        // A fresh id never has prior state, but wipe defensively: a
        // stale directory under a recycled path must not smuggle in an
        // old config or log.
        let dir = shard_dir(&self.cfg.base_dir, id, shard);
        let _ = std::fs::remove_dir_all(&dir);
        let net = &self.nets[shard as usize];
        let mailbox = net.register(id)?;
        let t = match spawn_replica(&self.reactor, &self.cfg, net, shard, id, &members, true, mailbox)
        {
            Ok(t) => t,
            Err(e) => {
                net.unregister(id);
                return Err(e);
            }
        };
        self.slots.lock().unwrap().insert((shard, id), t);
        if let Err(e) = self.conf_change(shard, ConfChange::AddLearner(id)) {
            // Roll the spawn back: the group never learned about the
            // node, so tearing it down leaves no trace.
            let _ = self.stop_node(shard, id, Req::Stop);
            return Err(e);
        }
        self.membership[shard as usize].lock().unwrap().members.push(id);
        Ok(id)
    }

    /// Shrink one shard's Raft group (DESIGN.md §9): propose `Remove`
    /// at the leader — which keeps replicating without counting itself
    /// if it is removing *itself*, then steps down and hands
    /// leadership off once the entry commits — and stop the removed
    /// replica's tasks after the change is in.  Safe for the leader's
    /// own id.
    pub fn remove_node(&self, shard: ShardId, id: NodeId) -> Result<()> {
        if !self.shard_members(shard).contains(&id) {
            bail!("node {id} is not a member of shard {shard}");
        }
        self.conf_change(shard, ConfChange::Remove(id))?;
        self.membership[shard as usize].lock().unwrap().members.retain(|&m| m != id);
        // The node may already be dead (removing a crashed member is
        // the repair path) — a missing slot is fine.
        let _ = self.stop_node(shard, id, Req::Stop);
        *self.leader_cache[shard as usize].lock().unwrap() = None;
        Ok(())
    }

    pub fn shutdown(self) -> Result<()> {
        // Ring every doorbell alongside the Stop — a consensus task
        // parked on its tick deadline must notice the request now, not
        // a tick later.
        let ids: Vec<(TaskId, TaskId)> = {
            let mut slots = self.slots.lock().unwrap();
            for t in slots.values() {
                let _ = t.tx.send(Req::Stop);
                t.mailbox.notify();
            }
            slots.drain().map(|(_, t)| (t.task, t.applier)).collect()
        };
        for (task, applier) in ids {
            let _ = self.reactor.wait_done(task, Duration::from_secs(30));
            let _ = self.reactor.wait_done(applier, Duration::from_secs(30));
        }
        for net in &self.nets {
            net.shutdown();
        }
        self.reactor.shutdown();
        Ok(())
    }
}

/// Canonical stale-leader rejection text.  `coordinator::server`
/// parses exactly this shape into its structured `NotLeader` client
/// redirect (`server::parse_not_leader`) — change the two together.
pub(crate) fn not_leader_msg(hint: Option<NodeId>) -> String {
    format!("not leader (hint {hint:?})")
}

/// Max client write commands folded into one consensus round.
const MAX_FOLD: usize = 512;

/// How long a replica parks a linearizable read (barrier unresolved or
/// apply point lagging) before failing it back so the client retries
/// another replica.  Covers an election round with margin.
const READ_BARRIER_TIMEOUT: Duration = Duration::from_secs(3);

/// How long a proposed write may wait for its apply point before the
/// replica fails it back as a stale-leader rejection.  A leader cut
/// off from its quorum (partition) cannot commit, and without this
/// bound the client would park the full 30 s request timeout on a
/// write that is going nowhere.  Failing is safe: the client re-routes
/// and re-proposes, and put/delete re-proposals are idempotent.
const WRITE_COMMIT_TIMEOUT: Duration = Duration::from_secs(3);

/// A read request parked in the replica's read-only lane while its
/// ReadIndex barrier resolves.
enum ReadWork {
    Get {
        key: Vec<u8>,
        resp: SyncSender<Result<Option<Vec<u8>>>>,
    },
    MultiGet {
        keys: Vec<Vec<u8>>,
        resp: SyncSender<Result<Vec<Option<Vec<u8>>>>>,
    },
    Scan {
        start: Vec<u8>,
        end: Vec<u8>,
        limit: usize,
        resp: SyncSender<Result<Vec<(Vec<u8>, Vec<u8>)>>>,
    },
}

/// Execute a read against the local engine and answer the client.
fn serve_read(replica: &Replica, work: ReadWork) {
    match work {
        ReadWork::Get { key, resp } => {
            let _ = resp.send(replica.engine().get(&key));
        }
        ReadWork::MultiGet { keys, resp } => {
            let _ = resp.send(replica.engine().multi_get(&keys));
        }
        ReadWork::Scan { start, end, limit, resp } => {
            let _ = resp.send(replica.engine().scan(&start, &end, limit));
        }
    }
}

/// Route one client read by its consistency level: serve immediately
/// (`Leader` on the leader, `Stale` anywhere), reject (`Leader` on a
/// non-leader), or park it behind a ReadIndex barrier
/// (`Linearizable`) until the barrier resolves and the local apply
/// point covers it.
fn begin_read(
    replica: &mut Replica,
    reads: &mut ReadLane<ReadWork>,
    work: ReadWork,
    consistency: ReadConsistency,
    send_out: &impl Fn(Outbox),
) -> Result<()> {
    match consistency {
        ReadConsistency::Leader => {
            if !replica.node.is_leader() {
                fail_read(work, not_leader_msg(replica.node.leader_hint()));
            } else if replica.node.can_serve_leader_read() {
                serve_read(replica, work);
            } else {
                // Leader without a live lease — possibly deposed and
                // unaware (partitioned-leader shape).  Serving from
                // local state here is the classic stale-read bug, so
                // confirm leadership through a barrier first; a real
                // leader resolves it in one heartbeat round, a deposed
                // one times out and the client re-routes.
                let ctx = reads.begin(work);
                let out = replica.node.request_read(ctx)?;
                send_out(out);
            }
        }
        ReadConsistency::Stale => serve_read(replica, work),
        ReadConsistency::Linearizable => {
            let ctx = reads.begin(work);
            let out = replica.node.request_read(ctx)?;
            send_out(out);
        }
    }
    Ok(())
}

/// Fail a read back to the client (it retries another replica).
fn fail_read(work: ReadWork, msg: String) {
    match work {
        ReadWork::Get { resp, .. } => {
            let _ = resp.send(Err(anyhow!("{msg}")));
        }
        ReadWork::MultiGet { resp, .. } => {
            let _ = resp.send(Err(anyhow!("{msg}")));
        }
        ReadWork::Scan { resp, .. } => {
            let _ = resp.send(Err(anyhow!("{msg}")));
        }
    }
}

/// Max committed entries applied per applier poll: bounds how long the
/// engine lock is held in one stretch so reads and GC interleave even
/// under a large apply backlog.
const APPLY_CHUNK: usize = 256;

/// One replica's engine stats with the consensus-side counters — raft
/// log fsyncs, committed entries, group-commit batching, apply-lane
/// queue depth — overlaid.  This is the view [`Status`] reports and
/// the fsyncs-per-committed-entry figure is computed from.
fn replica_stats(replica: &Replica, lane: &ApplyLane) -> EngineStats {
    let mut s = replica.stats();
    let m = &replica.node.metrics;
    s.log_syncs += m.log_syncs;
    s.entries_committed += m.entries_committed;
    s.group_commit_batches += m.group_commit_batches;
    s.group_commit_entries += m.group_commit_entries;
    s.group_commit_max_batch = s.group_commit_max_batch.max(m.group_commit_max_batch);
    s.apply_queue_depth = s.apply_queue_depth.max(lane.depth_max());
    s
}

/// The consensus half of one (shard, node) replica, scheduled on the
/// shared [`Reactor`].  Each poll is one former `node_loop` turn —
/// network input, tick catch-up, client requests, group-commit flush,
/// read/write completions, GC pump — except that instead of blocking
/// on its mailbox for 300 µs it parks until a doorbell rings or its
/// next tick (or group-commit) deadline fires.
struct ReplicaTask {
    id: NodeId,
    shard: ShardId,
    tick: Duration,
    /// Group-commit budget in µs; 0 = sync inside `propose_batch`.
    group_commit_us: u64,
    net: Net,
    mailbox: Arc<crate::raft::transport::Mailbox>,
    rx: Receiver<Req>,
    replica: Replica,
    lane: Arc<ApplyLane>,
    started: Instant,
    last_tick: Duration,
    /// (commit index awaited, proposed-at, responder)
    pending: Vec<(u64, Instant, SyncSender<Result<()>>)>,
    /// Linearizable reads parked on their ReadIndex barrier.
    reads: ReadLane<ReadWork>,
    /// Armed while proposals await their covering raft-log fsync.
    flush_deadline: Option<Instant>,
}

impl Task for ReplicaTask {
    fn poll(&mut self) -> PollOutcome {
        match self.turn() {
            Ok(outcome) => outcome,
            Err(e) => {
                eprintln!("node {} shard {} crashed: {e:#}", self.id, self.shard);
                self.lane.close_discard();
                PollOutcome::Done
            }
        }
    }
}

impl ReplicaTask {
    fn turn(&mut self) -> Result<PollOutcome> {
        // Disjoint field borrows: `send_out` captures the net while
        // the replica, lanes and queues are mutated independently.
        let Self {
            id,
            shard,
            tick,
            group_commit_us,
            net,
            mailbox,
            rx,
            replica,
            lane,
            started,
            last_tick,
            pending,
            reads,
            flush_deadline,
        } = self;
        let (id, shard) = (*id, *shard);
        let send_out = |out: Outbox| {
            for (dst, msg) in out {
                net.send(id, dst, &msg);
            }
        };

        // 1. Network input.
        let Some(msgs) = mailbox.try_drain() else {
            // Transport shut down: drain what is committed, then exit.
            lane.close();
            return Ok(PollOutcome::Done);
        };
        for (from, msg) in msgs {
            let out = replica.node.handle(from, msg)?;
            send_out(out);
        }

        // 2. Logical time.  Catch-up is capped: a task stalled in a
        // slow engine apply (or starved by a busy worker pool) must not
        // burn its whole election budget in one burst (busy ≠ dead) —
        // it ticks at most twice per poll and forgives the rest of the
        // stall.
        let now = started.elapsed();
        let mut caught_up = 0;
        while now.saturating_sub(*last_tick) >= *tick {
            *last_tick += *tick;
            caught_up += 1;
            if caught_up > 2 {
                // Forgive the stall for election purposes, but charge
                // it to the node's lease clock: a leader lease measured
                // against forgiven (under-counted) ticks could outlive
                // the followers' election timers in wall time.  Charged
                // rounding UP, plus this poll's own un-ticked step —
                // over-crediting only shortens the lease, which is the
                // safe direction.
                let stalled = now.saturating_sub(*last_tick).as_micros();
                let skipped = stalled.div_ceil(tick.as_micros().max(1)) as u64 + 1;
                replica.node.skip_ticks(skipped);
                *last_tick = now;
                break;
            }
            let out = replica.node.tick()?;
            send_out(out);
        }

        // 3. Client requests (fold writes into one consensus round).
        let mut write_cmds: Vec<Command> = Vec::new();
        let mut write_resps: Vec<(usize, SyncSender<Result<()>>)> = Vec::new();
        let mut stop = false;
        while let Ok(req) = rx.try_recv() {
            match req {
                Req::PutBatch { ops, resp } => {
                    if !replica.node.is_leader() {
                        let hint = replica.node.leader_hint();
                        let _ = resp.send(Err(anyhow!("{}", not_leader_msg(hint))));
                        continue;
                    }
                    for (k, v) in ops {
                        write_cmds.push(Command::Put { key: k, value: v });
                    }
                    write_resps.push((write_cmds.len(), resp));
                }
                Req::Delete { key, resp } => {
                    if !replica.node.is_leader() {
                        let hint = replica.node.leader_hint();
                        let _ = resp.send(Err(anyhow!("{}", not_leader_msg(hint))));
                        continue;
                    }
                    write_cmds.push(Command::Delete { key });
                    write_resps.push((write_cmds.len(), resp));
                }
                Req::Get { key, consistency, resp } => {
                    let work = ReadWork::Get { key, resp };
                    begin_read(replica, reads, work, consistency, &send_out)?;
                }
                Req::MultiGet { keys, consistency, resp } => {
                    let work = ReadWork::MultiGet { keys, resp };
                    begin_read(replica, reads, work, consistency, &send_out)?;
                }
                Req::Scan { start, end, limit, consistency, resp } => {
                    let work = ReadWork::Scan { start, end, limit, resp };
                    begin_read(replica, reads, work, consistency, &send_out)?;
                }
                Req::Status { resp } => {
                    let s = replica_stats(replica, lane);
                    let nm = replica.node.metrics;
                    let _ = resp.send(Status {
                        id,
                        shard,
                        role: replica.node.role(),
                        term: replica.node.term(),
                        leader_hint: replica.node.leader_hint(),
                        last_applied: replica.node.last_applied(),
                        raft_vlog_bytes: replica.raft_vlog_bytes(),
                        gc_phase: replica.engine().gc_phase(),
                        gc_cycles: s.gc_cycles,
                        engine: s,
                        snap: SnapProgress {
                            chunks_sent: nm.snap_chunks_sent,
                            bytes_sent: nm.snap_bytes_sent,
                            chunks_recv: nm.snap_chunks_recv,
                            resumes: nm.snap_resumes,
                            streams_done: nm.snap_streams_done,
                        },
                        voters: replica.node.voters().to_vec(),
                        learners: replica.node.learners().to_vec(),
                    });
                }
                Req::ConfChange { cc, resp } => {
                    // Proposed like a write but never folded: the node
                    // enforces one change in flight, and the entry's
                    // apply point (tracked through `pending` like any
                    // write) is the client-visible commit.
                    match replica.propose_conf(cc) {
                        Ok((idx, out)) => {
                            send_out(out);
                            pending.push((idx, Instant::now(), resp));
                        }
                        Err(e) => {
                            let _ = resp.send(Err(e));
                        }
                    }
                }
                Req::DrainGc { resp } => {
                    // Run every pending trigger to completion so the
                    // caller observes a fully settled Post-GC state
                    // (the paper's "loaded, two GC cycles done" setup).
                    let now_ms = started.elapsed().as_millis() as u64;
                    let r = (|| -> Result<()> {
                        for _ in 0..8 {
                            replica.pump_gc(now_ms)?;
                            // `gc_busy` also covers decoupled background
                            // merge jobs and their unreported outputs —
                            // settled means the whole cascade committed.
                            let busy = {
                                let eng = replica.engine();
                                eng.gc_phase() == GcPhase::During || eng.gc_busy()
                            };
                            if busy {
                                replica.finish_gc()?;
                            } else {
                                break;
                            }
                        }
                        Ok(())
                    })();
                    let _ = resp.send(r);
                }
                Req::GcHistory { resp } => {
                    let _ = resp.send(replica.gc_history.clone());
                }
                Req::Stop => stop = true,
                // Abrupt exit: no finish_gc, no responses to anything
                // still queued — pending responders drop, clients see
                // a closed channel and retry elsewhere.  Queued apply
                // work is discarded too; the committed entries
                // re-apply from the log on restart.
                Req::Crash => {
                    lane.close_discard();
                    return Ok(PollOutcome::Done);
                }
            }
            if write_cmds.len() >= MAX_FOLD {
                break;
            }
        }
        let saturated = write_cmds.len() >= MAX_FOLD;

        if !write_cmds.is_empty() {
            match replica.propose_batch(write_cmds) {
                Ok((indexes, out)) => {
                    send_out(out);
                    let now = Instant::now();
                    for (upto, resp) in write_resps {
                        // Command i completes when its index applies.
                        let idx = indexes[upto - 1];
                        pending.push((idx, now, resp));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, resp) in write_resps {
                        let _ = resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }

        // 3b. Group commit: with a budget configured, proposals above
        // were broadcast WITHOUT a local raft-log sync; one fsync at
        // the deadline covers every entry appended since the last one.
        // Commit still requires a quorum of durable copies — the
        // leader's own durable index is simply allowed to arrive last
        // (DESIGN.md §6).
        if *group_commit_us > 0 {
            if replica.node.has_unsynced() {
                let now = Instant::now();
                let budget = Duration::from_micros(*group_commit_us);
                let at = *flush_deadline.get_or_insert(now + budget);
                if now >= at {
                    replica.node.flush_group_commit()?;
                    *flush_deadline = None;
                }
            } else {
                // Followers (or a quorum of durable acks) covered the
                // batch; nothing left to flush.
                *flush_deadline = None;
            }
        }

        // 4. Read lane: barriers that resolved (or failed) via the
        // network input above, apply-point releases, and timeouts.
        // Node results are drained unconditionally — a barrier may
        // resolve after its read already timed out of the lane.
        let (ready, failed) = replica.node.take_read_results();
        let applied = replica.node.last_applied();
        for (ctx, ri) in ready {
            if let Some(w) = reads.on_ready(ctx, ri, applied) {
                serve_read(replica, w);
            }
        }
        for ctx in failed {
            if let Some(w) = reads.on_failed(ctx) {
                let hint = replica.node.leader_hint();
                fail_read(w, format!("read barrier failed (hint {hint:?})"));
            }
        }
        if !reads.is_empty() {
            for w in reads.take_applied(replica.node.last_applied()) {
                serve_read(replica, w);
            }
            for w in reads.take_timed_out(READ_BARRIER_TIMEOUT) {
                fail_read(w, format!("read barrier timed out on node {id} shard {shard}"));
            }
        }

        // 5. Completions.  A write whose apply point never comes —
        // leadership lost after the propose, or a quorum-less leader
        // that cannot commit (partition) — is failed back as a
        // stale-leader rejection instead of parking until the client's
        // 30 s request timeout.  Re-proposal is idempotent, and on a
        // genuinely deposed leader the entry may still commit later:
        // the client-visible outcome is "indeterminate, retried",
        // exactly what the linearizability checker models.
        if !pending.is_empty() {
            let applied = replica.node.last_applied();
            let deposed = !replica.node.is_leader();
            let hint = replica.node.leader_hint();
            pending.retain(|(idx, at, resp)| {
                if *idx <= applied {
                    let _ = resp.send(Ok(()));
                    false
                } else if deposed || at.elapsed() > WRITE_COMMIT_TIMEOUT {
                    let _ = resp.send(Err(anyhow!("{}", not_leader_msg(hint))));
                    false
                } else {
                    true
                }
            });
        }

        // 6. GC lifecycle.  A GC hiccup degrades (retried after
        // restart via the persisted GcState) but never kills the node.
        let now_ms = started.elapsed().as_millis() as u64;
        if let Err(e) = replica.pump_gc(now_ms) {
            eprintln!("node {id} shard {shard}: gc pump error (degraded): {e:#}");
        }

        if stop {
            // Finish any GC so files are consistent on disk; the
            // applier drains what is already committed, kept alive by
            // its own handle on the engine cell.
            let _ = replica.finish_gc();
            lane.close();
            return Ok(PollOutcome::Done);
        }

        // 7. Park.  More folded requests than one turn takes → requeue
        // behind other runnable tasks; otherwise sleep until the next
        // doorbell or the earlier of the tick and group-commit
        // deadlines.
        if saturated {
            return Ok(PollOutcome::Yield);
        }
        let next_tick = *started + *last_tick + *tick;
        let at = flush_deadline.map_or(next_tick, |d| next_tick.min(d));
        Ok(PollOutcome::Pending(Some(at)))
    }
}

/// The apply half of one replica: drains committed entries from the
/// [`ApplyLane`] into the shard's engine (sharing it with the
/// consensus task through the [`EngineCell`] lock), publishes the
/// apply cursor, and rings the replica's doorbell so parked read
/// barriers and write completions re-check it.
struct ApplierTask {
    id: NodeId,
    shard: ShardId,
    lane: Arc<ApplyLane>,
    engine: EngineCell,
    mailbox: Arc<crate::raft::transport::Mailbox>,
}

impl Task for ApplierTask {
    fn poll(&mut self) -> PollOutcome {
        let Some((generation, chunk)) = self.lane.pop_chunk(APPLY_CHUNK) else {
            return PollOutcome::Done;
        };
        if chunk.is_empty() {
            return PollOutcome::Pending(None);
        }
        {
            let mut eng = self.engine.lock();
            for (idx, entry, vref) in chunk {
                // A snapshot install superseded this chunk mid-flight:
                // drop the rest — the installer republishes the cursor.
                if self.lane.generation() != generation {
                    break;
                }
                if let Err(e) = eng.apply(&entry, vref) {
                    let (id, shard) = (self.id, self.shard);
                    eprintln!("node {id} shard {shard}: apply failed at {idx}: {e:#}");
                    self.lane.close_discard();
                    return PollOutcome::Done;
                }
                self.lane.set_applied(idx);
            }
        }
        self.mailbox.notify();
        if self.lane.depth() > 0 {
            PollOutcome::Yield
        } else {
            PollOutcome::Pending(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str, kind: EngineKind, nodes: usize) -> ClusterConfig {
        let base =
            std::env::temp_dir().join(format!("nezha-cluster-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut c = ClusterConfig::new(base, kind, nodes);
        c.engine.memtable_bytes = 64 << 10;
        c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 1 };
        c
    }

    fn sharded(name: &str, kind: EngineKind, nodes: usize, shards: u32) -> ClusterConfig {
        let mut c = cfg(name, kind, nodes);
        c.router = ShardRouter::hash(shards);
        c
    }

    #[test]
    fn three_node_nezha_put_get_scan() {
        let cluster = Cluster::start(cfg("basic", EngineKind::Nezha, 3)).unwrap();
        for i in 0..50u32 {
            cluster.put(format!("key{i:03}").as_bytes(), format!("val{i}").as_bytes()).unwrap();
        }
        assert_eq!(cluster.get(b"key025").unwrap(), Some(b"val25".to_vec()));
        assert_eq!(cluster.get(b"nothere").unwrap(), None);
        let rows = cluster.scan(b"key010", b"key020", 100).unwrap();
        assert_eq!(rows.len(), 10);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batch_writes_commit_atomically_visible() {
        let cluster = Cluster::start(cfg("batch", EngineKind::Original, 3)).unwrap();
        let ops: Vec<_> = (0..100u32)
            .map(|i| (format!("b{i:03}").into_bytes(), vec![i as u8; 32]))
            .collect();
        cluster.put_batch(ops).unwrap();
        assert_eq!(cluster.get(b"b099").unwrap(), Some(vec![99u8; 32]));
        cluster.shutdown().unwrap();
    }

    #[test]
    fn get_batch_matches_single_gets() {
        let cluster = Cluster::start(cfg("mget", EngineKind::Nezha, 3)).unwrap();
        for i in 0..40u32 {
            cluster.put(format!("m{i:03}").as_bytes(), format!("v{i}").as_bytes()).unwrap();
        }
        cluster.delete(b"m007").unwrap();
        let keys: Vec<Vec<u8>> = ["m000", "m007", "m025", "m039", "nope"]
            .iter()
            .map(|k| k.as_bytes().to_vec())
            .collect();
        let batched = cluster.get_batch(&keys).unwrap();
        assert_eq!(batched.len(), keys.len());
        for (k, b) in keys.iter().zip(&batched) {
            assert_eq!(*b, cluster.get(k).unwrap(), "{}", String::from_utf8_lossy(k));
        }
        assert!(cluster.get_batch(&[]).unwrap().is_empty());
        cluster.shutdown().unwrap();
    }

    #[test]
    fn delete_roundtrip() {
        let cluster = Cluster::start(cfg("delete", EngineKind::Nezha, 3)).unwrap();
        cluster.put(b"k", b"v").unwrap();
        assert_eq!(cluster.get(b"k").unwrap(), Some(b"v".to_vec()));
        cluster.delete(b"k").unwrap();
        assert_eq!(cluster.get(b"k").unwrap(), None);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn replicas_converge() {
        let cluster = Cluster::start(cfg("converge", EngineKind::Original, 3)).unwrap();
        for i in 0..30u32 {
            cluster.put(format!("c{i}").as_bytes(), b"x").unwrap();
        }
        // Wait for followers to apply everything.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let statuses: Vec<Status> =
                cluster.node_ids().iter().map(|&id| cluster.status(id).unwrap()).collect();
            let max = statuses.iter().map(|s| s.last_applied).max().unwrap();
            let min = statuses.iter().map(|s| s.last_applied).min().unwrap();
            if max == min && max >= 30 {
                break;
            }
            if Instant::now() > deadline {
                panic!("replicas did not converge: {statuses:?}");
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn gc_under_load_preserves_reads() {
        let base =
            std::env::temp_dir().join(format!("nezha-cluster-gcload-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut c = ClusterConfig::new(base, EngineKind::Nezha, 3);
        c.engine.memtable_bytes = 64 << 10;
        c.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: 1 };
        c.gc.threshold_bytes = 128 << 10; // tiny: force cycles
        let cluster = Cluster::start(c).unwrap();
        for i in 0..300u32 {
            cluster.put(format!("g{i:04}").as_bytes(), &[5u8; 1024]).unwrap();
        }
        cluster.drain_gc().unwrap();
        let st = cluster.status(cluster.wait_for_leader(Duration::from_secs(5)).unwrap()).unwrap();
        assert!(st.gc_cycles >= 1, "expected at least one GC cycle, got {}", st.gc_cycles);
        for i in (0..300u32).step_by(37) {
            assert_eq!(
                cluster.get(format!("g{i:04}").as_bytes()).unwrap(),
                Some(vec![5u8; 1024]),
                "g{i:04}"
            );
        }
        cluster.shutdown().unwrap();
    }

    /// Single-shard clusters must keep the pre-sharding directory
    /// layout so existing data dirs are adopted unchanged.
    #[test]
    fn shard0_layout_is_byte_compatible() {
        let base = Path::new("/b");
        assert_eq!(shard_dir(base, 2, 0), base.join("node-2"));
        assert_eq!(shard_dir(base, 2, 3), base.join("node-2").join("shard-3"));
    }

    /// Tentpole acceptance: a 4-shard cluster answers every op exactly
    /// like a single-shard cluster over the same history — routing and
    /// split/merge are invisible to clients.
    #[test]
    fn four_shards_match_single_shard_semantics() {
        let a = Cluster::start(sharded("shard1ref", EngineKind::Nezha, 3, 1)).unwrap();
        let b = Cluster::start(sharded("shard4", EngineKind::Nezha, 3, 4)).unwrap();
        let ops: Vec<(Vec<u8>, Vec<u8>)> = (0..120u32)
            .map(|i| (format!("sk{i:04}").into_bytes(), format!("val{i}").into_bytes()))
            .collect();
        a.put_batch(ops.clone()).unwrap();
        b.put_batch(ops).unwrap();
        for c in [&a, &b] {
            c.delete(b"sk0007").unwrap();
            c.put(b"sk0010", b"overwritten").unwrap();
        }
        let keys: Vec<Vec<u8>> = (0..130u32).map(|i| format!("sk{i:04}").into_bytes()).collect();
        assert_eq!(a.get_batch(&keys).unwrap(), b.get_batch(&keys).unwrap());
        // Scans merge across shards in key order with the limit honored.
        let sa = a.scan(b"sk0000", b"sk0099", 25).unwrap();
        let sb = b.scan(b"sk0000", b"sk0099", 25).unwrap();
        assert_eq!(sa, sb);
        assert_eq!(sb.len(), 25);
        assert!(sb.windows(2).all(|w| w[0].0 < w[1].0), "merged scan out of order");
        // Unlimited scans agree too (tombstone excluded on both sides).
        assert_eq!(
            a.scan(b"sk", b"sl", 1000).unwrap(),
            b.scan(b"sk", b"sl", 1000).unwrap()
        );
        a.shutdown().unwrap();
        b.shutdown().unwrap();
    }

    /// Tentpole acceptance: replica-served reads (both consistency
    /// levels) answer exactly like leader reads over a settled
    /// history, and the traffic genuinely spreads beyond the leader.
    #[test]
    fn replica_reads_match_leader_reads_and_spread() {
        for consistency in [ReadConsistency::Linearizable, ReadConsistency::Stale] {
            let name = format!("rread-{consistency:?}").to_ascii_lowercase();
            let mut c = cfg(&name, EngineKind::Nezha, 3);
            c.read_consistency = consistency;
            let cluster = Cluster::start(c).unwrap();
            let ops: Vec<(Vec<u8>, Vec<u8>)> = (0..60u32)
                .map(|i| (format!("r{i:03}").into_bytes(), format!("v{i}").into_bytes()))
                .collect();
            cluster.put_batch(ops).unwrap();
            cluster.delete(b"r007").unwrap();
            // Stale reads only promise replica-local state: settle
            // replication so every node answers alike.
            cluster.wait_converged(Duration::from_secs(10)).unwrap();
            let keys: Vec<Vec<u8>> = (0..70u32).map(|i| format!("r{i:03}").into_bytes()).collect();
            let got = cluster.get_batch(&keys).unwrap();
            for (i, v) in got.iter().enumerate() {
                let want = if i == 7 || i >= 60 {
                    None
                } else {
                    Some(format!("v{i}").into_bytes())
                };
                assert_eq!(*v, want, "{consistency:?} r{i:03}");
            }
            assert_eq!(cluster.get(b"r008").unwrap(), Some(b"v8".to_vec()));
            let rows = cluster.scan(b"r000", b"r999", 100).unwrap();
            assert_eq!(rows.len(), 59, "{consistency:?}");
            assert!(rows.windows(2).all(|w| w[0].0 < w[1].0));
            // The batch was big enough to spread: more than one node
            // must have served gets.
            let dist = cluster.read_distribution().unwrap();
            let readers = dist.iter().filter(|(_, gets, _)| *gets > 0).count();
            assert!(readers >= 2, "{consistency:?} reads did not spread: {dist:?}");
            cluster.shutdown().unwrap();
        }
    }

    /// Tentpole: the same cluster over real loopback TCP sockets
    /// answers exactly like the in-process bus — and the frames really
    /// crossed the network stack (wire stats move).
    #[test]
    fn tcp_transport_put_get_scan_matches_bus() {
        let mut c = cfg("tcp-basic", EngineKind::Nezha, 3);
        c.transport = TransportKind::Tcp;
        let cluster = Cluster::start(c).unwrap();
        for i in 0..50u32 {
            cluster.put(format!("t{i:03}").as_bytes(), format!("val{i}").as_bytes()).unwrap();
        }
        cluster.delete(b"t007").unwrap();
        assert_eq!(cluster.get(b"t025").unwrap(), Some(b"val25".to_vec()));
        assert_eq!(cluster.get(b"t007").unwrap(), None);
        assert_eq!(cluster.get(b"nothere").unwrap(), None);
        let rows = cluster.scan(b"t010", b"t030", 100).unwrap();
        assert_eq!(rows.len(), 19);
        let keys: Vec<Vec<u8>> = (0..60u32).map(|i| format!("t{i:03}").into_bytes()).collect();
        let got = cluster.get_batch(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            let want = if i == 7 || i >= 50 { None } else { Some(format!("val{i}").into_bytes()) };
            assert_eq!(*v, want, "t{i:03}");
        }
        let wire = cluster.wire_stats();
        assert!(wire.msgs > 0 && wire.bytes > 0, "no frames crossed TCP: {wire:?}");
        cluster.shutdown().unwrap();
    }

    /// A 2-shard TCP cluster: two independent raft groups, each over
    /// its own sockets, splitting and merging batches transparently.
    #[test]
    fn tcp_transport_two_shards() {
        let mut c = sharded("tcp-shard2", EngineKind::Nezha, 3, 2);
        c.transport = TransportKind::Tcp;
        let cluster = Cluster::start(c).unwrap();
        let ops: Vec<(Vec<u8>, Vec<u8>)> = (0..60u32)
            .map(|i| (format!("u{i:03}").into_bytes(), format!("v{i}").into_bytes()))
            .collect();
        cluster.put_batch(ops).unwrap();
        let keys: Vec<Vec<u8>> = (0..60u32).map(|i| format!("u{i:03}").into_bytes()).collect();
        let got = cluster.get_batch(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, Some(format!("v{i}").into_bytes()), "u{i:03}");
        }
        let rows = cluster.scan(b"u000", b"u999", 1000).unwrap();
        assert_eq!(rows.len(), 60);
        assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "merged scan out of order");
        cluster.shutdown().unwrap();
    }

    /// Each shard group elects its own (preferentially rotated)
    /// leader, and per-shard status rows roll up into the aggregate.
    #[test]
    fn shard_groups_elect_independent_leaders() {
        let cluster = Cluster::start(sharded("shardlead", EngineKind::Nezha, 3, 3)).unwrap();
        for i in 0..30u32 {
            cluster.put(format!("lk{i:02}").as_bytes(), b"v").unwrap();
        }
        let mut leaders = Vec::new();
        for shard in 0..3u32 {
            let l = cluster.shard_leader(shard).unwrap();
            let st = cluster.shard_status(l, shard).unwrap();
            assert_eq!(st.role, Role::Leader, "shard {shard}");
            assert_eq!(st.shard, shard);
            leaders.push(l);
        }
        leaders.sort_unstable();
        leaders.dedup();
        assert_eq!(leaders.len(), 3, "leaders did not spread across nodes: {leaders:?}");
        // Rollup sums per-shard applied counts (each shard applied its
        // own sub-history plus election noops).
        let id = cluster.node_ids()[0];
        let rows = cluster.node_status(id).unwrap();
        let agg = cluster.status(id).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(agg.last_applied, rows.iter().map(|s| s.last_applied).sum::<u64>());
        cluster.shutdown().unwrap();
    }

    /// The tentpole scaling claim: a 64-shard × 3-node cluster (192
    /// replicas, 384 tasks) runs on a worker pool far smaller than one
    /// thread per replica — the reactor multiplexes them.
    #[test]
    fn many_shards_run_on_a_small_worker_pool() {
        let cluster = Cluster::start(sharded("manyshards", EngineKind::Original, 3, 64)).unwrap();
        assert!(
            cluster.reactor.workers() < 64 * 3,
            "expected a multiplexing pool, got {} workers for 192 replicas",
            cluster.reactor.workers()
        );
        for i in 0..64u32 {
            cluster.put(format!("w{i:03}").as_bytes(), format!("v{i}").as_bytes()).unwrap();
        }
        assert_eq!(cluster.get(b"w031").unwrap(), Some(b"v31".to_vec()));
        assert_eq!(cluster.get(b"w063").unwrap(), Some(b"v63".to_vec()));
        cluster.shutdown().unwrap();
    }

    /// With a (deliberately huge) group-commit budget on a single-node
    /// cluster, a lone put can only commit through the deadline flush:
    /// the leader's own durable index is the entire quorum, so nothing
    /// commits until the batched fsync runs.  The put completing at
    /// all proves the timed-out budget flushes a partial batch.
    #[test]
    fn group_commit_deadline_flushes_partial_batch() {
        let mut c = cfg("gcommit", EngineKind::Nezha, 1);
        c.raft.group_commit_us = 50_000;
        let cluster = Cluster::start(c).unwrap();
        cluster.put(b"gk", b"gv").unwrap();
        assert_eq!(cluster.get(b"gk").unwrap(), Some(b"gv".to_vec()));
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let s = cluster.status(1).unwrap().engine;
            if s.group_commit_batches >= 1 {
                assert!(s.group_commit_entries >= 1);
                assert!(s.group_commit_max_batch >= 1);
                break;
            }
            assert!(Instant::now() < deadline, "no group-commit batch recorded: {s:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
        cluster.shutdown().unwrap();
    }
}
