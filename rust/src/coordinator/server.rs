//! Multi-process cluster runtime (`nezha serve`) and the thin TCP
//! client that talks to it.
//!
//! One [`Server`] per process hosts **one node's replica of every
//! shard group** — the deployment shape of the paper's evaluation
//! cluster (DESIGN.md §2).  Raft frames travel over [`TcpNet`] with a
//! fixed peer address map; clients speak a tiny length-prefixed
//! CRC-framed request protocol ([`ClientMsg`]/[`ClientResp`]) on a
//! separate listener.
//!
//! **Port convention.**  The `--peers` list names every node's
//! *client* address; node `n`'s raft listener for shard `s` binds the
//! same host at `client_port + 1 + s`.  A 3-node, 2-shard cluster on
//! one machine therefore spans ports 7100..=7102, 7200..=7202,
//! 7300..=7302 for peers `1=127.0.0.1:7100,2=127.0.0.1:7200,
//! 3=127.0.0.1:7300`.
//!
//! **Routing.**  The server is deliberately dumb: it serves a request
//! from its *local* replica of the routed shard and answers
//! [`ClientResp::NotLeader`] when that replica cannot (writes, or
//! leader-consistency reads, on a follower).  The [`Client`] owns the
//! retry loop: it caches a leader guess per shard, follows hints, and
//! walks the membership when a node is unreachable — the same policy
//! as the in-process `Cluster` handle, minus the fan-out parallelism
//! (it is a *thin* client).

use super::cluster::{spawn_replica, ClusterConfig, NodeSlot, ReadConsistency, Req, Status};
use super::router::{merge_sorted, split_keys, ShardId, ShardRouter};
use crate::raft::transport::tcp::{frame_encode, frame_parse, TcpNet};
use crate::raft::transport::{Mailbox, Net, WireSnapshot};
use crate::raft::{ConfChange, NodeId};
use crate::runtime::reactor::{self, Reactor};
use crate::util::{Decoder, Encoder};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::hash_map::Entry;
use std::collections::{BTreeMap, HashMap};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a server-side request may sit in a shard replica before
/// the handler gives up and the client retries elsewhere.
const SERVER_REQ_TIMEOUT: Duration = Duration::from_secs(30);

/// Client-side budget for one logical operation across all its
/// retries (leader moves, node restarts).  Checked *between*
/// attempts: a single in-flight round-trip against a wedged-but-alive
/// server can extend the total by up to the server-side timeout.
const CLIENT_OP_DEADLINE: Duration = Duration::from_secs(20);

/// The raft listener for shard `s` of a node whose client address is
/// `addr` (see the module docs' port convention).
pub fn raft_addr(addr: SocketAddr, shard: ShardId) -> SocketAddr {
    SocketAddr::new(addr.ip(), addr.port() + 1 + shard as u16)
}

// ---------------------------------------------------------------------
// Client protocol
// ---------------------------------------------------------------------

/// One client request (framed like raft traffic: `len ∥ crc ∥ body`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClientMsg {
    Put { key: Vec<u8>, value: Vec<u8> },
    Delete { key: Vec<u8> },
    Get { key: Vec<u8> },
    /// Batched point read; the thin client pre-splits batches by
    /// shard, but the server re-routes defensively.
    MultiGet { keys: Vec<Vec<u8>> },
    /// Range scan over **one** shard (the client fans out and k-way
    /// merges, exactly like the in-process cluster handle).
    Scan { shard: ShardId, start: Vec<u8>, end: Vec<u8>, limit: u64 },
    /// This node's per-shard status rows.
    Status,
    /// Admin: add `node` to `shard`'s Raft group as a learner
    /// (DESIGN.md §9).  The contacted replica must be the shard
    /// leader (else [`ClientResp::NotLeader`]); the operator starts
    /// the new node's process separately (`nezha serve --learner`).
    AddNode { shard: ShardId, node: NodeId },
    /// Admin: remove `node` from `shard`'s Raft group.  Removing the
    /// leader itself is supported — it transfers leadership after the
    /// change commits.
    RemoveNode { shard: ShardId, node: NodeId },
}

impl ClientMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        match self {
            ClientMsg::Put { key, value } => {
                e.u8(0).len_bytes(key).len_bytes(value);
            }
            ClientMsg::Delete { key } => {
                e.u8(1).len_bytes(key);
            }
            ClientMsg::Get { key } => {
                e.u8(2).len_bytes(key);
            }
            ClientMsg::MultiGet { keys } => {
                e.u8(3).varint(keys.len() as u64);
                for k in keys {
                    e.len_bytes(k);
                }
            }
            ClientMsg::Scan { shard, start, end, limit } => {
                e.u8(4).u32(*shard).len_bytes(start).len_bytes(end).u64(*limit);
            }
            ClientMsg::Status => {
                e.u8(5);
            }
            ClientMsg::AddNode { shard, node } => {
                e.u8(6).u32(*shard).u64(*node);
            }
            ClientMsg::RemoveNode { shard, node } => {
                e.u8(7).u32(*shard).u64(*node);
            }
        }
        e.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Decoder::new(buf);
        Ok(match d.u8()? {
            0 => ClientMsg::Put { key: d.len_bytes()?.to_vec(), value: d.len_bytes()?.to_vec() },
            1 => ClientMsg::Delete { key: d.len_bytes()?.to_vec() },
            2 => ClientMsg::Get { key: d.len_bytes()?.to_vec() },
            3 => {
                let n = d.varint()? as usize;
                let mut keys = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    keys.push(d.len_bytes()?.to_vec());
                }
                ClientMsg::MultiGet { keys }
            }
            4 => ClientMsg::Scan {
                shard: d.u32()?,
                start: d.len_bytes()?.to_vec(),
                end: d.len_bytes()?.to_vec(),
                limit: d.u64()?,
            },
            5 => ClientMsg::Status,
            6 => ClientMsg::AddNode { shard: d.u32()?, node: d.u64()? },
            7 => ClientMsg::RemoveNode { shard: d.u32()?, node: d.u64()? },
            other => bail!("client msg: unknown tag {other}"),
        })
    }
}

/// One row of [`ClientResp::Status`]: this node's view of one shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatusRow {
    pub shard: ShardId,
    pub role: String,
    pub term: u64,
    pub last_applied: u64,
    pub leader_hint: Option<NodeId>,
}

/// Server answer to a [`ClientMsg`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClientResp {
    /// Write acknowledged (committed + applied on the shard leader).
    Ok,
    Value(Option<Vec<u8>>),
    Values(Vec<Option<Vec<u8>>>),
    Rows(Vec<(Vec<u8>, Vec<u8>)>),
    Status(Vec<StatusRow>),
    /// The contacted replica cannot serve this request for `shard`;
    /// retry at `hint` (or walk the membership if `None`).
    NotLeader { shard: ShardId, hint: Option<NodeId> },
    Err(String),
}

fn encode_opt(e: &mut Encoder, v: &Option<Vec<u8>>) {
    match v {
        Some(b) => {
            e.u8(1).len_bytes(b);
        }
        None => {
            e.u8(0);
        }
    }
}

fn decode_opt(d: &mut Decoder) -> Result<Option<Vec<u8>>> {
    Ok(match d.u8()? {
        0 => None,
        _ => Some(d.len_bytes()?.to_vec()),
    })
}

impl ClientResp {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        match self {
            ClientResp::Ok => {
                e.u8(0);
            }
            ClientResp::Value(v) => {
                e.u8(1);
                encode_opt(&mut e, v);
            }
            ClientResp::Values(vs) => {
                e.u8(2).varint(vs.len() as u64);
                for v in vs {
                    encode_opt(&mut e, v);
                }
            }
            ClientResp::Rows(rows) => {
                e.u8(3).varint(rows.len() as u64);
                for (k, v) in rows {
                    e.len_bytes(k).len_bytes(v);
                }
            }
            ClientResp::Status(rows) => {
                e.u8(4).varint(rows.len() as u64);
                for r in rows {
                    e.u32(r.shard).len_bytes(r.role.as_bytes()).u64(r.term).u64(r.last_applied);
                    e.u64(r.leader_hint.map_or(0, |h| h + 1));
                }
            }
            ClientResp::NotLeader { shard, hint } => {
                e.u8(5).u32(*shard).u64(hint.map_or(0, |h| h + 1));
            }
            ClientResp::Err(msg) => {
                e.u8(6).len_bytes(msg.as_bytes());
            }
        }
        e.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Decoder::new(buf);
        Ok(match d.u8()? {
            0 => ClientResp::Ok,
            1 => ClientResp::Value(decode_opt(&mut d)?),
            2 => {
                let n = d.varint()? as usize;
                let mut vs = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    vs.push(decode_opt(&mut d)?);
                }
                ClientResp::Values(vs)
            }
            3 => {
                let n = d.varint()? as usize;
                let mut rows = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    rows.push((d.len_bytes()?.to_vec(), d.len_bytes()?.to_vec()));
                }
                ClientResp::Rows(rows)
            }
            4 => {
                let n = d.varint()? as usize;
                let mut rows = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    let shard = d.u32()?;
                    let role = String::from_utf8_lossy(d.len_bytes()?).into_owned();
                    let term = d.u64()?;
                    let last_applied = d.u64()?;
                    let hint = d.u64()?;
                    rows.push(StatusRow {
                        shard,
                        role,
                        term,
                        last_applied,
                        leader_hint: hint.checked_sub(1),
                    });
                }
                ClientResp::Status(rows)
            }
            5 => {
                let shard = d.u32()?;
                let hint = d.u64()?;
                ClientResp::NotLeader { shard, hint: hint.checked_sub(1) }
            }
            6 => ClientResp::Err(String::from_utf8_lossy(d.len_bytes()?).into_owned()),
            other => bail!("client resp: unknown tag {other}"),
        })
    }
}

/// Lift a replica rejection of the form `"not leader (hint Some(2))"`
/// into a structured redirect; returns `None` for every other error.
/// The shape is single-sourced in `cluster::not_leader_msg` — the two
/// functions form one contract and must change together (pinned by
/// the tests below).
fn parse_not_leader(msg: &str) -> Option<Option<NodeId>> {
    let rest = msg.split("not leader (hint ").nth(1)?;
    if let Some(num) = rest.strip_prefix("Some(") {
        let digits: String = num.chars().take_while(|c| c.is_ascii_digit()).collect();
        return digits.parse().ok().map(Some);
    }
    rest.starts_with("None").then_some(None)
}

/// Read one frame off a client connection.  `Ok(None)` means the peer
/// closed (or the server is shutting down); `Err` means the stream is
/// corrupt, or `deadline` passed, and the connection must be dropped.
/// The stream needs a read timeout set so the loop can poll `closed`
/// and `deadline`.
fn read_frame(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    closed: &AtomicBool,
    deadline: Option<Instant>,
) -> Result<Option<Vec<u8>>> {
    let mut chunk = vec![0u8; 16 << 10];
    loop {
        if let Some((payload, used)) = frame_parse(buf)? {
            buf.drain(..used);
            return Ok(Some(payload));
        }
        if closed.load(Ordering::Relaxed) {
            return Ok(None);
        }
        if deadline.is_some_and(|d| Instant::now() > d) {
            bail!("response timed out");
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(None),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(e) => return Err(e.into()),
        }
    }
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// Configuration for one `nezha serve` process.
#[derive(Clone)]
pub struct ServerOpts {
    /// Which node this process is.
    pub node: NodeId,
    /// Every node's **client** address (raft listeners derive from it
    /// — see the module docs).  Node ids must be `1..=len`.
    pub peers: BTreeMap<NodeId, SocketAddr>,
    /// Engine/raft/GC knobs + data dir + shard router.  `nodes` and
    /// `transport` are derived from `peers`/TCP and need not be set.
    pub cluster: ClusterConfig,
    /// Start this node as a **non-voting learner** of the other peers
    /// (DESIGN.md §9): the join flow is `add-node` at the leader, then
    /// `nezha serve --learner` for the new process.  The replica's
    /// persisted members sidecar outranks this flag on restart, so a
    /// promoted node that restarts comes back as the voter it became.
    pub learner: bool,
}

/// Cloned into each client-connection handler thread.
#[derive(Clone)]
struct ShardPorts {
    txs: Vec<Sender<Req>>,
    doorbells: Vec<Arc<Mailbox>>,
}

struct ServerShared {
    router: ShardRouter,
    consistency: ReadConsistency,
    closed: AtomicBool,
}

/// A running `nezha serve` process: this node's replica of every
/// shard, raft over [`TcpNet`], plus the client-protocol listener.
/// Shard replicas run as tasks on one process-wide [`Reactor`] pool
/// (the same runtime the in-process `Cluster` uses — DESIGN.md §6),
/// so serving many shards does not cost a thread per shard.
pub struct Server {
    node: NodeId,
    client_addr: SocketAddr,
    shared: Arc<ServerShared>,
    ports: ShardPorts,
    nets: Vec<TcpNet>,
    /// One slot per shard: this node's replica of that shard group.
    slots: Vec<NodeSlot>,
    /// The worker pool every shard replica task runs on.
    reactor: Reactor,
    accept_join: Option<JoinHandle<()>>,
}

impl Server {
    pub fn start(opts: ServerOpts) -> Result<Self> {
        let ServerOpts { node, peers, mut cluster, learner } = opts;
        let n = peers.len();
        if n == 0 {
            bail!("serve: empty peer list");
        }
        let ids: Vec<NodeId> = peers.keys().copied().collect();
        if ids != (1..=n as u64).collect::<Vec<_>>() {
            bail!("serve: node ids must be 1..={n}, got {ids:?}");
        }
        let me = *peers.get(&node).ok_or_else(|| anyhow!("serve: node {node} not in peers"))?;
        cluster.nodes = n;
        cluster.transport = crate::raft::TransportKind::Tcp;
        let shards = cluster.shards();
        let reactor = Reactor::new(reactor::default_workers());
        let mut nets = Vec::with_capacity(shards as usize);
        let mut slots = Vec::with_capacity(shards as usize);
        for shard in 0..shards {
            let raft_peers: HashMap<NodeId, SocketAddr> =
                peers.iter().map(|(&id, &addr)| (id, raft_addr(addr, shard))).collect();
            let net = TcpNet::with_peers(raft_peers);
            let mailbox = net.register(node)?;
            // A `--learner` process joins as a non-voter of the OTHER
            // peers' group; a normal process is a voter of the full
            // roster.  Either way the persisted members sidecar wins
            // on restart.
            let members: Vec<NodeId> =
                ids.iter().copied().filter(|&p| !learner || p != node).collect();
            let slot = spawn_replica(
                &reactor,
                &cluster,
                &Net::Tcp(net.clone()),
                shard,
                node,
                &members,
                learner,
                mailbox,
            )?;
            nets.push(net);
            slots.push(slot);
        }
        let shared = Arc::new(ServerShared {
            router: cluster.router.clone(),
            consistency: cluster.read_consistency,
            closed: AtomicBool::new(false),
        });
        let ports = ShardPorts {
            txs: slots.iter().map(|s| s.tx.clone()).collect(),
            doorbells: slots.iter().map(|s| Arc::clone(&s.mailbox)).collect(),
        };
        let listener = TcpListener::bind(me).with_context(|| format!("serve: bind {me}"))?;
        let client_addr = listener.local_addr()?;
        let accept_join = {
            let shared = Arc::clone(&shared);
            let ports = ports.clone();
            std::thread::Builder::new()
                .name("nezha-client-accept".into())
                .spawn(move || client_accept_loop(listener, shared, ports))?
        };
        Ok(Self {
            node,
            client_addr,
            shared,
            ports,
            nets,
            slots,
            reactor,
            accept_join: Some(accept_join),
        })
    }

    pub fn node(&self) -> NodeId {
        self.node
    }

    /// Where this process accepts client connections.
    pub fn client_addr(&self) -> SocketAddr {
        self.client_addr
    }

    /// Aggregate raft wire counters across this node's shard nets.
    pub fn wire_stats(&self) -> WireSnapshot {
        let mut agg = WireSnapshot::default();
        for net in &self.nets {
            agg.absorb(net.stats().snapshot());
        }
        agg
    }

    /// This node's per-shard status rows (the same data `Status`
    /// requests serve remotely).
    pub fn status(&self) -> Vec<StatusRow> {
        status_rows(&self.ports)
    }

    /// Graceful stop: finish in-flight GC, close sockets, wait out
    /// every shard task.  The killed-process fault case needs no
    /// cooperation — peers see connection resets and their frames
    /// count dropped.
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.closed.store(true, Ordering::Relaxed);
        // Stop + doorbell every shard first so tasks parked on tick
        // deadlines notice now, then wait each one out.
        for slot in &self.slots {
            let _ = slot.tx.send(Req::Stop);
            slot.mailbox.notify();
        }
        for slot in self.slots.drain(..) {
            let _ = self.reactor.wait_done(slot.task, Duration::from_secs(30));
            let _ = self.reactor.wait_done(slot.applier, Duration::from_secs(30));
        }
        for net in &self.nets {
            net.shutdown();
        }
        self.reactor.shutdown();
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        Ok(())
    }
}

fn client_accept_loop(listener: TcpListener, shared: Arc<ServerShared>, ports: ShardPorts) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if shared.closed.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let shared = Arc::clone(&shared);
                let ports = ports.clone();
                let _ = std::thread::Builder::new()
                    .name("nezha-client-conn".into())
                    .spawn(move || client_conn_loop(stream, shared, ports));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

fn client_conn_loop(mut stream: TcpStream, shared: Arc<ServerShared>, ports: ShardPorts) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut buf = Vec::new();
    loop {
        match read_frame(&mut stream, &mut buf, &shared.closed, None) {
            Ok(Some(payload)) => {
                let resp = match ClientMsg::decode(&payload) {
                    Ok(msg) => handle_client_msg(&shared, &ports, msg),
                    Err(e) => ClientResp::Err(format!("bad request: {e:#}")),
                };
                if stream.write_all(&frame_encode(&resp.encode())).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => return,
        }
    }
}

/// Send one request into a local shard replica and await its answer.
fn ask<T>(
    ports: &ShardPorts,
    shard: usize,
    make: impl FnOnce(SyncSender<Result<T>>) -> Req,
) -> Result<T> {
    let (tx, rx) = mpsc::sync_channel(1);
    ports.txs[shard].send(make(tx)).map_err(|_| anyhow!("shard {shard} stopped"))?;
    ports.doorbells[shard].notify();
    rx.recv_timeout(SERVER_REQ_TIMEOUT).map_err(|_| anyhow!("shard {shard} request timed out"))?
}

/// Map a replica-level result onto the wire: stale-leader rejections
/// become structured redirects, other failures become `Err`.
fn finish<T>(shard: usize, r: Result<T>, ok: impl FnOnce(T) -> ClientResp) -> ClientResp {
    match r {
        Ok(v) => ok(v),
        Err(e) => {
            let msg = format!("{e:#}");
            match parse_not_leader(&msg) {
                Some(hint) => ClientResp::NotLeader { shard: shard as ShardId, hint },
                None => ClientResp::Err(msg),
            }
        }
    }
}

/// One row per shard, *always* — clients derive the cluster's shard
/// count from this list's length, so a wedged replica yields a
/// placeholder row rather than a shorter answer.
fn status_rows(ports: &ShardPorts) -> Vec<StatusRow> {
    let mut rows = Vec::with_capacity(ports.txs.len());
    for shard in 0..ports.txs.len() {
        let (tx, rx) = mpsc::sync_channel::<Status>(1);
        let mut answered = None;
        if ports.txs[shard].send(Req::Status { resp: tx }).is_ok() {
            ports.doorbells[shard].notify();
            answered = rx.recv_timeout(SERVER_REQ_TIMEOUT).ok();
        }
        rows.push(match answered {
            Some(st) => StatusRow {
                shard: shard as ShardId,
                role: format!("{:?}", st.role),
                term: st.term,
                last_applied: st.last_applied,
                leader_hint: st.leader_hint,
            },
            None => StatusRow {
                shard: shard as ShardId,
                role: "Unreachable".into(),
                term: 0,
                last_applied: 0,
                leader_hint: None,
            },
        });
    }
    rows
}

fn handle_client_msg(shared: &ServerShared, ports: &ShardPorts, msg: ClientMsg) -> ClientResp {
    let consistency = shared.consistency;
    match msg {
        ClientMsg::Put { key, value } => {
            let shard = shared.router.route(&key) as usize;
            let r = ask(ports, shard, |tx| Req::PutBatch { ops: vec![(key, value)], resp: tx });
            finish(shard, r, |()| ClientResp::Ok)
        }
        ClientMsg::Delete { key } => {
            let shard = shared.router.route(&key) as usize;
            let r = ask(ports, shard, |tx| Req::Delete { key, resp: tx });
            finish(shard, r, |()| ClientResp::Ok)
        }
        ClientMsg::Get { key } => {
            let shard = shared.router.route(&key) as usize;
            let r = ask(ports, shard, |tx| Req::Get { key, consistency, resp: tx });
            finish(shard, r, ClientResp::Value)
        }
        ClientMsg::MultiGet { keys } => {
            // Defensive re-split: the thin client sends single-shard
            // batches, but any mix still answers correctly.
            let (per, slots) = split_keys(&shared.router, &keys);
            let mut per_out: Vec<Vec<Option<Vec<u8>>>> = per.iter().map(|_| Vec::new()).collect();
            for (shard, list) in per.into_iter().enumerate() {
                if list.is_empty() {
                    continue;
                }
                let r = ask(ports, shard, |tx| Req::MultiGet { keys: list, consistency, resp: tx });
                match r {
                    Ok(vs) => per_out[shard] = vs,
                    Err(e) => return finish(shard, Err(e), |_: ()| ClientResp::Ok),
                }
            }
            ClientResp::Values(slots.into_iter().map(|(s, p)| per_out[s][p].take()).collect())
        }
        ClientMsg::Scan { shard, start, end, limit } => {
            let shard = shard as usize;
            if shard >= ports.txs.len() {
                return ClientResp::Err(format!("no shard {shard}"));
            }
            let r = ask(ports, shard, |tx| Req::Scan {
                start,
                end,
                limit: limit as usize,
                consistency,
                resp: tx,
            });
            finish(shard, r, ClientResp::Rows)
        }
        ClientMsg::Status => ClientResp::Status(status_rows(ports)),
        ClientMsg::AddNode { shard, node } => {
            let shard = shard as usize;
            if shard >= ports.txs.len() {
                return ClientResp::Err(format!("no shard {shard}"));
            }
            let r = ask(ports, shard, |tx| Req::ConfChange {
                cc: ConfChange::AddLearner(node),
                resp: tx,
            });
            finish_conf(shard, r)
        }
        ClientMsg::RemoveNode { shard, node } => {
            let shard = shard as usize;
            if shard >= ports.txs.len() {
                return ClientResp::Err(format!("no shard {shard}"));
            }
            let r =
                ask(ports, shard, |tx| Req::ConfChange { cc: ConfChange::Remove(node), resp: tx });
            finish_conf(shard, r)
        }
    }
}

/// [`finish`] for membership changes, with the idempotent-success
/// mapping the in-process `Cluster::conf_change` applies (DESIGN.md
/// §9): a client that retries after an indeterminate first attempt —
/// the classic case being the removed leader dying between commit and
/// reply — hits "already a member" / "is not a member"-style
/// rejections at the new leader, and those mean the change is already
/// in, not that it failed.
fn finish_conf(shard: usize, r: Result<()>) -> ClientResp {
    match &r {
        Err(e) => {
            let msg = format!("{e:#}");
            if msg.contains("already a member")
                || msg.contains("already a voter")
                || msg.contains("is not a member")
                || msg.contains("is not a learner")
            {
                return ClientResp::Ok;
            }
        }
        Ok(()) => {}
    }
    finish(shard, r, |()| ClientResp::Ok)
}

// ---------------------------------------------------------------------
// Thin client
// ---------------------------------------------------------------------

/// Blocking single-connection-per-node client for `nezha serve`
/// clusters: routes by shard, caches a per-shard leader guess, and
/// retries across the membership on redirects/failures.
pub struct Client {
    peers: BTreeMap<NodeId, SocketAddr>,
    router: ShardRouter,
    conns: HashMap<NodeId, (TcpStream, Vec<u8>)>,
    leaders: HashMap<ShardId, NodeId>,
    /// Shard count confirmed against a live server (`None` until the
    /// first scan's discovery round-trip).
    discovered_shards: Option<u32>,
    rr: usize,
    /// Jitter source for retry backoff (decorrelates clients that all
    /// observed the same leader failure).
    rng: crate::util::Rng,
}

impl Client {
    /// `peers` is the same node → client-address map the servers were
    /// started with; `shards` should match the cluster's router.  A
    /// mismatch is tolerated: key-addressed ops are re-routed
    /// authoritatively by the servers, and scans validate the real
    /// shard count against a live node before fanning out.
    pub fn connect(peers: BTreeMap<NodeId, SocketAddr>, shards: u32) -> Self {
        Self {
            peers,
            router: ShardRouter::hash(shards),
            conns: HashMap::new(),
            leaders: HashMap::new(),
            discovered_shards: None,
            rr: 0,
            rng: crate::util::Rng::new(std::process::id() as u64 ^ crate::util::now_micros()),
        }
    }

    pub fn shards(&self) -> u32 {
        self.router.shards()
    }

    /// The cluster's true shard count, discovered from the first
    /// reachable node's status rows and cached.  Guards scan fan-out
    /// against a mis-specified `--shards` (which would otherwise
    /// silently truncate results); on mismatch the client's router is
    /// realigned too.
    fn cluster_shards(&mut self) -> Result<u32> {
        if let Some(n) = self.discovered_shards {
            return Ok(n);
        }
        let nodes: Vec<NodeId> = self.peers.keys().copied().collect();
        let mut last_err: Option<anyhow::Error> = None;
        for node in nodes {
            match self.call(node, &ClientMsg::Status) {
                Ok(ClientResp::Status(rows)) if !rows.is_empty() => {
                    let n = rows.len() as u32;
                    if n != self.router.shards() {
                        self.router = ShardRouter::hash(n);
                        self.leaders.clear();
                    }
                    self.discovered_shards = Some(n);
                    return Ok(n);
                }
                Ok(other) => last_err = Some(anyhow!("unexpected status response: {other:?}")),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("no peers to discover the shard count from")))
    }

    /// One framed request/response round-trip against a specific node.
    fn call(&mut self, node: NodeId, msg: &ClientMsg) -> Result<ClientResp> {
        let addr = *self.peers.get(&node).ok_or_else(|| anyhow!("unknown node {node}"))?;
        if let Entry::Vacant(slot) = self.conns.entry(node) {
            let stream = TcpStream::connect_timeout(&addr, Duration::from_millis(500))
                .with_context(|| format!("connect node {node} at {addr}"))?;
            let _ = stream.set_nodelay(true);
            stream.set_read_timeout(Some(Duration::from_millis(200)))?;
            stream.set_write_timeout(Some(Duration::from_secs(5)))?;
            slot.insert((stream, Vec::new()));
        }
        let (stream, buf) = self.conns.get_mut(&node).expect("just inserted");
        let r = (|| -> Result<ClientResp> {
            stream.write_all(&frame_encode(&msg.encode()))?;
            let deadline = Instant::now() + SERVER_REQ_TIMEOUT + Duration::from_secs(5);
            let never = AtomicBool::new(false);
            match read_frame(stream, buf, &never, Some(deadline))? {
                Some(payload) => ClientResp::decode(&payload),
                None => bail!("node {node} closed the connection"),
            }
        })();
        if r.is_err() {
            // Drop the (possibly desynced) connection; the retry loop
            // dials fresh.
            self.conns.remove(&node);
        }
        r
    }

    /// Jittered exponential backoff between retry attempts: sleep a
    /// uniform draw from `[cur/2, cur]`, clamped to the time left
    /// before `deadline`, then double `cur` up to the cap.  Bounded
    /// growth keeps a long outage from pushing the retry cadence past
    /// the election timescale; jitter keeps a fleet of clients that
    /// all saw the same leader die from re-dialing in lockstep.
    fn backoff_sleep(&mut self, cur: &mut Duration, deadline: Instant) {
        const CAP: Duration = Duration::from_millis(640);
        let ms = cur.as_millis() as u64;
        let mut sleep = Duration::from_millis(self.rng.range(ms / 2, ms + 1));
        match deadline.checked_duration_since(Instant::now()) {
            Some(left) => sleep = sleep.min(left),
            None => return, // the deadline check at loop top fails the op
        }
        std::thread::sleep(sleep);
        *cur = (*cur * 2).min(CAP);
    }

    /// Issue `msg` for `shard`, following redirects and walking the
    /// membership until it answers or the op deadline lapses.
    fn shard_call(&mut self, shard: ShardId, msg: &ClientMsg) -> Result<ClientResp> {
        let nodes: Vec<NodeId> = self.peers.keys().copied().collect();
        let deadline = Instant::now() + CLIENT_OP_DEADLINE;
        let mut target = self.leaders.get(&shard).copied().unwrap_or_else(|| {
            self.rr += 1;
            nodes[self.rr % nodes.len()]
        });
        let mut last_err: Option<anyhow::Error> = None;
        let mut backoff = Duration::from_millis(10);
        loop {
            if Instant::now() > deadline {
                let detail = last_err.map_or_else(String::new, |e| format!(": {e:#}"));
                bail!("shard {shard} request exhausted its retry budget{detail}");
            }
            match self.call(target, msg) {
                Ok(ClientResp::NotLeader { hint, .. }) => {
                    self.leaders.remove(&shard);
                    match hint.filter(|h| self.peers.contains_key(h)) {
                        // A fresh redirect is authoritative: follow it
                        // immediately, no backoff.
                        Some(h) if h != target => target = h,
                        _ => {
                            self.rr += 1;
                            target = nodes[self.rr % nodes.len()];
                            self.backoff_sleep(&mut backoff, deadline);
                        }
                    }
                }
                Ok(ClientResp::Err(msg_text)) => {
                    self.leaders.remove(&shard);
                    last_err = Some(anyhow!("{msg_text}"));
                    self.rr += 1;
                    target = nodes[self.rr % nodes.len()];
                    self.backoff_sleep(&mut backoff, deadline);
                }
                Ok(resp) => {
                    // Writes only succeed at the leader; remember it.
                    if matches!(msg, ClientMsg::Put { .. } | ClientMsg::Delete { .. }) {
                        self.leaders.insert(shard, target);
                    }
                    return Ok(resp);
                }
                Err(e) => {
                    self.leaders.remove(&shard);
                    last_err = Some(e);
                    self.rr += 1;
                    target = nodes[self.rr % nodes.len()];
                    self.backoff_sleep(&mut backoff, deadline);
                }
            }
        }
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        let shard = self.router.route(key);
        let msg = ClientMsg::Put { key: key.to_vec(), value: value.to_vec() };
        match self.shard_call(shard, &msg)? {
            ClientResp::Ok => Ok(()),
            other => bail!("unexpected put response: {other:?}"),
        }
    }

    pub fn delete(&mut self, key: &[u8]) -> Result<()> {
        let shard = self.router.route(key);
        let msg = ClientMsg::Delete { key: key.to_vec() };
        match self.shard_call(shard, &msg)? {
            ClientResp::Ok => Ok(()),
            other => bail!("unexpected delete response: {other:?}"),
        }
    }

    pub fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        let shard = self.router.route(key);
        let msg = ClientMsg::Get { key: key.to_vec() };
        match self.shard_call(shard, &msg)? {
            ClientResp::Value(v) => Ok(v),
            other => bail!("unexpected get response: {other:?}"),
        }
    }

    /// Batched point read in input order (split by shard client-side,
    /// one round-trip per involved shard).
    pub fn get_batch(&mut self, keys: &[Vec<u8>]) -> Result<Vec<Option<Vec<u8>>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let (per, slots) = split_keys(&self.router, keys);
        let mut per_out: Vec<Vec<Option<Vec<u8>>>> = per.iter().map(|_| Vec::new()).collect();
        for (shard, list) in per.into_iter().enumerate() {
            if list.is_empty() {
                continue;
            }
            let n = list.len();
            let msg = ClientMsg::MultiGet { keys: list };
            match self.shard_call(shard as ShardId, &msg)? {
                ClientResp::Values(vs) if vs.len() == n => per_out[shard] = vs,
                other => bail!("unexpected multi-get response: {other:?}"),
            }
        }
        Ok(slots.into_iter().map(|(s, p)| per_out[s][p].take()).collect())
    }

    /// Range scan `[start, end)` up to `limit` rows: one sub-scan per
    /// shard (the shard count is confirmed against a live server, so
    /// a wrong client-side `--shards` cannot silently truncate the
    /// result), k-way merged by key.
    pub fn scan(
        &mut self,
        start: &[u8],
        end: &[u8],
        limit: usize,
    ) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let shards = self.cluster_shards()?;
        let mut per = Vec::with_capacity(shards as usize);
        for shard in 0..shards {
            let msg = ClientMsg::Scan {
                shard,
                start: start.to_vec(),
                end: end.to_vec(),
                limit: limit as u64,
            };
            match self.shard_call(shard, &msg)? {
                ClientResp::Rows(rows) => per.push(rows),
                other => bail!("unexpected scan response: {other:?}"),
            }
        }
        Ok(merge_sorted(per, limit))
    }

    /// One node's per-shard status rows.
    pub fn status(&mut self, node: NodeId) -> Result<Vec<StatusRow>> {
        match self.call(node, &ClientMsg::Status)? {
            ClientResp::Status(rows) => Ok(rows),
            other => bail!("unexpected status response: {other:?}"),
        }
    }

    /// Admin: add `node` to `shard`'s group as a learner (follows
    /// `NotLeader` redirects to the shard leader like any write).
    pub fn add_node(&mut self, shard: ShardId, node: NodeId) -> Result<()> {
        match self.shard_call(shard, &ClientMsg::AddNode { shard, node })? {
            ClientResp::Ok => Ok(()),
            other => bail!("unexpected add-node response: {other:?}"),
        }
    }

    /// Admin: remove `node` from `shard`'s group (leader's own id
    /// included — it hands leadership off after the change commits).
    pub fn remove_node(&mut self, shard: ShardId, node: NodeId) -> Result<()> {
        match self.shard_call(shard, &ClientMsg::RemoveNode { shard, node })? {
            ClientResp::Ok => Ok(()),
            other => bail!("unexpected remove-node response: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_msg_roundtrip() {
        let msgs = [
            ClientMsg::Put { key: b"k".to_vec(), value: vec![7; 300] },
            ClientMsg::Delete { key: b"gone".to_vec() },
            ClientMsg::Get { key: b"".to_vec() },
            ClientMsg::MultiGet { keys: vec![b"a".to_vec(), b"bb".to_vec(), Vec::new()] },
            ClientMsg::Scan {
                shard: 3,
                start: b"a".to_vec(),
                end: b"z".to_vec(),
                limit: u64::MAX,
            },
            ClientMsg::Status,
            ClientMsg::AddNode { shard: 0, node: 4 },
            ClientMsg::RemoveNode { shard: 2, node: u64::MAX },
        ];
        for m in &msgs {
            assert_eq!(&ClientMsg::decode(&m.encode()).unwrap(), m);
        }
        assert!(ClientMsg::decode(&[99]).is_err());
        assert!(ClientMsg::decode(&[]).is_err());
    }

    #[test]
    fn client_resp_roundtrip() {
        let resps = [
            ClientResp::Ok,
            ClientResp::Value(None),
            ClientResp::Value(Some(vec![1, 2, 3])),
            ClientResp::Values(vec![None, Some(b"x".to_vec()), Some(Vec::new())]),
            ClientResp::Rows(vec![(b"k".to_vec(), b"v".to_vec()), (Vec::new(), Vec::new())]),
            ClientResp::Status(vec![StatusRow {
                shard: 1,
                role: "Leader".into(),
                term: 9,
                last_applied: 1234,
                leader_hint: Some(2),
            }]),
            ClientResp::NotLeader { shard: 0, hint: Some(3) },
            ClientResp::NotLeader { shard: 2, hint: None },
            ClientResp::Err("boom".into()),
        ];
        for r in &resps {
            assert_eq!(&ClientResp::decode(&r.encode()).unwrap(), r);
        }
        assert!(ClientResp::decode(&[99]).is_err());
    }

    /// The redirect contract: whatever `cluster::not_leader_msg`
    /// emits, `parse_not_leader` must lift — pinned here so the two
    /// sides cannot drift apart silently.
    #[test]
    fn not_leader_contract_matches_cluster_format() {
        use super::super::cluster::not_leader_msg;
        assert_eq!(parse_not_leader(&not_leader_msg(Some(2))), Some(Some(2)));
        assert_eq!(parse_not_leader(&not_leader_msg(None)), Some(None));
    }

    #[test]
    fn not_leader_hints_parse() {
        assert_eq!(parse_not_leader("not leader (hint Some(3))"), Some(Some(3)));
        assert_eq!(parse_not_leader("not leader (hint None)"), Some(None));
        assert_eq!(parse_not_leader("shard 0: not leader (hint Some(12)) extra"), Some(Some(12)));
        assert_eq!(parse_not_leader("read barrier failed (hint Some(1))"), None);
        assert_eq!(parse_not_leader("CONSENSUS_TIMEOUT"), None);
    }

    #[test]
    fn raft_addr_convention() {
        let base: SocketAddr = "127.0.0.1:7100".parse().unwrap();
        assert_eq!(raft_addr(base, 0), "127.0.0.1:7101".parse().unwrap());
        assert_eq!(raft_addr(base, 3), "127.0.0.1:7104".parse().unwrap());
    }
}
