//! Nemesis: a deterministic fault-schedule driver for a live cluster.
//!
//! A nemesis (the Jepsen term) is the adversary thread of a chaos run:
//! while client threads hammer the cluster, the nemesis walks a
//! pre-built schedule of [`NemesisEvent`]s — partition the leader,
//! heal, kill and restart a node, arm a disk fault — each at a fixed
//! offset from the run's start.  The schedule is *data*, so a chaos
//! test seed fully determines which faults fire and (modulo thread
//! scheduling) when; re-running a failing seed replays the same abuse.
//!
//! The nemesis only ever calls public [`Cluster`] surface —
//! [`Cluster::fault_plan`] for network faults,
//! [`Cluster::kill`]/[`Cluster::crash`]/[`Cluster::restart`] for
//! process faults, and [`crate::fault::disk`] for storage faults — so
//! everything it does is equally scriptable from a test by hand.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::cluster::{shard_dir, Cluster};
use crate::coordinator::router::ShardId;
use crate::fault::disk::DiskOp;
use crate::raft::NodeId;

/// One fault (or repair) action.
#[derive(Clone, Debug)]
pub enum NemesisOp {
    /// Cut the current leader of `shard` off from every peer
    /// (symmetric).  Resolved against live status at fire time.
    PartitionLeader { shard: ShardId },
    /// Symmetric cut between two named nodes.
    Partition(NodeId, NodeId),
    /// One-way cut: `from` → `to` frames drop, replies still flow.
    PartitionOneWay(NodeId, NodeId),
    /// Remove every partition (duplication/reorder/link overrides are
    /// left alone — use [`NemesisOp::ClearNetFaults`] for a full
    /// reset).
    Heal,
    /// Clear the entire network fault plan.
    ClearNetFaults,
    /// Graceful stop (flushes GC state on the way out).
    Kill { shard: ShardId, id: NodeId },
    /// Abrupt stop — the `kill -9` analogue; no GC finalization.
    Crash { shard: ShardId, id: NodeId },
    Restart { shard: ShardId, id: NodeId },
    /// Arm a one-shot disk fault against the *current leader* of
    /// `shard`: the `nth` matching `op` on a path under its data dir
    /// containing `file_substr` fails.  Remembers the victim so a
    /// later [`NemesisOp::CrashRemembered`] /
    /// [`NemesisOp::RestartRemembered`] hits the same node even after
    /// leadership moves.
    ArmLeaderDiskFault { shard: ShardId, file_substr: String, op: DiskOp, nth: u64 },
    /// Abruptly stop the smallest-id *follower* of `shard` (resolved
    /// against live status at fire time) and remember it — the
    /// snapshot-stream chaos victim: while it is down the leader
    /// compacts past it, so its restart needs a full catch-up
    /// transfer.
    CrashFollower { shard: ShardId },
    /// Abruptly stop the current leader of `shard` (the snapshot
    /// *sender* in stream chaos; the repair phase restarts it).  Does
    /// not touch the remembered victim.
    CrashLeader { shard: ShardId },
    /// Arm a one-shot disk fault under the *remembered* node's data
    /// dir — unlike [`NemesisOp::ArmLeaderDiskFault`], which targets
    /// the current leader.  Used to tear a snapshot receiver's staging
    /// writes (`file_substr = "snap-stage"`).
    ArmRememberedDiskFault { file_substr: String, op: DiskOp, nth: u64 },
    /// Abruptly stop the node remembered by the last
    /// [`NemesisOp::ArmLeaderDiskFault`] /
    /// [`NemesisOp::CrashFollower`] (no-op if none).
    CrashRemembered,
    RestartRemembered,
    /// Disarm all pending disk faults.
    ClearDiskFaults,
    /// Membership chaos (DESIGN.md §9): grow `shard`'s Raft group by a
    /// brand-new node.  It joins as a learner, catches up (possibly
    /// through a streamed snapshot) and is auto-promoted to voter.
    /// Remembers the joining node so a later
    /// [`NemesisOp::CrashRemembered`] tears it down mid-catch-up.
    AddNode { shard: ShardId },
    /// Membership chaos: remove a named node from `shard`'s group.
    RemoveNode { shard: ShardId, id: NodeId },
    /// Membership chaos: remove the *current leader* of `shard`
    /// (resolved at fire time) — the hardest single-server change: the
    /// leader replicates its own removal without counting itself, then
    /// steps down and hands leadership off once it commits.
    RemoveLeader { shard: ShardId },
    /// Flap the current leader's links: `times` rounds of
    /// `down_ms` fully lossy / `up_ms` healthy, via per-link loss
    /// overrides (not `heal`, so concurrent partitions survive).
    FlapLeaderLink { shard: ShardId, times: u32, down_ms: u64, up_ms: u64 },
    /// Set global frame duplication probability.
    SetDuplication(f64),
    /// Set global reorder probability and extra-latency window (µs).
    SetReorder(f64, u64),
}

/// One scheduled action, `at_ms` after the run starts.
#[derive(Clone, Debug)]
pub struct NemesisEvent {
    pub at_ms: u64,
    pub op: NemesisOp,
}

/// Walks a schedule against a live cluster.  Construct, then hand to a
/// thread with an `Arc<Cluster>`; [`Nemesis::run`] sleeps between
/// events and returns when the schedule is exhausted.
pub struct Nemesis {
    events: Vec<NemesisEvent>,
    /// Human-readable record of everything that fired (with actual
    /// offsets), for test failure dumps.
    log: Vec<String>,
    /// Victim of the last `ArmLeaderDiskFault`.
    remembered: Option<(ShardId, NodeId)>,
}

impl Nemesis {
    pub fn new(mut events: Vec<NemesisEvent>) -> Self {
        events.sort_by_key(|e| e.at_ms);
        Self { events, log: Vec::new(), remembered: None }
    }

    /// The fired-event record (available after [`Nemesis::run`]).
    pub fn log(&self) -> &[String] {
        &self.log
    }

    /// Execute the schedule.  Individual op failures (e.g. restarting
    /// a node that raced a concurrent kill) are recorded in the log
    /// and do not abort the schedule — a nemesis losing a race with
    /// the cluster is normal chaos, not a harness bug.
    pub fn run(&mut self, cluster: &Arc<Cluster>) {
        let start = Instant::now();
        let events = std::mem::take(&mut self.events);
        for ev in events {
            let due = Duration::from_millis(ev.at_ms);
            if let Some(wait) = due.checked_sub(start.elapsed()) {
                std::thread::sleep(wait);
            }
            let outcome = self.apply(cluster, &ev.op);
            let at = start.elapsed().as_millis();
            match outcome {
                Ok(desc) => self.log.push(format!("[{at:>6}ms] {desc}")),
                Err(e) => self.log.push(format!("[{at:>6}ms] {:?} failed: {e:#}", ev.op)),
            }
        }
    }

    fn apply(&mut self, cluster: &Arc<Cluster>, op: &NemesisOp) -> Result<String> {
        let plan = cluster.fault_plan();
        Ok(match op {
            NemesisOp::PartitionLeader { shard } => {
                let leader = cluster.shard_leader(*shard)?;
                let peers: Vec<NodeId> =
                    cluster.node_ids().into_iter().filter(|&p| p != leader).collect();
                plan.isolate(leader, &peers);
                format!("partitioned leader {leader} of shard {shard} from {peers:?}")
            }
            NemesisOp::Partition(a, b) => {
                plan.partition(*a, *b);
                format!("partitioned {a} <-> {b}")
            }
            NemesisOp::PartitionOneWay(from, to) => {
                plan.partition_one_way(*from, *to);
                format!("partitioned one-way {from} -> {to}")
            }
            NemesisOp::Heal => {
                plan.heal();
                "healed all partitions".to_string()
            }
            NemesisOp::ClearNetFaults => {
                plan.clear();
                "cleared the network fault plan".to_string()
            }
            NemesisOp::Kill { shard, id } => {
                cluster.kill(*shard, *id)?;
                format!("killed node {id} shard {shard}")
            }
            NemesisOp::Crash { shard, id } => {
                cluster.crash(*shard, *id)?;
                format!("crashed node {id} shard {shard}")
            }
            NemesisOp::Restart { shard, id } => {
                cluster.restart(*shard, *id)?;
                format!("restarted node {id} shard {shard}")
            }
            NemesisOp::ArmLeaderDiskFault { shard, file_substr, op, nth } => {
                let leader = cluster.shard_leader(*shard)?;
                let dir = shard_dir(&cluster.config().base_dir, leader, *shard);
                let dir_str = dir.to_string_lossy().into_owned();
                crate::fault::disk::arm(&[dir_str, file_substr.clone()], *op, *nth);
                self.remembered = Some((*shard, leader));
                format!(
                    "armed disk fault: {op:?} #{nth} on *{file_substr}* under node \
                     {leader} shard {shard}"
                )
            }
            NemesisOp::CrashFollower { shard } => {
                let leader = cluster.shard_leader(*shard)?;
                let victim = cluster
                    .node_ids()
                    .into_iter()
                    .find(|&p| p != leader)
                    .ok_or_else(|| anyhow::anyhow!("no follower alive to crash"))?;
                cluster.crash(*shard, victim)?;
                self.remembered = Some((*shard, victim));
                format!("crashed follower {victim} of shard {shard} (leader was {leader})")
            }
            NemesisOp::CrashLeader { shard } => {
                let leader = cluster.shard_leader(*shard)?;
                cluster.crash(*shard, leader)?;
                format!("crashed leader {leader} of shard {shard}")
            }
            NemesisOp::ArmRememberedDiskFault { file_substr, op, nth } => match self.remembered {
                Some((shard, id)) => {
                    let dir = shard_dir(&cluster.config().base_dir, id, shard);
                    let dir_str = dir.to_string_lossy().into_owned();
                    crate::fault::disk::arm(&[dir_str, file_substr.clone()], *op, *nth);
                    format!(
                        "armed disk fault: {op:?} #{nth} on *{file_substr}* under remembered \
                         node {id} shard {shard}"
                    )
                }
                None => "arm-remembered-disk-fault: nothing remembered".to_string(),
            },
            NemesisOp::CrashRemembered => match self.remembered {
                Some((shard, id)) => {
                    cluster.crash(shard, id)?;
                    format!("crashed remembered node {id} shard {shard}")
                }
                None => "crash-remembered: nothing remembered".to_string(),
            },
            NemesisOp::RestartRemembered => match self.remembered {
                Some((shard, id)) => {
                    cluster.restart(shard, id)?;
                    format!("restarted remembered node {id} shard {shard}")
                }
                None => "restart-remembered: nothing remembered".to_string(),
            },
            NemesisOp::ClearDiskFaults => {
                crate::fault::disk::clear();
                "cleared disk faults".to_string()
            }
            NemesisOp::AddNode { shard } => {
                let id = cluster.add_node(*shard)?;
                self.remembered = Some((*shard, id));
                format!("added node {id} to shard {shard} as a learner")
            }
            NemesisOp::RemoveNode { shard, id } => {
                cluster.remove_node(*shard, *id)?;
                format!("removed node {id} from shard {shard}")
            }
            NemesisOp::RemoveLeader { shard } => {
                let leader = cluster.shard_leader(*shard)?;
                cluster.remove_node(*shard, leader)?;
                format!("removed leader {leader} of shard {shard}")
            }
            NemesisOp::FlapLeaderLink { shard, times, down_ms, up_ms } => {
                let leader = cluster.shard_leader(*shard)?;
                let peers: Vec<NodeId> =
                    cluster.node_ids().into_iter().filter(|&p| p != leader).collect();
                let lossy = crate::fault::LinkFault { latency_us: None, loss: Some(1.0) };
                for _ in 0..*times {
                    for &p in &peers {
                        plan.set_link(leader, p, lossy);
                        plan.set_link(p, leader, lossy);
                    }
                    std::thread::sleep(Duration::from_millis(*down_ms));
                    for &p in &peers {
                        plan.clear_link(leader, p);
                        plan.clear_link(p, leader);
                    }
                    std::thread::sleep(Duration::from_millis(*up_ms));
                }
                format!("flapped leader {leader} links x{times} ({down_ms}ms down / {up_ms}ms up)")
            }
            NemesisOp::SetDuplication(p) => {
                plan.set_duplication(*p);
                format!("set duplication p={p}")
            }
            NemesisOp::SetReorder(p, window) => {
                plan.set_reorder(*p, *window);
                format!("set reorder p={p} window={window}us")
            }
        })
    }
}
