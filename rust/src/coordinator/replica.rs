//! One replica = Raft node + storage engine + GC trigger policy.
//!
//! This is the glue the paper's Figure 3 shows between the Consensus
//! Control module and the storage modules: the replica owns the
//! KVS-Raft node (whose log *is* the Active ValueLog), routes applies
//! into the engine, and drives the GC lifecycle (rotation → background
//! compaction → snapshot mark → epoch cleanup).

use crate::engine::{self, EngineCell, EngineKind, EngineOpts, EngineStats, KvEngine};
use crate::gc::{FrozenEpoch, GcConfig, GcOutput, GcPhase};
use crate::raft::node::Outbox;
use crate::raft::{Command, Config as RaftConfig, LogIndex, Node, NodeId};
use anyhow::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::MutexGuard;
use std::time::{Duration, Instant};

pub struct Replica {
    /// The consensus node drives an [`EngineCell`] — the engine behind
    /// a lock — so the apply-lane applier task can share it.
    pub node: Node<EngineCell>,
    pub kind: EngineKind,
    pub gc_cfg: GcConfig,
    last_gc_ms: u64,
    /// Completed GC cycles (for the harness).
    pub gc_history: Vec<GcOutput>,
}

/// The read-only request lane's bookkeeping: client reads parked
/// first while their ReadIndex barrier resolves (the leader's term
/// confirmation), then while the local apply point catches up to the
/// barrier's index.  Generic over the parked payload so the protocol
/// layer stays ignorant of responder channels.
pub struct ReadLane<T> {
    next_ctx: u64,
    /// ctx → (parked read, when it was parked).
    waiting_confirm: HashMap<u64, (T, Instant)>,
    /// Barrier resolved; serve when `last_applied >= read_index`.
    waiting_apply: Vec<(LogIndex, T, Instant)>,
}

impl<T> Default for ReadLane<T> {
    fn default() -> Self {
        Self { next_ctx: 0, waiting_confirm: HashMap::new(), waiting_apply: Vec::new() }
    }
}

impl<T> ReadLane<T> {
    pub fn is_empty(&self) -> bool {
        self.waiting_confirm.is_empty() && self.waiting_apply.is_empty()
    }

    /// Park a read and allocate the barrier token to request with.
    pub fn begin(&mut self, work: T) -> u64 {
        self.next_ctx += 1;
        let ctx = self.next_ctx;
        self.waiting_confirm.insert(ctx, (work, Instant::now()));
        ctx
    }

    /// A barrier resolved at `read_index`: returns the read if the
    /// apply point already covers it, else parks it for
    /// [`Self::take_applied`].  Unknown ctxs (already timed out) are
    /// dropped.
    pub fn on_ready(
        &mut self,
        ctx: u64,
        read_index: LogIndex,
        last_applied: LogIndex,
    ) -> Option<T> {
        let (work, since) = self.waiting_confirm.remove(&ctx)?;
        if read_index <= last_applied {
            return Some(work);
        }
        self.waiting_apply.push((read_index, work, since));
        None
    }

    /// A barrier failed (no leader / leadership lost): hand the read
    /// back so the caller can fail or retry it.
    pub fn on_failed(&mut self, ctx: u64) -> Option<T> {
        self.waiting_confirm.remove(&ctx).map(|(w, _)| w)
    }

    /// Reads whose barrier the apply point now covers, in park order.
    pub fn take_applied(&mut self, last_applied: LogIndex) -> Vec<T> {
        let mut due = Vec::new();
        let mut keep = Vec::new();
        for (ri, work, since) in self.waiting_apply.drain(..) {
            if ri <= last_applied {
                due.push(work);
            } else {
                keep.push((ri, work, since));
            }
        }
        self.waiting_apply = keep;
        due
    }

    /// Reads parked longer than `timeout` in either stage (leader
    /// unreachable, or the apply point stalled): the caller fails them
    /// so the client can retry another replica.
    pub fn take_timed_out(&mut self, timeout: Duration) -> Vec<T> {
        let now = Instant::now();
        let mut out = Vec::new();
        let expired: Vec<u64> = self
            .waiting_confirm
            .iter()
            .filter(|(_, (_, since))| now.duration_since(*since) > timeout)
            .map(|(&ctx, _)| ctx)
            .collect();
        for ctx in expired {
            if let Some((w, _)) = self.waiting_confirm.remove(&ctx) {
                out.push(w);
            }
        }
        let mut keep = Vec::new();
        for (ri, work, since) in self.waiting_apply.drain(..) {
            if now.duration_since(since) > timeout {
                out.push(work);
            } else {
                keep.push((ri, work, since));
            }
        }
        self.waiting_apply = keep;
        out
    }
}

/// Directory layout for one replica.
pub fn raft_dir(base: &Path) -> PathBuf {
    base.join("raft")
}

pub fn engine_dir(base: &Path) -> PathBuf {
    base.join("engine")
}

impl Replica {
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        id: NodeId,
        peers: Vec<NodeId>,
        base: &Path,
        kind: EngineKind,
        mut engine_opts: EngineOpts,
        raft_cfg: RaftConfig,
        gc_cfg: GcConfig,
        seed: u64,
    ) -> Result<Self> {
        std::fs::create_dir_all(base)?;
        engine_opts.dir = engine_dir(base);
        engine_opts.raft_dir = raft_dir(base);
        let eng = engine::build(kind, engine_opts)?;
        let cell = EngineCell::new(eng);
        let node = Node::new(id, peers, &raft_dir(base), cell, raft_cfg, seed)?;
        Ok(Self { node, kind, gc_cfg, last_gc_ms: 0, gc_history: Vec::new() })
    }

    /// Open a replica that joins the cluster as a *non-voting learner*
    /// of the config whose voters are `voters` (DESIGN.md §9).  It
    /// catches up via snapshot streaming + AppendEntries and is
    /// promoted by the leader once within [`RaftConfig::promote_lag`].
    #[allow(clippy::too_many_arguments)]
    pub fn open_learner(
        id: NodeId,
        voters: Vec<NodeId>,
        base: &Path,
        kind: EngineKind,
        mut engine_opts: EngineOpts,
        raft_cfg: RaftConfig,
        gc_cfg: GcConfig,
        seed: u64,
    ) -> Result<Self> {
        std::fs::create_dir_all(base)?;
        engine_opts.dir = engine_dir(base);
        engine_opts.raft_dir = raft_dir(base);
        let eng = engine::build(kind, engine_opts)?;
        let cell = EngineCell::new(eng);
        let node = Node::new_learner(id, voters, &raft_dir(base), cell, raft_cfg, seed)?;
        Ok(Self { node, kind, gc_cfg, last_gc_ms: 0, gc_history: Vec::new() })
    }

    /// Lock the shared engine.  Consensus applies (or the apply-lane
    /// applier), reads, and GC all serialize on this lock; hold the
    /// guard only for the duration of one operation.
    pub fn engine(&self) -> MutexGuard<'_, Box<dyn KvEngine>> {
        self.node.sm().lock()
    }

    /// The shared engine cell, for wiring an apply-lane applier task.
    pub fn engine_cell(&self) -> EngineCell {
        self.node.sm().clone()
    }

    pub fn stats(&self) -> EngineStats {
        self.engine().stats()
    }

    /// Total bytes the raft ValueLog has absorbed (the single value
    /// persist).
    pub fn raft_vlog_bytes(&self) -> u64 {
        self.node
            .log
            .vlog_bytes_counter()
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// A cycle finished: mark the Raft snapshot at the cycle's point
    /// and delete every frozen epoch it fully covers.  Epochs holding
    /// entries past the snapshot point (the cycle froze with an apply
    /// backlog) are retained — the engine's stored VRefs still resolve
    /// into them, and the next cycle compacts their tails.
    fn complete_cycle(&mut self, out: GcOutput) -> Result<GcOutput> {
        self.node.log.mark_snapshot(out.last_index, out.last_term)?;
        // Remember, per retained epoch, where the next cycle's flush
        // should seek to (the first byte above the new snapshot point)
        // so it skips the already-compacted prefix instead of
        // re-reading and filtering the whole file.
        for &(epoch, off) in &out.skip_offsets {
            self.node.log.set_epoch_skip(epoch, off);
        }
        self.node.log.drop_epochs_covered_by(out.last_index)?;
        self.gc_history.push(out.clone());
        Ok(out)
    }

    /// Route a completed GC output: flush cycles reclaim epochs via
    /// [`Self::complete_cycle`]; decoupled background merge jobs touch
    /// no epochs — the stack just got cheaper — so they only enter the
    /// history (fig10's per-cycle table).
    fn route_gc_output(&mut self, out: GcOutput) -> Result<GcOutput> {
        if out.is_merge_job {
            self.gc_history.push(out.clone());
            return Ok(out);
        }
        self.complete_cycle(out)
    }

    /// Drive the GC lifecycle.  Called from the node loop between
    /// request batches.  Returns a completed cycle's output, if one
    /// just finished.
    pub fn pump_gc(&mut self, now_ms: u64) -> Result<Option<GcOutput>> {
        if self.kind != EngineKind::Nezha {
            return Ok(None);
        }
        // Completion side.  (Bind the poll result first: the engine
        // guard must drop before `route_gc_output` re-borrows self.)
        let polled = self.engine().poll_gc()?;
        if let Some(out) = polled {
            return self.route_gc_output(out).map(Some);
        }
        // Trigger side (paper's multidimensional triggers: size +
        // schedule floor + load; see GcConfig).  `gc_busy` keeps the
        // trigger off while a background merge job holds the
        // generation allocator — flush cycles and merges are mutually
        // exclusive per engine.
        let (phase, busy) = {
            let eng = self.engine();
            (eng.gc_phase(), eng.gc_busy())
        };
        if phase == GcPhase::During || busy {
            return Ok(None);
        }
        let size_hit = self.node.log.live_epoch_bytes >= self.gc_cfg.threshold_bytes;
        let interval_ok = now_ms.saturating_sub(self.last_gc_ms) >= self.gc_cfg.min_interval_ms;
        // Load trigger: a bounded apply backlog never starves GC — the
        // cycle snapshots at `last_applied`, and the unapplied tail
        // stays in the (retained) frozen epoch for the next cycle.
        // Only genuine overload (backlog above the configured bound)
        // defers the cycle.
        let backlog = self.node.log.last_index().saturating_sub(self.node.last_applied());
        let load_ok = backlog <= self.gc_cfg.max_load_entries;
        // Something must have been applied since the last snapshot, or
        // the flush would be empty.
        let snap_at = self.node.last_applied();
        let progressed = snap_at > self.node.log.snap_index;
        if size_hit && interval_ok && load_ok && progressed {
            let last_term = self.node.log.term_at(snap_at).unwrap_or(0);
            let min_index = self.node.log.snap_index;
            self.node.log.rotate()?;
            let epochs: Vec<FrozenEpoch> = self
                .node
                .log
                .frozen_epoch_inputs()
                .into_iter()
                .map(|(epoch, skip_offset)| FrozenEpoch { epoch, skip_offset })
                .collect();
            self.engine().begin_gc(&epochs, min_index, snap_at, last_term)?;
            self.last_gc_ms = now_ms;
        }
        Ok(None)
    }

    /// Convenience: block until every running cycle AND cascading
    /// background merge job completes (tests, benches, clean
    /// shutdown).  Each output is routed; the flush cycle's output is
    /// returned (merge outputs land in `gc_history` only).
    pub fn finish_gc(&mut self) -> Result<Option<GcOutput>> {
        if self.kind != EngineKind::Nezha {
            return Ok(None);
        }
        let mut flush = None;
        loop {
            let waited = self.engine().wait_gc()?;
            let Some(out) = waited else { break };
            let routed = self.route_gc_output(out)?;
            if !routed.is_merge_job {
                flush = Some(routed);
            }
        }
        Ok(flush)
    }

    /// Leader-side batched propose: append all, persist once, fan out
    /// replication once (the group-commit batcher).  Returns the log
    /// index of each command.
    pub fn propose_batch(&mut self, cmds: Vec<Command>) -> Result<(Vec<u64>, Outbox)> {
        let mut indexes = Vec::with_capacity(cmds.len());
        for cmd in cmds {
            indexes.push(self.node.propose(cmd)?);
        }
        let out = self.node.replicate()?;
        Ok((indexes, out))
    }

    /// Leader-side membership change: append the `ConfChange` entry
    /// (config active immediately — append-time rule) and fan out
    /// replication.  Errors bubble the node's in-flight / membership
    /// validation.
    pub fn propose_conf(&mut self, cc: crate::raft::ConfChange) -> Result<(u64, Outbox)> {
        let idx = self.node.propose_conf(cc)?;
        let out = self.node.replicate()?;
        Ok((idx, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::raft::Message;

    fn base(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-repl-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn replica(name: &str, kind: EngineKind, gc_threshold: u64) -> Replica {
        let b = base(name);
        let mut opts = EngineOpts::new("x", "y");
        opts.memtable_bytes = 64 << 10;
        let gc = GcConfig { threshold_bytes: gc_threshold, ..Default::default() };
        Replica::open(1, vec![], &b, kind, opts, RaftConfig::default(), gc, 7).unwrap()
    }

    /// Single-node cluster: propose + replicate commits immediately.
    fn put(r: &mut Replica, k: &str, v: &[u8]) {
        let (idx, _out) = r
            .propose_batch(vec![Command::Put { key: k.into(), value: v.to_vec() }])
            .unwrap();
        assert!(r.node.last_applied() >= idx[0]);
    }

    fn make_leader(r: &mut Replica) {
        // Single-node: one election round makes it leader.
        for _ in 0..200 {
            let out = r.node.tick().unwrap();
            // Single node wins instantly (quorum of 1).
            let _: Vec<(NodeId, Message)> = out;
            if r.node.is_leader() {
                return;
            }
        }
        panic!("single node failed to elect itself");
    }

    #[test]
    fn single_node_put_get_cycle() {
        let mut r = replica("putget", EngineKind::Nezha, u64::MAX);
        make_leader(&mut r);
        put(&mut r, "hello", b"world");
        assert_eq!(r.engine().get(b"hello").unwrap(), Some(b"world".to_vec()));
    }

    #[test]
    fn gc_triggers_on_size_threshold() {
        let mut r = replica("gctrig", EngineKind::Nezha, 64 << 10);
        make_leader(&mut r);
        for i in 0..200u32 {
            put(&mut r, &format!("key{i:04}"), &[7u8; 512]);
        }
        // Size threshold crossed; pump should start + eventually finish.
        r.pump_gc(1000).unwrap();
        assert_eq!(r.engine().gc_phase(), GcPhase::During);
        r.finish_gc().unwrap();
        assert_eq!(r.engine().gc_phase(), GcPhase::Post);
        // Raft log dropped old epoch; data still readable.
        assert_eq!(r.engine().get(b"key0042").unwrap(), Some(vec![7u8; 512]));
        assert!(r.node.log.snap_index > 0);
    }

    #[test]
    fn writes_continue_during_gc() {
        let mut r = replica("duringgc", EngineKind::Nezha, 32 << 10);
        make_leader(&mut r);
        for i in 0..100u32 {
            put(&mut r, &format!("a{i:03}"), &[1u8; 512]);
        }
        r.pump_gc(0).unwrap();
        // During GC, keep writing.
        for i in 0..50u32 {
            put(&mut r, &format!("b{i:03}"), &[2u8; 512]);
        }
        r.finish_gc().unwrap();
        assert_eq!(r.engine().get(b"a050").unwrap(), Some(vec![1u8; 512]));
        assert_eq!(r.engine().get(b"b025").unwrap(), Some(vec![2u8; 512]));
    }

    /// Satellite regression: a cycle finished through `finish_gc` must
    /// stay in `gc_history` (the old code pushed and immediately
    /// popped it, so only `pump_gc`-finished cycles were recorded).
    #[test]
    fn finish_gc_keeps_history_entry() {
        let mut r = replica("gchist", EngineKind::Nezha, 16 << 10);
        make_leader(&mut r);
        for i in 0..100u32 {
            put(&mut r, &format!("h{i:03}"), &[3u8; 512]);
        }
        r.pump_gc(0).unwrap();
        assert_eq!(r.engine().gc_phase(), GcPhase::During);
        let out = r.finish_gc().unwrap().expect("cycle output returned");
        assert_eq!(r.gc_history.len(), 1, "finish_gc dropped the cycle from history");
        assert_eq!(r.gc_history[0].gen, out.gen);
        assert_eq!(r.gc_history[0].last_index, out.last_index);
        // A second cycle appends.
        for i in 0..100u32 {
            put(&mut r, &format!("i{i:03}"), &[4u8; 512]);
        }
        r.pump_gc(10_000).unwrap();
        r.finish_gc().unwrap();
        assert_eq!(r.gc_history.len(), 2);
    }

    /// Satellite regression: the trigger must fire with a bounded apply
    /// backlog (the old `quiesced` gate made the load trigger dead code
    /// and let the active ValueLog grow without bound under sustained
    /// traffic).  The cycle snapshots at `last_applied`; the unapplied
    /// tail survives in the retained frozen epoch and is compacted by
    /// the next cycle.
    #[test]
    fn gc_triggers_under_apply_backlog() {
        let mut r = replica("gcload", EngineKind::Nezha, 8 << 10);
        make_leader(&mut r);
        for i in 0..40u32 {
            put(&mut r, &format!("a{i:03}"), &[5u8; 512]);
        }
        let applied_at_trigger = r.node.last_applied();
        // Build an apply backlog: propose without replicating.
        for i in 0..20u32 {
            let key = format!("b{i:03}").into_bytes();
            r.node.propose(Command::Put { key, value: vec![6u8; 512] }).unwrap();
        }
        assert!(r.node.log.last_index() > r.node.last_applied(), "backlog exists");
        r.pump_gc(0).unwrap();
        assert_eq!(
            r.engine().gc_phase(),
            GcPhase::During,
            "trigger starved by backlog"
        );
        // Drain the backlog (single-node commit) and finish the cycle.
        r.node.replicate().unwrap();
        let out = r.finish_gc().unwrap().expect("cycle output");
        assert_eq!(out.last_index, applied_at_trigger, "snapshot point = last_applied");
        // The retained epoch carries a prefix-skip offset: the next
        // cycle's flush seeks past the already-compacted prefix.
        let inputs = r.node.log.frozen_epoch_inputs();
        assert_eq!(inputs.len(), 1, "epoch with the backlog tail is retained");
        assert!(inputs[0].1 > 0, "no skip offset recorded for the retained epoch");
        // Backlog values live in the retained frozen epoch.
        assert_eq!(r.engine().get(b"a000").unwrap(), Some(vec![5u8; 512]));
        assert_eq!(r.engine().get(b"b010").unwrap(), Some(vec![6u8; 512]));
        // The next cycle compacts the retained tail; reads stay intact
        // after the old epoch is finally dropped.
        for i in 0..40u32 {
            put(&mut r, &format!("c{i:03}"), &[7u8; 512]);
        }
        r.pump_gc(10_000).unwrap();
        assert_eq!(r.engine().gc_phase(), GcPhase::During, "second cycle runs");
        r.finish_gc().unwrap().expect("second cycle output");
        assert_eq!(r.engine().get(b"b010").unwrap(), Some(vec![6u8; 512]));
        assert_eq!(r.engine().get(b"a039").unwrap(), Some(vec![5u8; 512]));
        assert_eq!(r.engine().get(b"c025").unwrap(), Some(vec![7u8; 512]));
    }

    /// The read lane's three-stage lifecycle: confirm-wait →
    /// apply-wait → served, plus failure hand-back and timeouts.
    #[test]
    fn read_lane_stages_failures_and_timeouts() {
        let mut lane: ReadLane<&'static str> = ReadLane::default();
        assert!(lane.is_empty());
        let a = lane.begin("a");
        let b = lane.begin("b");
        assert_ne!(a, b);
        // `a`'s barrier is already covered by the apply point.
        assert_eq!(lane.on_ready(a, 5, 10), Some("a"));
        // `b` must wait for the apply point to reach its barrier.
        assert_eq!(lane.on_ready(b, 20, 10), None);
        assert!(lane.take_applied(19).is_empty());
        assert_eq!(lane.take_applied(20), vec!["b"]);
        assert!(lane.is_empty());
        // A failed barrier hands the work back exactly once.
        let c = lane.begin("c");
        assert_eq!(lane.on_failed(c), Some("c"));
        assert_eq!(lane.on_failed(c), None);
        // Unknown ctx (already failed/timed out) is dropped quietly.
        assert_eq!(lane.on_ready(c, 1, 10), None);
        // Timeouts expire both stages.
        let _d = lane.begin("d");
        let e = lane.begin("e");
        assert_eq!(lane.on_ready(e, 99, 0), None);
        std::thread::sleep(Duration::from_millis(5));
        let mut out = lane.take_timed_out(Duration::from_millis(1));
        out.sort_unstable();
        assert_eq!(out, vec!["d", "e"]);
        assert!(lane.is_empty());
    }

    #[test]
    fn baselines_never_gc() {
        let mut r = replica("nogc", EngineKind::Original, 1);
        make_leader(&mut r);
        for i in 0..50u32 {
            put(&mut r, &format!("k{i}"), &[1u8; 256]);
        }
        assert!(r.pump_gc(10_000).unwrap().is_none());
        assert_eq!(r.engine().gc_phase(), GcPhase::Pre);
    }

    #[test]
    fn write_amplification_ordering_across_engines() {
        // The paper's headline: Nezha writes each value once, Original
        // ≥3 times.  Compare raft-vlog + engine write volume.
        let value = vec![9u8; 2048];
        let mut totals = std::collections::HashMap::new();
        for kind in [EngineKind::Original, EngineKind::Pasv, EngineKind::Nezha] {
            let mut r = replica(&format!("wa-{}", kind.name()), kind, u64::MAX);
            make_leader(&mut r);
            for i in 0..300u32 {
                put(&mut r, &format!("key{i:05}"), &value);
            }
            let total = r.raft_vlog_bytes() + r.stats().engine_write_bytes();
            totals.insert(kind, total);
        }
        let orig = totals[&EngineKind::Original];
        let pasv = totals[&EngineKind::Pasv];
        let nezha = totals[&EngineKind::Nezha];
        assert!(nezha < pasv, "nezha {nezha} < pasv {pasv}");
        assert!(pasv < orig, "pasv {pasv} < orig {orig}");
        assert!(orig as f64 / nezha as f64 > 2.0, "orig/nezha = {:.2}", orig as f64 / nezha as f64);
    }
}
