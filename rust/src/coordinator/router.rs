//! Deterministic key→shard routing for the multi-Raft cluster.
//!
//! The keyspace is partitioned across `N` independent consensus groups
//! (shards); every replica node hosts one Raft participant per shard.
//! The router is the single source of truth for which group owns a
//! key: it is recorded in `ClusterConfig` so every client and every
//! node derives the same placement, and it must stay stable across
//! restarts (a key that moved shards would strand its data).
//!
//! Two partitioning schemes:
//!
//! * [`ShardRouter::Hash`] — FNV-1a over the whole key, mod `shards`.
//!   Balanced under any key distribution; scans must fan out to every
//!   shard.
//! * [`ShardRouter::Range`] — explicit split points; shard `i` owns
//!   `[points[i-1], points[i])`.  Scans could be pruned to overlapping
//!   shards (the cluster currently fans out to all and lets empty
//!   shards answer cheaply).
//!
//! The pure split/merge helpers here implement the cluster's batch
//! semantics — per-shard sub-batches preserve relative op order, point
//! reads re-merge in input order, scans k-way merge by key — and are
//! property-tested below.  **No cross-shard atomicity**: a multi-shard
//! `put_batch` is linearizable per shard only.

pub type ShardId = u32;

/// One `(key, value)` row as the client API moves it.
pub type Row = (Vec<u8>, Vec<u8>);

/// A key's destination after a batch split: `(shard, position within
/// that shard's sub-batch)`.
pub type KeySlot = (usize, usize);

/// FNV-1a 64-bit over the whole key.  Stable across platforms and
/// process restarts — the routing function is part of the on-disk
/// contract once a cluster has data.
fn fnv1a64(key: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in key {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Deterministic key→shard map (recorded in `ClusterConfig`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardRouter {
    /// `shard = fnv1a64(key) % shards`.
    Hash { shards: u32 },
    /// Byte-wise range partitioning: shard `i` owns keys in
    /// `[points[i-1], points[i])` (shard 0 is unbounded below, shard
    /// `points.len()` unbounded above).  Points must be sorted.
    Range { points: Vec<Vec<u8>> },
}

impl ShardRouter {
    /// Hash-partitioned router over `shards` groups (min 1).
    pub fn hash(shards: u32) -> Self {
        ShardRouter::Hash { shards: shards.max(1) }
    }

    /// Range-partitioned router with the given sorted split points.
    pub fn range(mut points: Vec<Vec<u8>>) -> Self {
        points.sort();
        ShardRouter::Range { points }
    }

    pub fn shards(&self) -> u32 {
        match self {
            ShardRouter::Hash { shards } => (*shards).max(1),
            ShardRouter::Range { points } => points.len() as u32 + 1,
        }
    }

    /// The shard that owns `key`.
    pub fn route(&self, key: &[u8]) -> ShardId {
        match self {
            ShardRouter::Hash { shards } => (fnv1a64(key) % (*shards).max(1) as u64) as ShardId,
            ShardRouter::Range { points } => {
                points.partition_point(|p| p.as_slice() <= key) as ShardId
            }
        }
    }
}

/// Partition a write batch into per-shard sub-batches.  Relative order
/// inside each shard is preserved, and a key always routes to the same
/// shard, so per-key ordering survives the split (the property tests
/// below pin this down).
pub fn split_ops(router: &ShardRouter, ops: Vec<Row>) -> Vec<Vec<Row>> {
    let mut per: Vec<Vec<Row>> = vec![Vec::new(); router.shards() as usize];
    for (k, v) in ops {
        let s = router.route(&k) as usize;
        per[s].push((k, v));
    }
    per
}

/// Partition point-read keys by shard.  Returns the per-shard key
/// lists plus, for each input key in order, its `(shard, position)`
/// slot — enough to re-merge per-shard results into input order.
pub fn split_keys(router: &ShardRouter, keys: &[Vec<u8>]) -> (Vec<Vec<Vec<u8>>>, Vec<KeySlot>) {
    let mut per: Vec<Vec<Vec<u8>>> = vec![Vec::new(); router.shards() as usize];
    let mut slots = Vec::with_capacity(keys.len());
    for k in keys {
        let s = router.route(k) as usize;
        slots.push((s, per[s].len()));
        per[s].push(k.clone());
    }
    (per, slots)
}

/// K-way merge of per-shard scan results (each key-sorted) into one
/// key-sorted row set of at most `limit` rows.  Keys are unique across
/// shards (each key lives on exactly one), so no tie-breaking is
/// needed.
pub fn merge_sorted(mut lists: Vec<Vec<Row>>, limit: usize) -> Vec<Row> {
    let mut idx = vec![0usize; lists.len()];
    let mut out = Vec::new();
    while out.len() < limit {
        let mut win: Option<usize> = None;
        for (l, list) in lists.iter().enumerate() {
            if idx[l] < list.len() {
                let better = match win {
                    None => true,
                    Some(w) => list[idx[l]].0 < lists[w][idx[w]].0,
                };
                if better {
                    win = Some(l);
                }
            }
        }
        let Some(w) = win else { break };
        out.push(std::mem::take(&mut lists[w][idx[w]]));
        idx[w] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::BTreeMap;

    fn routers(g: &mut prop::Gen) -> ShardRouter {
        if g.bool() {
            ShardRouter::hash(g.usize_in(1..9) as u32)
        } else {
            let points = g.vec(0..6, |g| g.key(1..6));
            ShardRouter::range(points)
        }
    }

    #[test]
    fn hash_routing_is_deterministic_and_in_range() {
        let r = ShardRouter::hash(7);
        for i in 0..500u32 {
            let k = format!("user{i:08}").into_bytes();
            let s = r.route(&k);
            assert!(s < 7);
            assert_eq!(s, r.route(&k));
        }
        // One shard maps everything to 0.
        let one = ShardRouter::hash(1);
        assert_eq!(one.route(b"anything"), 0);
        assert_eq!(one.shards(), 1);
        // Degenerate configs clamp instead of dividing by zero.
        assert_eq!(ShardRouter::hash(0).shards(), 1);
    }

    #[test]
    fn hash_routing_is_roughly_balanced() {
        let r = ShardRouter::hash(4);
        let mut counts = [0usize; 4];
        for i in 0..4000u32 {
            counts[r.route(format!("user{i:010}").as_bytes()) as usize] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!((500..2000).contains(&c), "shard {s} got {c} of 4000");
        }
    }

    #[test]
    fn range_routing_respects_split_points() {
        let r = ShardRouter::range(vec![b"g".to_vec(), b"p".to_vec()]);
        assert_eq!(r.shards(), 3);
        assert_eq!(r.route(b"apple"), 0);
        assert_eq!(r.route(b"g"), 1); // split point belongs to the right
        assert_eq!(r.route(b"melon"), 1);
        assert_eq!(r.route(b"p"), 2);
        assert_eq!(r.route(b"zebra"), 2);
    }

    /// Satellite property: splitting a batch preserves per-key order
    /// (each shard list is exactly the route-filtered subsequence),
    /// and replaying the per-shard sub-batches reproduces the same
    /// last-write-wins state as replaying the batch globally.
    #[test]
    fn prop_split_preserves_per_key_ordering() {
        prop::check("shard-split-order", 300, |g| {
            let router = routers(g);
            let n = g.usize_in(0..120);
            let ops: Vec<(Vec<u8>, Vec<u8>)> =
                (0..n).map(|i| (g.key(1..10), vec![i as u8, g.u8()])).collect();
            let per = split_ops(&router, ops.clone());
            if per.len() != router.shards() as usize {
                return Err(format!("{} shard lists for {} shards", per.len(), router.shards()));
            }
            for (s, list) in per.iter().enumerate() {
                let expect: Vec<_> = ops
                    .iter()
                    .filter(|(k, _)| router.route(k) as usize == s)
                    .cloned()
                    .collect();
                if *list != expect {
                    return Err(format!("shard {s} list is not the routed subsequence"));
                }
            }
            // Last-write-wins model equivalence.
            let mut global: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
            for (k, v) in &ops {
                global.insert(k.clone(), v.clone());
            }
            let mut sharded: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
            for list in per {
                for (k, v) in list {
                    sharded.insert(k, v);
                }
            }
            if global != sharded {
                return Err("sharded replay diverged from global replay".into());
            }
            Ok(())
        });
    }

    /// Satellite property: `split_keys` slots reassemble per-shard
    /// results into exact input order.
    #[test]
    fn prop_split_keys_restores_input_order() {
        prop::check("shard-key-slots", 300, |g| {
            let router = routers(g);
            let keys = g.vec(0..80, |g| g.key(1..10));
            let (per, slots) = split_keys(&router, &keys);
            if slots.len() != keys.len() {
                return Err("slot per input key".into());
            }
            for (i, (s, p)) in slots.iter().enumerate() {
                if per[*s][*p] != keys[i] {
                    return Err(format!("slot {i} points at the wrong key"));
                }
            }
            // Simulate per-shard answers (echo the key) and re-merge.
            let answers: Vec<Vec<Vec<u8>>> = per;
            let merged: Vec<Vec<u8>> = slots.iter().map(|&(s, p)| answers[s][p].clone()).collect();
            if merged != keys {
                return Err("re-merge is not input order".into());
            }
            Ok(())
        });
    }

    /// Satellite property: fanning a scan out per shard and k-way
    /// merging equals scanning the global sorted dataset.
    #[test]
    fn prop_scan_merge_equals_global_sort() {
        prop::check("shard-scan-merge", 300, |g| {
            let router = routers(g);
            let mut global: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
            for _ in 0..g.usize_in(0..100) {
                global.insert(g.key(1..10), g.bytes(0..8));
            }
            let mut per: Vec<Vec<(Vec<u8>, Vec<u8>)>> = vec![Vec::new(); router.shards() as usize];
            // BTreeMap iteration is key-sorted, so each shard list is too.
            for (k, v) in &global {
                per[router.route(k) as usize].push((k.clone(), v.clone()));
            }
            let limit = g.usize_in(0..120);
            let merged = merge_sorted(per, limit);
            let expect: Vec<(Vec<u8>, Vec<u8>)> = global
                .iter()
                .take(limit)
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            if merged != expect {
                return Err(format!("merge of {} keys diverged at limit {limit}", global.len()));
            }
            Ok(())
        });
    }
}
