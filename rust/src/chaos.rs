//! Chaos harness: concurrent clients + nemesis + linearizability check.
//!
//! One [`run_chaos`] call is a complete Jepsen-style experiment:
//!
//! 1. start a replicated cluster (Bus or TCP transport) with its
//!    [`crate::fault::FaultPlan`] seeded from the run seed,
//! 2. spawn client threads doing register writes/reads over a small
//!    key space, each recording a [`crate::check::ClientOp`] history
//!    entry with monotonic call/return timestamps,
//! 3. walk a [`Nemesis`] schedule against the live cluster — leader
//!    partitions, link flapping, disk-fault + crash + restart, torn
//!    group commit, torn partitioned merge, torn snapshot stream,
//!    membership churn (add a learner, crash it mid-catch-up, remove
//!    the leader) — picked by [`ScheduleKind`],
//! 4. repair everything (heal, disarm disk faults, restart dead
//!    nodes), let the clients run a short post-heal grace period so
//!    the rejoined node serves traffic,
//! 5. stop, merge histories, and run the WGL checker
//!    ([`crate::check::check_history`]) in the mode matching the
//!    cluster's read consistency.
//!
//! Failed writes are recorded as *indeterminate* (the proposal may
//! commit after the client gave up — the checker treats them as
//! optional); failed reads carry no information and are dropped.
//!
//! Determinism: the fault plan's drop/duplicate/reorder verdicts are a
//! pure function of the seed (see `fault::tests` and the SimNet trace
//! test), and the nemesis schedule is fixed data derived from the
//! options — so a seed names one abuse pattern exactly.  Thread
//! interleaving still varies between runs; the *checker* is what turns
//! that nondeterminism into a pass/fail oracle.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::check::{check_history, ClientOp, Mode, OpKind, Violation};
use crate::coordinator::{Cluster, ClusterConfig, Nemesis, NemesisEvent, NemesisOp, ReadConsistency};
use crate::engine::EngineKind;
use crate::fault::disk::DiskOp;
use crate::raft::{NetConfig, NodeId, TransportKind};
use crate::util::{now_micros, Rng};

/// Which abuse pattern the nemesis walks (offsets are fractions of
/// [`ChaosOpts::run_ms`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Symmetrically partition the leader off at 20%, heal at 60%.
    PartitionHeal,
    /// Arm a one-shot LEVELS-manifest fsync fault on the leader at
    /// 15% (its next GC commit point fails mid-cycle), crash that
    /// node abruptly at 45%, restart it at 65% — the genuine
    /// "kill -9 mid-GC, recover from disk" drill.
    CrashRestartMidGc,
    /// Three down/up rounds of fully-lossy leader links starting at
    /// 20%, with background duplication + reordering for the whole
    /// run.
    FlappingLinks,
    /// Torn group commit: the run enables raft-log fsync plus a 500 µs
    /// group-commit budget, arms a one-shot fsync fault on the
    /// leader's raft log at 15% (so its next group-commit flush fails
    /// *after* the pipelined AppendEntries broadcast already left),
    /// crashes the remembered node at 45%, restarts it at 65%.
    /// Exercises the pipelining safety argument: entries the dead
    /// leader never made durable locally may still commit through the
    /// follower quorum, and every acknowledged write must survive its
    /// recovery.
    TornGroupCommit,
    /// Torn partitioned merge: the run shrinks the GC budgets so level
    /// merges split into multiple key-range partitions on >1 worker,
    /// arms a one-shot fsync fault on the leader's *second* sorted-run
    /// output at 15% (a partition's — or a flush's — `finish()` fails
    /// mid-GC with sibling partitions already sealed), crashes the
    /// remembered node at 45%, restarts it at 65%.  Recovery must
    /// resume or replan the merge deterministically (same plan ⇒
    /// byte-identical stack; see `gc::tests`) and the history must
    /// stay linearizable.
    TornPartitionedMerge,
    /// Torn snapshot stream (DESIGN.md §8): crash a follower at 5% and
    /// leave it down while the leader GCs and compacts its raft log
    /// past it, so the restart at 45% needs a run-shipping catch-up
    /// transfer.  The run shrinks the snapshot chunk size (4 KiB) so
    /// that transfer spans many chunks, and at 38% arms a one-shot
    /// write fault on the victim's `snap-stage/` dir — the receiver's
    /// staging tears mid-stream and must resume from the durable
    /// prefix via the sender's stall re-offer.  At 60% the receiver is
    /// crashed again mid/post-transfer and restarted at 68% (resume
    /// across a process death), and at 80% the *sender* (leader) is
    /// crashed — a leader change mid-transfer; the repair phase
    /// restarts it.  Every acknowledged write must survive, i.e. a
    /// torn transfer is never read as installed.
    TornSnapshotStream,
    /// Membership churn (DESIGN.md §9): grow the group by a brand-new
    /// node at 10% (it joins as a learner and catches up — with small
    /// snapshot chunks so a streamed transfer spans many frames),
    /// crash that joining node mid-catch-up at 30%, remove the
    /// *current leader* at 45% (it replicates its own removal without
    /// counting itself, steps down on commit and transfers
    /// leadership), restart the joiner at 55%, and clear residual
    /// network faults at 70%.  The cluster churns 3 → 4 → 3 members
    /// under live load and every acknowledged write must stay
    /// linearizable throughout.
    MembershipChurn,
}

impl ScheduleKind {
    pub const ALL: [ScheduleKind; 7] = [
        ScheduleKind::PartitionHeal,
        ScheduleKind::CrashRestartMidGc,
        ScheduleKind::FlappingLinks,
        ScheduleKind::TornGroupCommit,
        ScheduleKind::TornPartitionedMerge,
        ScheduleKind::TornSnapshotStream,
        ScheduleKind::MembershipChurn,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ScheduleKind::PartitionHeal => "partition-heal",
            ScheduleKind::CrashRestartMidGc => "crash-restart-mid-gc",
            ScheduleKind::FlappingLinks => "flapping-links",
            ScheduleKind::TornGroupCommit => "torn-group-commit",
            ScheduleKind::TornPartitionedMerge => "torn-partitioned-merge",
            ScheduleKind::TornSnapshotStream => "torn-snapshot-stream",
            ScheduleKind::MembershipChurn => "membership-churn",
        }
    }

    pub fn parse(s: &str) -> Option<ScheduleKind> {
        ScheduleKind::ALL.into_iter().find(|k| k.name() == s)
    }

    fn events(self, run_ms: u64) -> Vec<NemesisEvent> {
        let at = |f: f64| (run_ms as f64 * f) as u64;
        match self {
            ScheduleKind::PartitionHeal => vec![
                NemesisEvent { at_ms: at(0.2), op: NemesisOp::PartitionLeader { shard: 0 } },
                NemesisEvent { at_ms: at(0.6), op: NemesisOp::Heal },
            ],
            ScheduleKind::CrashRestartMidGc => vec![
                NemesisEvent {
                    at_ms: at(0.15),
                    op: NemesisOp::ArmLeaderDiskFault {
                        shard: 0,
                        file_substr: "LEVELS".to_string(),
                        op: DiskOp::Sync,
                        nth: 1,
                    },
                },
                NemesisEvent { at_ms: at(0.45), op: NemesisOp::CrashRemembered },
                NemesisEvent { at_ms: at(0.5), op: NemesisOp::ClearDiskFaults },
                NemesisEvent { at_ms: at(0.65), op: NemesisOp::RestartRemembered },
            ],
            ScheduleKind::FlappingLinks => vec![
                NemesisEvent { at_ms: at(0.05), op: NemesisOp::SetDuplication(0.05) },
                NemesisEvent { at_ms: at(0.05), op: NemesisOp::SetReorder(0.10, 500) },
                NemesisEvent {
                    at_ms: at(0.2),
                    op: NemesisOp::FlapLeaderLink { shard: 0, times: 3, down_ms: 150, up_ms: 150 },
                },
            ],
            ScheduleKind::TornGroupCommit => vec![
                NemesisEvent {
                    at_ms: at(0.15),
                    op: NemesisOp::ArmLeaderDiskFault {
                        shard: 0,
                        file_substr: "raft-".to_string(),
                        op: DiskOp::Sync,
                        nth: 1,
                    },
                },
                NemesisEvent { at_ms: at(0.45), op: NemesisOp::CrashRemembered },
                NemesisEvent { at_ms: at(0.5), op: NemesisOp::ClearDiskFaults },
                NemesisEvent { at_ms: at(0.65), op: NemesisOp::RestartRemembered },
            ],
            ScheduleKind::TornPartitionedMerge => vec![
                NemesisEvent {
                    at_ms: at(0.15),
                    op: NemesisOp::ArmLeaderDiskFault {
                        shard: 0,
                        // Sorted-run outputs sync in `finish()`; nth 2
                        // lets the first output (usually the L0 flush)
                        // seal, so the fault lands in a later output —
                        // under partitioned merges, one partition of a
                        // multi-partition job.
                        file_substr: "sorted-".to_string(),
                        op: DiskOp::Sync,
                        nth: 2,
                    },
                },
                NemesisEvent { at_ms: at(0.45), op: NemesisOp::CrashRemembered },
                NemesisEvent { at_ms: at(0.5), op: NemesisOp::ClearDiskFaults },
                NemesisEvent { at_ms: at(0.65), op: NemesisOp::RestartRemembered },
            ],
            ScheduleKind::TornSnapshotStream => vec![
                NemesisEvent { at_ms: at(0.05), op: NemesisOp::CrashFollower { shard: 0 } },
                NemesisEvent {
                    at_ms: at(0.38),
                    // Tear the receiver's staging mid-stream: the nth
                    // chunk write under its snap-stage/ dir fails.
                    // One-shot, so the stall re-offer then resumes
                    // cleanly from the durable prefix.
                    op: NemesisOp::ArmRememberedDiskFault {
                        file_substr: "snap-stage".to_string(),
                        op: DiskOp::Write,
                        nth: 6,
                    },
                },
                NemesisEvent { at_ms: at(0.45), op: NemesisOp::RestartRemembered },
                NemesisEvent { at_ms: at(0.6), op: NemesisOp::CrashRemembered },
                NemesisEvent { at_ms: at(0.62), op: NemesisOp::ClearDiskFaults },
                NemesisEvent { at_ms: at(0.68), op: NemesisOp::RestartRemembered },
                NemesisEvent { at_ms: at(0.8), op: NemesisOp::CrashLeader { shard: 0 } },
            ],
            ScheduleKind::MembershipChurn => vec![
                NemesisEvent { at_ms: at(0.1), op: NemesisOp::AddNode { shard: 0 } },
                NemesisEvent { at_ms: at(0.3), op: NemesisOp::CrashRemembered },
                NemesisEvent { at_ms: at(0.45), op: NemesisOp::RemoveLeader { shard: 0 } },
                NemesisEvent { at_ms: at(0.55), op: NemesisOp::RestartRemembered },
                NemesisEvent { at_ms: at(0.7), op: NemesisOp::ClearNetFaults },
            ],
        }
    }
}

/// One chaos experiment's knobs.
#[derive(Clone, Debug)]
pub struct ChaosOpts {
    /// Seeds the fault plan, the client op streams, and the data dir
    /// name.  Same seed ⇒ same abuse pattern.
    pub seed: u64,
    pub schedule: ScheduleKind,
    pub read_consistency: ReadConsistency,
    pub transport: TransportKind,
    pub clients: usize,
    /// Nominal run length; the post-heal grace period adds ~25%.
    pub run_ms: u64,
    /// Data directory; defaults to a seed-named temp dir (removed on
    /// success, kept on violation for the post-mortem).
    pub dir: Option<PathBuf>,
}

impl ChaosOpts {
    pub fn new(seed: u64, schedule: ScheduleKind) -> Self {
        Self {
            seed,
            schedule,
            read_consistency: ReadConsistency::Linearizable,
            transport: TransportKind::Inproc,
            clients: 3,
            run_ms: 4_000,
            dir: None,
        }
    }
}

/// What a chaos run produced.
#[derive(Debug)]
pub struct ChaosReport {
    pub writes: usize,
    pub reads: usize,
    /// Writes whose ack was lost (errored/timed out); the checker
    /// treats them as may-or-may-not-have-happened.
    pub indeterminate: usize,
    /// `None` = history checked clean.
    pub violation: Option<Violation>,
    /// The nemesis's fired-event record, for failure dumps.
    pub nemesis_log: Vec<String>,
    /// Nodes that were dead at repair time and restarted.
    pub restarted: Vec<NodeId>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violation.is_none()
    }
}

const KEYS: usize = 6;

/// Stored value size.  The register payload is the first 8 bytes; the
/// zero padding keeps the vlog growing fast enough that GC cycles
/// genuinely run during a few-second chaos window.
const VALUE_BYTES: usize = 256;

fn chaos_key(k: usize) -> Vec<u8> {
    format!("chaos-key-{k}").into_bytes()
}

fn encode_value(v: u64) -> Vec<u8> {
    let mut buf = v.to_be_bytes().to_vec();
    buf.resize(VALUE_BYTES, 0);
    buf
}

fn parse_value(bytes: &[u8]) -> Option<u64> {
    bytes.get(..8).map(|b| u64::from_be_bytes(b.try_into().expect("8 bytes")))
}

/// Run one chaos experiment end to end.  `Ok(report)` even when the
/// checker found a violation — `report.violation` is the verdict;
/// `Err` means the harness itself broke (cluster never started, node
/// never restarted, ...).
pub fn run_chaos(opts: &ChaosOpts) -> Result<ChaosReport> {
    let dir = opts.dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!(
            "nezha-chaos-{}-{}-{:x}-{}",
            opts.schedule.name(),
            match opts.transport {
                TransportKind::Inproc => "bus",
                TransportKind::Tcp => "tcp",
            },
            opts.seed,
            std::process::id()
        ))
    });
    let _ = std::fs::remove_dir_all(&dir);

    let mut cfg = ClusterConfig::new(&dir, EngineKind::Nezha, 3);
    cfg.engine.memtable_bytes = 64 << 10;
    cfg.gc.threshold_bytes = 32 << 10; // plenty of GC cycles during the run
    cfg.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: opts.seed };
    cfg.seed = opts.seed;
    cfg.read_consistency = opts.read_consistency;
    cfg.transport = opts.transport;
    cfg.faults = Arc::new(crate::fault::FaultPlan::new(opts.seed));
    if opts.schedule == ScheduleKind::TornGroupCommit {
        // The torn-write drill needs real fsyncs (the armed fault
        // fires on the raft log's sync path) and a group-commit
        // window for the broadcast to be pipelined ahead of.
        cfg.raft.fsync = true;
        cfg.raft.group_commit_us = 500;
    }
    if opts.schedule == ScheduleKind::TornPartitionedMerge {
        // Shrink the level budgets so the few-second run genuinely
        // cascades into level merges, and make partitions tiny so
        // those merges split into several key ranges on two workers —
        // the armed fault then tears one partition's sealed output.
        cfg.engine.gc_level0_bytes = 32 << 10;
        cfg.engine.gc_fanout = 4;
        cfg.engine.gc_partition_bytes = 4 << 10;
        cfg.engine.gc_workers = 2;
    }
    if opts.schedule == ScheduleKind::TornSnapshotStream {
        // Small chunks so the catch-up transfer spans many frames (the
        // mid-stream tears must land *inside* it), and level budgets
        // low enough that sealed runs exist to ship.
        cfg.raft.snap_chunk_bytes = 4 << 10;
        cfg.raft.snap_window = 2;
        cfg.engine.gc_level0_bytes = 32 << 10;
        cfg.engine.gc_fanout = 4;
    }
    if opts.schedule == ScheduleKind::MembershipChurn {
        // The joining learner may need a streamed snapshot (the leader
        // GCs during the run); small chunks make that transfer span
        // many frames so the 30% crash genuinely lands mid-stream.
        cfg.raft.snap_chunk_bytes = 4 << 10;
        cfg.raft.snap_window = 2;
        cfg.engine.gc_level0_bytes = 32 << 10;
        cfg.engine.gc_fanout = 4;
    }
    // A clean slate in case an earlier run in this process armed one.
    crate::fault::disk::clear();

    let cluster = Arc::new(Cluster::start(cfg).context("chaos cluster start")?);
    let stop = Arc::new(AtomicBool::new(false));

    // Client threads: register writes/reads over a small key space,
    // values unique per (client, seq) so the checker can map a read
    // back to its write.
    let mut workers = Vec::new();
    for c in 0..opts.clients {
        let cluster = Arc::clone(&cluster);
        let stop = Arc::clone(&stop);
        let seed = opts.seed;
        workers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(seed.wrapping_mul(1_000_003).wrapping_add(c as u64 + 1));
            let mut history: Vec<ClientOp> = Vec::new();
            let mut seq = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let key = chaos_key(rng.below(KEYS as u64) as usize);
                if rng.chance(0.5) {
                    seq += 1;
                    let value = ((c as u64 + 1) << 32) | seq;
                    let call_us = now_micros();
                    let res = cluster.put(&key, &encode_value(value));
                    let ret_us = now_micros();
                    history.push(ClientOp {
                        client: c as u32,
                        key,
                        kind: OpKind::Write { value, acked: res.is_ok() },
                        call_us,
                        ret_us: if res.is_ok() { ret_us } else { u64::MAX },
                    });
                } else {
                    let call_us = now_micros();
                    let res = cluster.get(&key);
                    let ret_us = now_micros();
                    if let Ok(v) = res {
                        history.push(ClientOp {
                            client: c as u32,
                            key,
                            kind: OpKind::Read { value: v.as_deref().and_then(parse_value) },
                            call_us,
                            ret_us,
                        });
                    }
                    // A failed read observed nothing: drop it.
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            history
        }));
    }

    // The nemesis walks its schedule on this thread.
    let mut nemesis = Nemesis::new(opts.schedule.events(opts.run_ms));
    nemesis.run(&cluster);

    // Repair: heal the network, disarm disk faults, restart whatever
    // died, and insist on a leader before the grace period.
    cluster.fault_plan().clear();
    crate::fault::disk::clear();
    let alive = cluster.node_ids();
    let mut restarted = Vec::new();
    // Walk the *membership view*, not `1..=3`: churn schedules may
    // have added node 4 and removed an original — a removed node must
    // stay down, a dead member (whatever its id) must come back.
    for id in cluster.shard_members(0) {
        if !alive.contains(&id) {
            cluster.restart(0, id).with_context(|| format!("repair restart of node {id}"))?;
            restarted.push(id);
        }
    }
    cluster.wait_for_leader(Duration::from_secs(10)).context("no leader after repair")?;

    // Post-heal grace: the rejoined/restarted node takes live traffic.
    std::thread::sleep(Duration::from_millis(opts.run_ms / 4));
    stop.store(true, Ordering::Relaxed);

    let mut history: Vec<ClientOp> = Vec::new();
    let mut indeterminate = 0;
    for w in workers {
        let h = w.join().expect("client thread panicked");
        indeterminate +=
            h.iter().filter(|o| matches!(o.kind, OpKind::Write { acked: false, .. })).count();
        history.extend(h);
    }
    let writes = history.iter().filter(|o| matches!(o.kind, OpKind::Write { .. })).count();
    let reads = history.len() - writes;

    let mode = match opts.read_consistency {
        ReadConsistency::Stale => Mode::Stale,
        _ => Mode::Linearizable,
    };
    let violation = check_history(&history, mode).err();

    let report = ChaosReport {
        writes,
        reads,
        indeterminate,
        violation,
        nemesis_log: nemesis.log().to_vec(),
        restarted,
    };

    let cluster = Arc::try_unwrap(cluster)
        .map_err(|_| anyhow::anyhow!("cluster Arc still shared after join"))?;
    cluster.shutdown().context("chaos cluster shutdown")?;
    if report.ok() && opts.dir.is_none() {
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full chaos experiments live in `tests/chaos.rs` (they take
    // seconds each); here we only pin the cheap pure pieces.

    #[test]
    fn schedules_are_sorted_and_in_range() {
        for kind in ScheduleKind::ALL {
            let evs = kind.events(4_000);
            assert!(!evs.is_empty());
            assert!(evs.windows(2).all(|w| w[0].at_ms <= w[1].at_ms), "{kind:?}");
            assert!(evs.iter().all(|e| e.at_ms < 4_000), "{kind:?}");
            assert_eq!(ScheduleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScheduleKind::parse("nope"), None);
    }

    #[test]
    fn value_codec_round_trips() {
        let v = (7u64 << 32) | 42;
        assert_eq!(parse_value(&encode_value(v)), Some(v));
        assert_eq!(encode_value(v).len(), VALUE_BYTES);
        assert_eq!(parse_value(b""), None);
        assert_eq!(parse_value(b"short"), None);
    }
}
