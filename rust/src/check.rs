//! Linearizability checking over recorded client histories.
//!
//! The chaos harness ([`crate::chaos`]) records every client operation
//! as a [`ClientOp`]: a register write or read against one key, with
//! wall-clock call/return instants ([`crate::util::now_micros`]).  This
//! module decides whether such a history is **linearizable** — some
//! total order of the operations (i) respects real time (an op that
//! returned before another was called orders first) and (ii) matches
//! sequential register semantics (every read returns the latest
//! preceding write, or `None` before any write).
//!
//! The search is the Wing & Gong / WGL construction: per key
//! (independent registers linearize independently), depth-first over
//! "which pending op linearizes next", with the classic candidate rule
//! — an op may go next only if it was *called* no later than the
//! earliest *return* among pending required ops — and memoization on
//! `(linearized-set, register value)` so revisited configurations
//! prune (Lowe's optimization, the difference between exponential and
//! usable).
//!
//! **Indeterminate writes** (the client saw an error or a timeout; the
//! proposal may still commit later) get `ret_us = ∞` and become
//! *optional*: the search may linearize them at any point after their
//! call, or never.  This is exactly Jepsen's `:info` op treatment.
//!
//! [`Mode::Stale`] is the weaker contract for
//! `ReadConsistency::Stale`: stale reads may lag acknowledged writes,
//! so full linearizability is out — instead every read must return
//! `None` or a value whose write was *called* before the read
//! returned (no fabricated and no from-the-future values), and the
//! writes alone must still be linearizable.

use std::collections::{HashMap, HashSet};

/// One recorded client operation against one key.
#[derive(Clone, Debug)]
pub struct ClientOp {
    /// Recording client (diagnostics only; the checker is shared-memory
    /// linearizability, not per-client sequential consistency).
    pub client: u32,
    pub key: Vec<u8>,
    pub kind: OpKind,
    /// Invocation instant, µs (monotonic, shared by all clients).
    pub call_us: u64,
    /// Return instant, µs.  `u64::MAX` marks an indeterminate op
    /// (errored/timed out — it may or may not have taken effect).
    pub ret_us: u64,
}

/// Register semantics: unique-valued writes, point reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// `acked = false` ⇒ indeterminate (optional in the search).
    Write { value: u64, acked: bool },
    Read { value: Option<u64> },
}

/// What contract to hold the history to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Linearizable,
    /// Writes linearizable; reads bounded by "no fabricated, no
    /// future values" (see module docs).
    Stale,
}

/// A checker verdict: which key failed and why.
#[derive(Clone, Debug)]
pub struct Violation {
    pub key: Vec<u8>,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "key {:?}: {}", String::from_utf8_lossy(&self.key), self.detail)
    }
}

/// Check a whole history (all keys) against `mode`.
pub fn check_history(ops: &[ClientOp], mode: Mode) -> Result<(), Violation> {
    let mut per_key: HashMap<&[u8], Vec<&ClientOp>> = HashMap::new();
    for op in ops {
        per_key.entry(&op.key).or_default().push(op);
    }
    // Deterministic key order so a failing run reports stably.
    let mut keys: Vec<&[u8]> = per_key.keys().copied().collect();
    keys.sort_unstable();
    for key in keys {
        let mut kops = per_key.remove(key).expect("key listed");
        kops.sort_by_key(|o| (o.call_us, o.ret_us));
        let res = match mode {
            Mode::Linearizable => check_key(&kops),
            Mode::Stale => check_key_stale(&kops),
        };
        if let Err(detail) = res {
            return Err(Violation { key: key.to_vec(), detail });
        }
    }
    Ok(())
}

/// Effective return instant: indeterminate ops never constrain the
/// candidate rule.
fn ret_of(op: &ClientOp) -> u64 {
    match op.kind {
        OpKind::Write { acked: false, .. } => u64::MAX,
        _ => op.ret_us,
    }
}

fn required(op: &ClientOp) -> bool {
    !matches!(op.kind, OpKind::Write { acked: false, .. })
}

/// Fixed-size-free bitset key for the memo table.
fn mask_of(done: &[bool]) -> Vec<u64> {
    let mut m = vec![0u64; done.len().div_ceil(64)];
    for (i, &d) in done.iter().enumerate() {
        if d {
            m[i / 64] |= 1 << (i % 64);
        }
    }
    m
}

/// WGL search for one key's register history.
fn check_key(ops: &[&ClientOp]) -> Result<(), String> {
    // Cheap pre-pass: a read returning a value no write ever carried
    // can never linearize; fail it without burning search time.
    let written: HashSet<u64> = ops
        .iter()
        .filter_map(|o| match o.kind {
            OpKind::Write { value, .. } => Some(value),
            _ => None,
        })
        .collect();
    for o in ops {
        if let OpKind::Read { value: Some(v) } = o.kind {
            if !written.contains(&v) {
                return Err(format!("read returned {v}, which no write ever wrote"));
            }
        }
    }

    let n = ops.len();
    let mut done = vec![false; n];
    let mut reg: Option<u64> = None;
    // (index linearized, register value it replaced)
    let mut stack: Vec<(usize, Option<u64>)> = Vec::new();
    let mut memo: HashSet<(Vec<u64>, Option<u64>)> = HashSet::new();
    // Resume point after backtracking: start scanning candidates
    // strictly after the op we just undid.
    let mut resume = 0usize;

    loop {
        // Done when every required op is linearized (leftover
        // indeterminate writes simply never took effect).
        if ops.iter().enumerate().all(|(i, o)| done[i] || !required(o)) {
            return Ok(());
        }
        // Candidate bound: the earliest return among pending required
        // ops.  Anything called after that cannot go first.
        let bound = ops
            .iter()
            .enumerate()
            .filter(|&(i, o)| !done[i] && required(o))
            .map(|(_, o)| ret_of(o))
            .min()
            .unwrap_or(u64::MAX);
        let mut advanced = false;
        for i in resume..n {
            if done[i] || ops[i].call_us > bound {
                continue;
            }
            // Does op i linearize against the current register?
            let next_reg = match ops[i].kind {
                OpKind::Write { value, .. } => Some(value),
                OpKind::Read { value } => {
                    if value != reg {
                        continue;
                    }
                    reg
                }
            };
            done[i] = true;
            let memo_key = (mask_of(&done), next_reg);
            if !memo.insert(memo_key) {
                done[i] = false;
                continue; // configuration already explored
            }
            stack.push((i, reg));
            reg = next_reg;
            resume = 0;
            advanced = true;
            break;
        }
        if advanced {
            continue;
        }
        // Dead end: undo the last choice and try later candidates.
        match stack.pop() {
            Some((i, prev_reg)) => {
                done[i] = false;
                reg = prev_reg;
                resume = i + 1;
            }
            None => {
                return Err(format!(
                    "no linearization exists ({} ops; first unexplained: {})",
                    n,
                    first_unexplained(ops)
                ));
            }
        }
    }
}

/// Diagnostic: the earliest-returning read (reads are what make
/// register histories fail).
fn first_unexplained(ops: &[&ClientOp]) -> String {
    ops.iter()
        .filter(|o| matches!(o.kind, OpKind::Read { .. }))
        .min_by_key(|o| o.ret_us)
        .map(|o| {
            format!(
                "client {} read {:?} in [{}, {}]µs",
                o.client,
                match o.kind {
                    OpKind::Read { value } => value,
                    _ => None,
                },
                o.call_us,
                o.ret_us
            )
        })
        .unwrap_or_else(|| "(no reads)".to_string())
}

/// The `Stale` contract for one key (see module docs).
fn check_key_stale(ops: &[&ClientOp]) -> Result<(), String> {
    // 1. No fabricated and no from-the-future read values: the value's
    //    write must have been *called* before the read *returned*.
    let mut write_call: HashMap<u64, u64> = HashMap::new();
    for o in ops {
        if let OpKind::Write { value, .. } = o.kind {
            write_call.insert(value, o.call_us);
        }
    }
    for o in ops {
        if let OpKind::Read { value: Some(v) } = o.kind {
            match write_call.get(&v) {
                None => return Err(format!("stale read returned {v}, which was never written")),
                Some(&wc) if wc > o.ret_us => {
                    return Err(format!(
                        "stale read returned {v} before its write was even called \
                         (write call {wc}µs > read return {}µs)",
                        o.ret_us
                    ));
                }
                Some(_) => {}
            }
        }
    }
    // 2. The writes alone must still linearize (they go through the
    //    leader regardless of read consistency).
    let writes: Vec<&ClientOp> =
        ops.iter().copied().filter(|o| matches!(o.kind, OpKind::Write { .. })).collect();
    check_key(&writes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(client: u32, value: u64, call: u64, ret: u64) -> ClientOp {
        ClientOp {
            client,
            key: b"k".to_vec(),
            kind: OpKind::Write { value, acked: true },
            call_us: call,
            ret_us: ret,
        }
    }

    fn w_maybe(client: u32, value: u64, call: u64) -> ClientOp {
        ClientOp {
            client,
            key: b"k".to_vec(),
            kind: OpKind::Write { value, acked: false },
            call_us: call,
            ret_us: u64::MAX,
        }
    }

    fn r(client: u32, value: Option<u64>, call: u64, ret: u64) -> ClientOp {
        ClientOp {
            client,
            key: b"k".to_vec(),
            kind: OpKind::Read { value },
            call_us: call,
            ret_us: ret,
        }
    }

    #[test]
    fn empty_and_trivial_histories_pass() {
        assert!(check_history(&[], Mode::Linearizable).is_ok());
        let h = [w(1, 10, 0, 5), r(1, Some(10), 6, 8)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
        let h = [r(1, None, 0, 2)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
    }

    #[test]
    fn stale_read_after_acked_write_fails() {
        // w=10 fully returned before the read began, yet the read saw
        // the initial state: the canonical linearizability violation.
        let h = [w(1, 10, 0, 5), r(2, None, 10, 12)];
        let err = check_history(&h, Mode::Linearizable).unwrap_err();
        assert!(err.detail.contains("no linearization"), "{err}");
    }

    #[test]
    fn old_value_after_newer_acked_write_fails() {
        let h = [w(1, 10, 0, 5), w(1, 20, 6, 9), r(2, Some(10), 15, 18)];
        assert!(check_history(&h, Mode::Linearizable).is_err());
    }

    #[test]
    fn concurrent_read_may_see_either_side() {
        // The read overlaps the write: both old and new values are
        // legal linearizations.
        let h1 = [w(1, 10, 0, 5), w(1, 20, 10, 20), r(2, Some(10), 12, 18)];
        assert!(check_history(&h1, Mode::Linearizable).is_ok());
        let h2 = [w(1, 10, 0, 5), w(1, 20, 10, 20), r(2, Some(20), 12, 18)];
        assert!(check_history(&h2, Mode::Linearizable).is_ok());
    }

    #[test]
    fn fabricated_value_fails_fast() {
        let h = [w(1, 10, 0, 5), r(2, Some(99), 6, 8)];
        let err = check_history(&h, Mode::Linearizable).unwrap_err();
        assert!(err.detail.contains("no write ever wrote"), "{err}");
    }

    #[test]
    fn indeterminate_write_may_or_may_not_apply() {
        // The errored write's value shows up later: legal (it committed
        // after the client gave up).
        let h = [w_maybe(1, 10, 0), r(2, Some(10), 100, 110)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
        // It never shows up: equally legal.
        let h = [w_maybe(1, 10, 0), r(2, None, 100, 110)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
        // But it cannot un-write an acked later value...
        let h = [w_maybe(1, 10, 0), w(2, 20, 50, 60), r(3, Some(20), 100, 110)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
        // ...unless it linearized after it (overlapping futures): the
        // old value may legally surface if the indeterminate write
        // landed after the acked one.
        let h = [w_maybe(1, 10, 0), w(2, 20, 50, 60), r(3, Some(10), 100, 110)];
        assert!(check_history(&h, Mode::Linearizable).is_ok());
    }

    #[test]
    fn real_time_order_is_respected_for_writes() {
        // w=10 ret 5, w=20 call 10 (strictly later), read well after
        // both sees 10: only legal if w=10 linearized after w=20 —
        // impossible in real time.
        let h = [w(1, 10, 0, 5), w(2, 20, 10, 15), r(3, Some(10), 20, 25)];
        assert!(check_history(&h, Mode::Linearizable).is_err());
    }

    #[test]
    fn keys_check_independently() {
        let mut a = w(1, 10, 0, 5);
        a.key = b"a".to_vec();
        let mut b = r(2, None, 10, 12);
        b.key = b"b".to_vec();
        // Stale on key "a" would fail; the read is on key "b".
        assert!(check_history(&[a, b], Mode::Linearizable).is_ok());
    }

    #[test]
    fn stale_mode_allows_lag_but_not_fabrication_or_futures() {
        // Lagging read (saw the older value after a newer ack): fine.
        let h = [w(1, 10, 0, 5), w(1, 20, 6, 9), r(2, Some(10), 15, 18)];
        assert!(check_history(&h, Mode::Stale).is_ok());
        // Initial-state read long after writes: fine under Stale.
        let h = [w(1, 10, 0, 5), r(2, None, 15, 18)];
        assert!(check_history(&h, Mode::Stale).is_ok());
        // Fabricated value: never fine.
        let h = [w(1, 10, 0, 5), r(2, Some(99), 15, 18)];
        assert!(check_history(&h, Mode::Stale).is_err());
        // Value from the future (write called after the read
        // returned): never fine.
        let h = [r(2, Some(10), 0, 3), w(1, 10, 50, 55)];
        assert!(check_history(&h, Mode::Stale).is_err());
    }

    #[test]
    fn interleaved_multi_client_history_passes() {
        // A dense, fully sequential ping-pong: always linearizable.
        let mut h = Vec::new();
        let mut t = 0;
        let mut last = None;
        for i in 0..200u64 {
            let c = (i % 3) as u32 + 1;
            if i % 2 == 0 {
                h.push(w(c, i, t, t + 3));
                last = Some(i);
            } else {
                h.push(r(c, last, t, t + 3));
            }
            t += 5;
        }
        assert!(check_history(&h, Mode::Linearizable).is_ok());
        assert!(check_history(&h, Mode::Stale).is_ok());
    }

    #[test]
    fn memoization_survives_heavy_concurrency() {
        // 12 fully-overlapping writes then a read of one of them: the
        // naive search is 12! orders; the memo table must make this
        // instant.
        let mut h: Vec<ClientOp> = (0..12u64).map(|i| w(i as u32, i, 0, 100)).collect();
        h.push(r(99, Some(7), 200, 210));
        assert!(check_history(&h, Mode::Linearizable).is_ok());
    }
}
