//! Key hashing — bit-identical mirror of the L1 Pallas kernel
//! (`python/compile/kernels/hash_kernel.py`).  The GC index build can
//! run either through the AOT XLA artifact (`runtime::IndexPlanner`) or
//! through these functions; parity is enforced by golden vectors here
//! and by `rust/tests/xla_parity.rs` end-to-end.

/// FNV-1a 32-bit parameters (same constants as the kernel).
pub const FNV_OFFSET: u32 = 0x811C_9DC5;
pub const FNV_PRIME: u32 = 0x0100_0193;
pub const SEED1: u32 = 0x0;
pub const SEED2: u32 = 0x9747_B28C;
pub const KEY_WORDS: usize = 4;

/// murmur3 finalizer — full avalanche on a u32.
#[inline]
pub fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h
}

/// Canonicalize a raw key: 4 LE u32 words of the zero-padded 16-byte
/// prefix + the original byte length.
#[inline]
pub fn canonicalize(key: &[u8]) -> ([u32; KEY_WORDS], u32) {
    let mut buf = [0u8; 16];
    let n = key.len().min(16);
    buf[..n].copy_from_slice(&key[..n]);
    let words = [
        u32::from_le_bytes(buf[0..4].try_into().unwrap()),
        u32::from_le_bytes(buf[4..8].try_into().unwrap()),
        u32::from_le_bytes(buf[8..12].try_into().unwrap()),
        u32::from_le_bytes(buf[12..16].try_into().unwrap()),
    ];
    (words, key.len() as u32)
}

#[inline]
fn fnv1a_words(words: &[u32; KEY_WORDS], len: u32, seed: u32) -> u32 {
    let mut h = (FNV_OFFSET ^ seed) ^ len;
    for &w in words {
        h = (h ^ w).wrapping_mul(FNV_PRIME);
    }
    fmix32(h)
}

/// (h1, h2) for canonical words — the exact kernel computation.
#[inline]
pub fn hash_pair_words(words: &[u32; KEY_WORDS], len: u32) -> (u32, u32) {
    (
        fnv1a_words(words, len, SEED1),
        fnv1a_words(words, len, SEED2) | 1,
    )
}

/// (h1, h2) for a raw key.
#[inline]
pub fn hash_pair(key: &[u8]) -> (u32, u32) {
    let (words, len) = canonicalize(key);
    hash_pair_words(&words, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Golden vectors emitted by `python/tests/test_model.py::
    /// test_golden_vectors_for_rust_parity` — if either side's hash
    /// changes, both suites fail.
    const GOLDEN: &[(&[u8], u32, u32)] = &[
        (b"", 1234692987, 3655303237),
        (b"a", 3027164831, 1582046191),
        (b"foo", 3087426195, 2072970941),
        (b"user4928", 2592917649, 3420158651),
        (b"0123456789abcdef", 3339109223, 3175851325),
        (b"0123456789abcdefXYZ", 1464148333, 3632624859),
    ];

    #[test]
    fn golden_vectors_match_python() {
        for &(key, h1, h2) in GOLDEN {
            assert_eq!(hash_pair(key), (h1, h2), "key {key:?}");
        }
    }

    #[test]
    fn h2_is_odd() {
        prop::check("h2-odd", 300, |g| {
            let key = g.bytes(0..40);
            let (_, h2) = hash_pair(&key);
            if h2 & 1 != 1 {
                return Err(format!("even h2 for {key:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn length_distinguishes_padded_prefixes() {
        assert_ne!(hash_pair(b"a"), hash_pair(b"a\x00"));
        assert_ne!(hash_pair(b""), hash_pair(b"\x00"));
    }

    #[test]
    fn canonicalize_truncates_at_16() {
        let (w1, l1) = canonicalize(b"0123456789abcdefXYZ");
        let (w2, l2) = canonicalize(b"0123456789abcdefABC");
        assert_eq!(w1, w2);
        assert_eq!(l1, 19);
        assert_eq!(l2, 19);
        // ...so equal-length same-prefix keys collide by design (the
        // hash index stores full keys and verifies).
        assert_eq!(hash_pair(b"0123456789abcdefXYZ"), hash_pair(b"0123456789abcdefABC"));
    }

    #[test]
    fn distribution_rough_uniformity() {
        let mut counts = [0u32; 64];
        for i in 0..64_000u32 {
            let key = format!("user{i}");
            let (h1, _) = hash_pair(key.as_bytes());
            counts[(h1 % 64) as usize] += 1;
        }
        let expect = 1000.0;
        for &c in &counts {
            assert!((c as f64) > expect * 0.7 && (c as f64) < expect * 1.3, "c={c}");
        }
    }
}
