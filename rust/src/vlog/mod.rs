//! ValueLog — the heart of KVS-Raft (paper §III-B).
//!
//! In Nezha a client value is persisted **exactly once**: serialized
//! together with its consensus metadata (term, index) into the
//! append-only ValueLog at Raft log-append time.  The state machine
//! then stores only the lightweight `(key → offset)` mapping.
//!
//! * [`log`] — the unordered, append-only ValueLog written on the hot
//!   path (Active/New storage modules).
//! * [`sorted`] — the key-ordered ValueLog produced by GC (Final
//!   Compacted Storage), doubling as the Raft snapshot (it carries
//!   `last_term`/`last_index` per §III-C).
//! * [`hashindex`] — the open-addressing hash index over a sorted
//!   ValueLog that gives Nezha its point-lookup edge (built either in
//!   Rust or from the AOT XLA `index_build` artifact).
//! * [`hash`] — the key hash, bit-identical to the L1 Pallas kernel.

pub mod hash;
pub mod hashindex;
pub mod log;
pub mod sorted;

pub use hashindex::HashIndex;
pub use log::{VLog, VLogReader};
pub use sorted::{SortedVLog, SortedVLogWriter};

/// One ValueLog record: the key-value pair plus the Raft metadata that
/// makes the log usable for consensus recovery (paper §III-B step 3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub term: u64,
    pub index: u64,
    pub key: Vec<u8>,
    /// `None` encodes a tombstone (delete).
    pub value: Option<Vec<u8>>,
}

impl Entry {
    pub fn put(term: u64, index: u64, key: impl Into<Vec<u8>>, value: impl Into<Vec<u8>>) -> Self {
        Self { term, index, key: key.into(), value: Some(value.into()) }
    }

    pub fn delete(term: u64, index: u64, key: impl Into<Vec<u8>>) -> Self {
        Self { term, index, key: key.into(), value: None }
    }

    /// Approximate serialized size (for GC trigger accounting).
    pub fn approx_len(&self) -> usize {
        24 + self.key.len() + self.value.as_ref().map_or(0, |v| v.len())
    }
}

/// Offset of an entry within a ValueLog file.
pub type Offset = u64;

/// A value reference: which ValueLog epoch file, and where in it.
/// This 12-byte token is what Nezha's state machine stores in place of
/// the value (paper §III-B step 5) — epoch 0 is the first Active
/// Storage ValueLog; each GC cycle rotates to a new epoch (the New
/// Storage's log, which becomes the next Active log).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VRef {
    pub epoch: u32,
    pub off: Offset,
}

impl VRef {
    pub const ENCODED_LEN: usize = 12;

    pub fn new(epoch: u32, off: Offset) -> Self {
        Self { epoch, off }
    }

    pub fn encode(&self) -> [u8; Self::ENCODED_LEN] {
        let mut b = [0u8; Self::ENCODED_LEN];
        b[0..4].copy_from_slice(&self.epoch.to_le_bytes());
        b[4..12].copy_from_slice(&self.off.to_le_bytes());
        b
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(buf.len() == Self::ENCODED_LEN, "bad VRef length {}", buf.len());
        Ok(Self {
            epoch: u32::from_le_bytes(buf[0..4].try_into().unwrap()),
            off: u64::from_le_bytes(buf[4..12].try_into().unwrap()),
        })
    }
}

/// Lazily-opened read-only handles over the epoch ValueLog files of a
/// Raft log directory.  The engines' read paths resolve stored
/// [`VRef`]s through this (Algorithm 2's `ReadValue(currentLog/oldLog,
/// offset)`); the GC thread uses its own instance.
pub struct EpochReaders {
    dir: std::path::PathBuf,
    readers: std::sync::Mutex<std::collections::HashMap<u32, std::sync::Arc<VLogReader>>>,
}

impl EpochReaders {
    pub fn new(dir: impl Into<std::path::PathBuf>) -> Self {
        Self { dir: dir.into(), readers: std::sync::Mutex::new(Default::default()) }
    }

    fn reader(&self, epoch: u32) -> anyhow::Result<std::sync::Arc<VLogReader>> {
        let mut g = self.readers.lock().unwrap();
        if let Some(r) = g.get(&epoch) {
            return Ok(std::sync::Arc::clone(r));
        }
        let path = crate::raft::log::epoch_path(&self.dir, epoch);
        let r = std::sync::Arc::new(VLogReader::open(&path)?);
        g.insert(epoch, std::sync::Arc::clone(&r));
        Ok(r)
    }

    /// Resolve a stored reference to its full entry.
    pub fn read(&self, vref: VRef) -> anyhow::Result<Entry> {
        // The write path buffers up to 1 MiB before the file owns the
        // bytes; engines only hold VRefs for *applied* (hence flushed)
        // entries, so a plain file read suffices.  A reader opened
        // before the entry hit the file just needs a retry-once.
        match self.reader(vref.epoch)?.read(vref.off) {
            Ok(e) => Ok(e),
            Err(_) => {
                self.readers.lock().unwrap().remove(&vref.epoch);
                self.reader(vref.epoch)?.read(vref.off)
            }
        }
    }

    /// Drop cached handles for epochs `< min_epoch` (after GC deletes
    /// the files).
    pub fn invalidate_below(&self, min_epoch: u32) {
        self.readers.lock().unwrap().retain(|&e, _| e >= min_epoch);
    }
}

#[cfg(test)]
mod vref_tests {
    use super::VRef;

    #[test]
    fn vref_roundtrip() {
        let v = VRef::new(7, 0xDEAD_BEEF_1234);
        assert_eq!(VRef::decode(&v.encode()).unwrap(), v);
    }

    #[test]
    fn vref_rejects_bad_length() {
        assert!(VRef::decode(&[0u8; 11]).is_err());
        assert!(VRef::decode(&[0u8; 13]).is_err());
    }
}
