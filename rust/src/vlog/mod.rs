//! ValueLog — the heart of KVS-Raft (paper §III-B).
//!
//! In Nezha a client value is persisted **exactly once**: serialized
//! together with its consensus metadata (term, index) into the
//! append-only ValueLog at Raft log-append time.  The state machine
//! then stores only the lightweight `(key → offset)` mapping.
//!
//! * [`log`] — the unordered, append-only ValueLog written on the hot
//!   path (Active/New storage modules).
//! * [`sorted`] — the key-ordered ValueLog produced by GC (Final
//!   Compacted Storage), doubling as the Raft snapshot (it carries
//!   `last_term`/`last_index` per §III-C).
//! * [`hashindex`] — the open-addressing hash index over a sorted
//!   ValueLog that gives Nezha its point-lookup edge (built either in
//!   Rust or from the AOT XLA `index_build` artifact — the parity
//!   contract of DESIGN.md §1).
//! * [`hash`] — the key hash, bit-identical to the L1 Pallas kernel.
//! * [`readahead`] — the fixed-capacity segment cache behind batched
//!   point-read resolution.
//!
//! GC's leveling of the sorted ValueLog is specified in DESIGN.md §3.

pub mod hash;
pub mod hashindex;
pub mod log;
pub mod readahead;
pub mod sorted;

pub use hashindex::HashIndex;
pub use log::{VLog, VLogReader};
pub use readahead::ReadaheadCache;
pub use sorted::{SortedVLog, SortedVLogWriter};

/// One ValueLog record: the key-value pair plus the Raft metadata that
/// makes the log usable for consensus recovery (paper §III-B step 3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub term: u64,
    pub index: u64,
    pub key: Vec<u8>,
    /// `None` encodes a tombstone (delete).
    pub value: Option<Vec<u8>>,
}

impl Entry {
    pub fn put(term: u64, index: u64, key: impl Into<Vec<u8>>, value: impl Into<Vec<u8>>) -> Self {
        Self { term, index, key: key.into(), value: Some(value.into()) }
    }

    pub fn delete(term: u64, index: u64, key: impl Into<Vec<u8>>) -> Self {
        Self { term, index, key: key.into(), value: None }
    }

    /// Approximate serialized size (for GC trigger accounting).
    pub fn approx_len(&self) -> usize {
        24 + self.key.len() + self.value.as_ref().map_or(0, |v| v.len())
    }
}

/// Offset of an entry within a ValueLog file.
pub type Offset = u64;

/// A value reference: which ValueLog epoch file, and where in it.
/// This 12-byte token is what Nezha's state machine stores in place of
/// the value (paper §III-B step 5) — epoch 0 is the first Active
/// Storage ValueLog; each GC cycle rotates to a new epoch (the New
/// Storage's log, which becomes the next Active log).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VRef {
    pub epoch: u32,
    pub off: Offset,
}

impl VRef {
    pub const ENCODED_LEN: usize = 12;

    pub fn new(epoch: u32, off: Offset) -> Self {
        Self { epoch, off }
    }

    pub fn encode(&self) -> [u8; Self::ENCODED_LEN] {
        let mut b = [0u8; Self::ENCODED_LEN];
        b[0..4].copy_from_slice(&self.epoch.to_le_bytes());
        b[4..12].copy_from_slice(&self.off.to_le_bytes());
        b
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(buf.len() == Self::ENCODED_LEN, "bad VRef length {}", buf.len());
        Ok(Self {
            epoch: u32::from_le_bytes(buf[0..4].try_into().unwrap()),
            off: u64::from_le_bytes(buf[4..12].try_into().unwrap()),
        })
    }
}

/// Lazily-opened read-only handles over the epoch ValueLog files of a
/// Raft log directory.  The engines' read paths resolve stored
/// [`VRef`]s through this (Algorithm 2's `ReadValue(currentLog/oldLog,
/// offset)`); the GC thread uses its own instance.
///
/// # Batched value resolution
///
/// [`read_vrefs_batched`](Self::read_vrefs_batched) is the preferred
/// read path for anything resolving more than one reference (engine
/// `multi_get`, the scan value pass).  Strategy:
///
/// 1. **Epoch grouping** — references are bucketed per epoch file so
///    each file is visited exactly once with one open handle.
/// 2. **Offset sort** — within an epoch the offsets are sorted, turning
///    random resolution order into a monotonic forward walk of the
///    append-only file.
/// 3. **Readahead** — the walk is served through the fixed-capacity
///    [`ReadaheadCache`] (64 KiB aligned segments, LRU), so adjacent
///    values cost one `pread` instead of two each.
///
/// Results are returned in the caller's input order.  The whole path is
/// read-only over append-only files, so it has no crash-safety impact:
/// see the [`readahead`] module docs for the coherence argument.
pub struct EpochReaders {
    dir: std::path::PathBuf,
    readers: std::sync::Mutex<std::collections::HashMap<u32, std::sync::Arc<VLogReader>>>,
    cache: ReadaheadCache,
    io: std::sync::Arc<crate::lsm::IoStats>,
}

impl EpochReaders {
    pub fn new(dir: impl Into<std::path::PathBuf>) -> Self {
        let io = std::sync::Arc::new(crate::lsm::IoStats::default());
        Self {
            dir: dir.into(),
            readers: std::sync::Mutex::new(Default::default()),
            cache: ReadaheadCache::new(readahead::DEFAULT_SEGMENTS, std::sync::Arc::clone(&io)),
            io,
        }
    }

    /// Shared counters: `vlog_reads`, `vlog_read_bytes`,
    /// `readahead_hits`, `readahead_misses`.
    pub fn io_stats(&self) -> std::sync::Arc<crate::lsm::IoStats> {
        std::sync::Arc::clone(&self.io)
    }

    fn count_read(&self, e: &Entry) {
        use std::sync::atomic::Ordering;
        self.io.vlog_reads.fetch_add(1, Ordering::Relaxed);
        self.io.vlog_read_bytes.fetch_add(
            e.value.as_ref().map_or(0, |v| v.len() as u64),
            Ordering::Relaxed,
        );
    }

    fn reader(&self, epoch: u32) -> anyhow::Result<std::sync::Arc<VLogReader>> {
        let mut g = self.readers.lock().unwrap();
        if let Some(r) = g.get(&epoch) {
            return Ok(std::sync::Arc::clone(r));
        }
        let path = crate::raft::log::epoch_path(&self.dir, epoch);
        let r = std::sync::Arc::new(VLogReader::open(&path)?);
        g.insert(epoch, std::sync::Arc::clone(&r));
        Ok(r)
    }

    /// Resolve a stored reference to its full entry.
    pub fn read(&self, vref: VRef) -> anyhow::Result<Entry> {
        let reader = self.reader(vref.epoch)?;
        // Probe the readahead cache (populated by batched passes)
        // without loading into it: point reads of the growing
        // live-epoch tail would otherwise reload a 64 KiB segment per
        // fresh entry.  On a miss, fall through to exact-size reads.
        if let Some(e) = reader.read_resident(vref.off, vref.epoch, &self.cache)? {
            self.count_read(&e);
            return Ok(e);
        }
        // The write path buffers up to 1 MiB before the file owns the
        // bytes; engines only hold VRefs for *applied* (hence flushed)
        // entries, so a plain file read suffices.  A reader opened
        // before the entry hit the file just needs a retry-once.
        let e = match reader.read(vref.off) {
            Ok(e) => e,
            Err(_) => {
                self.readers.lock().unwrap().remove(&vref.epoch);
                self.reader(vref.epoch)?.read(vref.off)?
            }
        };
        self.count_read(&e);
        Ok(e)
    }

    /// Resolve a batch of references: grouped by epoch, offset-sorted
    /// within each epoch, served through the readahead cache.  Returns
    /// the entries in the same order as `vrefs` (duplicates allowed).
    pub fn read_vrefs_batched(&self, vrefs: &[VRef]) -> anyhow::Result<Vec<Entry>> {
        if vrefs.len() <= 1 {
            return vrefs.iter().map(|&v| self.read(v)).collect();
        }
        let mut by_epoch: std::collections::BTreeMap<u32, Vec<(usize, Offset)>> =
            std::collections::BTreeMap::new();
        for (i, v) in vrefs.iter().enumerate() {
            by_epoch.entry(v.epoch).or_default().push((i, v.off));
        }
        let mut out: Vec<Option<Entry>> = vec![None; vrefs.len()];
        for (epoch, mut offs) in by_epoch {
            offs.sort_unstable_by_key(|&(_, off)| off);
            let reader = self.reader(epoch)?;
            for (i, off) in offs {
                let e = match reader.read_cached(off, epoch, &self.cache) {
                    Ok(e) => e,
                    Err(_) => {
                        // Same retry-once as `read`: the handle (or a
                        // cached tail segment) may predate the entry.
                        self.readers.lock().unwrap().remove(&epoch);
                        self.reader(epoch)?.read_cached(off, epoch, &self.cache)?
                    }
                };
                self.count_read(&e);
                out[i] = Some(e);
            }
        }
        Ok(out.into_iter().map(|e| e.expect("every vref resolved")).collect())
    }

    /// Drop cached handles and readahead segments for epochs
    /// `< min_epoch` (after GC deletes the files).
    pub fn invalidate_below(&self, min_epoch: u32) {
        self.readers.lock().unwrap().retain(|&e, _| e >= min_epoch);
        self.cache.invalidate_below(min_epoch);
    }

    /// Drop cached handles and readahead segments for epochs
    /// `>= epoch`.  Raft conflict resolution truncates and rewrites
    /// those files in place, so resident bytes (cached while the tail
    /// was still uncommitted) may no longer match the file.
    pub fn invalidate_from(&self, epoch: u32) {
        self.readers.lock().unwrap().retain(|&e, _| e < epoch);
        self.cache.invalidate_from(epoch);
    }
}

#[cfg(test)]
mod vref_tests {
    use super::VRef;

    #[test]
    fn vref_roundtrip() {
        let v = VRef::new(7, 0xDEAD_BEEF_1234);
        assert_eq!(VRef::decode(&v.encode()).unwrap(), v);
    }

    #[test]
    fn vref_rejects_bad_length() {
        assert!(VRef::decode(&[0u8; 11]).is_err());
        assert!(VRef::decode(&[0u8; 13]).is_err());
    }
}

#[cfg(test)]
mod batched_tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::Ordering;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-epochrd-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Write `entries` into the epoch file `epoch` of `dir`, returning
    /// each entry's VRef.
    fn write_epoch(dir: &std::path::Path, epoch: u32, entries: &[Entry]) -> Vec<VRef> {
        let mut v = VLog::open(&crate::raft::log::epoch_path(dir, epoch)).unwrap();
        let refs = entries
            .iter()
            .map(|e| VRef::new(epoch, v.append(e).unwrap()))
            .collect();
        v.sync().unwrap();
        refs
    }

    #[test]
    fn batched_read_matches_single_reads_across_epochs() {
        let dir = tmpdir("match");
        let e0: Vec<Entry> = (0..40u64)
            .map(|i| Entry::put(1, i + 1, format!("a{i:03}"), vec![i as u8; 100]))
            .collect();
        let e1: Vec<Entry> = (0..40u64)
            .map(|i| Entry::put(2, i + 41, format!("b{i:03}"), vec![(i + 1) as u8; 100]))
            .collect();
        let mut refs = write_epoch(&dir, 0, &e0);
        refs.extend(write_epoch(&dir, 1, &e1));
        let readers = EpochReaders::new(&dir);
        // Shuffle the request order: interleave epochs, descending offsets.
        let mut req: Vec<VRef> = refs.iter().copied().rev().collect();
        req.push(refs[3]); // duplicate
        let got = readers.read_vrefs_batched(&req).unwrap();
        assert_eq!(got.len(), req.len());
        let fresh = EpochReaders::new(&dir);
        for (r, e) in req.iter().zip(&got) {
            assert_eq!(*e, fresh.read(*r).unwrap());
        }
    }

    #[test]
    fn batched_read_uses_readahead_cache() {
        let dir = tmpdir("cache");
        let entries: Vec<Entry> =
            (0..200u64).map(|i| Entry::put(1, i + 1, format!("k{i:04}"), vec![7u8; 64])).collect();
        let refs = write_epoch(&dir, 0, &entries);
        let readers = EpochReaders::new(&dir);
        readers.read_vrefs_batched(&refs).unwrap();
        let io = readers.io_stats();
        assert_eq!(io.vlog_reads.load(Ordering::Relaxed), 200);
        assert!(io.vlog_read_bytes.load(Ordering::Relaxed) >= 200 * 64);
        // 200 × ~100-byte frames fit in one 64 KiB segment: far fewer
        // misses than entries, and hits dominate.
        let hits = io.readahead_hits.load(Ordering::Relaxed);
        let misses = io.readahead_misses.load(Ordering::Relaxed);
        assert!(misses < 10, "misses={misses}");
        assert!(hits > 300, "hits={hits}"); // 2 cache reads per entry
    }

    #[test]
    fn empty_and_singleton_batches() {
        let dir = tmpdir("edge");
        let refs = write_epoch(&dir, 0, &[Entry::put(1, 1, "k", "v")]);
        let readers = EpochReaders::new(&dir);
        assert!(readers.read_vrefs_batched(&[]).unwrap().is_empty());
        let one = readers.read_vrefs_batched(&refs).unwrap();
        assert_eq!(one[0].value, Some(b"v".to_vec()));
    }

    #[test]
    fn invalidate_from_prevents_stale_reads_after_truncation() {
        let dir = tmpdir("trunc");
        let entries: Vec<Entry> = (0..10u64)
            .map(|i| Entry::put(1, i + 1, format!("k{i}"), format!("old{i}")))
            .collect();
        let refs = write_epoch(&dir, 0, &entries);
        let readers = EpochReaders::new(&dir);
        readers.read_vrefs_batched(&refs).unwrap(); // segment now resident
        // Simulate Raft conflict resolution: truncate the epoch file at
        // entry 5's offset and rewrite a different entry there.
        let path = crate::raft::log::epoch_path(&dir, 0);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(refs[5].off).unwrap();
        drop(f);
        let mut v = VLog::open(&path).unwrap();
        let new_off = v.append(&Entry::put(2, 6, "k5", "NEW")).unwrap();
        v.sync().unwrap();
        assert_eq!(new_off, refs[5].off, "rewrite lands at the truncated offset");
        // What `StateMachine::on_log_truncated` triggers:
        readers.invalidate_from(0);
        let got = readers.read(VRef::new(0, new_off)).unwrap();
        assert_eq!(got.value, Some(b"NEW".to_vec()));
        assert_eq!(got.term, 2);
    }

    #[test]
    fn tombstones_resolve_to_none_value() {
        let dir = tmpdir("tomb");
        let refs = write_epoch(
            &dir,
            0,
            &[Entry::put(1, 1, "k", "v"), Entry::delete(1, 2, "k")],
        );
        let readers = EpochReaders::new(&dir);
        let got = readers.read_vrefs_batched(&refs).unwrap();
        assert_eq!(got[0].value, Some(b"v".to_vec()));
        assert_eq!(got[1].value, None);
    }
}
