//! Hash index over a sorted ValueLog (paper §III-C: "maintaining a
//! hash index for key-to-offset mapping accelerates point queries,
//! while the sequential organization of data enhances range query
//! efficiency").
//!
//! Two structures in one file:
//! * open-addressing table (linear probing) of `(h1, offset)` slots —
//!   point lookups hit the home slot, then verify the full key against
//!   the log entry (the canonical 16-byte-prefix hash can collide);
//! * sparse ordered index (every `SPARSE_EVERY`-th key) — a range scan
//!   binary-searches it for the start offset, then reads sequentially.
//!
//! The `(h1, bucket)` pairs can come from the pure-Rust hash
//! ([`super::hash`]) or from the AOT XLA `index_build` artifact via
//! [`crate::runtime::IndexPlanner`]; both produce identical tables
//! (enforced by `rust/tests/xla_parity.rs`).

use super::hash::hash_pair;
use super::{Offset, SortedVLog};
use crate::util::{Decoder, Encoder};
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: u64 = 0x4E5A_4849_4458_0001; // "NZHIDX" v1
pub const SPARSE_EVERY: usize = 16;

/// In-memory (and load/save-able) index.
pub struct HashIndex {
    /// Power-of-two slot array; `offset+1` stored so 0 = empty.
    slots: Vec<(u32, u64)>,
    mask: u32,
    /// Sorted (key, offset) samples for range-start search.
    sparse: Vec<(Vec<u8>, Offset)>,
    pub entry_count: u64,
}

impl HashIndex {
    /// Capacity for `n` keys at ~0.6 load factor, power of two.
    pub fn capacity_for(n: usize) -> usize {
        ((n * 5 / 3).max(8)).next_power_of_two()
    }

    /// Build from sorted `(key, offset)` pairs using the Rust-side
    /// hash (bit-identical to the XLA planner path).
    pub fn build(key_offsets: &[(Vec<u8>, Offset)]) -> Self {
        let cap = Self::capacity_for(key_offsets.len());
        let mut idx = Self {
            slots: vec![(0, 0); cap],
            mask: (cap - 1) as u32,
            sparse: Vec::with_capacity(key_offsets.len() / SPARSE_EVERY + 1),
            entry_count: key_offsets.len() as u64,
        };
        for (i, (key, off)) in key_offsets.iter().enumerate() {
            let (h1, _) = hash_pair(key);
            idx.insert_hashed(h1, h1 & idx.mask, *off);
            if i % SPARSE_EVERY == 0 {
                idx.sparse.push((key.clone(), *off));
            }
        }
        idx
    }

    /// Build from externally computed hashes/buckets (the XLA
    /// `index_build` path). `hashes[i]`/`buckets[i]` must correspond to
    /// `key_offsets[i]`, and `buckets` must have been computed with
    /// `n_buckets == capacity_for(len)`.
    pub fn build_from_planner(
        key_offsets: &[(Vec<u8>, Offset)],
        hashes: &[u32],
        buckets: &[u32],
    ) -> Result<Self> {
        anyhow::ensure!(
            hashes.len() == key_offsets.len() && buckets.len() == key_offsets.len(),
            "planner output length mismatch"
        );
        let cap = Self::capacity_for(key_offsets.len());
        let mut idx = Self {
            slots: vec![(0, 0); cap],
            mask: (cap - 1) as u32,
            sparse: Vec::with_capacity(key_offsets.len() / SPARSE_EVERY + 1),
            entry_count: key_offsets.len() as u64,
        };
        for (i, (key, off)) in key_offsets.iter().enumerate() {
            idx.insert_hashed(hashes[i], buckets[i], *off);
            if i % SPARSE_EVERY == 0 {
                idx.sparse.push((key.clone(), *off));
            }
        }
        Ok(idx)
    }

    fn insert_hashed(&mut self, h1: u32, bucket: u32, off: Offset) {
        let mut slot = (bucket & self.mask) as usize;
        loop {
            if self.slots[slot].1 == 0 {
                self.slots[slot] = (h1, off + 1);
                return;
            }
            slot = (slot + 1) & self.mask as usize;
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Candidate offsets whose stored h1 matches `key`'s h1, in probe
    /// order.  The caller verifies the full key against the log.
    pub fn candidates(&self, key: &[u8]) -> Vec<Offset> {
        let (h1, _) = hash_pair(key);
        let mut out = Vec::new();
        let mut slot = (h1 & self.mask) as usize;
        loop {
            let (sh, so) = self.slots[slot];
            if so == 0 {
                return out;
            }
            if sh == h1 {
                out.push(so - 1);
            }
            slot = (slot + 1) & self.mask as usize;
            if slot == (h1 & self.mask) as usize {
                return out; // table full wrap (shouldn't happen at 0.6 load)
            }
        }
    }

    /// Verified point lookup against the sorted log.
    pub fn lookup(&self, key: &[u8], log: &SortedVLog) -> Result<Option<super::Entry>> {
        for off in self.candidates(key) {
            let e = log.read(off).context("hashindex candidate read")?;
            if e.key == key {
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    /// The sorted sparse sample keys (every [`SPARSE_EVERY`]-th key of
    /// the run).  GC partition planning draws key-range bounds from
    /// these samples: they are durable with the sealed run, so a
    /// resumed merge reconstructs the exact same bounds.
    pub fn sample_keys(&self) -> impl Iterator<Item = &[u8]> {
        self.sparse.iter().map(|(k, _)| k.as_slice())
    }

    /// Offset to start a sequential scan for keys `>= start`: the
    /// sparse sample at or before `start` (one random read).
    pub fn scan_start(&self, start: &[u8]) -> Offset {
        if self.sparse.is_empty() {
            return super::sorted::HEADER_LEN;
        }
        let i = self.sparse.partition_point(|(k, _)| k.as_slice() <= start);
        if i == 0 {
            super::sorted::HEADER_LEN
        } else {
            self.sparse[i - 1].1
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut e = Encoder::new();
        e.u64(MAGIC);
        e.u64(self.entry_count);
        e.u32(self.mask);
        e.varint(self.sparse.len() as u64);
        for (k, o) in &self.sparse {
            e.len_bytes(k).varint(*o);
        }
        e.varint(self.slots.len() as u64);
        for (h, o) in &self.slots {
            e.u32(*h).u64(*o);
        }
        let body = e.into_vec();
        let mut framed = Encoder::with_capacity(body.len() + 8);
        framed.u32(crc32fast::hash(&body)).bytes(&body);
        let tmp = path.with_extension("tmp");
        // Fsync data + directory: a sealed run's index must be durable
        // before the GC manifest commit deletes the merge inputs — a
        // torn .idx with the inputs gone would be unrecoverable.
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(framed.as_slice())?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, path)?;
        if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::File::open(dir)?.sync_data()?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path).with_context(|| format!("hashindex load {path:?}"))?;
        let mut d = Decoder::new(&buf);
        let crc = d.u32()?;
        let body = d.bytes(d.remaining())?;
        if crc32fast::hash(body) != crc {
            bail!("hashindex crc mismatch");
        }
        let mut d = Decoder::new(body);
        if d.u64()? != MAGIC {
            bail!("hashindex bad magic");
        }
        let entry_count = d.u64()?;
        let mask = d.u32()?;
        let nsparse = d.varint()? as usize;
        let mut sparse = Vec::with_capacity(nsparse);
        for _ in 0..nsparse {
            let k = d.len_bytes()?.to_vec();
            let o = d.varint()?;
            sparse.push((k, o));
        }
        let nslots = d.varint()? as usize;
        anyhow::ensure!(nslots == mask as usize + 1, "hashindex size mismatch");
        let mut slots = Vec::with_capacity(nslots);
        for _ in 0..nslots {
            let h = d.u32()?;
            let o = d.u64()?;
            slots.push((h, o));
        }
        Ok(Self { slots, mask, sparse, entry_count })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vlog::{Entry, SortedVLogWriter};
    use std::path::PathBuf;

    fn tmppath(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-hidx-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn build_log(path: &Path, n: u32) -> (SortedVLog, Vec<(Vec<u8>, Offset)>) {
        let mut w = SortedVLogWriter::create(path, 1, n as u64).unwrap();
        for i in 0..n {
            w.add(&Entry::put(1, i as u64, format!("key{i:06}"), format!("val{i}"))).unwrap();
        }
        let (_, kos) = w.finish().unwrap();
        (SortedVLog::open(path).unwrap(), kos)
    }

    #[test]
    fn lookup_finds_every_key() {
        let p = tmppath("lookup");
        let (log, kos) = build_log(&p, 1000);
        let idx = HashIndex::build(&kos);
        for i in 0..1000u32 {
            let k = format!("key{i:06}");
            let e = idx.lookup(k.as_bytes(), &log).unwrap().unwrap();
            assert_eq!(e.value, Some(format!("val{i}").into_bytes()));
        }
    }

    #[test]
    fn lookup_misses_absent_keys() {
        let p = tmppath("miss");
        let (log, kos) = build_log(&p, 500);
        let idx = HashIndex::build(&kos);
        for i in 0..200u32 {
            let k = format!("absent{i}");
            assert!(idx.lookup(k.as_bytes(), &log).unwrap().is_none());
        }
    }

    #[test]
    fn scan_start_finds_position_at_or_before() {
        let p = tmppath("scanstart");
        let (log, kos) = build_log(&p, 200);
        let idx = HashIndex::build(&kos);
        let start = b"key000100";
        let off = idx.scan_start(start);
        let got = log.scan_from(off, start, b"key000110", 100).unwrap();
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].key, start.to_vec());
        // Start before everything:
        let off0 = idx.scan_start(b"aaa");
        assert_eq!(off0, crate::vlog::sorted::HEADER_LEN);
    }

    #[test]
    fn save_load_roundtrip() {
        let plog = tmppath("slr.log");
        let pidx = tmppath("slr.idx");
        let (log, kos) = build_log(&plog, 300);
        let idx = HashIndex::build(&kos);
        idx.save(&pidx).unwrap();
        let idx2 = HashIndex::load(&pidx).unwrap();
        assert_eq!(idx2.entry_count, 300);
        assert_eq!(idx2.capacity(), idx.capacity());
        for i in (0..300u32).step_by(17) {
            let k = format!("key{i:06}");
            assert!(idx2.lookup(k.as_bytes(), &log).unwrap().is_some());
        }
    }

    #[test]
    fn planner_build_matches_rust_build() {
        let p = tmppath("planner");
        let (log, kos) = build_log(&p, 400);
        let cap = HashIndex::capacity_for(kos.len()) as u32;
        let (hashes, buckets): (Vec<u32>, Vec<u32>) = kos
            .iter()
            .map(|(k, _)| {
                let (h1, _) = hash_pair(k);
                (h1, h1 % cap)
            })
            .unzip();
        let a = HashIndex::build(&kos);
        let b = HashIndex::build_from_planner(&kos, &hashes, &buckets).unwrap();
        assert_eq!(a.capacity(), b.capacity());
        for i in 0..400u32 {
            let k = format!("key{i:06}");
            let ea = a.lookup(k.as_bytes(), &log).unwrap();
            let eb = b.lookup(k.as_bytes(), &log).unwrap();
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn colliding_prefix_keys_resolve_by_verification() {
        // >16-byte keys with equal prefix + equal length hash equally;
        // the index must disambiguate via the log.
        let p = tmppath("collide");
        let mut w = SortedVLogWriter::create(&p, 0, 0).unwrap();
        let k1 = b"0123456789abcdefAAA".to_vec();
        let k2 = b"0123456789abcdefBBB".to_vec();
        w.add(&Entry::put(1, 1, k1.clone(), "one")).unwrap();
        w.add(&Entry::put(1, 2, k2.clone(), "two")).unwrap();
        let (_, kos) = w.finish().unwrap();
        let log = SortedVLog::open(&p).unwrap();
        let idx = HashIndex::build(&kos);
        assert_eq!(idx.lookup(&k1, &log).unwrap().unwrap().value, Some(b"one".to_vec()));
        assert_eq!(idx.lookup(&k2, &log).unwrap().unwrap().value, Some(b"two".to_vec()));
    }

    #[test]
    fn empty_index_behaves() {
        let idx = HashIndex::build(&[]);
        assert!(idx.candidates(b"x").is_empty());
        assert_eq!(idx.scan_start(b"x"), crate::vlog::sorted::HEADER_LEN);
    }
}
