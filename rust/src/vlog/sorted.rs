//! Sorted ValueLog — the Final Compacted Storage data file (paper
//! §III-C).
//!
//! GC reorganizes the live entries of the Active ValueLog into key
//! order here, which (a) restores sequential I/O for range queries and
//! (b) doubles as the Raft snapshot: the header carries `last_term` /
//! `last_index` of the log prefix it replaces, "which aligns with the
//! log compaction mechanism described in the Raft paper".
//!
//! Layout: `[magic u64][last_term u64][last_index u64]` then standard
//! ValueLog frames in strictly increasing key order.

use super::{Entry, Offset};
use crate::util::Encoder;
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

const MAGIC: u64 = 0x4E5A_534F_5254_0001; // "NZSORT" v1
pub const HEADER_LEN: u64 = 24;

const OP_PUT: u8 = 0;
const OP_DELETE: u8 = 1;

fn encode_frame(e: &Entry) -> Vec<u8> {
    let mut payload = Encoder::with_capacity(e.approx_len() + 16);
    payload.u64(e.term).u64(e.index);
    match &e.value {
        Some(v) => {
            payload.u8(OP_PUT).len_bytes(&e.key).len_bytes(v);
        }
        None => {
            payload.u8(OP_DELETE).len_bytes(&e.key);
        }
    }
    let body = payload.as_slice();
    let mut frame = Encoder::with_capacity(body.len() + 8);
    frame.u32(body.len() as u32).u32(crc32fast::hash(body)).bytes(body);
    frame.into_vec()
}

/// Streaming writer; keys must arrive strictly increasing.
pub struct SortedVLogWriter {
    path: PathBuf,
    file: BufWriter<File>,
    offset: u64,
    last_key: Option<Vec<u8>>,
    /// (key, offset) of every entry — handed to the hash-index builder.
    pub key_offsets: Vec<(Vec<u8>, Offset)>,
    /// Delete frames written so far (recorded per run in the LEVELS
    /// manifest so tombstone-free runs can move levels without a
    /// rewrite).
    tombstones: usize,
}

impl SortedVLogWriter {
    pub fn create(path: &Path, last_term: u64, last_index: u64) -> Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("sorted vlog create {path:?}"))?;
        let mut w = BufWriter::new(file);
        let mut hdr = Encoder::with_capacity(HEADER_LEN as usize);
        hdr.u64(MAGIC).u64(last_term).u64(last_index);
        w.write_all(hdr.as_slice())?;
        Ok(Self {
            path: path.to_path_buf(),
            file: w,
            offset: HEADER_LEN,
            last_key: None,
            key_offsets: Vec::new(),
            tombstones: 0,
        })
    }

    /// Re-open a partially-written sorted log after a crash: scan the
    /// valid prefix, truncate any torn tail, and continue appending.
    /// The last valid key is the paper's "GC interrupt point"
    /// (§III-E: "identifies the last key in the sorted file as the GC
    /// interrupt point and continues executing GC from that position").
    pub fn resume(path: &Path) -> Result<Self> {
        use std::os::unix::fs::FileExt;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("sorted vlog resume {path:?}"))?;
        let size = file.metadata()?.len();
        anyhow::ensure!(size >= HEADER_LEN, "sorted vlog resume: no header");
        let mut hdr = [0u8; HEADER_LEN as usize];
        file.read_exact_at(&mut hdr, 0)?;
        anyhow::ensure!(
            u64::from_le_bytes(hdr[0..8].try_into().unwrap()) == MAGIC,
            "sorted vlog resume: bad magic"
        );
        // Scan valid frames, collecting key offsets.
        let mut key_offsets = Vec::new();
        let mut last_key = None;
        let mut tombstones = 0usize;
        let mut pos = HEADER_LEN;
        loop {
            let mut fh = [0u8; 8];
            if pos + 8 > size || file.read_exact_at(&mut fh, pos).is_err() {
                break;
            }
            let len = u32::from_le_bytes(fh[0..4].try_into().unwrap()) as u64;
            let crc = u32::from_le_bytes(fh[4..8].try_into().unwrap());
            if pos + 8 + len > size {
                break;
            }
            let mut body = vec![0u8; len as usize];
            if file.read_exact_at(&mut body, pos + 8).is_err()
                || crc32fast::hash(&body) != crc
            {
                break;
            }
            if body[16] == OP_DELETE {
                tombstones += 1;
            }
            // key lives after term(8) + index(8) + op(1).
            let mut d = crate::util::Decoder::new(&body[17..]);
            let key = d.len_bytes()?.to_vec();
            key_offsets.push((key.clone(), pos));
            last_key = Some(key);
            pos += 8 + len;
        }
        file.set_len(pos)?;
        use std::io::{Seek, SeekFrom};
        let mut file = file;
        file.seek(SeekFrom::Start(pos))?;
        Ok(Self {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            offset: pos,
            last_key,
            key_offsets,
            tombstones,
        })
    }

    /// Key of the last entry written so far (resume point).
    pub fn last_key(&self) -> Option<&[u8]> {
        self.last_key.as_deref()
    }

    pub fn add(&mut self, e: &Entry) -> Result<Offset> {
        if let Some(last) = &self.last_key {
            if e.key.as_slice() <= last.as_slice() {
                bail!("sorted vlog: keys out of order");
            }
        }
        if e.value.is_none() {
            self.tombstones += 1;
        }
        let frame = encode_frame(e);
        let off = self.offset;
        self.file.write_all(&frame)?;
        self.offset += frame.len() as u64;
        self.last_key = Some(e.key.clone());
        self.key_offsets.push((e.key.clone(), off));
        Ok(off)
    }

    /// Finish: flush + fsync. Returns total file size.
    pub fn finish(mut self) -> Result<(u64, Vec<(Vec<u8>, Offset)>)> {
        self.file.flush()?;
        // Durability point for a sealed run (fault-injectable: a torn
        // seal here is what crash-resume of a GC output recovers from).
        crate::fault::disk::check(&self.path, crate::fault::disk::DiskOp::Sync)?;
        self.file.get_ref().sync_data()?;
        Ok((self.offset, self.key_offsets))
    }

    pub fn entry_count(&self) -> usize {
        self.key_offsets.len()
    }

    /// Delete frames written so far (survives [`Self::resume`], which
    /// recounts them from the valid prefix).
    pub fn tombstone_count(&self) -> usize {
        self.tombstones
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Read-only sorted ValueLog.
pub struct SortedVLog {
    path: PathBuf,
    file: File,
    pub last_term: u64,
    pub last_index: u64,
    pub file_size: u64,
}

impl SortedVLog {
    pub fn open(path: &Path) -> Result<Self> {
        use std::os::unix::fs::FileExt;
        let file = File::open(path).with_context(|| format!("sorted vlog open {path:?}"))?;
        let file_size = file.metadata()?.len();
        if file_size < HEADER_LEN {
            bail!("sorted vlog too small");
        }
        let mut hdr = [0u8; HEADER_LEN as usize];
        file.read_exact_at(&mut hdr, 0)?;
        let magic = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        if magic != MAGIC {
            bail!("sorted vlog bad magic");
        }
        let last_term = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let last_index = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        Ok(Self { path: path.to_path_buf(), file, last_term, last_index, file_size })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Random read at an exact entry offset.
    pub fn read(&self, offset: Offset) -> Result<Entry> {
        let (e, _) = self.read_with_len(offset)?;
        Ok(e)
    }

    fn read_with_len(&self, offset: Offset) -> Result<(Entry, u64)> {
        use std::os::unix::fs::FileExt;
        let mut hdr = [0u8; 8];
        self.file.read_exact_at(&mut hdr, offset)?;
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let mut body = vec![0u8; len];
        self.file.read_exact_at(&mut body, offset + 8)?;
        if crc32fast::hash(&body) != crc {
            bail!("sorted vlog crc mismatch @{offset}");
        }
        let mut d = crate::util::Decoder::new(&body);
        let term = d.u64()?;
        let index = d.u64()?;
        let op = d.u8()?;
        let key = d.len_bytes()?.to_vec();
        let value = match op {
            OP_PUT => Some(d.len_bytes()?.to_vec()),
            OP_DELETE => None,
            other => bail!("sorted vlog: unknown op {other}"),
        };
        Ok((Entry { term, index, key, value }, 8 + len as u64))
    }

    /// Sequential scan starting at `offset` (one random read, then
    /// sequential — the paper's range-query fast path), yielding
    /// entries with key in `[start, end)` up to `limit`.  An empty
    /// `end` means unbounded (scan to the last key).
    ///
    /// Reads the file in large chunks (one `pread` per ~256 KiB
    /// instead of two per entry) so the access pattern is genuinely
    /// sequential — §Perf L3 optimization #2.
    pub fn scan_from(
        &self,
        offset: Offset,
        start: &[u8],
        end: &[u8],
        limit: usize,
    ) -> Result<Vec<Entry>> {
        use std::os::unix::fs::FileExt;
        const CHUNK: usize = 256 << 10;
        let mut out = Vec::new();
        let mut buf: Vec<u8> = Vec::with_capacity(CHUNK);
        let mut buf_start = offset; // file offset of buf[0]
        let mut pos = offset;
        'outer: while pos < self.file_size && out.len() < limit {
            // Ensure the frame at `pos` is fully buffered.
            let need_hdr = (pos - buf_start) as usize + 8;
            if buf.len() < need_hdr {
                refill(&self.file, &mut buf, &mut buf_start, pos, CHUNK, self.file_size)?;
            }
            let rel = (pos - buf_start) as usize;
            if buf.len() < rel + 8 {
                break; // truncated tail
            }
            let len = u32::from_le_bytes(buf[rel..rel + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(buf[rel + 4..rel + 8].try_into().unwrap());
            if buf.len() < rel + 8 + len {
                // Frame crosses the buffer end: refill anchored at pos.
                let want = CHUNK.max(len + 8);
                refill(&self.file, &mut buf, &mut buf_start, pos, want, self.file_size)?;
                let rel = (pos - buf_start) as usize;
                if buf.len() < rel + 8 + len {
                    break 'outer; // truncated file
                }
            }
            let rel = (pos - buf_start) as usize;
            let body = &buf[rel + 8..rel + 8 + len];
            if crc32fast::hash(body) != crc {
                bail!("sorted vlog crc mismatch @{pos}");
            }
            let mut d = crate::util::Decoder::new(body);
            let term = d.u64()?;
            let index = d.u64()?;
            let op = d.u8()?;
            let key = d.len_bytes()?;
            if !crate::util::key_before_end(key, end) {
                break;
            }
            if key >= start {
                let value = match op {
                    OP_PUT => Some(d.len_bytes()?.to_vec()),
                    OP_DELETE => None,
                    other => bail!("sorted vlog: unknown op {other}"),
                };
                out.push(Entry { term, index, key: key.to_vec(), value });
            }
            pos += 8 + len as u64;
        }
        return Ok(out);

        /// Read up to `chunk` bytes anchored at `pos` into `buf`.
        fn refill(
            file: &File,
            buf: &mut Vec<u8>,
            buf_start: &mut u64,
            pos: u64,
            chunk: usize,
            file_size: u64,
        ) -> Result<()> {
            let want = chunk.min((file_size - pos) as usize);
            buf.resize(want, 0);
            file.read_exact_at(buf, pos)?;
            *buf_start = pos;
            Ok(())
        }
    }

    /// Full iteration (recovery / follower catch-up / next GC cycle).
    pub fn iter(&self) -> SortedIter<'_> {
        SortedIter { log: self, pos: HEADER_LEN }
    }

    /// Iteration starting at a frame offset (a partitioned merge seeks
    /// each source to its key range via the hash index's sparse
    /// samples, then reads forward).
    pub fn iter_from(&self, offset: Offset) -> SortedIter<'_> {
        SortedIter { log: self, pos: offset.max(HEADER_LEN) }
    }
}

pub struct SortedIter<'a> {
    log: &'a SortedVLog,
    pos: u64,
}

impl Iterator for SortedIter<'_> {
    type Item = Result<(Offset, Entry)>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.log.file_size {
            return None;
        }
        let off = self.pos;
        match self.log.read_with_len(off) {
            Ok((e, flen)) => {
                self.pos += flen;
                Some(Ok((off, e)))
            }
            Err(e) => {
                self.pos = self.log.file_size;
                Some(Err(e))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-sorted-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn build(path: &Path, n: u32) -> (u64, Vec<(Vec<u8>, Offset)>) {
        let mut w = SortedVLogWriter::create(path, 3, 99).unwrap();
        for i in 0..n {
            w.add(&Entry::put(1, i as u64, format!("key{i:06}"), format!("val{i}"))).unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn header_carries_snapshot_point() {
        let p = tmppath("hdr");
        build(&p, 10);
        let s = SortedVLog::open(&p).unwrap();
        assert_eq!(s.last_term, 3);
        assert_eq!(s.last_index, 99);
    }

    #[test]
    fn random_reads_by_offset() {
        let p = tmppath("read");
        let (_, kos) = build(&p, 100);
        let s = SortedVLog::open(&p).unwrap();
        for (k, o) in kos.iter().step_by(13) {
            let e = s.read(*o).unwrap();
            assert_eq!(&e.key, k);
        }
    }

    #[test]
    fn out_of_order_rejected() {
        let p = tmppath("ooo");
        let mut w = SortedVLogWriter::create(&p, 0, 0).unwrap();
        w.add(&Entry::put(1, 1, "b", "1")).unwrap();
        assert!(w.add(&Entry::put(1, 2, "a", "2")).is_err());
        assert!(w.add(&Entry::put(1, 3, "b", "3")).is_err());
    }

    #[test]
    fn scan_from_respects_bounds_and_limit() {
        let p = tmppath("scan");
        let (_, kos) = build(&p, 100);
        let s = SortedVLog::open(&p).unwrap();
        // Start scanning from key key000010's offset.
        let start_off = kos[10].1;
        let got = s.scan_from(start_off, b"key000010", b"key000020", 100).unwrap();
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].key, b"key000010".to_vec());
        let limited = s.scan_from(start_off, b"key000010", b"key000099", 5).unwrap();
        assert_eq!(limited.len(), 5);
    }

    #[test]
    fn iter_returns_sorted_entries() {
        let p = tmppath("iter");
        build(&p, 50);
        let s = SortedVLog::open(&p).unwrap();
        let keys: Vec<_> = s.iter().map(|r| r.unwrap().1.key).collect();
        assert_eq!(keys.len(), 50);
        for w in keys.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn tombstone_count_tracks_writes_and_resume() {
        let p = tmppath("tombcount");
        let mut w = SortedVLogWriter::create(&p, 1, 9).unwrap();
        w.add(&Entry::put(1, 1, "a", "1")).unwrap();
        w.add(&Entry::delete(1, 2, "b")).unwrap();
        w.add(&Entry::put(1, 3, "c", "3")).unwrap();
        w.add(&Entry::delete(1, 4, "d")).unwrap();
        assert_eq!(w.tombstone_count(), 2);
        w.finish().unwrap();
        // Resume recounts tombstones from the valid prefix.
        let w = SortedVLogWriter::resume(&p).unwrap();
        assert_eq!(w.tombstone_count(), 2);
        assert_eq!(w.entry_count(), 4);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmppath("magic");
        std::fs::write(&p, vec![0u8; 64]).unwrap();
        assert!(SortedVLog::open(&p).is_err());
    }
}
