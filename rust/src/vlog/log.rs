//! Append-only ValueLog file.
//!
//! Frame: `[len u32][crc32 u32][payload]`, payload =
//! `term u64, index u64, op u8, key len_bytes, [value len_bytes]`.
//!
//! The single persist of a value in Nezha happens here (Algorithm 1,
//! line 3).  Appends are buffered; `sync()` is the commit point the
//! engines call per batch.  Reads use `pread` at an exact offset — the
//! offset returned by `append` is what the state machine stores.

use super::{Entry, Offset};
use crate::util::{Decoder, Encoder};
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const OP_PUT: u8 = 0;
const OP_DELETE: u8 = 1;

fn encode_entry(e: &Entry) -> Vec<u8> {
    let mut payload = Encoder::with_capacity(e.approx_len() + 16);
    payload.u64(e.term).u64(e.index);
    match &e.value {
        Some(v) => {
            payload.u8(OP_PUT).len_bytes(&e.key).len_bytes(v);
        }
        None => {
            payload.u8(OP_DELETE).len_bytes(&e.key);
        }
    }
    let body = payload.as_slice();
    let mut frame = Encoder::with_capacity(body.len() + 8);
    frame.u32(body.len() as u32).u32(crc32fast::hash(body)).bytes(body);
    frame.into_vec()
}

fn decode_payload(body: &[u8]) -> Result<Entry> {
    let mut d = Decoder::new(body);
    let term = d.u64()?;
    let index = d.u64()?;
    let op = d.u8()?;
    let key = d.len_bytes()?.to_vec();
    let value = match op {
        OP_PUT => Some(d.len_bytes()?.to_vec()),
        OP_DELETE => None,
        other => bail!("vlog: unknown op {other}"),
    };
    Ok(Entry { term, index, key, value })
}

/// Writable ValueLog (the Active / New storage module's log file).
pub struct VLog {
    path: PathBuf,
    file: File,
    /// Bytes durably owned by the file (i.e. written through).
    len: u64,
    /// Buffered but not yet written frames.
    buf: Vec<u8>,
    bytes_appended: Arc<AtomicU64>,
}

impl VLog {
    /// Open (creating if missing) and recover: scan frames, truncating
    /// any torn tail.
    pub fn open(path: &Path) -> Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("vlog open {path:?}"))?;
        let valid = scan_valid_len(&file)?;
        file.set_len(valid)?;
        Ok(Self {
            path: path.to_path_buf(),
            file,
            len: valid,
            buf: Vec::with_capacity(256 << 10),
            bytes_appended: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn bytes_appended_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.bytes_appended)
    }

    /// Append one entry; returns its offset. THE single value persist.
    pub fn append(&mut self, e: &Entry) -> Result<Offset> {
        let frame = encode_entry(e);
        let offset = self.len + self.buf.len() as u64;
        self.buf.extend_from_slice(&frame);
        self.bytes_appended.fetch_add(frame.len() as u64, Ordering::Relaxed);
        // Keep the write buffer bounded.
        if self.buf.len() >= 1 << 20 {
            self.flush_buf()?;
        }
        Ok(offset)
    }

    fn flush_buf(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            use std::os::unix::fs::FileExt;
            crate::fault::disk::check(&self.path, crate::fault::disk::DiskOp::Write)?;
            self.file.write_all_at(&self.buf, self.len)?;
            self.len += self.buf.len() as u64;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush buffered frames to the OS.
    pub fn flush(&mut self) -> Result<()> {
        self.flush_buf()
    }

    /// Durability point: flush + fdatasync.
    pub fn sync(&mut self) -> Result<()> {
        self.flush_buf()?;
        crate::fault::disk::check(&self.path, crate::fault::disk::DiskOp::Sync)?;
        self.file.sync_data()?;
        Ok(())
    }

    /// Logical length (including buffered tail).
    pub fn len_bytes(&self) -> u64 {
        self.len + self.buf.len() as u64
    }

    /// Random read of the entry at `offset` (flushes if the offset is
    /// still buffered).
    pub fn read(&mut self, offset: Offset) -> Result<Entry> {
        if offset >= self.len {
            self.flush_buf()?;
        }
        read_entry_at(&self.file, offset)
    }

    /// Read-only handle usable from other threads (GC, parallel point
    /// queries).  Callers must `flush()` first for full visibility.
    pub fn reader(&self) -> Result<VLogReader> {
        VLogReader::open(&self.path)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Iterate every entry (offset, entry) from the start. Flushes
    /// buffered writes first.
    pub fn iter(&mut self) -> Result<VLogIter> {
        self.flush_buf()?;
        Ok(VLogIter { file: self.file.try_clone()?, pos: 0, end: self.len })
    }
}

/// Shared read-only view of a ValueLog file.
pub struct VLogReader {
    file: File,
}

impl VLogReader {
    pub fn open(path: &Path) -> Result<Self> {
        Ok(Self { file: File::open(path).with_context(|| format!("vlog reader {path:?}"))? })
    }

    pub fn read(&self, offset: Offset) -> Result<Entry> {
        read_entry_at(&self.file, offset)
    }

    /// Serve the entry at `offset` from already-resident readahead
    /// segments, touching neither the file nor the cache contents.
    /// `Ok(None)` means "not resident — fall back to a direct read".
    /// Exactly one readahead hit is counted, and only once both the
    /// header and the body were served from residency — a
    /// header-resident/body-absent read falls back uncached and counts
    /// nothing.
    pub fn read_resident(
        &self,
        offset: Offset,
        epoch: u32,
        cache: &super::readahead::ReadaheadCache,
    ) -> Result<Option<Entry>> {
        let mut hdr = [0u8; 8];
        if !cache.read_resident_at(epoch, offset, &mut hdr) {
            return Ok(None);
        }
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let mut body = vec![0u8; len];
        if !cache.read_resident_at(epoch, offset + 8, &mut body) {
            return Ok(None);
        }
        if crc32fast::hash(&body) != crc {
            bail!("vlog crc mismatch @{offset}");
        }
        cache.note_hit();
        decode_payload(&body).map(Some)
    }

    /// Read the entry at `offset` through a
    /// [`super::readahead::ReadaheadCache`] so adjacent entries (a
    /// batched, offset-sorted resolution pass) share one aligned
    /// segment `pread` instead of two raw reads each.
    pub fn read_cached(
        &self,
        offset: Offset,
        epoch: u32,
        cache: &super::readahead::ReadaheadCache,
    ) -> Result<Entry> {
        let mut hdr = [0u8; 8];
        cache
            .read_exact_at(epoch, &self.file, offset, &mut hdr)
            .with_context(|| format!("vlog cached read header @{offset}"))?;
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let mut body = vec![0u8; len];
        cache
            .read_exact_at(epoch, &self.file, offset + 8, &mut body)
            .with_context(|| format!("vlog cached read body @{offset} len={len}"))?;
        if crc32fast::hash(&body) != crc {
            bail!("vlog crc mismatch @{offset}");
        }
        decode_payload(&body)
    }

    pub fn iter(&self) -> Result<VLogIter> {
        self.iter_from(0)
    }

    /// Iterate from `offset` (must be a frame boundary — e.g. a
    /// prefix-skip point recorded by an earlier scan).  An offset at or
    /// past the end yields an empty iteration.
    pub fn iter_from(&self, offset: Offset) -> Result<VLogIter> {
        let end = self.file.metadata()?.len();
        Ok(VLogIter { file: self.file.try_clone()?, pos: offset.min(end), end })
    }
}

fn read_entry_at(file: &File, offset: u64) -> Result<Entry> {
    use std::os::unix::fs::FileExt;
    let mut hdr = [0u8; 8];
    file.read_exact_at(&mut hdr, offset)
        .with_context(|| format!("vlog read header @{offset}"))?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    let mut body = vec![0u8; len];
    file.read_exact_at(&mut body, offset + 8)
        .with_context(|| format!("vlog read body @{offset} len={len}"))?;
    if crc32fast::hash(&body) != crc {
        bail!("vlog crc mismatch @{offset}");
    }
    decode_payload(&body)
}

/// Scan from the start, returning the length of the valid prefix.
fn scan_valid_len(file: &File) -> Result<u64> {
    use std::os::unix::fs::FileExt;
    let end = file.metadata()?.len();
    let mut pos = 0u64;
    let mut hdr = [0u8; 8];
    while pos + 8 <= end {
        if file.read_exact_at(&mut hdr, pos).is_err() {
            break;
        }
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as u64;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if pos + 8 + len > end {
            break;
        }
        let mut body = vec![0u8; len as usize];
        if file.read_exact_at(&mut body, pos + 8).is_err() {
            break;
        }
        if crc32fast::hash(&body) != crc {
            break;
        }
        pos += 8 + len;
    }
    Ok(pos)
}

/// Forward iterator over (offset, entry).
pub struct VLogIter {
    file: File,
    pos: u64,
    end: u64,
}

impl Iterator for VLogIter {
    type Item = Result<(Offset, Entry)>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + 8 > self.end {
            return None;
        }
        let offset = self.pos;
        match read_entry_at(&self.file, offset) {
            Ok(e) => {
                // Recompute frame length to advance.
                let frame = encode_entry(&e);
                self.pos += frame.len() as u64;
                Some(Ok((offset, e)))
            }
            Err(err) => {
                self.pos = self.end; // stop on error
                Some(Err(err))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-vlog-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn append_read_roundtrip() {
        let mut v = VLog::open(&tmppath("rt")).unwrap();
        let e1 = Entry::put(1, 1, "alpha", vec![1u8; 100]);
        let e2 = Entry::delete(1, 2, "beta");
        let o1 = v.append(&e1).unwrap();
        let o2 = v.append(&e2).unwrap();
        assert!(o2 > o1);
        assert_eq!(v.read(o1).unwrap(), e1);
        assert_eq!(v.read(o2).unwrap(), e2);
    }

    #[test]
    fn offsets_stable_across_reopen() {
        let p = tmppath("reopen");
        let (o1, e1);
        {
            let mut v = VLog::open(&p).unwrap();
            e1 = Entry::put(3, 7, "k", "v");
            o1 = v.append(&e1).unwrap();
            v.sync().unwrap();
        }
        let mut v = VLog::open(&p).unwrap();
        assert_eq!(v.read(o1).unwrap(), e1);
        // New appends land after the recovered tail.
        let o2 = v.append(&Entry::put(3, 8, "k2", "v2")).unwrap();
        assert!(o2 > o1);
    }

    #[test]
    fn torn_tail_truncated_on_open() {
        let p = tmppath("torn");
        {
            let mut v = VLog::open(&p).unwrap();
            v.append(&Entry::put(1, 1, "a", "1")).unwrap();
            v.sync().unwrap();
        }
        // Simulate a torn append.
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[200, 0, 0, 0, 1, 2]).unwrap();
        }
        let mut v = VLog::open(&p).unwrap();
        let entries: Vec<_> = v.iter().unwrap().map(|r| r.unwrap()).collect();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].1.key, b"a".to_vec());
    }

    #[test]
    fn iter_yields_offsets_matching_append() {
        let mut v = VLog::open(&tmppath("iter")).unwrap();
        let mut offs = Vec::new();
        for i in 0..50u64 {
            offs.push(
                v.append(&Entry::put(1, i, format!("k{i}"), format!("v{i}"))).unwrap(),
            );
        }
        let got: Vec<_> = v.iter().unwrap().map(|r| r.unwrap()).collect();
        assert_eq!(got.len(), 50);
        for (i, (off, e)) in got.iter().enumerate() {
            assert_eq!(*off, offs[i]);
            assert_eq!(e.index, i as u64);
        }
    }

    #[test]
    fn iter_from_resumes_at_a_frame_boundary() {
        let p = tmppath("iterfrom");
        let mut v = VLog::open(&p).unwrap();
        let mut offs = Vec::new();
        for i in 0..20u64 {
            offs.push(v.append(&Entry::put(1, i, format!("k{i:02}"), "v")).unwrap());
        }
        v.sync().unwrap();
        let r = VLogReader::open(&p).unwrap();
        let tail: Vec<_> = r.iter_from(offs[12]).unwrap().map(|x| x.unwrap()).collect();
        assert_eq!(tail.len(), 8);
        assert_eq!(tail[0].0, offs[12]);
        assert_eq!(tail[0].1.index, 12);
        // Past-the-end offsets read as empty, not as an error.
        assert_eq!(r.iter_from(u64::MAX).unwrap().count(), 0);
    }

    #[test]
    fn read_of_buffered_entry_flushes() {
        let mut v = VLog::open(&tmppath("buffered")).unwrap();
        let e = Entry::put(1, 1, "x", vec![5u8; 10]);
        let o = v.append(&e).unwrap();
        // No explicit flush — read must still work.
        assert_eq!(v.read(o).unwrap(), e);
    }

    #[test]
    fn reader_sees_flushed_entries() {
        let mut v = VLog::open(&tmppath("reader")).unwrap();
        let e = Entry::put(2, 9, "rk", "rv");
        let o = v.append(&e).unwrap();
        v.flush().unwrap();
        let r = v.reader().unwrap();
        assert_eq!(r.read(o).unwrap(), e);
        assert_eq!(r.iter().unwrap().count(), 1);
    }

    #[test]
    fn crc_corruption_detected() {
        let p = tmppath("crc");
        let o;
        {
            let mut v = VLog::open(&p).unwrap();
            o = v.append(&Entry::put(1, 1, "a", vec![9u8; 50])).unwrap();
            v.sync().unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        let l = bytes.len();
        bytes[l - 1] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        // Direct read fails...
        let r = VLogReader::open(&p).unwrap();
        assert!(r.read(o).is_err());
        // ...and open() truncates the corrupt record away.
        let v = VLog::open(&p).unwrap();
        assert_eq!(v.len_bytes(), 0);
    }
}
