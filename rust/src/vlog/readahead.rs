//! Readahead block cache for epoch ValueLog reads.
//!
//! The batched read path ([`super::EpochReaders::read_vrefs_batched`])
//! groups a slice of [`super::VRef`]s by epoch and sorts them by
//! offset, so consecutive resolutions walk each epoch file forward.
//! This cache turns that ordered walk into large sequential I/O: the
//! file is read in fixed, aligned segments ([`SEGMENT_BYTES`] = 64 KiB)
//! that are kept in a small LRU, so N adjacent values cost one `pread`
//! instead of N (two per entry, header + body, without it).
//!
//! Crash-safety: this layer is read-only — it never writes to a
//! ValueLog and never serves bytes that are not already in the file, so
//! it cannot affect the single-write durability story.  Epoch files are
//! append-only and immutable below their flushed length, which makes
//! cached segments trivially coherent: a cached segment can only be
//! *short* (taken while the file tail was still growing), never wrong.
//! A read past a cached segment's end simply reloads that segment.
//!
//! Hit/miss counters land in the shared [`IoStats`] (`readahead_hits` /
//! `readahead_misses`), alongside `vlog_reads`/`vlog_read_bytes`
//! maintained by [`super::EpochReaders`], so benches can print the
//! cache hit rate.

use crate::lsm::IoStats;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::fs::File;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Aligned segment size: big enough that a handful of segments cover a
/// typical scan's value window, small enough that point-read pollution
/// stays bounded.
pub const SEGMENT_BYTES: u64 = 64 << 10;

/// Default cache capacity in segments (128 × 64 KiB = 8 MiB).
pub const DEFAULT_SEGMENTS: usize = 128;

struct CachedSeg {
    data: Arc<Vec<u8>>,
    last_used: u64,
}

struct Inner {
    map: HashMap<(u32, u64), CachedSeg>,
    tick: u64,
}

/// Fixed-capacity LRU of 64 KiB aligned ValueLog segments, keyed by
/// `(epoch, segment_index)`.
pub struct ReadaheadCache {
    capacity: usize,
    inner: Mutex<Inner>,
    io: Arc<IoStats>,
}

impl ReadaheadCache {
    pub fn new(capacity: usize, io: Arc<IoStats>) -> Self {
        Self {
            capacity: capacity.max(4),
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            io,
        }
    }

    pub fn io_stats(&self) -> Arc<IoStats> {
        Arc::clone(&self.io)
    }

    /// Number of resident segments (tests).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all segments of epochs `< min_epoch` (after GC deletes the
    /// files).
    pub fn invalidate_below(&self, min_epoch: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|&(e, _), _| e >= min_epoch);
    }

    /// Drop all segments of epochs `>= epoch` (Raft conflict
    /// truncation rewrites those files in place, so resident bytes may
    /// no longer match the file).
    pub fn invalidate_from(&self, epoch: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|&(e, _), _| e < epoch);
    }

    /// Return the segment `(epoch, seg)` with at least `need_len` valid
    /// bytes, loading (or reloading a stale-short copy) from `file`.
    /// `need_len == 0` accepts any resident length.
    fn segment(
        &self,
        epoch: u32,
        seg: u64,
        need_len: usize,
        file: &File,
    ) -> Result<Arc<Vec<u8>>> {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(c) = inner.map.get_mut(&(epoch, seg)) {
                if c.data.len() >= need_len {
                    c.last_used = tick;
                    self.io.readahead_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Arc::clone(&c.data));
                }
                // Stale partial tail segment (file has grown since it
                // was cached): fall through and reload.
            }
        }
        self.io.readahead_misses.fetch_add(1, Ordering::Relaxed);
        let data = Arc::new(load_segment(file, seg)?);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if inner.map.len() >= self.capacity && !inner.map.contains_key(&(epoch, seg)) {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, c)| c.last_used)
                .map(|(&k, _)| k);
            if let Some(victim) = victim {
                inner.map.remove(&victim);
            }
        }
        inner.map.insert((epoch, seg), CachedSeg { data: Arc::clone(&data), last_used: tick });
        Ok(data)
    }

    /// Copy `buf.len()` bytes at `offset` out of already-resident
    /// segments only.  Returns `false` (with `buf` possibly partially
    /// written) when any covering segment is absent or too short;
    /// nothing is loaded or evicted either way.  The single-key read
    /// path uses this to probe segments populated by batched passes
    /// without polluting the cache: a point read of the growing
    /// live-epoch tail would otherwise reload a 64 KiB segment per
    /// fresh entry.  Probes touch *no* hit/miss counter: a failed
    /// probe intentionally never loads (the fallback is a direct
    /// read), so counting a miss would deflate the reported hit rate
    /// on point-read-heavy workloads — and a multi-probe caller must
    /// not count a hit until *every* probe of one logical read has
    /// succeeded (see [`note_hit`](Self::note_hit)), or a
    /// header-resident/body-absent read would inflate it.
    pub fn read_resident_at(&self, epoch: u32, offset: u64, buf: &mut [u8]) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let mut pos = offset;
        let end = offset + buf.len() as u64;
        while pos < end {
            let seg = pos / SEGMENT_BYTES;
            let seg_start = seg * SEGMENT_BYTES;
            let in_seg = (pos - seg_start) as usize;
            let take = ((end - pos) as usize).min(SEGMENT_BYTES as usize - in_seg);
            let Some(c) = inner.map.get_mut(&(epoch, seg)) else {
                return false;
            };
            if c.data.len() < in_seg + take {
                return false;
            }
            c.last_used = tick;
            let dst = (pos - offset) as usize;
            buf[dst..dst + take].copy_from_slice(&c.data[in_seg..in_seg + take]);
            pos += take as u64;
        }
        true
    }

    /// Record one read served entirely from resident segments.  Called
    /// by [`read_resident_at`](Self::read_resident_at) users once every
    /// probe of a logical read has succeeded, so the hit rate counts
    /// whole reads actually served by the cache.
    pub fn note_hit(&self) {
        self.io.readahead_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Fill `buf` from `file` at `offset`, served segment-by-segment
    /// through the cache.  Errors if the file (even after reloading the
    /// covering segments) does not own `offset + buf.len()` bytes.
    pub fn read_exact_at(
        &self,
        epoch: u32,
        file: &File,
        offset: u64,
        buf: &mut [u8],
    ) -> Result<()> {
        let mut pos = offset;
        let end = offset + buf.len() as u64;
        while pos < end {
            let seg = pos / SEGMENT_BYTES;
            let seg_start = seg * SEGMENT_BYTES;
            let in_seg = (pos - seg_start) as usize;
            let take = ((end - pos) as usize).min(SEGMENT_BYTES as usize - in_seg);
            let data = self.segment(epoch, seg, in_seg + take, file)?;
            if data.len() < in_seg + take {
                bail!(
                    "vlog readahead: read past end of file (segment {seg} has {} bytes, need {})",
                    data.len(),
                    in_seg + take
                );
            }
            let dst = (pos - offset) as usize;
            buf[dst..dst + take].copy_from_slice(&data[in_seg..in_seg + take]);
            pos += take as u64;
        }
        Ok(())
    }
}

/// One `pread` of the whole aligned segment (short at the file tail).
fn load_segment(file: &File, seg: u64) -> Result<Vec<u8>> {
    use std::os::unix::fs::FileExt;
    let start = seg * SEGMENT_BYTES;
    let file_len = file.metadata()?.len();
    if start >= file_len {
        return Ok(Vec::new());
    }
    let want = (file_len - start).min(SEGMENT_BYTES) as usize;
    let mut buf = vec![0u8; want];
    file.read_exact_at(&mut buf, start)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::path::PathBuf;

    fn tmpfile(name: &str, bytes: &[u8]) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-ra-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        std::fs::File::create(&p).unwrap().write_all(bytes).unwrap();
        p
    }

    fn cache(capacity: usize) -> ReadaheadCache {
        ReadaheadCache::new(capacity, Arc::new(IoStats::default()))
    }

    #[test]
    fn adjacent_reads_hit_one_segment() {
        let data: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        let p = tmpfile("adjacent", &data);
        let f = File::open(&p).unwrap();
        let c = cache(16);
        let mut buf = [0u8; 100];
        for i in 0..50u64 {
            c.read_exact_at(0, &f, i * 100, &mut buf).unwrap();
            assert_eq!(buf[0], data[(i * 100) as usize]);
        }
        let io = c.io_stats();
        // 5000 bytes span a single 64 KiB segment: 1 miss, rest hits.
        assert_eq!(io.readahead_misses.load(Ordering::Relaxed), 1);
        assert_eq!(io.readahead_hits.load(Ordering::Relaxed), 49);
    }

    #[test]
    fn read_spanning_segments_assembles() {
        let data: Vec<u8> = (0..(3 * SEGMENT_BYTES) as usize).map(|i| (i % 253) as u8).collect();
        let p = tmpfile("span", &data);
        let f = File::open(&p).unwrap();
        let c = cache(16);
        let start = SEGMENT_BYTES - 17;
        let mut buf = vec![0u8; 64];
        c.read_exact_at(3, &f, start, &mut buf).unwrap();
        assert_eq!(&buf[..], &data[start as usize..start as usize + 64]);
    }

    #[test]
    fn stale_short_segment_reloaded_after_append() {
        let p = tmpfile("grow", b"hello");
        {
            let f = File::open(&p).unwrap();
            let c = cache(8);
            let mut buf = [0u8; 5];
            c.read_exact_at(0, &f, 0, &mut buf).unwrap();
            assert_eq!(&buf, b"hello");
            // File grows within the same segment.
            let mut w = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            w.write_all(b" world").unwrap();
            let mut buf2 = [0u8; 11];
            c.read_exact_at(0, &f, 0, &mut buf2).unwrap();
            assert_eq!(&buf2, b"hello world");
        }
    }

    #[test]
    fn read_past_eof_errors() {
        let p = tmpfile("eof", b"tiny");
        let f = File::open(&p).unwrap();
        let c = cache(8);
        let mut buf = [0u8; 16];
        assert!(c.read_exact_at(0, &f, 0, &mut buf).is_err());
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let data = vec![7u8; (6 * SEGMENT_BYTES) as usize];
        let p = tmpfile("evict", &data);
        let f = File::open(&p).unwrap();
        let c = cache(4);
        let mut buf = [0u8; 8];
        for seg in 0..6u64 {
            c.read_exact_at(0, &f, seg * SEGMENT_BYTES, &mut buf).unwrap();
        }
        assert!(c.len() <= 4);
        // Re-reading the most recent segment is still a hit.
        let hits0 = c.io_stats().readahead_hits.load(Ordering::Relaxed);
        c.read_exact_at(0, &f, 5 * SEGMENT_BYTES, &mut buf).unwrap();
        assert_eq!(c.io_stats().readahead_hits.load(Ordering::Relaxed), hits0 + 1);
    }

    #[test]
    fn invalidate_below_drops_old_epochs() {
        let data = vec![1u8; 1024];
        let p = tmpfile("inval", &data);
        let f = File::open(&p).unwrap();
        let c = cache(8);
        let mut buf = [0u8; 8];
        for epoch in 0..3u32 {
            c.read_exact_at(epoch, &f, 0, &mut buf).unwrap();
        }
        assert_eq!(c.len(), 3);
        c.invalidate_below(2);
        assert_eq!(c.len(), 1);
    }
}
