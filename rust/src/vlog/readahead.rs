//! Readahead block cache for epoch ValueLog reads.
//!
//! The batched read path ([`super::EpochReaders::read_vrefs_batched`])
//! groups a slice of [`super::VRef`]s by epoch and sorts them by
//! offset, so consecutive resolutions walk each epoch file forward.
//! This cache turns that ordered walk into large sequential I/O: the
//! file is read in aligned segments kept in a small LRU, so N adjacent
//! values cost one `pread` instead of N (two per entry, header + body,
//! without it).
//!
//! Segment size is adaptive per file: small files use the base
//! [`SEGMENT_BYTES`] (64 KiB), while larger files — deep sorted runs
//! read through [`crate::engine`]'s batched paths — step up to 128 KiB
//! and 256 KiB (see [`segment_bytes_for`]).  A bigger segment amortizes
//! more per-`pread` overhead exactly where walks are longest, without
//! inflating point-read pollution on the small live-epoch tail.  The
//! size is chosen once per epoch id, at the first load, from the file
//! length at that moment, and pinned until every segment of that epoch
//! is invalidated: segment indices are offsets divided by the pinned
//! size, so mixing sizes within one epoch would alias distinct byte
//! ranges.  The most recent choice is reported via
//! `IoStats::readahead_seg_bytes` (monotone max) so benches can print
//! the active segment size.
//!
//! Crash-safety: this layer is read-only — it never writes to a
//! ValueLog and never serves bytes that are not already in the file, so
//! it cannot affect the single-write durability story.  Epoch files are
//! append-only and immutable below their flushed length, which makes
//! cached segments trivially coherent: a cached segment can only be
//! *short* (taken while the file tail was still growing), never wrong.
//! A read past a cached segment's end simply reloads that segment.
//!
//! Hit/miss counters land in the shared [`IoStats`] (`readahead_hits` /
//! `readahead_misses`), alongside `vlog_reads`/`vlog_read_bytes`
//! maintained by [`super::EpochReaders`], so benches can print the
//! cache hit rate.

use crate::lsm::IoStats;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::fs::File;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Base aligned segment size: big enough that a handful of segments
/// cover a typical scan's value window, small enough that point-read
/// pollution stays bounded.  Files above [`SEGMENT_STEP_BYTES`] /
/// [`SEGMENT_BIG_BYTES`] get larger segments (see
/// [`segment_bytes_for`]).
pub const SEGMENT_BYTES: u64 = 64 << 10;

/// Files at least this long get 128 KiB segments.
pub const SEGMENT_STEP_BYTES: u64 = 4 << 20;

/// Files at least this long get 256 KiB segments.
pub const SEGMENT_BIG_BYTES: u64 = 32 << 20;

/// Default cache capacity in segments (128 × 64 KiB = 8 MiB at the
/// base size).
pub const DEFAULT_SEGMENTS: usize = 128;

/// Segment size for a file of `file_len` bytes: 64 KiB below 4 MiB,
/// 128 KiB below 32 MiB, 256 KiB above.  Deep sorted runs are long and
/// walked sequentially, so they amortize the bigger `pread`.
pub fn segment_bytes_for(file_len: u64) -> u64 {
    if file_len >= SEGMENT_BIG_BYTES {
        256 << 10
    } else if file_len >= SEGMENT_STEP_BYTES {
        128 << 10
    } else {
        SEGMENT_BYTES
    }
}

struct CachedSeg {
    data: Arc<Vec<u8>>,
    last_used: u64,
}

struct Inner {
    map: HashMap<(u32, u64), CachedSeg>,
    /// Pinned segment size per epoch id (chosen at first load; see
    /// module docs for why it must not change while segments are
    /// resident).
    seg_bytes: HashMap<u32, u64>,
    tick: u64,
}

/// Fixed-capacity LRU of aligned ValueLog segments, keyed by
/// `(epoch, segment_index)`.  Segment size is per-epoch, chosen from
/// the file length at first load ([`segment_bytes_for`]).
pub struct ReadaheadCache {
    capacity: usize,
    inner: Mutex<Inner>,
    io: Arc<IoStats>,
}

impl ReadaheadCache {
    pub fn new(capacity: usize, io: Arc<IoStats>) -> Self {
        Self {
            capacity: capacity.max(4),
            inner: Mutex::new(Inner { map: HashMap::new(), seg_bytes: HashMap::new(), tick: 0 }),
            io,
        }
    }

    /// Pinned segment size for `epoch`, choosing (and recording) one
    /// from the current file length on first use.
    fn seg_bytes(&self, epoch: u32, file: &File) -> Result<u64> {
        {
            let inner = self.inner.lock().unwrap();
            if let Some(&sb) = inner.seg_bytes.get(&epoch) {
                return Ok(sb);
            }
        }
        let sb = segment_bytes_for(file.metadata()?.len());
        self.io.readahead_seg_bytes.fetch_max(sb, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        // Another thread may have pinned a size between the two locks;
        // the map entry wins so all segment indices stay coherent.
        Ok(*inner.seg_bytes.entry(epoch).or_insert(sb))
    }

    pub fn io_stats(&self) -> Arc<IoStats> {
        Arc::clone(&self.io)
    }

    /// Number of resident segments (tests).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all segments of epochs `< min_epoch` (after GC deletes the
    /// files).
    pub fn invalidate_below(&self, min_epoch: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|&(e, _), _| e >= min_epoch);
        inner.seg_bytes.retain(|&e, _| e >= min_epoch);
    }

    /// Drop all segments of epochs `>= epoch` (Raft conflict
    /// truncation rewrites those files in place, so resident bytes may
    /// no longer match the file).
    pub fn invalidate_from(&self, epoch: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|&(e, _), _| e < epoch);
        // Truncation can change the file length class, so let the next
        // load re-derive the segment size too.
        inner.seg_bytes.retain(|&e, _| e < epoch);
    }

    /// Return the segment `(epoch, seg)` with at least `need_len` valid
    /// bytes, loading (or reloading a stale-short copy) from `file`.
    /// `need_len == 0` accepts any resident length.
    fn segment(
        &self,
        epoch: u32,
        seg: u64,
        seg_bytes: u64,
        need_len: usize,
        file: &File,
    ) -> Result<Arc<Vec<u8>>> {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(c) = inner.map.get_mut(&(epoch, seg)) {
                if c.data.len() >= need_len {
                    c.last_used = tick;
                    self.io.readahead_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Arc::clone(&c.data));
                }
                // Stale partial tail segment (file has grown since it
                // was cached): fall through and reload.
            }
        }
        self.io.readahead_misses.fetch_add(1, Ordering::Relaxed);
        let data = Arc::new(load_segment(file, seg, seg_bytes)?);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if inner.map.len() >= self.capacity && !inner.map.contains_key(&(epoch, seg)) {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, c)| c.last_used)
                .map(|(&k, _)| k);
            if let Some(victim) = victim {
                inner.map.remove(&victim);
            }
        }
        inner.map.insert((epoch, seg), CachedSeg { data: Arc::clone(&data), last_used: tick });
        Ok(data)
    }

    /// Copy `buf.len()` bytes at `offset` out of already-resident
    /// segments only.  Returns `false` (with `buf` possibly partially
    /// written) when any covering segment is absent or too short;
    /// nothing is loaded or evicted either way.  The single-key read
    /// path uses this to probe segments populated by batched passes
    /// without polluting the cache: a point read of the growing
    /// live-epoch tail would otherwise reload a 64 KiB segment per
    /// fresh entry.  Probes touch *no* hit/miss counter: a failed
    /// probe intentionally never loads (the fallback is a direct
    /// read), so counting a miss would deflate the reported hit rate
    /// on point-read-heavy workloads — and a multi-probe caller must
    /// not count a hit until *every* probe of one logical read has
    /// succeeded (see [`note_hit`](Self::note_hit)), or a
    /// header-resident/body-absent read would inflate it.
    pub fn read_resident_at(&self, epoch: u32, offset: u64, buf: &mut [u8]) -> bool {
        let mut inner = self.inner.lock().unwrap();
        // No pinned size means no segment of this epoch is resident.
        let Some(&seg_bytes) = inner.seg_bytes.get(&epoch) else {
            return false;
        };
        inner.tick += 1;
        let tick = inner.tick;
        let mut pos = offset;
        let end = offset + buf.len() as u64;
        while pos < end {
            let seg = pos / seg_bytes;
            let seg_start = seg * seg_bytes;
            let in_seg = (pos - seg_start) as usize;
            let take = ((end - pos) as usize).min(seg_bytes as usize - in_seg);
            let Some(c) = inner.map.get_mut(&(epoch, seg)) else {
                return false;
            };
            if c.data.len() < in_seg + take {
                return false;
            }
            c.last_used = tick;
            let dst = (pos - offset) as usize;
            buf[dst..dst + take].copy_from_slice(&c.data[in_seg..in_seg + take]);
            pos += take as u64;
        }
        true
    }

    /// Record one read served entirely from resident segments.  Called
    /// by [`read_resident_at`](Self::read_resident_at) users once every
    /// probe of a logical read has succeeded, so the hit rate counts
    /// whole reads actually served by the cache.
    pub fn note_hit(&self) {
        self.io.readahead_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Fill `buf` from `file` at `offset`, served segment-by-segment
    /// through the cache.  Errors if the file (even after reloading the
    /// covering segments) does not own `offset + buf.len()` bytes.
    pub fn read_exact_at(
        &self,
        epoch: u32,
        file: &File,
        offset: u64,
        buf: &mut [u8],
    ) -> Result<()> {
        let seg_bytes = self.seg_bytes(epoch, file)?;
        let mut pos = offset;
        let end = offset + buf.len() as u64;
        while pos < end {
            let seg = pos / seg_bytes;
            let seg_start = seg * seg_bytes;
            let in_seg = (pos - seg_start) as usize;
            let take = ((end - pos) as usize).min(seg_bytes as usize - in_seg);
            let data = self.segment(epoch, seg, seg_bytes, in_seg + take, file)?;
            if data.len() < in_seg + take {
                bail!(
                    "vlog readahead: read past end of file (segment {seg} has {} bytes, need {})",
                    data.len(),
                    in_seg + take
                );
            }
            let dst = (pos - offset) as usize;
            buf[dst..dst + take].copy_from_slice(&data[in_seg..in_seg + take]);
            pos += take as u64;
        }
        Ok(())
    }
}

/// One `pread` of the whole aligned segment (short at the file tail).
fn load_segment(file: &File, seg: u64, seg_bytes: u64) -> Result<Vec<u8>> {
    use std::os::unix::fs::FileExt;
    let start = seg * seg_bytes;
    let file_len = file.metadata()?.len();
    if start >= file_len {
        return Ok(Vec::new());
    }
    let want = (file_len - start).min(seg_bytes) as usize;
    let mut buf = vec![0u8; want];
    file.read_exact_at(&mut buf, start)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::path::PathBuf;

    fn tmpfile(name: &str, bytes: &[u8]) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-ra-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        std::fs::File::create(&p).unwrap().write_all(bytes).unwrap();
        p
    }

    fn cache(capacity: usize) -> ReadaheadCache {
        ReadaheadCache::new(capacity, Arc::new(IoStats::default()))
    }

    #[test]
    fn adjacent_reads_hit_one_segment() {
        let data: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        let p = tmpfile("adjacent", &data);
        let f = File::open(&p).unwrap();
        let c = cache(16);
        let mut buf = [0u8; 100];
        for i in 0..50u64 {
            c.read_exact_at(0, &f, i * 100, &mut buf).unwrap();
            assert_eq!(buf[0], data[(i * 100) as usize]);
        }
        let io = c.io_stats();
        // 5000 bytes span a single 64 KiB segment: 1 miss, rest hits.
        assert_eq!(io.readahead_misses.load(Ordering::Relaxed), 1);
        assert_eq!(io.readahead_hits.load(Ordering::Relaxed), 49);
    }

    #[test]
    fn read_spanning_segments_assembles() {
        let data: Vec<u8> = (0..(3 * SEGMENT_BYTES) as usize).map(|i| (i % 253) as u8).collect();
        let p = tmpfile("span", &data);
        let f = File::open(&p).unwrap();
        let c = cache(16);
        let start = SEGMENT_BYTES - 17;
        let mut buf = vec![0u8; 64];
        c.read_exact_at(3, &f, start, &mut buf).unwrap();
        assert_eq!(&buf[..], &data[start as usize..start as usize + 64]);
    }

    #[test]
    fn stale_short_segment_reloaded_after_append() {
        let p = tmpfile("grow", b"hello");
        {
            let f = File::open(&p).unwrap();
            let c = cache(8);
            let mut buf = [0u8; 5];
            c.read_exact_at(0, &f, 0, &mut buf).unwrap();
            assert_eq!(&buf, b"hello");
            // File grows within the same segment.
            let mut w = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            w.write_all(b" world").unwrap();
            let mut buf2 = [0u8; 11];
            c.read_exact_at(0, &f, 0, &mut buf2).unwrap();
            assert_eq!(&buf2, b"hello world");
        }
    }

    #[test]
    fn read_past_eof_errors() {
        let p = tmpfile("eof", b"tiny");
        let f = File::open(&p).unwrap();
        let c = cache(8);
        let mut buf = [0u8; 16];
        assert!(c.read_exact_at(0, &f, 0, &mut buf).is_err());
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let data = vec![7u8; (6 * SEGMENT_BYTES) as usize];
        let p = tmpfile("evict", &data);
        let f = File::open(&p).unwrap();
        let c = cache(4);
        let mut buf = [0u8; 8];
        for seg in 0..6u64 {
            c.read_exact_at(0, &f, seg * SEGMENT_BYTES, &mut buf).unwrap();
        }
        assert!(c.len() <= 4);
        // Re-reading the most recent segment is still a hit.
        let hits0 = c.io_stats().readahead_hits.load(Ordering::Relaxed);
        c.read_exact_at(0, &f, 5 * SEGMENT_BYTES, &mut buf).unwrap();
        assert_eq!(c.io_stats().readahead_hits.load(Ordering::Relaxed), hits0 + 1);
    }

    #[test]
    fn segment_size_scales_with_file_length() {
        assert_eq!(segment_bytes_for(0), 64 << 10);
        assert_eq!(segment_bytes_for((4 << 20) - 1), 64 << 10);
        assert_eq!(segment_bytes_for(4 << 20), 128 << 10);
        assert_eq!(segment_bytes_for((32 << 20) - 1), 128 << 10);
        assert_eq!(segment_bytes_for(32 << 20), 256 << 10);
        assert_eq!(segment_bytes_for(1 << 30), 256 << 10);
    }

    #[test]
    fn large_file_uses_bigger_pinned_segments() {
        let data = vec![3u8; (4 << 20) + 100];
        let p = tmpfile("large", &data);
        let f = File::open(&p).unwrap();
        let c = cache(16);
        let mut buf = [0u8; 8];
        // Two reads in the same 128 KiB segment but in *different*
        // 64 KiB base segments: with the adaptive size pinned at
        // 128 KiB, the second read is a hit.
        c.read_exact_at(0, &f, 10, &mut buf).unwrap();
        c.read_exact_at(0, &f, (64 << 10) + 10, &mut buf).unwrap();
        let io = c.io_stats();
        assert_eq!(io.readahead_misses.load(Ordering::Relaxed), 1);
        assert_eq!(io.readahead_hits.load(Ordering::Relaxed), 1);
        assert_eq!(io.readahead_seg_bytes.load(Ordering::Relaxed), 128 << 10);
    }

    #[test]
    fn invalidate_below_drops_old_epochs() {
        let data = vec![1u8; 1024];
        let p = tmpfile("inval", &data);
        let f = File::open(&p).unwrap();
        let c = cache(8);
        let mut buf = [0u8; 8];
        for epoch in 0..3u32 {
            c.read_exact_at(epoch, &f, 0, &mut buf).unwrap();
        }
        assert_eq!(c.len(), 3);
        c.invalidate_below(2);
        assert_eq!(c.len(), 1);
    }
}
