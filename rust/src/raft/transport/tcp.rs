//! Real TCP transport (DESIGN.md §2).
//!
//! [`TcpNet`] carries the same encoded [`Message`] frames as the
//! in-process [`super::Bus`], over real sockets, with the same
//! register/send/drain contract and [`WireStats`] parity:
//!
//! * **Framing.**  Every frame is `u32 len ∥ u32 crc32 ∥ payload`
//!   (little-endian, CRC over the payload — the same discipline as the
//!   ValueLog's on-disk records).  The first frame on a connection is a
//!   handshake naming the sender, so per-message frames carry no
//!   addressing overhead.  A frame that fails its CRC (or declares an
//!   absurd length) desynchronizes the stream: the receiver counts it
//!   `dropped` and closes the connection; the sender reconnects lazily.
//! * **Inbound.**  `register(id)` binds one listener per local node and
//!   spawns an accept loop; each accepted connection gets a reader
//!   thread that parses frames and pushes them into the node's
//!   [`Mailbox`] — the node loop's `drain` is unchanged from the bus.
//! * **Outbound.**  Connections are established lazily on first send
//!   and re-established (rate-limited) after failures.  Each (from, to)
//!   pair has a writer thread behind a **bounded** queue: a dead or
//!   slow peer overflows the queue and the frames count `dropped` —
//!   the sending node loop never blocks on a peer (Raft retries by
//!   design; blocking a leader's loop on a dead follower would stall
//!   the whole shard).
//!
//! Two construction modes:
//! * [`TcpNet::new`] — loopback with OS-assigned ports; the cluster
//!   harness registers every node in one process and peers discover
//!   each other through the shared address map (`--transport tcp`).
//! * [`TcpNet::with_peers`] — a fixed node→address map for real
//!   multi-process clusters (`nezha serve`): each process registers
//!   only its own node and dials the others at the configured
//!   addresses.

use super::super::node::NodeId;
use super::super::rpc::Message;
use super::{Mailbox, WireStats};
use crate::fault::FaultPlan;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on one frame's payload.  Generous enough for an
/// `InstallSnapshot` carrying a whole sorted-ValueLog snapshot at bench
/// scale; small enough that a corrupt length field can't trigger a
/// multi-gigabyte allocation.
pub const MAX_FRAME: usize = 256 << 20;

/// Magic opening the handshake frame ("NZRA": Nezha raft).
const HELLO_MAGIC: u32 = 0x4E5A_5241;

/// Frames queued per (from, to) connection before sends to that peer
/// start counting `dropped`.  Bounded so a dead peer's queue cannot
/// grow without limit while reconnects fail.
const SEND_QUEUE_FRAMES: usize = 256;

/// Minimum spacing between reconnect attempts to one peer.  Frames
/// arriving inside the window are dropped immediately instead of
/// paying a connect timeout each (Raft's own retries provide the
/// eventual redelivery).
const RECONNECT_PACE: Duration = Duration::from_millis(50);

// ---------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------

/// Encode one wire frame: `u32 len ∥ u32 crc32(payload) ∥ payload`.
pub fn frame_encode(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32fast::hash(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Parse one frame off the front of `buf`.
///
/// * `Ok(Some((payload, consumed)))` — a complete, CRC-valid frame.
/// * `Ok(None)` — the buffer holds a truncated frame; read more bytes.
/// * `Err(_)` — the stream is corrupt (bad CRC or an absurd length):
///   the connection cannot be resynchronized and must be dropped.
pub fn frame_parse(buf: &[u8]) -> Result<Option<(Vec<u8>, usize)>> {
    if buf.len() < 8 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("tcp: frame length {len} exceeds the {MAX_FRAME}-byte cap");
    }
    let crc = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if buf.len() < 8 + len {
        return Ok(None);
    }
    let payload = &buf[8..8 + len];
    if crc32fast::hash(payload) != crc {
        bail!("tcp: frame crc mismatch");
    }
    Ok(Some((payload.to_vec(), 8 + len)))
}

/// Write one frame to a stream.  Small payloads are copied into one
/// contiguous buffer (one syscall, one packet under `TCP_NODELAY`);
/// large ones — bulk AppendEntries, snapshots — write the 8-byte
/// header separately so the payload is never memcpy'd a second time.
fn write_frame(s: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    const INLINE_FRAME: usize = 64 << 10;
    if payload.len() <= INLINE_FRAME {
        return s.write_all(&frame_encode(payload));
    }
    let mut hdr = [0u8; 8];
    hdr[0..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    hdr[4..8].copy_from_slice(&crc32fast::hash(payload).to_le_bytes());
    s.write_all(&hdr)?;
    s.write_all(payload)
}

fn hello_payload(id: NodeId) -> Vec<u8> {
    let mut p = Vec::with_capacity(12);
    p.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
    p.extend_from_slice(&id.to_le_bytes());
    p
}

fn parse_hello(p: &[u8]) -> Option<NodeId> {
    if p.len() != 12 || u32::from_le_bytes(p[0..4].try_into().unwrap()) != HELLO_MAGIC {
        return None;
    }
    Some(u64::from_le_bytes(p[4..12].try_into().unwrap()))
}

// ---------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------

struct LocalNode {
    mailbox: Arc<Mailbox>,
    /// Stops this node's accept loop and reader threads (fault
    /// injection / shutdown).
    closed: Arc<AtomicBool>,
}

struct TcpInner {
    /// node → dialable address.  Pre-filled by [`TcpNet::with_peers`];
    /// filled at `register` time (with the OS-assigned port) in
    /// loopback mode.  Shared with writer threads so lazily-dialed
    /// peers resolve whenever they come up.
    addrs: Arc<Mutex<HashMap<NodeId, SocketAddr>>>,
    local: Mutex<HashMap<NodeId, LocalNode>>,
    /// (from, to) → bounded frame queue into that pair's writer thread.
    conns: Mutex<HashMap<(NodeId, NodeId), SyncSender<Vec<u8>>>>,
    stats: Arc<WireStats>,
    closed: Arc<AtomicBool>,
    /// Shared fault plan, applied best-effort at the send queue: the
    /// plan's drop verdicts (partitions, link loss) and duplication
    /// inject before enqueue; latency/reordering are not simulated —
    /// the kernel's scheduling already provides both on a real wire.
    faults: Arc<FaultPlan>,
    /// Per-peer outbound dial attempts (successful or not), so chaos
    /// runs can assert redial pacing.  The total also feeds
    /// [`WireStats::reconnects`].
    dials: Arc<Mutex<HashMap<NodeId, u64>>>,
}

/// Thread-safe TCP network handle: register local nodes, then clone
/// freely (same contract as [`super::Bus`]).
#[derive(Clone)]
pub struct TcpNet {
    inner: Arc<TcpInner>,
}

impl Default for TcpNet {
    fn default() -> Self {
        Self::new()
    }
}

impl TcpNet {
    /// Loopback mode: every registered node binds `127.0.0.1:0` and
    /// advertises its OS-assigned port through the shared address map.
    pub fn new() -> Self {
        Self::with_peers(HashMap::new())
    }

    /// Multi-process mode: `peers` maps every node (including the
    /// local one) to its raft address.  `register(id)` binds the
    /// configured address for `id`; sends dial the others.
    pub fn with_peers(peers: HashMap<NodeId, SocketAddr>) -> Self {
        Self::with_peers_and_faults(peers, Arc::new(FaultPlan::new(0xFA17)))
    }

    /// Loopback mode whose sends consult `faults` (shared with the
    /// nemesis driver).
    pub fn with_faults(faults: Arc<FaultPlan>) -> Self {
        Self::with_peers_and_faults(HashMap::new(), faults)
    }

    /// Full constructor: explicit peer map + shared fault plan.
    pub fn with_peers_and_faults(
        peers: HashMap<NodeId, SocketAddr>,
        faults: Arc<FaultPlan>,
    ) -> Self {
        Self {
            inner: Arc::new(TcpInner {
                addrs: Arc::new(Mutex::new(peers)),
                local: Mutex::new(HashMap::new()),
                conns: Mutex::new(HashMap::new()),
                stats: Arc::new(WireStats::default()),
                closed: Arc::new(AtomicBool::new(false)),
                faults,
                dials: Arc::new(Mutex::new(HashMap::new())),
            }),
        }
    }

    pub fn stats(&self) -> &WireStats {
        &self.inner.stats
    }

    /// Per-peer outbound dial attempts, sorted by peer id — the chaos
    /// suite asserts redial pacing against this instead of eyeballing
    /// logs.
    pub fn reconnect_counts(&self) -> Vec<(NodeId, u64)> {
        let mut v: Vec<(NodeId, u64)> =
            self.inner.dials.lock().unwrap().iter().map(|(&k, &n)| (k, n)).collect();
        v.sort_unstable();
        v
    }

    /// The address a registered node actually listens on (loopback
    /// mode assigns ports at bind time).
    pub fn addr_of(&self, id: NodeId) -> Option<SocketAddr> {
        self.inner.addrs.lock().unwrap().get(&id).copied()
    }

    /// Bind `id`'s listener, spawn its accept loop, and return its
    /// mailbox.  In loopback mode the listener binds an OS-assigned
    /// port and publishes it; in `with_peers` mode it binds the
    /// configured address.
    pub fn register(&self, id: NodeId) -> Result<Arc<Mailbox>> {
        let configured = self.inner.addrs.lock().unwrap().get(&id).copied();
        let bind_addr = configured.unwrap_or_else(|| SocketAddr::from(([127, 0, 0, 1], 0)));
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("tcp: bind {bind_addr} for node {id}"))?;
        let actual = listener.local_addr().context("tcp: local_addr")?;
        self.inner.addrs.lock().unwrap().insert(id, actual);
        let mailbox = Arc::new(Mailbox::new(Arc::clone(&self.inner.stats)));
        let node_closed = Arc::new(AtomicBool::new(false));
        {
            let mailbox = Arc::clone(&mailbox);
            let stats = Arc::clone(&self.inner.stats);
            let node_closed = Arc::clone(&node_closed);
            let net_closed = Arc::clone(&self.inner.closed);
            std::thread::Builder::new()
                .name(format!("tcp-accept-{id}"))
                .spawn(move || accept_loop(listener, mailbox, stats, node_closed, net_closed))
                .context("tcp: spawn accept loop")?;
        }
        self.inner
            .local
            .lock()
            .unwrap()
            .insert(id, LocalNode { mailbox: Arc::clone(&mailbox), closed: node_closed });
        Ok(mailbox)
    }

    /// Send one message.  Never blocks: the frame is handed to the
    /// (from, to) writer's bounded queue, and a full or dead queue
    /// counts the frame `dropped`.
    pub fn send(&self, from: NodeId, to: NodeId, msg: &Message) {
        let buf = msg.encode();
        let stats = &self.inner.stats;
        stats.count_send(msg, buf.len());
        if buf.len() > MAX_FRAME {
            // The receiver would reject the length prefix and kill
            // the connection, and Raft would retry the identical
            // frame forever — drop it here, visibly, instead of
            // livelocking the link.
            stats.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if self.inner.closed.load(Ordering::Relaxed) {
            stats.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // Injected faults apply at the send queue (best-effort: frames
        // already in flight are beyond reach on a real wire).
        let copies = match self.inner.faults.decide(from, to) {
            Some(d) if d.dropped() => {
                stats.count_drop(true);
                return;
            }
            Some(d) => d.copies.len(),
            None => 1,
        };
        let tx = {
            let mut conns = self.inner.conns.lock().unwrap();
            conns.entry((from, to)).or_insert_with(|| self.spawn_writer(from, to)).clone()
        };
        for _ in 0..copies {
            if tx.try_send(buf.clone()).is_err() {
                // Full (slow peer) or disconnected (the writer exited
                // at shutdown): either way the frame is dropped, the
                // node loop moves on.
                stats.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn spawn_writer(&self, from: NodeId, to: NodeId) -> SyncSender<Vec<u8>> {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(SEND_QUEUE_FRAMES);
        let addrs = Arc::clone(&self.inner.addrs);
        let stats = Arc::clone(&self.inner.stats);
        let closed = Arc::clone(&self.inner.closed);
        let dials = Arc::clone(&self.inner.dials);
        // Writer threads are detached: they exit when their sender is
        // dropped (unregister/shutdown clears the conns map) or when
        // the net-wide closed flag trips.
        let _ = std::thread::Builder::new()
            .name(format!("tcp-w-{from}-{to}"))
            .spawn(move || writer_loop(from, to, rx, addrs, stats, closed, dials));
        tx
    }

    /// Remove a node for good: close its mailbox, stop its accept
    /// loop/readers (releasing the listening port) and kill its
    /// outbound connections.  Peers' subsequent sends to it fail and
    /// count `dropped` — the in-process analogue of killing the
    /// node's process.
    pub fn unregister(&self, id: NodeId) {
        if let Some(node) = self.inner.local.lock().unwrap().remove(&id) {
            node.closed.store(true, Ordering::Relaxed);
            node.mailbox.close();
        }
        self.inner.addrs.lock().unwrap().remove(&id);
        // Dropping the senders disconnects the writers' queues.
        self.inner.conns.lock().unwrap().retain(|&(f, _), _| f != id);
    }

    pub fn shutdown(&self) {
        self.inner.closed.store(true, Ordering::Relaxed);
        for (_, node) in self.inner.local.lock().unwrap().drain() {
            node.closed.store(true, Ordering::Relaxed);
            node.mailbox.close();
        }
        self.inner.conns.lock().unwrap().clear();
    }
}

/// Accept connections for one local node until it (or the whole net)
/// closes.  Nonblocking accept polled on a short interval: connections
/// are long-lived, so accept latency is irrelevant, and polling lets
/// the loop observe the closed flags without a self-connect trick.
fn accept_loop(
    listener: TcpListener,
    mailbox: Arc<Mailbox>,
    stats: Arc<WireStats>,
    node_closed: Arc<AtomicBool>,
    net_closed: Arc<AtomicBool>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if node_closed.load(Ordering::Relaxed) || net_closed.load(Ordering::Relaxed) {
            return; // drops the listener, releasing the port
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let mailbox = Arc::clone(&mailbox);
                let stats = Arc::clone(&stats);
                let node_closed = Arc::clone(&node_closed);
                let net_closed = Arc::clone(&net_closed);
                let _ = std::thread::Builder::new()
                    .name("tcp-read".into())
                    .spawn(move || reader_loop(stream, mailbox, stats, node_closed, net_closed));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Read frames off one inbound connection into the node's mailbox.
/// The first frame must be the handshake naming the sender; every
/// later frame is an encoded [`Message`] body.  Frame-level corruption
/// (CRC/length) counts `dropped` and closes the connection — the
/// stream cannot be resynchronized past a bad length prefix.
fn reader_loop(
    mut stream: TcpStream,
    mailbox: Arc<Mailbox>,
    stats: Arc<WireStats>,
    node_closed: Arc<AtomicBool>,
    net_closed: Arc<AtomicBool>,
) {
    // The timeout bounds how long a dying node's reader lingers.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf: Vec<u8> = Vec::new();
    let mut from: Option<NodeId> = None;
    let mut chunk = vec![0u8; 64 << 10];
    loop {
        if node_closed.load(Ordering::Relaxed) || net_closed.load(Ordering::Relaxed) {
            return;
        }
        let n = match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        };
        buf.extend_from_slice(&chunk[..n]);
        loop {
            match frame_parse(&buf) {
                Ok(Some((payload, consumed))) => {
                    buf.drain(..consumed);
                    match from {
                        None => match parse_hello(&payload) {
                            Some(id) => from = Some(id),
                            None => {
                                // Not one of ours (or garbage): count
                                // and drop the connection.
                                stats.dropped.fetch_add(1, Ordering::Relaxed);
                                return;
                            }
                        },
                        Some(id) => mailbox.push(id, payload),
                    }
                }
                Ok(None) => break, // partial frame: need more bytes
                Err(_) => {
                    stats.dropped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        }
    }
}

/// One (from, to) pair's outbound worker: connect lazily (paced after
/// failures), handshake, then stream frames from the bounded queue.  A
/// frame that cannot be delivered — peer unknown, connect failed, or
/// the write errored — counts `dropped`; the next frame retries the
/// connection.
fn writer_loop(
    from: NodeId,
    to: NodeId,
    rx: Receiver<Vec<u8>>,
    addrs: Arc<Mutex<HashMap<NodeId, SocketAddr>>>,
    stats: Arc<WireStats>,
    closed: Arc<AtomicBool>,
    dials: Arc<Mutex<HashMap<NodeId, u64>>>,
) {
    let mut stream: Option<TcpStream> = None;
    let mut last_attempt: Option<Instant> = None;
    loop {
        let buf = match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(b) => b,
            Err(RecvTimeoutError::Timeout) => {
                if closed.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        if closed.load(Ordering::Relaxed) {
            stats.dropped.fetch_add(1, Ordering::Relaxed);
            continue; // drain the queue counting drops until disconnect
        }
        if stream.is_none() {
            if last_attempt.is_some_and(|t| t.elapsed() < RECONNECT_PACE) {
                // Inside the reconnect pacing window: drop instead of
                // paying a connect timeout per queued frame.
                stats.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            last_attempt = Some(Instant::now());
            let addr = addrs.lock().unwrap().get(&to).copied();
            let Some(addr) = addr else {
                stats.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            // This is a real dial attempt: count it per peer (and in
            // the aggregate) whether or not it succeeds.
            *dials.lock().unwrap().entry(to).or_insert(0) += 1;
            stats.reconnects.fetch_add(1, Ordering::Relaxed);
            match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
                Ok(mut s) => {
                    let _ = s.set_nodelay(true);
                    let _ = s.set_write_timeout(Some(Duration::from_secs(2)));
                    if write_frame(&mut s, &hello_payload(from)).is_err() {
                        stats.dropped.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    stream = Some(s);
                    last_attempt = None;
                }
                Err(_) => {
                    stats.dropped.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
        }
        let s = stream.as_mut().expect("connected above");
        if write_frame(s, &buf).is_err() {
            // Connection died mid-write: this frame is lost; the next
            // one re-dials.
            stream = None;
            stats.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::raft::rpc::{Command, LogEntry};
    use std::time::Instant;

    fn msg(term: u64) -> Message {
        Message::RequestVoteResp { term, granted: true }
    }

    fn wait_for(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        cond()
    }

    #[test]
    fn frame_roundtrip() {
        let payloads: Vec<Vec<u8>> = vec![Vec::new(), b"x".to_vec(), vec![7u8; 100_000]];
        for payload in &payloads {
            let framed = frame_encode(payload);
            let (got, consumed) = frame_parse(&framed).unwrap().expect("complete");
            assert_eq!(&got, payload);
            assert_eq!(consumed, framed.len());
        }
        // Two frames back to back parse in sequence.
        let mut both = frame_encode(b"first");
        both.extend_from_slice(&frame_encode(b"second"));
        let (p1, c1) = frame_parse(&both).unwrap().unwrap();
        assert_eq!(p1, b"first");
        let (p2, c2) = frame_parse(&both[c1..]).unwrap().unwrap();
        assert_eq!(p2, b"second");
        assert_eq!(c1 + c2, both.len());
    }

    #[test]
    fn truncated_frames_wait_for_more_bytes() {
        let framed = frame_encode(b"hello world");
        for cut in 0..framed.len() {
            assert!(
                frame_parse(&framed[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes must parse as incomplete"
            );
        }
    }

    #[test]
    fn corrupt_frames_rejected() {
        // Flipped payload byte → CRC mismatch.
        let mut framed = frame_encode(b"payload");
        let last = framed.len() - 1;
        framed[last] ^= 0xff;
        assert!(frame_parse(&framed).is_err());
        // Flipped CRC byte.
        let mut framed = frame_encode(b"payload");
        framed[4] ^= 0xff;
        assert!(frame_parse(&framed).is_err());
        // Absurd length prefix must not allocate; it must error.
        let mut framed = frame_encode(b"payload");
        framed[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(frame_parse(&framed).is_err());
    }

    #[test]
    fn hello_roundtrip_and_rejection() {
        assert_eq!(parse_hello(&hello_payload(42)), Some(42));
        assert_eq!(parse_hello(b"not a hello"), None);
        assert_eq!(parse_hello(&hello_payload(1)[..11]), None);
        let mut bad = hello_payload(1);
        bad[0] ^= 0xff;
        assert_eq!(parse_hello(&bad), None);
    }

    #[test]
    fn loopback_roundtrip_between_nodes() {
        let net = TcpNet::new();
        let mb1 = net.register(1).unwrap();
        let mb2 = net.register(2).unwrap();
        net.send(1, 2, &msg(5));
        let got = recv_one(&mb2);
        assert_eq!(got, (1, msg(5)));
        net.send(2, 1, &msg(9));
        let got = recv_one(&mb1);
        assert_eq!(got, (2, msg(9)));
        let st = net.stats().snapshot();
        assert_eq!(st.msgs, 2);
        assert!(st.bytes > 0);
        assert_eq!(st.dropped, 0);
        net.shutdown();
    }

    fn recv_one(mb: &Arc<Mailbox>) -> (NodeId, Message) {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let batch = mb.drain(Duration::from_millis(100)).expect("mailbox open");
            if let Some(first) = batch.into_iter().next() {
                return first;
            }
            assert!(Instant::now() < deadline, "no message within deadline");
        }
    }

    #[test]
    fn send_to_unknown_peer_counts_dropped() {
        let net = TcpNet::new();
        let _mb = net.register(1).unwrap();
        net.send(1, 99, &msg(1));
        assert!(
            wait_for(Duration::from_secs(5), || net.stats().snapshot().dropped >= 1),
            "send to unknown peer never counted dropped"
        );
        net.shutdown();
    }

    #[test]
    fn unregister_makes_peer_dead_and_sends_count_dropped() {
        let net = TcpNet::new();
        let mb1 = net.register(1).unwrap();
        let mb2 = net.register(2).unwrap();
        net.send(1, 2, &msg(1));
        assert_eq!(recv_one(&mb2), (1, msg(1)));
        net.unregister(2);
        assert!(mb2.drain(Duration::from_millis(10)).is_none(), "mailbox closed");
        // The established connection dies (listener + readers closed);
        // subsequent sends eventually count dropped.
        let before = net.stats().snapshot().dropped;
        assert!(
            wait_for(Duration::from_secs(10), || {
                net.send(1, 2, &msg(2));
                net.stats().snapshot().dropped > before
            }),
            "sends to a dead peer never counted dropped"
        );
        drop(mb1);
        net.shutdown();
    }

    #[test]
    fn garbage_connection_counts_dropped_and_is_closed() {
        let net = TcpNet::new();
        let _mb = net.register(1).unwrap();
        let addr = net.addr_of(1).unwrap();
        // A raw client that speaks garbage instead of the handshake.
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&frame_encode(b"definitely not a handshake")).unwrap();
        assert!(
            wait_for(Duration::from_secs(5), || net.stats().snapshot().dropped >= 1),
            "garbage handshake never counted dropped"
        );
        // Corrupt framing (not just a bad handshake) is also counted.
        let mut s2 = TcpStream::connect(addr).unwrap();
        s2.write_all(&[0xff; 16]).unwrap();
        assert!(
            wait_for(Duration::from_secs(5), || net.stats().snapshot().dropped >= 2),
            "corrupt frame never counted dropped"
        );
        net.shutdown();
    }

    #[test]
    fn fault_plan_drops_at_send_and_attributes() {
        let plan = Arc::new(FaultPlan::new(31));
        let net = TcpNet::with_faults(Arc::clone(&plan));
        let _mb1 = net.register(1).unwrap();
        let mb2 = net.register(2).unwrap();
        net.send(1, 2, &msg(1));
        assert_eq!(recv_one(&mb2), (1, msg(1)));
        plan.partition(1, 2);
        net.send(1, 2, &msg(2));
        let st = net.stats().snapshot();
        assert_eq!(st.fault_dropped, 1, "partitioned send attributes to faults");
        assert_eq!(st.dropped, 1);
        plan.heal();
        net.send(1, 2, &msg(3));
        assert_eq!(recv_one(&mb2), (1, msg(3)));
        net.shutdown();
    }

    /// Satellite: redial pacing is observable through per-peer
    /// reconnect counts instead of eyeballing logs.  A dead peer that
    /// refuses connections must see roughly `duration / RECONNECT_PACE`
    /// dial attempts, not one per frame.
    #[test]
    fn reconnect_attempts_are_paced_and_counted_per_peer() {
        // An address that refuses connections: bind, note the port,
        // drop the listener.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let mut peers = HashMap::new();
        peers.insert(2u64, dead);
        let net = TcpNet::with_peers(peers);
        let _mb1 = net.register(1).unwrap();
        let window = Duration::from_millis(400);
        let t0 = Instant::now();
        while t0.elapsed() < window {
            net.send(1, 2, &msg(1));
            std::thread::sleep(Duration::from_millis(5));
        }
        // Let the writer drain its queue before reading the counters.
        std::thread::sleep(Duration::from_millis(100));
        let counts = net.reconnect_counts();
        let to_peer2 = counts.iter().find(|&&(id, _)| id == 2).map_or(0, |&(_, n)| n);
        assert!(to_peer2 >= 2, "expected repeated dial attempts, got {to_peer2}");
        // Pacing bound: attempts ≤ window / RECONNECT_PACE, with slack
        // for the first unpaced dial and scheduling jitter.
        let ceiling = (window.as_millis() / RECONNECT_PACE.as_millis()) as u64 + 3;
        assert!(to_peer2 <= ceiling, "dial attempts {to_peer2} exceed pacing ceiling {ceiling}");
        assert_eq!(net.stats().snapshot().reconnects, to_peer2, "aggregate mirrors per-peer");
        net.shutdown();
    }

    #[test]
    fn large_frames_cross_intact() {
        // An AppendEntries with a payload comfortably above one read
        // chunk (64 KiB) must reassemble from partial reads.
        let net = TcpNet::new();
        let _mb1 = net.register(1).unwrap();
        let mb2 = net.register(2).unwrap();
        let big = Message::AppendEntries {
            term: 3,
            leader: 1,
            prev_log_index: 0,
            prev_log_term: 0,
            entries: vec![LogEntry {
                term: 3,
                index: 1,
                cmd: Command::Put { key: b"big".to_vec(), value: vec![0xAB; 300 << 10] },
            }],
            leader_commit: 0,
            seq: 1,
        };
        net.send(1, 2, &big);
        let (from, got) = recv_one(&mb2);
        assert_eq!(from, 1);
        assert_eq!(got, big);
        net.shutdown();
    }
}
