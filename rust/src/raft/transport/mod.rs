//! Message transports.
//!
//! The paper's cluster is gRPC over 10 GbE (DESIGN.md §2).  Three
//! transports share one contract — register a node for a mailbox,
//! `send` encoded [`Message`] frames, account every frame in
//! [`WireStats`]:
//!
//! * [`SimNet`] — deterministic single-threaded event queue with
//!   logical microsecond time: used by protocol tests, the safety
//!   model checker, and property tests (reproducible seeds).
//! * [`Bus`] — thread-safe in-process mailboxes for the live cluster
//!   runtime (one thread per node), with wall-clock latency.
//! * [`TcpNet`] ([`tcp`]) — real TCP sockets, length-prefixed
//!   CRC-framed, one accept loop per registered node and lazily
//!   established reconnecting outbound connections.  This is the
//!   deployable path: `nezha serve` runs one process per node over it,
//!   and the in-process harness drives it over loopback for
//!   in-process-vs-TCP deltas (`--transport tcp`).
//!
//! [`Net`] is the runtime-chosen handle ([`Bus`] or [`TcpNet`]) the
//! coordinator threads the cluster over; [`TransportKind`] is the
//! config knob that picks it.
//!
//! Every transport consults a shared [`crate::fault::FaultPlan`]
//! before delivering: partitions, duplication, reordering, and
//! per-link overrides inject at the send boundary, and
//! [`WireStats::fault_dropped`] attributes those drops separately from
//! real backpressure.  [`SimNet`] applies the full plan
//! deterministically; [`Bus`] applies drops and duplication (thread
//! scheduling already reorders); [`TcpNet`] applies drops and
//! duplication best-effort at the send queue.

use super::node::NodeId;
use super::rpc::Message;
use crate::fault::FaultPlan;
use crate::util::Rng;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

pub mod tcp;

pub use tcp::TcpNet;

/// Link characteristics.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// One-way latency range, microseconds.
    pub latency_us: (u64, u64),
    /// Probability a message is dropped.
    pub loss: f64,
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // 10 GbE same-rack RTT ~100–250us one way.
        Self { latency_us: (50, 150), loss: 0.0, seed: 0xC0FFEE }
    }
}

/// Wire accounting shared by every transport.  `dropped` counts
/// **every** frame that was sent but never delivered to a mailbox:
/// lossy-link and partition drops, sends to unknown/dead peers, full
/// or broken TCP send queues, and frames that failed
/// [`Message::decode`] on the receive side.  `fault_dropped` is the
/// subset attributable to *injected* faults (a [`FaultPlan`] verdict
/// or a [`SimNet`] partition), so chaos runs can tell nemesis damage
/// apart from real backpressure: `dropped - fault_dropped` is the
/// structural loss.  `reconnects` counts outbound TCP dial attempts
/// ([`TcpNet`] only; zero elsewhere).
#[derive(Debug, Default)]
pub struct WireStats {
    pub msgs: AtomicU64,
    pub bytes: AtomicU64,
    /// Subset of `bytes` carried by snapshot-transfer frames
    /// (`InstallSnapshot`, `SnapMeta`, `SnapChunk` — DESIGN.md §8), so
    /// steady-state replication traffic in fig4/fig5 wire lines is
    /// never inflated by a concurrent follower catch-up.
    pub snap_bytes: AtomicU64,
    pub dropped: AtomicU64,
    pub fault_dropped: AtomicU64,
    pub reconnects: AtomicU64,
}

impl WireStats {
    pub fn snapshot(&self) -> WireSnapshot {
        WireSnapshot {
            msgs: self.msgs.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            snap_bytes: self.snap_bytes.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            fault_dropped: self.fault_dropped.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
        }
    }

    /// Count one outbound frame (shared by every transport's send
    /// path), attributing snapshot-transfer frames to `snap_bytes`.
    fn count_send(&self, msg: &Message, encoded_len: usize) {
        self.msgs.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(encoded_len as u64, Ordering::Relaxed);
        if msg.is_snapshot_xfer() {
            self.snap_bytes.fetch_add(encoded_len as u64, Ordering::Relaxed);
        }
    }

    /// Count one dropped frame; `fault` attributes it to injected
    /// faults on top of the total.
    fn count_drop(&self, fault: bool) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
        if fault {
            self.fault_dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Point-in-time copy of [`WireStats`] (bench/CLI reporting).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireSnapshot {
    pub msgs: u64,
    pub bytes: u64,
    /// Subset of `bytes` carried by snapshot-transfer frames
    /// (DESIGN.md §8).
    pub snap_bytes: u64,
    pub dropped: u64,
    /// Subset of `dropped` caused by injected faults.
    pub fault_dropped: u64,
    /// Outbound dial attempts (TCP transports only).
    pub reconnects: u64,
}

impl WireSnapshot {
    /// Sum two snapshots (aggregating per-shard transports).
    pub fn absorb(&mut self, other: WireSnapshot) {
        self.msgs += other.msgs;
        self.bytes += other.bytes;
        self.snap_bytes += other.snap_bytes;
        self.dropped += other.dropped;
        self.fault_dropped += other.fault_dropped;
        self.reconnects += other.reconnects;
    }
}

/// Common behaviour: encode, maybe drop, deliver after latency.
pub trait Transport {
    fn send(&mut self, from: NodeId, to: NodeId, msg: Message);
}

/// Which wire carries Raft frames between a cluster's replicas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mailboxes ([`Bus`]): frames are encoded and
    /// accounted, but never leave the process — the original
    /// simulation substitution of DESIGN.md §2.
    #[default]
    Inproc,
    /// Real TCP sockets ([`TcpNet`]): every frame crosses the kernel
    /// network stack (loopback in the single-process harness, real
    /// links under `nezha serve`).
    Tcp,
}

impl TransportKind {
    /// Bench/CLI label.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a `--transport` flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "bus" | "inprocess" => Some(TransportKind::Inproc),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// Runtime-chosen transport handle: the cluster and node loops are
/// written against this enum, so the same code runs over the
/// in-process [`Bus`] or real [`TcpNet`] sockets.
#[derive(Clone)]
pub enum Net {
    Bus(Bus),
    Tcp(TcpNet),
}

impl Net {
    /// Register a local node: binds its mailbox (and, for TCP, its
    /// listener) so peers can reach it.
    pub fn register(&self, id: NodeId) -> Result<Arc<Mailbox>> {
        match self {
            Net::Bus(b) => Ok(b.register(id)),
            Net::Tcp(t) => t.register(id),
        }
    }

    pub fn send(&self, from: NodeId, to: NodeId, msg: &Message) {
        match self {
            Net::Bus(b) => b.send(from, to, msg),
            Net::Tcp(t) => t.send(from, to, msg),
        }
    }

    /// Remove a node for good (fault injection): closes its mailbox,
    /// and for TCP also its listener and connections — the in-process
    /// analogue of killing the node's process.
    pub fn unregister(&self, id: NodeId) {
        match self {
            Net::Bus(b) => b.unregister(id),
            Net::Tcp(t) => t.unregister(id),
        }
    }

    pub fn shutdown(&self) {
        match self {
            Net::Bus(b) => b.shutdown(),
            Net::Tcp(t) => t.shutdown(),
        }
    }

    pub fn stats(&self) -> WireSnapshot {
        match self {
            Net::Bus(b) => b.stats.snapshot(),
            Net::Tcp(t) => t.stats().snapshot(),
        }
    }
}

// ---------------------------------------------------------------------
// Deterministic simulator
// ---------------------------------------------------------------------

/// One event in a [`SimNet`] delivery/drop trace — the determinism
/// regression currency: same `(NetConfig seed, FaultPlan)` ⇒ same
/// trace, element for element.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// A copy entered the queue, due at `at_us`.
    Queued { from: NodeId, to: NodeId, at_us: u64, len: usize },
    /// A frame was dropped; `fault` attributes it to injected faults.
    Dropped { from: NodeId, to: NodeId, at_us: u64, fault: bool },
    /// A frame reached its destination at `at_us`.
    Delivered { from: NodeId, to: NodeId, at_us: u64, len: usize },
}

/// Single-threaded discrete-event network with logical microseconds.
pub struct SimNet {
    cfg: NetConfig,
    rng: Rng,
    now_us: u64,
    seq: u64,
    /// (deliver_at, seq) -> (from, to, encoded)
    queue: BinaryHeap<Reverse<(u64, u64, NodeId, NodeId, Vec<u8>)>>,
    pub stats: WireStats,
    /// Partitioned node pairs (both directions blocked).
    cut: Vec<(NodeId, NodeId)>,
    /// Shared fault plan (partitions/dup/reorder/link overrides).
    faults: Option<Arc<FaultPlan>>,
    /// When `Some`, every queue/drop/deliver event is recorded.
    trace: Option<Vec<TraceEvent>>,
}

impl SimNet {
    pub fn new(cfg: NetConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        Self {
            cfg,
            rng,
            now_us: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            stats: WireStats::default(),
            cut: Vec::new(),
            faults: None,
            trace: None,
        }
    }

    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Attach a shared fault plan; consulted on every subsequent send.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Start recording a delivery/drop trace (determinism regression).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Take the recorded trace, leaving recording enabled.
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        match self.trace.as_mut() {
            Some(t) => std::mem::take(t),
            None => Vec::new(),
        }
    }

    fn record(&mut self, ev: TraceEvent) {
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    fn drop_frame(&mut self, from: NodeId, to: NodeId, fault: bool) {
        self.stats.count_drop(fault);
        let at_us = self.now_us;
        self.record(TraceEvent::Dropped { from, to, at_us, fault });
    }

    /// Block all traffic between `a` and `b`.
    pub fn partition(&mut self, a: NodeId, b: NodeId) {
        self.cut.push((a, b));
    }

    /// Restore all links.
    pub fn heal(&mut self) {
        self.cut.clear();
    }

    pub fn is_cut(&self, a: NodeId, b: NodeId) -> bool {
        self.cut.iter().any(|&(x, y)| (x == a && y == b) || (x == b && y == a))
    }

    /// Advance to `t_us`, returning all messages due, in order.
    pub fn advance(&mut self, t_us: u64) -> Vec<(NodeId, NodeId, Message)> {
        self.now_us = self.now_us.max(t_us);
        let mut out = Vec::new();
        while let Some(Reverse((at, _, _, _, _))) = self.queue.peek() {
            if *at > self.now_us {
                break;
            }
            let Reverse((at, _, from, to, buf)) = self.queue.pop().unwrap();
            // Re-check partitions at delivery time: a frame in flight
            // when the cut landed is lost, like a real link going dark.
            let cut_now = self.is_cut(from, to)
                || self.faults.as_ref().is_some_and(|p| p.is_blocked(from, to));
            if cut_now {
                self.stats.count_drop(true);
                self.record(TraceEvent::Dropped { from, to, at_us: at, fault: true });
                continue;
            }
            match Message::decode(&buf) {
                Ok(m) => {
                    self.record(TraceEvent::Delivered { from, to, at_us: at, len: buf.len() });
                    out.push((from, to, m));
                }
                // An undecodable frame is a lost frame, not a silent
                // no-op: it must show up in the drop accounting.
                Err(_) => {
                    self.stats.count_drop(false);
                    self.record(TraceEvent::Dropped { from, to, at_us: at, fault: false });
                }
            }
        }
        out
    }

    /// Earliest pending delivery time, if any.
    pub fn next_event_at(&self) -> Option<u64> {
        self.queue.peek().map(|Reverse((at, ..))| *at)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

impl Transport for SimNet {
    fn send(&mut self, from: NodeId, to: NodeId, msg: Message) {
        let buf = msg.encode();
        self.stats.count_send(&msg, buf.len());
        // Configured (structural) loss draws first so the fault plan
        // never perturbs the baseline RNG sequence.
        if self.cfg.loss > 0.0 && self.rng.chance(self.cfg.loss) {
            self.drop_frame(from, to, false);
            return;
        }
        if self.is_cut(from, to) {
            self.drop_frame(from, to, true);
            return;
        }
        let verdict = self.faults.as_ref().and_then(|p| p.decide(from, to));
        if let Some(d) = &verdict {
            if d.dropped() {
                self.drop_frame(from, to, true);
                return;
            }
        }
        let (lo, hi) = verdict
            .as_ref()
            .and_then(|d| d.latency_us)
            .unwrap_or(self.cfg.latency_us);
        let copies = verdict.map_or_else(|| vec![0], |d| d.copies);
        for extra in copies {
            let lat = if hi > lo { self.rng.range(lo, hi + 1) } else { lo };
            self.seq += 1;
            let at_us = self.now_us + lat + extra;
            self.record(TraceEvent::Queued { from, to, at_us, len: buf.len() });
            self.queue.push(Reverse((at_us, self.seq, from, to, buf.clone())));
        }
    }
}

// ---------------------------------------------------------------------
// Threaded bus
// ---------------------------------------------------------------------

struct MailboxInner {
    queue: VecDeque<(NodeId, Vec<u8>)>,
    closed: bool,
    /// Doorbell: an out-of-band wakeup (client request queued at the
    /// coordinator level) so `drain` returns without waiting out its
    /// timeout.
    doorbell: bool,
}

/// A node's inbound queue (blocking pop with timeout, or non-blocking
/// [`Self::try_drain`] under the reactor).
pub struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
    /// Reactor doorbell: when set, every event that would wake a
    /// blocked [`Self::drain`] (frame arrival, [`Self::notify`],
    /// [`Self::close`]) also invokes this callback, so an event-driven
    /// owner polling via [`Self::try_drain`] learns about input
    /// without ever parking on the condvar.
    waker: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
    /// The owning transport's counters: frames that arrive but fail
    /// [`Message::decode`] in [`Self::drain`] count as `dropped`.
    stats: Arc<WireStats>,
}

impl Mailbox {
    fn new(stats: Arc<WireStats>) -> Self {
        Self {
            inner: Mutex::new(MailboxInner {
                queue: VecDeque::new(),
                closed: false,
                doorbell: false,
            }),
            cv: Condvar::new(),
            waker: Mutex::new(None),
            stats,
        }
    }

    /// Install the reactor-side wakeup callback (see [`Self::waker`]).
    /// The condvar path keeps working, so a mailbox can serve blocking
    /// and event-driven owners across its lifetime.
    pub fn set_waker(&self, waker: Box<dyn Fn() + Send + Sync>) {
        *self.waker.lock().unwrap() = Some(waker);
    }

    fn ring(&self) {
        if let Some(w) = self.waker.lock().unwrap().as_ref() {
            w();
        }
    }

    pub fn push(&self, from: NodeId, buf: Vec<u8>) {
        {
            let mut g = self.inner.lock().unwrap();
            if g.closed {
                // The node is gone (killed / shut down) but a reader
                // thread still delivered a frame: nobody will ever drain
                // it, so it counts as dropped, keeping the accounting
                // parity promise of [`WireStats`].
                self.stats.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            g.queue.push_back((from, buf));
            self.cv.notify_one();
        }
        self.ring();
    }

    /// Out-of-band wakeup: makes a blocked (or about-to-block)
    /// `drain` return immediately even with no network messages.
    pub fn notify(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.doorbell = true;
            self.cv.notify_one();
        }
        self.ring();
    }

    /// Pop everything queued, blocking up to `timeout` for the first
    /// message (or a doorbell). Returns None if the bus shut down.
    pub fn drain(&self, timeout: std::time::Duration) -> Option<Vec<(NodeId, Message)>> {
        let mut g = self.inner.lock().unwrap();
        if g.queue.is_empty() && !g.closed && !g.doorbell {
            let (g2, _) = self.cv.wait_timeout(g, timeout).unwrap();
            g = g2;
        }
        g.doorbell = false;
        if g.closed && g.queue.is_empty() {
            return None;
        }
        let mut out = Vec::with_capacity(g.queue.len());
        while let Some((from, buf)) = g.queue.pop_front() {
            match Message::decode(&buf) {
                Ok(m) => out.push((from, m)),
                Err(_) => {
                    // Delivered but undecodable = dropped, not silently
                    // discarded.
                    self.stats.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Some(out)
    }

    /// Non-blocking drain for event-driven owners: pop everything
    /// queued right now (clearing the doorbell), with the same decode
    /// and drop accounting as [`Self::drain`].  Returns `None` iff the
    /// mailbox is closed *and* empty — the owner should exit.
    pub fn try_drain(&self) -> Option<Vec<(NodeId, Message)>> {
        let mut g = self.inner.lock().unwrap();
        g.doorbell = false;
        if g.closed && g.queue.is_empty() {
            return None;
        }
        let mut out = Vec::with_capacity(g.queue.len());
        while let Some((from, buf)) = g.queue.pop_front() {
            match Message::decode(&buf) {
                Ok(m) => out.push((from, m)),
                Err(_) => {
                    self.stats.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Some(out)
    }

    pub fn close(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.closed = true;
            self.cv.notify_all();
        }
        self.ring();
    }
}

/// Thread-safe in-process network: register each node, then clone the
/// handle freely.
#[derive(Clone)]
pub struct Bus {
    mailboxes: Arc<Mutex<HashMap<NodeId, Arc<Mailbox>>>>,
    cfg: Arc<NetConfig>,
    rng: Arc<Mutex<Rng>>,
    pub stats: Arc<WireStats>,
    /// Shared fault plan (inert by default).  The bus applies drops
    /// (partitions, link loss) and duplication; reordering and latency
    /// overrides are simulation-only — thread scheduling already
    /// reorders, and the node loops poll faster than any realistic
    /// injected latency.
    faults: Arc<FaultPlan>,
}

impl Bus {
    pub fn new(cfg: NetConfig) -> Self {
        let plan = Arc::new(FaultPlan::new(cfg.seed ^ 0xFA17));
        Self::with_faults(cfg, plan)
    }

    /// A bus whose sends consult `faults` (shared with the nemesis).
    pub fn with_faults(cfg: NetConfig, faults: Arc<FaultPlan>) -> Self {
        let rng = Rng::new(cfg.seed);
        Self {
            mailboxes: Arc::new(Mutex::new(HashMap::new())),
            cfg: Arc::new(cfg),
            rng: Arc::new(Mutex::new(rng)),
            stats: Arc::new(WireStats::default()),
            faults,
        }
    }

    pub fn register(&self, id: NodeId) -> Arc<Mailbox> {
        let mb = Arc::new(Mailbox::new(Arc::clone(&self.stats)));
        self.mailboxes.lock().unwrap().insert(id, Arc::clone(&mb));
        mb
    }

    /// Remove a node's mailbox (it stopped for good — fault injection).
    /// Subsequent sends to it count as dropped instead of queueing
    /// forever in a mailbox nobody drains.
    pub fn unregister(&self, id: NodeId) {
        if let Some(mb) = self.mailboxes.lock().unwrap().remove(&id) {
            mb.close();
        }
    }

    pub fn send(&self, from: NodeId, to: NodeId, msg: &Message) {
        let buf = msg.encode();
        self.stats.count_send(msg, buf.len());
        if self.cfg.loss > 0.0 && self.rng.lock().unwrap().chance(self.cfg.loss) {
            self.stats.count_drop(false);
            return;
        }
        let copies = match self.faults.decide(from, to) {
            Some(d) if d.dropped() => {
                self.stats.count_drop(true);
                return;
            }
            Some(d) => d.copies.len(),
            None => 1,
        };
        // Latency: at bench scale the contribution is simulated by the
        // node loop's poll granularity; we spin-sleep only for large
        // configured latencies to avoid burning the single test core.
        let (lo, hi) = self.cfg.latency_us;
        if lo >= 1000 {
            let lat = if hi > lo { self.rng.lock().unwrap().range(lo, hi + 1) } else { lo };
            std::thread::sleep(std::time::Duration::from_micros(lat));
        }
        let mb = self.mailboxes.lock().unwrap().get(&to).cloned();
        if let Some(mb) = mb {
            for _ in 0..copies {
                mb.push(from, buf.clone());
            }
        } else {
            self.stats.count_drop(false);
        }
    }

    pub fn shutdown(&self) {
        for mb in self.mailboxes.lock().unwrap().values() {
            mb.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(term: u64) -> Message {
        Message::RequestVoteResp { term, granted: true }
    }

    #[test]
    fn simnet_delivers_in_latency_order() {
        let mut net = SimNet::new(NetConfig { latency_us: (100, 100), loss: 0.0, seed: 1 });
        net.send(1, 2, msg(1));
        net.send(1, 2, msg(2));
        assert!(net.advance(99).is_empty());
        let got = net.advance(100);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].2, msg(1)); // FIFO for equal latency
        assert_eq!(got[1].2, msg(2));
    }

    #[test]
    fn simnet_partition_drops() {
        let mut net = SimNet::new(NetConfig::default());
        net.partition(1, 2);
        net.send(1, 2, msg(1));
        net.send(2, 1, msg(2));
        net.send(1, 3, msg(3));
        let got = net.advance(1_000_000);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1, 3);
        net.heal();
        net.send(1, 2, msg(4));
        assert_eq!(net.advance(2_000_000).len(), 1);
    }

    #[test]
    fn simnet_loss_is_deterministic_per_seed() {
        let run = |seed| {
            let mut net = SimNet::new(NetConfig { latency_us: (10, 20), loss: 0.5, seed });
            for i in 0..100 {
                net.send(1, 2, msg(i));
            }
            net.advance(1_000_000).len()
        };
        assert_eq!(run(7), run(7));
        // Roughly half arrive.
        let n = run(7);
        assert!(n > 20 && n < 80, "n={n}");
    }

    #[test]
    fn bus_roundtrip_between_threads() {
        let bus = Bus::new(NetConfig { latency_us: (0, 0), loss: 0.0, seed: 2 });
        let mb2 = bus.register(2);
        let bus2 = bus.clone();
        let h = std::thread::spawn(move || {
            let got = mb2.drain(std::time::Duration::from_secs(5)).unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].0, 1);
            bus2.send(2, 1, &msg(9));
        });
        let mb1 = bus.register(1);
        bus.send(1, 2, &msg(5));
        let back = mb1.drain(std::time::Duration::from_secs(5)).unwrap();
        h.join().unwrap();
        assert_eq!(back[0].1, msg(9));
        assert_eq!(bus.stats.msgs.load(Ordering::Relaxed), 2);
        assert!(bus.stats.bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn bus_close_unblocks() {
        let bus = Bus::new(NetConfig::default());
        let mb = bus.register(1);
        bus.shutdown();
        assert!(mb.drain(std::time::Duration::from_millis(10)).is_none());
    }

    #[test]
    fn send_to_unknown_counts_dropped() {
        let bus = Bus::new(NetConfig::default());
        bus.send(1, 99, &msg(1));
        assert_eq!(bus.stats.dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn doorbell_wakes_blocked_drain_and_resets() {
        let bus = Bus::new(NetConfig::default());
        let mb = bus.register(1);
        let mb2 = Arc::clone(&mb);
        let h = std::thread::spawn(move || {
            // Blocks with no message in flight; the doorbell must wake
            // it well before the 60 s timeout and yield an empty batch.
            let t0 = std::time::Instant::now();
            let got = mb2.drain(std::time::Duration::from_secs(60)).unwrap();
            assert!(got.is_empty(), "doorbell wake carries no message");
            t0.elapsed()
        });
        // Give the drainer time to park before ringing.
        std::thread::sleep(std::time::Duration::from_millis(50));
        mb.notify();
        let waited = h.join().unwrap();
        assert!(waited < std::time::Duration::from_secs(10), "drain waited out its timeout");
        // The flag resets after one wake: the next drain blocks again
        // until its own timeout instead of spinning on a stale bell.
        let t0 = std::time::Instant::now();
        let got = mb.drain(std::time::Duration::from_millis(80)).unwrap();
        assert!(got.is_empty());
        assert!(
            t0.elapsed() >= std::time::Duration::from_millis(60),
            "stale doorbell short-circuited the next drain"
        );
    }

    #[test]
    fn waker_rings_on_push_notify_and_close() {
        use std::sync::atomic::AtomicUsize;
        let bus = Bus::new(NetConfig { latency_us: (0, 0), loss: 0.0, seed: 14 });
        let mb = bus.register(1);
        let rings = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&rings);
        mb.set_waker(Box::new(move || {
            r2.fetch_add(1, Ordering::Relaxed);
        }));
        bus.send(2, 1, &msg(1));
        assert_eq!(rings.load(Ordering::Relaxed), 1, "push rings");
        mb.notify();
        assert_eq!(rings.load(Ordering::Relaxed), 2, "notify rings");
        mb.close();
        assert_eq!(rings.load(Ordering::Relaxed), 3, "close rings");
    }

    #[test]
    fn try_drain_is_nonblocking_and_signals_close() {
        let bus = Bus::new(NetConfig { latency_us: (0, 0), loss: 0.0, seed: 15 });
        let mb = bus.register(1);
        // Empty + open: immediate empty batch.
        assert_eq!(mb.try_drain().unwrap().len(), 0);
        bus.send(2, 1, &msg(1));
        bus.send(3, 1, &msg(2));
        // A corrupt frame counts dropped, like in `drain`.
        mb.push(2, vec![0xEE, 0x01]);
        let got = mb.try_drain().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(bus.stats.dropped.load(Ordering::Relaxed), 1);
        // Closed with a frame still queued: the frame drains first,
        // then the closed+empty state reads as None.
        bus.send(2, 1, &msg(3));
        mb.close();
        assert_eq!(mb.try_drain().unwrap().len(), 1);
        assert!(mb.try_drain().is_none());
    }

    #[test]
    fn undecodable_frame_counts_dropped_in_drain() {
        let bus = Bus::new(NetConfig { latency_us: (0, 0), loss: 0.0, seed: 4 });
        let mb = bus.register(1);
        bus.send(2, 1, &msg(1));
        // A corrupt frame pushed straight into the mailbox (as a TCP
        // reader would after a CRC-valid but semantically bad frame).
        mb.push(2, vec![0xEE, 0x01, 0x02]);
        let got = mb.drain(std::time::Duration::from_millis(10)).unwrap();
        assert_eq!(got.len(), 1, "the good frame still drains");
        assert_eq!(bus.stats.dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn simnet_counts_undecodable_frames_dropped() {
        let mut net = SimNet::new(NetConfig { latency_us: (10, 10), loss: 0.0, seed: 6 });
        net.send(1, 2, msg(1));
        // Corrupt the queued frame in place.
        let Reverse((at, seq, from, to, _)) = net.queue.pop().unwrap();
        net.queue.push(Reverse((at, seq, from, to, vec![0xEE])));
        assert!(net.advance(1_000).is_empty());
        assert_eq!(net.stats.dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn wire_snapshot_absorbs() {
        let s = WireStats::default();
        s.msgs.fetch_add(3, Ordering::Relaxed);
        s.bytes.fetch_add(100, Ordering::Relaxed);
        let mut a = s.snapshot();
        let other = WireSnapshot {
            msgs: 1,
            bytes: 10,
            snap_bytes: 7,
            dropped: 2,
            fault_dropped: 1,
            reconnects: 4,
        };
        a.absorb(other);
        let want = WireSnapshot {
            msgs: 4,
            bytes: 110,
            snap_bytes: 7,
            dropped: 2,
            fault_dropped: 1,
            reconnects: 4,
        };
        assert_eq!(a, want);
    }

    #[test]
    fn snapshot_xfer_frames_attribute_to_snap_bytes() {
        let mut net = SimNet::new(NetConfig { latency_us: (10, 10), loss: 0.0, seed: 7 });
        net.send(1, 2, msg(1)); // AppendEntries: replication traffic
        net.send(
            1,
            2,
            Message::SnapChunk { term: 1, leader: 1, xfer_id: 9, offset: 0, data: vec![0xAB; 64] },
        );
        let s = net.stats.snapshot();
        assert_eq!(s.msgs, 2);
        assert!(s.snap_bytes > 64, "chunk frame counted");
        assert!(s.snap_bytes < s.bytes, "replication frame not counted");
    }

    #[test]
    fn simnet_fault_plan_partitions_and_attributes_drops() {
        let plan = Arc::new(FaultPlan::new(11));
        let mut net = SimNet::new(NetConfig { latency_us: (10, 10), loss: 0.0, seed: 11 });
        net.set_faults(Arc::clone(&plan));
        plan.partition_one_way(1, 2);
        net.send(1, 2, msg(1)); // blocked direction
        net.send(2, 1, msg(2)); // open direction
        let got = net.advance(1_000);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 2);
        let s = net.stats.snapshot();
        assert_eq!(s.dropped, 1);
        assert_eq!(s.fault_dropped, 1, "partition drops attribute to faults");
        plan.heal();
        net.send(1, 2, msg(3));
        assert_eq!(net.advance(2_000).len(), 1);
    }

    #[test]
    fn simnet_duplication_delivers_twice() {
        let plan = Arc::new(FaultPlan::new(12));
        plan.set_duplication(1.0);
        let mut net = SimNet::new(NetConfig { latency_us: (5, 5), loss: 0.0, seed: 12 });
        net.set_faults(plan);
        net.send(1, 2, msg(1));
        let got = net.advance(1_000);
        assert_eq!(got.len(), 2, "dup=1.0 delivers two copies");
        assert_eq!(got[0].2, got[1].2);
        assert_eq!(net.stats.msgs.load(Ordering::Relaxed), 1, "one logical send");
    }

    #[test]
    fn simnet_reorder_lets_later_frames_overtake() {
        let plan = Arc::new(FaultPlan::new(13));
        let mut net = SimNet::new(NetConfig { latency_us: (10, 10), loss: 0.0, seed: 13 });
        net.set_faults(Arc::clone(&plan));
        // First frame delayed far beyond the second's arrival.
        plan.set_reorder(1.0, 10_000);
        net.send(1, 2, msg(1));
        plan.set_reorder(0.0, 0);
        net.send(1, 2, msg(2));
        let got = net.advance(1_000_000);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].2, msg(2), "undelayed frame overtakes the reordered one");
    }

    /// Satellite: same `NetConfig` seed + same `FaultPlan` ⇒ identical
    /// delivery/drop trace across two runs, event for event.
    #[test]
    fn simnet_trace_is_identical_across_runs_for_same_seed_and_plan() {
        let run = |net_seed: u64, plan_seed: u64| {
            let plan = Arc::new(FaultPlan::new(plan_seed));
            plan.set_duplication(0.25);
            plan.set_reorder(0.25, 2_000);
            plan.set_link(1, 2, crate::fault::LinkFault { latency_us: None, loss: Some(0.3) });
            let mut net =
                SimNet::new(NetConfig { latency_us: (20, 80), loss: 0.1, seed: net_seed });
            net.set_faults(Arc::clone(&plan));
            net.enable_trace();
            let mut t = 0;
            for i in 0..300u64 {
                let (from, to) = (1 + i % 3, 1 + (i + 1) % 3);
                net.send(from, to, msg(i));
                if i == 100 {
                    plan.partition(2, 3);
                }
                if i == 200 {
                    plan.heal();
                }
                t += 40;
                let _ = net.advance(t);
            }
            let _ = net.advance(t + 100_000);
            net.take_trace()
        };
        let a = run(0xDECAF, 0x5EED);
        let b = run(0xDECAF, 0x5EED);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same (seed, plan) must replay the identical trace");
        let c = run(0xDECAF, 0x5EED + 1);
        assert_ne!(a, c, "a different plan seed must perturb the trace");
    }

    #[test]
    fn bus_fault_plan_drops_and_duplicates() {
        let plan = Arc::new(FaultPlan::new(21));
        let bus = Bus::with_faults(
            NetConfig { latency_us: (0, 0), loss: 0.0, seed: 21 },
            Arc::clone(&plan),
        );
        let mb1 = bus.register(1);
        let mb2 = bus.register(2);
        plan.partition(1, 2);
        bus.send(1, 2, &msg(1));
        bus.send(2, 1, &msg(2));
        assert!(mb2.drain(std::time::Duration::from_millis(10)).unwrap().is_empty());
        assert!(mb1.drain(std::time::Duration::from_millis(10)).unwrap().is_empty());
        let s = bus.stats.snapshot();
        assert_eq!(s.dropped, 2);
        assert_eq!(s.fault_dropped, 2);
        plan.clear();
        plan.set_duplication(1.0);
        bus.send(1, 2, &msg(3));
        let got = mb2.drain(std::time::Duration::from_millis(100)).unwrap();
        assert_eq!(got.len(), 2, "dup=1.0 delivers two copies over the bus");
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("TCP"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::Inproc));
        assert_eq!(TransportKind::parse("bus"), Some(TransportKind::Inproc));
        assert_eq!(TransportKind::parse("udp"), None);
        assert_eq!(TransportKind::default().name(), "inproc");
    }

    #[test]
    fn unregister_closes_mailbox_and_drops_future_sends() {
        let bus = Bus::new(NetConfig { latency_us: (0, 0), loss: 0.0, seed: 9 });
        let mb = bus.register(1);
        bus.send(2, 1, &msg(1));
        bus.unregister(1);
        // The already-delivered frame still drains; then the mailbox
        // reads closed.
        let got = mb.drain(std::time::Duration::from_millis(10)).unwrap();
        assert_eq!(got.len(), 1);
        assert!(mb.drain(std::time::Duration::from_millis(10)).is_none());
        // Further sends count as dropped instead of queueing forever.
        bus.send(2, 1, &msg(2));
        assert_eq!(bus.stats.dropped.load(Ordering::Relaxed), 1);
    }
}
