//! Raft consensus — the KVS-Raft substrate (paper §III-B).
//!
//! A from-scratch Raft: leader election, log replication, commitment,
//! snapshot install (monolithic blob, or the streamed run-shipping
//! transfer of DESIGN.md §8), crash recovery.  Two properties make it
//! "KVS-Raft-capable":
//!
//! 1. the persistent log is a [`crate::vlog::VLog`], so appending a
//!    log entry *is* the single value persist, and
//! 2. [`node::StateMachine::apply`] receives the entry's ValueLog
//!    offset, letting Nezha's state machine store `(key → offset)`
//!    while baselines re-persist full values.
//!
//! Module map: [`rpc`] (messages + wire codec), [`log`] (persistent
//! log + hard state), [`node`] (the protocol state machine), [`snap`]
//! (chunked snapshot manifests + the ack-clocked stream sender —
//! DESIGN.md §8), [`transport`] (deterministic sim net, threaded
//! in-process bus, and the real TCP transport behind one
//! [`transport::Net`] handle — DESIGN.md §2).
//!
//! Linearizable reads avoid the log entirely: a **ReadIndex** barrier
//! (leader confirms its term with one heartbeat quorum round and
//! hands out its commit index) or the **leader lease** fast path (a
//! clock-bound lease renewed by ordinary heartbeat echoes, so
//! steady-state reads cost zero extra RPCs).  Any replica may serve a
//! read once `last_applied` reaches the barrier's index — see
//! [`node::Node::request_read`].

pub mod log;
pub mod node;
pub mod rpc;
pub mod snap;
pub mod transport;

pub use log::{HardState, RaftLog};
pub use node::{ApplyLane, Config, Node, NodeId, NodeMetrics, Role, StateMachine};
pub use rpc::{Command, ConfChange, LogEntry, LogIndex, Message, Term};
pub use snap::{PlanItem, PlanSource, SnapItem, SnapManifest, SnapPlan, SnapSender};
pub use transport::{
    Bus, Net, NetConfig, SimNet, TcpNet, TraceEvent, Transport, TransportKind, WireSnapshot,
    WireStats,
};
