//! Persistent Raft log, backed by epoch-rotated ValueLogs.
//!
//! This file is where KVS-Raft's unification happens (paper §III-B):
//! the Raft log entry — key, value, term, index — is serialized once
//! into the ValueLog, and the returned [`VRef`] is exactly what Nezha's
//! state machine later stores.  Baselines use the same log but ignore
//! the VRef and re-persist the value through their storage engine.
//!
//! **Epochs = the paper's storage modules.**  The live epoch file is
//! the Active Storage's ValueLog.  When GC triggers, [`RaftLog::rotate`]
//! freezes it and opens the next epoch (the New Storage's log, which
//! becomes the next Active log); after GC completes the replica calls
//! [`RaftLog::mark_snapshot`] + [`RaftLog::drop_epochs_covered_by`],
//! exactly the "safely remove the old ValueLog" step of §III-C —
//! epochs whose index range is not yet fully snapshotted (cycles
//! triggered with an apply backlog) are retained for the next cycle.
//!
//! In-memory, the log keeps a suffix of entries (`mem`) for
//! replication; entries older than `mem_first` were compacted out of
//! memory after apply, and followers that lag behind them receive an
//! InstallSnapshot instead.

use super::rpc::{Command, ConfChange, LogEntry, LogIndex, Term};
use crate::util::{Decoder, Encoder};
use crate::vlog::{Entry as VEntry, VLog, VLogReader, VRef};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};

/// Durable (term, voted_for) — must hit disk before answering RPCs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HardState {
    pub term: Term,
    pub voted_for: Option<u64>,
}

impl HardState {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut e = Encoder::with_capacity(24);
        e.u64(self.term);
        e.u64(self.voted_for.map_or(u64::MAX, |v| v));
        let body = e.into_vec();
        let mut framed = Encoder::with_capacity(body.len() + 4);
        framed.u32(crc32fast::hash(&body)).bytes(&body);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, framed.as_slice())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Option<Self>> {
        let buf = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let mut d = Decoder::new(&buf);
        let crc = d.u32()?;
        let body = d.bytes(d.remaining())?;
        if crc32fast::hash(body) != crc {
            bail!("hardstate crc mismatch");
        }
        let mut d = Decoder::new(body);
        let term = d.u64()?;
        let v = d.u64()?;
        Ok(Some(Self { term, voted_for: if v == u64::MAX { None } else { Some(v) } }))
    }
}

/// Convert a Raft command into its ValueLog representation.
fn to_ventry(term: Term, index: LogIndex, cmd: &Command) -> VEntry {
    match cmd {
        Command::Put { key, value } => VEntry::put(term, index, key.clone(), value.clone()),
        Command::Delete { key } => VEntry::delete(term, index, key.clone()),
        // Internal entries ride the empty key (user keys are never
        // empty — the coordinator rejects them): Noop is an empty-key
        // delete, a membership change an empty-key put whose value is
        // the encoded ConfChange.
        Command::Noop => VEntry::delete(term, index, Vec::new()),
        Command::ConfChange(cc) => VEntry::put(term, index, Vec::new(), cc.encode()),
    }
}

fn from_ventry(e: &VEntry) -> LogEntry {
    let cmd = if e.key.is_empty() {
        match &e.value {
            None => Command::Noop,
            Some(v) => match ConfChange::decode_bytes(v) {
                Ok(cc) => Command::ConfChange(cc),
                // An undecodable internal entry would mean log
                // corruption the CRC layer missed; degrade to Noop
                // rather than poison replay.
                Err(_) => Command::Noop,
            },
        }
    } else {
        match &e.value {
            Some(v) => Command::Put { key: e.key.clone(), value: v.clone() },
            None => Command::Delete { key: e.key.clone() },
        }
    };
    LogEntry { term: e.term, index: e.index, cmd }
}

/// Path of an epoch's ValueLog file (shared with the engines' read
/// path via [`crate::vlog::EpochReaders`]).
pub fn epoch_path(dir: &Path, epoch: u32) -> PathBuf {
    dir.join(format!("raft-{epoch:06}.vlog"))
}

/// The replicated log: epoch-rotated VLog persistence + in-memory
/// suffix.
pub struct RaftLog {
    dir: PathBuf,
    /// Live epoch (append target).
    epoch: u32,
    vlog: VLog,
    /// Frozen epochs, read-only.
    old: BTreeMap<u32, VLogReader>,
    /// Highest entry index stored in each epoch file (live included).
    /// Drives snapshot-safe epoch deletion: an epoch file may only be
    /// removed once the snapshot covers its *entire* index range —
    /// GC cycles triggered with an apply backlog leave tails in frozen
    /// epochs that later cycles still need.
    epoch_max: BTreeMap<u32, LogIndex>,
    /// Per retained frozen epoch: first byte offset above the snapshot
    /// point, recorded by the last GC cycle so the next one seeks past
    /// the already-compacted prefix.  Purely an optimization — entries
    /// are invalidated whenever the underlying file could change
    /// (truncation, snapshot reset) and a missing entry means "read
    /// from byte 0".  Deliberately not persisted: a restart falls back
    /// to full reads, which are always correct.
    epoch_skip: BTreeMap<u32, u64>,
    /// In-memory suffix, `mem[0].index == mem_first`.
    mem: VecDeque<(LogEntry, VRef)>,
    mem_first: LogIndex,
    /// Log prefix replaced by a snapshot.
    pub snap_index: LogIndex,
    pub snap_term: Term,
    last_index: LogIndex,
    last_term: Term,
    /// Bytes appended to the live epoch since it was opened (GC
    /// trigger input).
    pub live_epoch_bytes: u64,
}

impl RaftLog {
    /// Open/recover the log in `dir` (files: `raft-NNNNNN.vlog`,
    /// `snapmeta`).
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let (snap_index, snap_term) = Self::load_snapmeta(dir)?.unwrap_or((0, 0));
        // Discover epoch files.
        let mut epochs: Vec<u32> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("raft-").and_then(|s| s.strip_suffix(".vlog")) {
                if let Ok(e) = num.parse::<u32>() {
                    epochs.push(e);
                }
            }
        }
        epochs.sort_unstable();
        let live_epoch = *epochs.last().unwrap_or(&0);

        let mut mem = VecDeque::new();
        let mut last_index = snap_index;
        let mut last_term = snap_term;
        let mut old = BTreeMap::new();
        let mut epoch_max: BTreeMap<u32, LogIndex> = BTreeMap::new();
        // Replay all epochs in order to rebuild the in-memory suffix.
        for &ep in &epochs {
            let reader = VLogReader::open(&epoch_path(dir, ep))?;
            for item in reader.iter()? {
                let (off, ve) = item?;
                let le = from_ventry(&ve);
                let m = epoch_max.entry(ep).or_insert(0);
                *m = (*m).max(le.index);
                if le.index <= snap_index {
                    continue; // compacted by snapshot
                }
                // A later epoch supersedes on conflict (can only happen
                // after a crash mid-truncate; keep the newest).
                while mem.back().is_some_and(|(e, _): &(LogEntry, VRef)| e.index >= le.index) {
                    mem.pop_back();
                }
                last_index = le.index;
                last_term = le.term;
                mem.push_back((le, VRef::new(ep, off)));
            }
            if ep != live_epoch {
                old.insert(ep, reader);
            }
        }
        let vlog = VLog::open(&epoch_path(dir, live_epoch))?;
        let live_epoch_bytes = vlog.len_bytes();
        let mem_first = mem.front().map_or(last_index + 1, |(e, _)| e.index);
        Ok(Self {
            dir: dir.to_path_buf(),
            epoch: live_epoch,
            vlog,
            old,
            epoch_max,
            epoch_skip: BTreeMap::new(),
            mem,
            mem_first,
            snap_index,
            snap_term,
            last_index,
            last_term,
            live_epoch_bytes,
        })
    }

    fn load_snapmeta(dir: &Path) -> Result<Option<(LogIndex, Term)>> {
        let p = dir.join("snapmeta");
        match std::fs::read(&p) {
            Ok(b) => {
                let mut d = Decoder::new(&b);
                Ok(Some((d.u64()?, d.u64()?)))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn save_snapmeta(&self) -> Result<()> {
        let mut e = Encoder::with_capacity(16);
        e.u64(self.snap_index).u64(self.snap_term);
        std::fs::write(self.dir.join("snapmeta"), e.as_slice())?;
        Ok(())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn last_index(&self) -> LogIndex {
        self.last_index
    }

    pub fn last_term(&self) -> Term {
        self.last_term
    }

    pub fn first_in_mem(&self) -> LogIndex {
        self.mem_first
    }

    pub fn mem_len(&self) -> usize {
        self.mem.len()
    }

    pub fn live_epoch(&self) -> u32 {
        self.epoch
    }

    pub fn vlog_len_bytes(&self) -> u64 {
        self.vlog.len_bytes()
    }

    /// Counter handle for disk accounting (bytes appended to the live
    /// epoch ValueLog — i.e. the ONE value persist of KVS-Raft).
    pub fn vlog_bytes_counter(&self) -> std::sync::Arc<std::sync::atomic::AtomicU64> {
        self.vlog.bytes_appended_counter()
    }

    /// Append a new entry (leader path or follower replication).
    /// Persists to the live ValueLog epoch and returns the [`VRef`] —
    /// **the single value persist in KVS-Raft**.
    pub fn append(&mut self, entry: LogEntry) -> Result<VRef> {
        debug_assert_eq!(entry.index, self.last_index + 1, "log must be contiguous");
        let ve = to_ventry(entry.term, entry.index, &entry.cmd);
        let off = self.vlog.append(&ve)?;
        self.live_epoch_bytes = self.vlog.len_bytes();
        self.last_index = entry.index;
        self.last_term = entry.term;
        if self.mem.is_empty() {
            self.mem_first = entry.index;
        }
        let vref = VRef::new(self.epoch, off);
        let m = self.epoch_max.entry(self.epoch).or_insert(0);
        *m = (*m).max(self.last_index);
        self.mem.push_back((entry, vref));
        Ok(vref)
    }

    /// Group-commit durability point.
    pub fn sync(&mut self) -> Result<()> {
        self.vlog.sync()
    }

    pub fn flush(&mut self) -> Result<()> {
        self.vlog.flush()
    }

    /// Freeze the live epoch and open the next one (GC initialization,
    /// paper §III-C step 1).  Returns the frozen epoch id.
    pub fn rotate(&mut self) -> Result<u32> {
        self.vlog.sync()?;
        let frozen = self.epoch;
        self.old.insert(frozen, VLogReader::open(&epoch_path(&self.dir, frozen))?);
        self.epoch += 1;
        self.vlog = VLog::open(&epoch_path(&self.dir, self.epoch))?;
        self.live_epoch_bytes = 0;
        Ok(frozen)
    }

    /// Delete every frozen epoch whose entire index range is covered
    /// by the snapshot at `snap_index`.  Epochs holding entries past
    /// the snapshot point (cycles triggered with an apply backlog
    /// freeze such tails) are retained: their values are still the
    /// only durable copy for the engine's stored VRefs, and the next
    /// GC cycle compacts them.
    pub fn drop_epochs_covered_by(&mut self, snap_index: LogIndex) -> Result<()> {
        let dead: Vec<u32> = self
            .old
            .keys()
            .copied()
            .filter(|e| self.epoch_max.get(e).is_none_or(|&m| m <= snap_index))
            .collect();
        for e in dead {
            self.old.remove(&e);
            self.epoch_max.remove(&e);
            self.epoch_skip.remove(&e);
            let _ = std::fs::remove_file(epoch_path(&self.dir, e));
        }
        Ok(())
    }

    /// Retained frozen epoch ids, oldest first (the next GC cycle's
    /// input set — some may hold uncompacted tails).
    pub fn frozen_epochs(&self) -> Vec<u32> {
        self.old.keys().copied().collect()
    }

    /// Retained frozen epochs with their recorded prefix-skip offsets
    /// (`0` = no record, read from the start), oldest first.
    pub fn frozen_epoch_inputs(&self) -> Vec<(u32, u64)> {
        self.old
            .keys()
            .map(|&e| (e, self.epoch_skip.get(&e).copied().unwrap_or(0)))
            .collect()
    }

    /// Record that everything below `off` in frozen epoch `epoch` is
    /// already compacted (reported by a completed GC cycle).  Ignored
    /// for non-frozen epochs — the live file is still growing and is
    /// not a GC input.
    pub fn set_epoch_skip(&mut self, epoch: u32, off: u64) {
        if self.old.contains_key(&epoch) {
            self.epoch_skip.insert(epoch, off);
        }
    }

    /// Term of entry `index`, if known (snapshot point included).
    pub fn term_at(&self, index: LogIndex) -> Option<Term> {
        if index == self.snap_index {
            return Some(self.snap_term);
        }
        if index == 0 {
            return Some(0);
        }
        self.entry(index).map(|e| e.term)
    }

    /// In-memory entry lookup.
    pub fn entry(&self, index: LogIndex) -> Option<&LogEntry> {
        if index < self.mem_first || index > self.last_index {
            return None;
        }
        self.mem.get((index - self.mem_first) as usize).map(|(e, _)| e)
    }

    pub fn vref_of(&self, index: LogIndex) -> Option<VRef> {
        if index < self.mem_first || index > self.last_index {
            return None;
        }
        self.mem.get((index - self.mem_first) as usize).map(|(_, v)| *v)
    }

    /// Entries `[from, to]` for replication (clamped to memory).
    pub fn entries(&self, from: LogIndex, to: LogIndex, max_bytes: usize) -> Vec<LogEntry> {
        let mut out = Vec::new();
        let mut budget = max_bytes;
        let mut i = from.max(self.mem_first);
        while i <= to.min(self.last_index) {
            let Some(e) = self.entry(i) else { break };
            let sz = e.approx_len();
            if !out.is_empty() && sz > budget {
                break;
            }
            budget = budget.saturating_sub(sz);
            out.push(e.clone());
            i += 1;
        }
        out
    }

    /// Truncate the log suffix starting at `from` (conflict
    /// resolution).  Handles truncation points inside frozen epochs by
    /// deleting every newer epoch and reopening the containing one.
    pub fn truncate_from(&mut self, from: LogIndex) -> Result<()> {
        if from > self.last_index {
            return Ok(());
        }
        anyhow::ensure!(
            from >= self.mem_first,
            "cannot truncate below in-memory prefix ({from} < {})",
            self.mem_first
        );
        let keep = (from - self.mem_first) as usize;
        let cut = self.mem[keep].1; // VRef of first removed entry
        self.mem.truncate(keep);
        // Truncation rewrites the containing file and deletes every
        // newer one: their recorded skip offsets no longer describe
        // the bytes on disk.  Dropping the records is always safe —
        // the next cycle falls back to a full filtered read.
        self.epoch_skip.retain(|&e, _| e < cut.epoch);

        if cut.epoch != self.epoch {
            // Conflict inside a frozen epoch: kill all newer epochs,
            // reopen the containing epoch as live, truncated.
            let newer: Vec<u32> = self.old.keys().copied().filter(|&e| e > cut.epoch).collect();
            for e in newer {
                self.old.remove(&e);
                self.epoch_max.remove(&e);
                let _ = std::fs::remove_file(epoch_path(&self.dir, e));
            }
            let _ = std::fs::remove_file(epoch_path(&self.dir, self.epoch));
            self.epoch_max.remove(&self.epoch);
            self.old.remove(&cut.epoch);
            self.epoch = cut.epoch;
            self.vlog = VLog::open(&epoch_path(&self.dir, cut.epoch))?;
        }
        self.vlog.flush()?;
        truncate_file(&epoch_path(&self.dir, self.epoch), cut.off)?;
        self.vlog = VLog::open(&epoch_path(&self.dir, self.epoch))?;
        self.live_epoch_bytes = self.vlog.len_bytes();
        // The containing file now ends before `from` (conservatively
        // keep `from - 1` as its max; overstating only delays drops).
        if let Some(m) = self.epoch_max.get_mut(&self.epoch) {
            *m = (*m).min(from.saturating_sub(1));
        }

        if let Some((e, _)) = self.mem.back() {
            self.last_index = e.index;
            self.last_term = e.term;
        } else {
            self.last_index = self.snap_index;
            self.last_term = self.snap_term;
            self.mem_first = self.snap_index + 1;
        }
        Ok(())
    }

    /// Drop in-memory entries ≤ `upto` (already applied), keeping
    /// `keep_tail` for laggards.  Disk content is untouched (it is the
    /// value store!); this is purely a memory bound.
    pub fn compact_mem(&mut self, upto: LogIndex, keep_tail: u64) {
        let bound = upto.saturating_sub(keep_tail);
        while let Some((e, _)) = self.mem.front() {
            if e.index <= bound && self.mem.len() > 1 {
                self.mem.pop_front();
            } else {
                break;
            }
        }
        if let Some((e, _)) = self.mem.front() {
            self.mem_first = e.index;
        }
    }

    /// Install a snapshot boundary: everything ≤ `index` is covered by
    /// the state-machine snapshot; all epochs restart.
    pub fn reset_to_snapshot(&mut self, index: LogIndex, term: Term) -> Result<()> {
        self.snap_index = index;
        self.snap_term = term;
        self.last_index = index;
        self.last_term = term;
        self.mem.clear();
        self.mem_first = index + 1;
        self.save_snapmeta()?;
        // Remove every epoch file and start a fresh epoch.
        let olds: Vec<u32> = self.old.keys().copied().collect();
        for e in olds {
            self.old.remove(&e);
            let _ = std::fs::remove_file(epoch_path(&self.dir, e));
        }
        self.epoch_max.clear();
        self.epoch_skip.clear();
        let _ = std::fs::remove_file(epoch_path(&self.dir, self.epoch));
        self.epoch += 1;
        self.vlog = VLog::open(&epoch_path(&self.dir, self.epoch))?;
        self.live_epoch_bytes = 0;
        Ok(())
    }

    /// Record that a GC cycle produced a snapshot at (`index`, `term`)
    /// *without* touching the live epoch (the GC framework then calls
    /// [`Self::drop_epochs_covered_by`]).  Snapshot points only move
    /// forward — a stale mark (e.g. from a GC cycle that raced an
    /// InstallSnapshot) is ignored.
    pub fn mark_snapshot(&mut self, index: LogIndex, term: Term) -> Result<()> {
        if index <= self.snap_index {
            return Ok(());
        }
        self.snap_index = index;
        self.snap_term = term;
        self.save_snapmeta()
    }

    /// Read the full value-log entry for a [`VRef`] (engines resolving
    /// stored references — Algorithm 2's `ReadValue`).
    pub fn read_vref(&mut self, vref: VRef) -> Result<VEntry> {
        if vref.epoch == self.epoch {
            self.vlog.read(vref.off)
        } else if let Some(r) = self.old.get(&vref.epoch) {
            r.read(vref.off)
        } else {
            bail!("read_vref: epoch {} not available", vref.epoch)
        }
    }

    /// Independent read-only handle for an epoch (engines' read path /
    /// background GC).
    pub fn reader_for(&self, epoch: u32) -> Result<VLogReader> {
        VLogReader::open(&epoch_path(&self.dir, epoch))
    }

    /// Flush, then return a reader for the live epoch.
    pub fn live_reader(&mut self) -> Result<VLogReader> {
        self.vlog.flush()?;
        self.vlog.reader()
    }
}

fn truncate_file(path: &Path, new_len: u64) -> Result<()> {
    let f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.set_len(new_len)?;
    f.sync_data()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-rlog-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn put(term: Term, index: LogIndex, k: &str, v: &str) -> LogEntry {
        LogEntry { term, index, cmd: Command::Put { key: k.into(), value: v.into() } }
    }

    #[test]
    fn append_and_lookup() {
        let mut log = RaftLog::open(&tmpdir("append")).unwrap();
        assert_eq!(log.last_index(), 0);
        log.append(put(1, 1, "a", "1")).unwrap();
        log.append(put(1, 2, "b", "2")).unwrap();
        assert_eq!(log.last_index(), 2);
        assert_eq!(log.term_at(1), Some(1));
        assert_eq!(log.entry(2).unwrap().cmd.key(), b"b");
        assert_eq!(log.entry(3), None);
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = tmpdir("reopen");
        {
            let mut log = RaftLog::open(&dir).unwrap();
            for i in 1..=10 {
                log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
            }
            log.sync().unwrap();
        }
        let log = RaftLog::open(&dir).unwrap();
        assert_eq!(log.last_index(), 10);
        assert_eq!(log.entry(7).unwrap().cmd.key(), b"k7");
    }

    #[test]
    fn truncate_removes_conflicting_suffix() {
        let dir = tmpdir("trunc");
        {
            let mut log = RaftLog::open(&dir).unwrap();
            for i in 1..=5 {
                log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
            }
            log.truncate_from(3).unwrap();
            assert_eq!(log.last_index(), 2);
            log.append(put(2, 3, "k3b", "v2")).unwrap();
            assert_eq!(log.entry(3).unwrap().term, 2);
            log.sync().unwrap();
        }
        let log = RaftLog::open(&dir).unwrap();
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.entry(3).unwrap().term, 2);
        assert_eq!(log.entry(3).unwrap().cmd.key(), b"k3b");
    }

    #[test]
    fn rotation_freezes_epoch_and_reads_still_work() {
        let dir = tmpdir("rotate");
        let mut log = RaftLog::open(&dir).unwrap();
        let mut vrefs = Vec::new();
        for i in 1..=5 {
            vrefs.push(log.append(put(1, i, &format!("k{i}"), &format!("v{i}"))).unwrap());
        }
        let frozen = log.rotate().unwrap();
        assert_eq!(frozen, 0);
        assert_eq!(log.live_epoch(), 1);
        for i in 6..=8 {
            vrefs.push(log.append(put(1, i, &format!("k{i}"), &format!("v{i}"))).unwrap());
        }
        // Reads across both epochs.
        for (i, vref) in vrefs.iter().enumerate() {
            let e = log.read_vref(*vref).unwrap();
            assert_eq!(e.key, format!("k{}", i + 1).into_bytes());
        }
        assert_eq!(vrefs[0].epoch, 0);
        assert_eq!(vrefs[7].epoch, 1);
    }

    #[test]
    fn recovery_spans_epochs() {
        let dir = tmpdir("recepochs");
        {
            let mut log = RaftLog::open(&dir).unwrap();
            for i in 1..=5 {
                log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
            }
            log.rotate().unwrap();
            for i in 6..=10 {
                log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
            }
            log.sync().unwrap();
        }
        let mut log = RaftLog::open(&dir).unwrap();
        assert_eq!(log.last_index(), 10);
        assert_eq!(log.live_epoch(), 1);
        // Both epoch files' entries readable.
        let v3 = log.vref_of(3).unwrap();
        assert_eq!(v3.epoch, 0);
        assert_eq!(log.read_vref(v3).unwrap().key, b"k3".to_vec());
    }

    #[test]
    fn drop_epochs_covered_by_respects_index_ranges() {
        let dir = tmpdir("dropep");
        let mut log = RaftLog::open(&dir).unwrap();
        log.append(put(1, 1, "a", "1")).unwrap();
        log.append(put(1, 2, "a2", "1")).unwrap();
        log.rotate().unwrap();
        log.append(put(1, 3, "b", "2")).unwrap();
        assert!(epoch_path(&dir, 0).exists());
        // Snapshot at 1 leaves index 2's only copy in epoch 0: retained.
        log.mark_snapshot(1, 1).unwrap();
        log.drop_epochs_covered_by(1).unwrap();
        assert!(epoch_path(&dir, 0).exists(), "uncovered tail must survive");
        // Snapshot at 2 covers the whole epoch: dropped.
        log.mark_snapshot(2, 1).unwrap();
        log.drop_epochs_covered_by(2).unwrap();
        assert!(!epoch_path(&dir, 0).exists());
        // Live epoch unaffected.
        let v = log.vref_of(3).unwrap();
        assert_eq!(log.read_vref(v).unwrap().key, b"b".to_vec());
    }

    #[test]
    fn truncate_across_rotation() {
        let dir = tmpdir("truncrot");
        let mut log = RaftLog::open(&dir).unwrap();
        for i in 1..=5 {
            log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
        }
        log.rotate().unwrap();
        for i in 6..=8 {
            log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
        }
        // Conflict at index 4 (inside frozen epoch 0).
        log.truncate_from(4).unwrap();
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.live_epoch(), 0); // reopened as live
        log.append(put(2, 4, "k4b", "v")).unwrap();
        assert_eq!(log.entry(4).unwrap().term, 2);
        // Epoch-1 file removed.
        assert!(!epoch_path(&dir, 1).exists());
    }

    #[test]
    fn epoch_skip_offsets_follow_epoch_lifecycle() {
        let dir = tmpdir("epskip");
        let mut log = RaftLog::open(&dir).unwrap();
        for i in 1..=4 {
            log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
        }
        log.rotate().unwrap();
        for i in 5..=8 {
            log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
        }
        // Recorded for the frozen epoch; ignored for the live one.
        log.set_epoch_skip(0, 123);
        log.set_epoch_skip(1, 999);
        assert_eq!(log.frozen_epoch_inputs(), vec![(0, 123)]);
        // Truncation inside the frozen epoch invalidates its record.
        log.truncate_from(3).unwrap();
        assert_eq!(log.live_epoch(), 0);
        assert!(log.frozen_epoch_inputs().is_empty());
        // Dropped epochs lose their records too.
        log.append(put(2, 3, "k3b", "v")).unwrap();
        log.rotate().unwrap();
        log.set_epoch_skip(0, 77);
        log.mark_snapshot(3, 2).unwrap();
        log.drop_epochs_covered_by(3).unwrap();
        assert!(log.frozen_epoch_inputs().is_empty());
    }

    #[test]
    fn compact_mem_keeps_disk_and_tail() {
        let mut log = RaftLog::open(&tmpdir("compact")).unwrap();
        for i in 1..=100 {
            log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
        }
        log.compact_mem(90, 5);
        assert!(log.first_in_mem() >= 85);
        assert!(log.entry(50).is_none());
        assert_eq!(log.last_index(), 100);
        assert!(log.vlog_len_bytes() > 0);
    }

    #[test]
    fn entries_respects_byte_budget() {
        let mut log = RaftLog::open(&tmpdir("budget")).unwrap();
        for i in 1..=10 {
            log.append(LogEntry {
                term: 1,
                index: i,
                cmd: Command::Put { key: vec![b'k'; 10], value: vec![0; 1000] },
            })
            .unwrap();
        }
        let es = log.entries(1, 10, 2500);
        assert!(es.len() >= 2 && es.len() <= 3, "len={}", es.len());
        let one = log.entries(1, 10, 1);
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn snapshot_reset_restarts_log() {
        let dir = tmpdir("snap");
        {
            let mut log = RaftLog::open(&dir).unwrap();
            for i in 1..=20 {
                log.append(put(1, i, &format!("k{i}"), "v")).unwrap();
            }
            log.reset_to_snapshot(20, 1).unwrap();
            assert_eq!(log.last_index(), 20);
            assert_eq!(log.vlog_len_bytes(), 0);
            log.append(put(2, 21, "k21", "v")).unwrap();
            log.sync().unwrap();
        }
        let log = RaftLog::open(&dir).unwrap();
        assert_eq!(log.snap_index, 20);
        assert_eq!(log.last_index(), 21);
        assert_eq!(log.term_at(20), Some(1));
        assert_eq!(log.entry(21).unwrap().cmd.key(), b"k21");
    }

    #[test]
    fn hardstate_roundtrip() {
        let dir = tmpdir("hs");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("hardstate");
        assert_eq!(HardState::load(&p).unwrap(), None);
        let hs = HardState { term: 7, voted_for: Some(2) };
        hs.save(&p).unwrap();
        assert_eq!(HardState::load(&p).unwrap(), Some(hs));
        let hs2 = HardState { term: 8, voted_for: None };
        hs2.save(&p).unwrap();
        assert_eq!(HardState::load(&p).unwrap(), Some(hs2));
    }

    #[test]
    fn noop_entries_roundtrip() {
        let dir = tmpdir("noop");
        {
            let mut log = RaftLog::open(&dir).unwrap();
            log.append(LogEntry { term: 1, index: 1, cmd: Command::Noop }).unwrap();
            log.sync().unwrap();
        }
        let log = RaftLog::open(&dir).unwrap();
        assert_eq!(log.entry(1).unwrap().cmd, Command::Noop);
    }

    #[test]
    fn conf_change_entries_roundtrip() {
        let dir = tmpdir("conf");
        let ccs = [ConfChange::AddLearner(4), ConfChange::Promote(4), ConfChange::Remove(2)];
        {
            let mut log = RaftLog::open(&dir).unwrap();
            for (i, cc) in ccs.iter().enumerate() {
                log.append(LogEntry {
                    term: 1,
                    index: i as u64 + 1,
                    cmd: Command::ConfChange(*cc),
                })
                .unwrap();
            }
            log.sync().unwrap();
        }
        // Survives the epoch replay on reopen intact (distinct from
        // Noop, which shares the empty-key representation).
        let log = RaftLog::open(&dir).unwrap();
        for (i, cc) in ccs.iter().enumerate() {
            assert_eq!(log.entry(i as u64 + 1).unwrap().cmd, Command::ConfChange(*cc));
        }
    }

    #[test]
    fn live_epoch_bytes_tracks_appends_and_rotation() {
        let mut log = RaftLog::open(&tmpdir("gctrig")).unwrap();
        assert_eq!(log.live_epoch_bytes, 0);
        log.append(put(1, 1, "k", &"v".repeat(100))).unwrap();
        assert!(log.live_epoch_bytes > 100);
        log.rotate().unwrap();
        assert_eq!(log.live_epoch_bytes, 0);
    }
}
