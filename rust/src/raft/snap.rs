//! Streamed snapshot transfer: manifest, plan, and sender window.
//!
//! Catch-up ships the engine's sealed, immutable GC runs as files
//! instead of one monolithic `snapshot_bytes()` blob (DESIGN.md §8).
//! The leader asks its state machine for a [`SnapPlan`] — an ordered
//! list of byte sources (run files on disk plus small in-memory
//! residual items) — and streams them as one logical byte range:
//! global offset 0 is the first byte of the first item, and chunks
//! never span item boundaries so the receiver can land each item in
//! its own staging file.
//!
//! The wire protocol is three messages ([`super::rpc::Message`]):
//! `SnapMeta` (the encoded [`SnapManifest`]: names, lengths, CRCs,
//! level shape — never data), `SnapChunk` (one bounded slice at an
//! offset), and `SnapAck` (cumulative: the next offset the receiver
//! wants). Offset-based acks make the stream resumable across
//! reconnects, receiver restarts, and leader changes: a new or
//! recovering sender re-offers `SnapMeta`, and the receiver answers
//! with wherever its staging directory already got to.
//!
//! [`SnapSender`] is ack-clocked go-back-N with a bounded in-flight
//! window, so a catch-up transfer can never starve `AppendEntries`
//! to healthy followers: at most `window` chunks ride the wire per
//! transfer, and new chunks are released only by acks (or a stall
//! rewind on heartbeat ticks).

use crate::util::{Decoder, Encoder};
use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom};
use std::path::PathBuf;

use super::rpc::{LogIndex, Message, Term};

/// One shipped file (or in-memory blob) in a snapshot transfer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapItem {
    /// Name the receiver stages the bytes under (e.g. `sorted-42.vlog`).
    pub name: String,
    pub len: u64,
    /// CRC32 of the item's full contents, verified at receiver commit.
    pub crc: u32,
}

/// The transfer's table of contents, shipped encoded inside `SnapMeta`.
///
/// `shape` is an opaque engine-owned blob describing how the shipped
/// items reassemble (for Nezha: the level stack, per-run tombstone
/// counts, and partition groups of the `LEVELS` manifest).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapManifest {
    pub last_index: LogIndex,
    pub last_term: Term,
    pub total_len: u64,
    pub items: Vec<SnapItem>,
    pub shape: Vec<u8>,
}

impl SnapManifest {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.u64(self.last_index).u64(self.last_term).u64(self.total_len);
        e.varint(self.items.len() as u64);
        for it in &self.items {
            e.len_bytes(it.name.as_bytes()).u64(it.len).u32(it.crc);
        }
        e.len_bytes(&self.shape);
        e.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Decoder::new(buf);
        let last_index = d.u64()?;
        let last_term = d.u64()?;
        let total_len = d.u64()?;
        let n = d.varint()? as usize;
        if n > 1 << 20 {
            bail!("snap manifest: absurd item count {n}");
        }
        let mut items = Vec::with_capacity(n);
        let mut sum = 0u64;
        for _ in 0..n {
            let name = String::from_utf8(d.len_bytes()?.to_vec())
                .context("snap manifest: item name not utf8")?;
            let len = d.u64()?;
            let crc = d.u32()?;
            sum = sum.saturating_add(len);
            items.push(SnapItem { name, len, crc });
        }
        let shape = d.len_bytes()?.to_vec();
        if d.remaining() != 0 {
            bail!("snap manifest: trailing bytes");
        }
        if sum != total_len {
            bail!("snap manifest: item lengths sum {sum} != total {total_len}");
        }
        Ok(Self { last_index, last_term, total_len, items, shape })
    }
}

/// Where a plan item's bytes come from on the sender.
#[derive(Clone, Debug)]
pub enum PlanSource {
    /// A sealed, immutable file on disk (pinned against GC deletion
    /// for the life of the plan).
    File(PathBuf),
    /// Small in-memory bytes (the residual-epoch tail).
    Bytes(Vec<u8>),
}

#[derive(Clone, Debug)]
pub struct PlanItem {
    pub name: String,
    pub len: u64,
    pub crc: u32,
    pub src: PlanSource,
}

/// The sender-side snapshot plan a state machine hands to raft.
///
/// `id` is engine-scoped: the engine keeps the named runs pinned
/// (deletion-deferred) until [`super::node::StateMachine::snap_stream_end`]
/// releases it.
#[derive(Clone, Debug)]
pub struct SnapPlan {
    pub id: u64,
    pub last_index: LogIndex,
    pub last_term: Term,
    pub items: Vec<PlanItem>,
    pub shape: Vec<u8>,
}

impl SnapPlan {
    pub fn total_len(&self) -> u64 {
        self.items.iter().map(|i| i.len).sum()
    }

    pub fn manifest(&self) -> SnapManifest {
        SnapManifest {
            last_index: self.last_index,
            last_term: self.last_term,
            total_len: self.total_len(),
            items: self
                .items
                .iter()
                .map(|i| SnapItem { name: i.name.clone(), len: i.len, crc: i.crc })
                .collect(),
            shape: self.shape.clone(),
        }
    }
}

/// Heartbeat ticks with zero ack progress before the sender rewinds
/// to the last cumulative ack and re-offers `SnapMeta` (covers lost
/// chunks, lost acks, and receiver restarts alike).
const STALL_TICKS: u32 = 3;

/// Per-follower sender state for one streamed snapshot transfer.
pub struct SnapSender {
    pub xfer_id: u64,
    plan: SnapPlan,
    manifest_bytes: Vec<u8>,
    total_len: u64,
    /// Cumulative ack: everything below this offset is at the receiver.
    pub acked: u64,
    /// Next offset to put on the wire.
    next: u64,
    meta_acked: bool,
    chunk_bytes: usize,
    window: usize,
    idle_ticks: u32,
    /// Membership as of the snapshot's `last_index`, stamped on every
    /// `SnapMeta` offer so a joining node whose config entries were
    /// compacted into the snapshot still learns the member set.
    voters: Vec<u64>,
    learners: Vec<u64>,
}

impl SnapSender {
    pub fn new(
        plan: SnapPlan,
        xfer_id: u64,
        chunk_bytes: usize,
        window: usize,
        voters: Vec<u64>,
        learners: Vec<u64>,
    ) -> Self {
        let manifest_bytes = plan.manifest().encode();
        let total_len = plan.total_len();
        Self {
            xfer_id,
            plan,
            manifest_bytes,
            total_len,
            acked: 0,
            next: 0,
            meta_acked: false,
            chunk_bytes: chunk_bytes.max(1),
            window: window.max(1),
            idle_ticks: 0,
            voters,
            learners,
        }
    }

    pub fn plan_id(&self) -> u64 {
        self.plan.id
    }

    pub fn last_index(&self) -> LogIndex {
        self.plan.last_index
    }

    pub fn last_term(&self) -> Term {
        self.plan.last_term
    }

    pub fn total_len(&self) -> u64 {
        self.total_len
    }

    pub fn meta_msg(&self, term: Term, leader: u64) -> Message {
        Message::SnapMeta {
            term,
            leader,
            xfer_id: self.xfer_id,
            last_index: self.plan.last_index,
            last_term: self.plan.last_term,
            manifest: self.manifest_bytes.clone(),
            voters: self.voters.clone(),
            learners: self.learners.clone(),
        }
    }

    /// Process a cumulative ack; returns the burst of chunks the
    /// freed window admits. The first ack also confirms `SnapMeta`
    /// (it carries the receiver's resume offset).
    pub fn on_ack(&mut self, offset: u64) -> Result<Vec<Message>> {
        let offset = offset.min(self.total_len);
        if !self.meta_acked {
            // Resume point from the receiver's staging dir.
            self.meta_acked = true;
            self.acked = offset;
            self.next = offset;
            self.idle_ticks = 0;
        } else if offset > self.acked {
            self.acked = offset;
            if offset > self.next {
                self.next = offset;
            }
            self.idle_ticks = 0;
        } else {
            // Duplicate ack: the receiver is re-requesting `offset`
            // (a gap — lost or reordered chunk). Go-back-N.
            self.next = offset;
            self.acked = offset;
        }
        Ok(Vec::new())
    }

    /// Fill the in-flight window with chunks starting at `next`.
    pub fn fill_window(&mut self, term: Term, leader: u64) -> Result<Vec<Message>> {
        if !self.meta_acked {
            return Ok(Vec::new());
        }
        let limit = self.acked.saturating_add((self.window * self.chunk_bytes) as u64);
        let mut out = Vec::new();
        while self.next < self.total_len && self.next < limit {
            let want = (self.chunk_bytes as u64).min(limit - self.next);
            let data = self.read_at(self.next, want as usize)?;
            if data.is_empty() {
                bail!("snap sender: zero-length read at offset {}", self.next);
            }
            let len = data.len() as u64;
            out.push(Message::SnapChunk {
                term,
                leader,
                xfer_id: self.xfer_id,
                offset: self.next,
                data,
            });
            self.next += len;
        }
        Ok(out)
    }

    /// Heartbeat-driven maintenance: re-offer `SnapMeta` until acked,
    /// and rewind to the cumulative ack after a stall.
    pub fn tick(&mut self, term: Term, leader: u64) -> Result<Vec<Message>> {
        if !self.meta_acked {
            return Ok(vec![self.meta_msg(term, leader)]);
        }
        if self.acked >= self.total_len {
            // Everything delivered; nudge the receiver if the final
            // done-ack went missing.
            self.idle_ticks += 1;
            if self.idle_ticks >= STALL_TICKS {
                self.idle_ticks = 0;
                return Ok(vec![self.meta_msg(term, leader)]);
            }
            return Ok(Vec::new());
        }
        self.idle_ticks += 1;
        if self.idle_ticks >= STALL_TICKS {
            self.idle_ticks = 0;
            self.next = self.acked;
            return self.fill_window(term, leader);
        }
        Ok(Vec::new())
    }

    /// Read `max` bytes at global `offset`, clipped so the slice never
    /// crosses an item boundary (each staged file lands whole).
    fn read_at(&self, offset: u64, max: usize) -> Result<Vec<u8>> {
        let mut base = 0u64;
        for item in &self.plan.items {
            if offset < base + item.len {
                let rel = offset - base;
                let n = ((item.len - rel) as usize).min(max);
                return match &item.src {
                    PlanSource::Bytes(b) => Ok(b[rel as usize..rel as usize + n].to_vec()),
                    PlanSource::File(path) => {
                        let mut f = std::fs::File::open(path)
                            .with_context(|| format!("snap sender: open {}", path.display()))?;
                        f.seek(SeekFrom::Start(rel))?;
                        let mut buf = vec![0u8; n];
                        f.read_exact(&mut buf).with_context(|| {
                            format!("snap sender: short read {} @{rel}", path.display())
                        })?;
                        Ok(buf)
                    }
                };
            }
            base += item.len;
        }
        bail!("snap sender: offset {offset} beyond total {}", self.total_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes_plan(chunks: &[&[u8]]) -> SnapPlan {
        SnapPlan {
            id: 1,
            last_index: 10,
            last_term: 2,
            items: chunks
                .iter()
                .enumerate()
                .map(|(i, b)| PlanItem {
                    name: format!("item-{i}"),
                    len: b.len() as u64,
                    crc: crc32fast::hash(b),
                    src: PlanSource::Bytes(b.to_vec()),
                })
                .collect(),
            shape: vec![9, 9],
        }
    }

    #[test]
    fn manifest_roundtrip() {
        let m = bytes_plan(&[b"hello", b"world!!"]).manifest();
        let enc = m.encode();
        assert_eq!(SnapManifest::decode(&enc).unwrap(), m);
    }

    #[test]
    fn manifest_truncation_and_corruption_rejected() {
        let m = bytes_plan(&[b"hello", b"world!!"]).manifest();
        let enc = m.encode();
        for cut in 0..enc.len() {
            assert!(SnapManifest::decode(&enc[..cut]).is_err(), "cut at {cut} accepted");
        }
        // Flip a length byte: item sums no longer match total.
        let mut bad = enc.clone();
        bad[16] ^= 0xff; // total_len field
        assert!(SnapManifest::decode(&bad).is_err());
        // Trailing garbage rejected.
        let mut long = enc.clone();
        long.push(0);
        assert!(SnapManifest::decode(&long).is_err());
    }

    #[test]
    fn read_at_respects_item_boundaries() {
        let plan = bytes_plan(&[b"aaaa", b"bb", b"cccccc"]);
        let s = SnapSender::new(plan, 7, 100, 4, vec![1, 2, 3], vec![]);
        assert_eq!(s.read_at(0, 100).unwrap(), b"aaaa");
        assert_eq!(s.read_at(2, 100).unwrap(), b"aa");
        assert_eq!(s.read_at(4, 100).unwrap(), b"bb");
        assert_eq!(s.read_at(6, 3).unwrap(), b"ccc");
        assert_eq!(s.read_at(11, 100).unwrap(), b"c");
        assert!(s.read_at(12, 1).is_err());
    }

    #[test]
    fn window_is_ack_clocked() {
        let plan = bytes_plan(&[&[1u8; 10][..]]);
        let mut s = SnapSender::new(plan, 7, 2, 2, vec![1, 2, 3], vec![]); // 2-byte chunks, window 2
        // Meta not acked yet: nothing flows.
        assert!(s.fill_window(1, 0).unwrap().is_empty());
        // Receiver acks resume offset 0 → window opens: 2 chunks.
        s.on_ack(0).unwrap();
        let burst = s.fill_window(1, 0).unwrap();
        assert_eq!(burst.len(), 2);
        // Window full: nothing more until an ack.
        assert!(s.fill_window(1, 0).unwrap().is_empty());
        // Ack first chunk → one more slot.
        s.on_ack(2).unwrap();
        assert_eq!(s.fill_window(1, 0).unwrap().len(), 1);
        // Duplicate ack rewinds (go-back-N).
        s.on_ack(2).unwrap();
        let resend = s.fill_window(1, 0).unwrap();
        assert!(matches!(
            &resend[0],
            Message::SnapChunk { offset: 2, .. }
        ));
    }

    #[test]
    fn resume_offset_skips_delivered_prefix() {
        let plan = bytes_plan(&[&[3u8; 8][..]]);
        let mut s = SnapSender::new(plan, 7, 4, 4, vec![1, 2, 3], vec![]);
        s.on_ack(4).unwrap(); // receiver already staged 4 bytes
        let burst = s.fill_window(1, 0).unwrap();
        assert_eq!(burst.len(), 1);
        assert!(matches!(&burst[0], Message::SnapChunk { offset: 4, data, .. } if data.len() == 4));
    }

    #[test]
    fn stall_rewinds_and_resends() {
        let plan = bytes_plan(&[&[5u8; 6][..]]);
        let mut s = SnapSender::new(plan, 7, 2, 3, vec![1, 2, 3], vec![]);
        // Unacked meta: every tick re-offers it.
        assert!(matches!(&s.tick(1, 0).unwrap()[0], Message::SnapMeta { .. }));
        s.on_ack(0).unwrap();
        let sent = s.fill_window(1, 0).unwrap();
        assert_eq!(sent.len(), 3);
        // No acks arrive: after STALL_TICKS the window replays from 0.
        let mut replay = Vec::new();
        for _ in 0..STALL_TICKS {
            replay = s.tick(1, 0).unwrap();
        }
        assert_eq!(replay.len(), 3);
        assert!(matches!(&replay[0], Message::SnapChunk { offset: 0, .. }));
    }
}
