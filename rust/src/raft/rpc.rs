//! Raft wire messages + binary encoding.
//!
//! The in-process [`super::transport::Bus`] moves *encoded* frames so
//! the benches account for real serialization cost and wire volume
//! (the paper's cluster used gRPC/protobuf over 10 GbE — DESIGN.md §2).

use crate::util::{Decoder, Encoder};
use anyhow::{bail, Result};

pub type Term = u64;
pub type LogIndex = u64;

/// A single-server membership change, replicated as a log entry
/// (DESIGN.md §9).  One change is in flight at a time, and each adds or
/// removes exactly one server — the overlap argument that makes joint
/// consensus unnecessary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfChange {
    /// Add `node` as a non-voting learner: it receives appends and
    /// snapshots but counts toward no quorum.
    AddLearner(u64),
    /// Promote a caught-up learner to voter.
    Promote(u64),
    /// Remove `node` (voter or learner) from the configuration.
    Remove(u64),
}

impl ConfChange {
    pub fn node(&self) -> u64 {
        match self {
            ConfChange::AddLearner(n) | ConfChange::Promote(n) | ConfChange::Remove(n) => *n,
        }
    }

    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            ConfChange::AddLearner(n) => e.u8(0).u64(*n),
            ConfChange::Promote(n) => e.u8(1).u64(*n),
            ConfChange::Remove(n) => e.u8(2).u64(*n),
        };
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_vec()
    }

    pub fn decode(d: &mut Decoder) -> Result<Self> {
        Ok(match d.u8()? {
            0 => ConfChange::AddLearner(d.u64()?),
            1 => ConfChange::Promote(d.u64()?),
            2 => ConfChange::Remove(d.u64()?),
            other => bail!("rpc: unknown conf-change kind {other}"),
        })
    }

    pub fn decode_bytes(buf: &[u8]) -> Result<Self> {
        ConfChange::decode(&mut Decoder::new(buf))
    }
}

/// A state-machine command carried in a Raft log entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Command {
    Put { key: Vec<u8>, value: Vec<u8> },
    Delete { key: Vec<u8> },
    /// No-op barrier appended by a new leader to commit prior terms.
    Noop,
    /// Membership change; applied to the node's config at *append*,
    /// a no-op for the storage engine.
    ConfChange(ConfChange),
}

impl Command {
    pub fn key(&self) -> &[u8] {
        match self {
            Command::Put { key, .. } | Command::Delete { key } => key,
            Command::Noop | Command::ConfChange(_) => &[],
        }
    }

    pub fn value_len(&self) -> usize {
        match self {
            Command::Put { value, .. } => value.len(),
            _ => 0,
        }
    }

    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            Command::Put { key, value } => {
                e.u8(0).len_bytes(key).len_bytes(value);
            }
            Command::Delete { key } => {
                e.u8(1).len_bytes(key);
            }
            Command::Noop => {
                e.u8(2);
            }
            Command::ConfChange(cc) => {
                e.u8(3);
                cc.encode_into(e);
            }
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self> {
        Ok(match d.u8()? {
            0 => Command::Put { key: d.len_bytes()?.to_vec(), value: d.len_bytes()?.to_vec() },
            1 => Command::Delete { key: d.len_bytes()?.to_vec() },
            2 => Command::Noop,
            3 => Command::ConfChange(ConfChange::decode(d)?),
            other => bail!("rpc: unknown command tag {other}"),
        })
    }
}

/// A replicated log entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LogEntry {
    pub term: Term,
    pub index: LogIndex,
    pub cmd: Command,
}

impl LogEntry {
    pub fn approx_len(&self) -> usize {
        17 + self.cmd.key().len() + self.cmd.value_len()
    }

    fn encode_into(&self, e: &mut Encoder) {
        e.u64(self.term).u64(self.index);
        self.cmd.encode_into(e);
    }

    fn decode(d: &mut Decoder) -> Result<Self> {
        Ok(Self { term: d.u64()?, index: d.u64()?, cmd: Command::decode(d)? })
    }
}

/// Upper bound on a wire-carried member list; real configs are a
/// handful of nodes, so anything bigger is a corrupt frame.
const MAX_WIRE_MEMBERS: usize = 1024;

fn encode_ids(e: &mut Encoder, ids: &[u64]) {
    e.varint(ids.len() as u64);
    for &id in ids {
        e.u64(id);
    }
}

fn decode_ids(d: &mut Decoder) -> Result<Vec<u64>> {
    let n = d.varint()? as usize;
    if n > MAX_WIRE_MEMBERS {
        bail!("rpc: member list too long ({n})");
    }
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(d.u64()?);
    }
    Ok(ids)
}

/// Raft RPCs (§5 of the Raft paper, plus InstallSnapshot from §7).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Message {
    RequestVote {
        term: Term,
        candidate: u64,
        last_log_index: LogIndex,
        last_log_term: Term,
        /// Set by a candidate campaigning on a leadership transfer
        /// (`TimeoutNow`): voters skip the liveness stickiness gate,
        /// since the old leader sanctioned this election (§4.2.3).
        transfer: bool,
    },
    RequestVoteResp {
        term: Term,
        granted: bool,
    },
    AppendEntries {
        term: Term,
        leader: u64,
        prev_log_index: LogIndex,
        prev_log_term: Term,
        entries: Vec<LogEntry>,
        leader_commit: LogIndex,
        /// Heartbeat round number, echoed by the follower: an ack for
        /// round `n` proves the follower saw this leader *after* round
        /// `n` was broadcast — the quorum-confirmation primitive behind
        /// ReadIndex barriers and leader leases.
        seq: u64,
    },
    AppendEntriesResp {
        term: Term,
        success: bool,
        /// Highest index known replicated on the follower (on success),
        /// or the follower's conflict hint (on failure).
        match_index: LogIndex,
        /// Echo of the heartbeat round being answered.
        seq: u64,
    },
    InstallSnapshot {
        term: Term,
        leader: u64,
        last_index: LogIndex,
        last_term: Term,
        /// Opaque state-machine snapshot (Nezha: the sorted ValueLog
        /// bytes — paper §III-E "Recovery leverages the sorted
        /// ValueLog ... as an efficient snapshot mechanism").
        data: Vec<u8>,
        /// Membership as of `last_index`, so a receiver whose config
        /// entries were compacted into this snapshot still learns it.
        voters: Vec<u64>,
        learners: Vec<u64>,
    },
    InstallSnapshotResp {
        term: Term,
        last_index: LogIndex,
    },
    /// Leader → follower: open (or re-offer) a streamed snapshot
    /// transfer.  Carries the transfer's encoded [`snap::SnapManifest`]
    /// — the file list + CRCs + level shape — never the data itself,
    /// so it stays small regardless of snapshot size (DESIGN.md §8).
    ///
    /// [`snap::SnapManifest`]: super::snap::SnapManifest
    SnapMeta {
        term: Term,
        leader: u64,
        /// Transfer id; chunks and acks for a different id are stale.
        xfer_id: u64,
        last_index: LogIndex,
        last_term: Term,
        manifest: Vec<u8>,
        /// Membership as of `last_index` (see `InstallSnapshot`).
        voters: Vec<u64>,
        learners: Vec<u64>,
    },
    /// Leader → follower: one bounded-size slice of the transfer's
    /// byte stream at `offset` (a global offset over the concatenated
    /// manifest items).  Resumable: the receiver acks the next offset
    /// it wants, so a reconnect re-enters mid-stream.
    SnapChunk {
        term: Term,
        leader: u64,
        xfer_id: u64,
        offset: u64,
        data: Vec<u8>,
    },
    /// Follower → leader: cumulative ack.  `offset` is the next byte
    /// the receiver wants (`u64::MAX` = streaming refused, fall back
    /// to the monolithic path); `done` means the snapshot was
    /// committed at the receiver.
    SnapAck {
        term: Term,
        xfer_id: u64,
        offset: u64,
        done: bool,
    },
    /// Replica → leader: ask for a linearizable read barrier.  The
    /// leader answers with its commit index once it has confirmed its
    /// leadership for the current term (a heartbeat quorum round, or a
    /// still-valid lease).  `ctx` is an opaque requester-side token.
    ReadIndex {
        term: Term,
        ctx: u64,
    },
    /// Leader → requester: the `(read_index, term)` handed out for
    /// `ctx`.  The requester serves its read from local state once
    /// `last_applied >= read_index`.  `ok: false` means the node asked
    /// was not a confirmed leader — re-resolve the leader and retry.
    ReadIndexResp {
        term: Term,
        ctx: u64,
        read_index: LogIndex,
        ok: bool,
    },
    /// Removed leader → best-caught-up voter: campaign *now*, without
    /// waiting out an election timeout (Raft §4.2.3 leadership
    /// transfer).  The recipient starts an election with the
    /// `transfer` flag set on its vote requests.
    TimeoutNow {
        term: Term,
    },
}

impl Message {
    pub fn term(&self) -> Term {
        match self {
            Message::RequestVote { term, .. }
            | Message::RequestVoteResp { term, .. }
            | Message::AppendEntries { term, .. }
            | Message::AppendEntriesResp { term, .. }
            | Message::InstallSnapshot { term, .. }
            | Message::InstallSnapshotResp { term, .. }
            | Message::SnapMeta { term, .. }
            | Message::SnapChunk { term, .. }
            | Message::SnapAck { term, .. }
            | Message::ReadIndex { term, .. }
            | Message::ReadIndexResp { term, .. }
            | Message::TimeoutNow { term } => *term,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        match self {
            Message::RequestVote { term, candidate, last_log_index, last_log_term, transfer } => {
                e.u8(0).u64(*term).u64(*candidate).u64(*last_log_index).u64(*last_log_term);
                e.u8(*transfer as u8);
            }
            Message::RequestVoteResp { term, granted } => {
                e.u8(1).u64(*term).u8(*granted as u8);
            }
            Message::AppendEntries {
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                seq,
            } => {
                e.u8(2).u64(*term).u64(*leader).u64(*prev_log_index).u64(*prev_log_term);
                e.u64(*leader_commit).u64(*seq);
                e.varint(entries.len() as u64);
                for ent in entries {
                    ent.encode_into(&mut e);
                }
            }
            Message::AppendEntriesResp { term, success, match_index, seq } => {
                e.u8(3).u64(*term).u8(*success as u8).u64(*match_index).u64(*seq);
            }
            Message::InstallSnapshot { term, leader, last_index, last_term, data, voters, learners } => {
                e.u8(4).u64(*term).u64(*leader).u64(*last_index).u64(*last_term).len_bytes(data);
                encode_ids(&mut e, voters);
                encode_ids(&mut e, learners);
            }
            Message::InstallSnapshotResp { term, last_index } => {
                e.u8(5).u64(*term).u64(*last_index);
            }
            Message::ReadIndex { term, ctx } => {
                e.u8(6).u64(*term).u64(*ctx);
            }
            Message::ReadIndexResp { term, ctx, read_index, ok } => {
                e.u8(7).u64(*term).u64(*ctx).u64(*read_index).u8(*ok as u8);
            }
            Message::SnapMeta { term, leader, xfer_id, last_index, last_term, manifest, voters, learners } => {
                e.u8(8).u64(*term).u64(*leader).u64(*xfer_id).u64(*last_index).u64(*last_term);
                e.len_bytes(manifest);
                encode_ids(&mut e, voters);
                encode_ids(&mut e, learners);
            }
            Message::SnapChunk { term, leader, xfer_id, offset, data } => {
                e.u8(9).u64(*term).u64(*leader).u64(*xfer_id).u64(*offset).len_bytes(data);
            }
            Message::SnapAck { term, xfer_id, offset, done } => {
                e.u8(10).u64(*term).u64(*xfer_id).u64(*offset).u8(*done as u8);
            }
            Message::TimeoutNow { term } => {
                e.u8(11).u64(*term);
            }
        }
        e.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Decoder::new(buf);
        let tag = d.u8()?;
        Ok(match tag {
            0 => Message::RequestVote {
                term: d.u64()?,
                candidate: d.u64()?,
                last_log_index: d.u64()?,
                last_log_term: d.u64()?,
                transfer: d.u8()? != 0,
            },
            1 => Message::RequestVoteResp { term: d.u64()?, granted: d.u8()? != 0 },
            2 => {
                let term = d.u64()?;
                let leader = d.u64()?;
                let prev_log_index = d.u64()?;
                let prev_log_term = d.u64()?;
                let leader_commit = d.u64()?;
                let seq = d.u64()?;
                let n = d.varint()? as usize;
                // Cap the preallocation: a corrupt count must fail on
                // decode underflow, not abort on a huge reservation.
                let mut entries = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    entries.push(LogEntry::decode(&mut d)?);
                }
                Message::AppendEntries {
                    term,
                    leader,
                    prev_log_index,
                    prev_log_term,
                    entries,
                    leader_commit,
                    seq,
                }
            }
            3 => Message::AppendEntriesResp {
                term: d.u64()?,
                success: d.u8()? != 0,
                match_index: d.u64()?,
                seq: d.u64()?,
            },
            4 => Message::InstallSnapshot {
                term: d.u64()?,
                leader: d.u64()?,
                last_index: d.u64()?,
                last_term: d.u64()?,
                data: d.len_bytes()?.to_vec(),
                voters: decode_ids(&mut d)?,
                learners: decode_ids(&mut d)?,
            },
            5 => Message::InstallSnapshotResp { term: d.u64()?, last_index: d.u64()? },
            6 => Message::ReadIndex { term: d.u64()?, ctx: d.u64()? },
            7 => Message::ReadIndexResp {
                term: d.u64()?,
                ctx: d.u64()?,
                read_index: d.u64()?,
                ok: d.u8()? != 0,
            },
            8 => Message::SnapMeta {
                term: d.u64()?,
                leader: d.u64()?,
                xfer_id: d.u64()?,
                last_index: d.u64()?,
                last_term: d.u64()?,
                manifest: d.len_bytes()?.to_vec(),
                voters: decode_ids(&mut d)?,
                learners: decode_ids(&mut d)?,
            },
            9 => Message::SnapChunk {
                term: d.u64()?,
                leader: d.u64()?,
                xfer_id: d.u64()?,
                offset: d.u64()?,
                data: d.len_bytes()?.to_vec(),
            },
            10 => Message::SnapAck {
                term: d.u64()?,
                xfer_id: d.u64()?,
                offset: d.u64()?,
                done: d.u8()? != 0,
            },
            11 => Message::TimeoutNow { term: d.u64()? },
            other => bail!("rpc: unknown message tag {other}"),
        })
    }

    /// True for messages that carry snapshot-transfer payload —
    /// attributed to `WireStats::snap_bytes` so fig4/fig5 wire lines
    /// don't count catch-up traffic as steady-state replication.
    pub fn is_snapshot_xfer(&self) -> bool {
        matches!(
            self,
            Message::InstallSnapshot { .. } | Message::SnapMeta { .. } | Message::SnapChunk { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(m: &Message) {
        let enc = m.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(&dec, m);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(&Message::RequestVote {
            term: 5,
            candidate: 2,
            last_log_index: 10,
            last_log_term: 4,
            transfer: false,
        });
        roundtrip(&Message::RequestVote {
            term: 6,
            candidate: 3,
            last_log_index: 11,
            last_log_term: 5,
            transfer: true,
        });
        roundtrip(&Message::RequestVoteResp { term: 5, granted: true });
        roundtrip(&Message::AppendEntries {
            term: 7,
            leader: 1,
            prev_log_index: 3,
            prev_log_term: 2,
            entries: vec![
                LogEntry {
                    term: 7,
                    index: 4,
                    cmd: Command::Put { key: b"k".to_vec(), value: vec![9; 100] },
                },
                LogEntry { term: 7, index: 5, cmd: Command::Delete { key: b"d".to_vec() } },
                LogEntry { term: 7, index: 6, cmd: Command::Noop },
                LogEntry {
                    term: 7,
                    index: 7,
                    cmd: Command::ConfChange(ConfChange::AddLearner(4)),
                },
                LogEntry { term: 7, index: 8, cmd: Command::ConfChange(ConfChange::Promote(4)) },
                LogEntry { term: 7, index: 9, cmd: Command::ConfChange(ConfChange::Remove(2)) },
            ],
            leader_commit: 3,
            seq: 11,
        });
        roundtrip(&Message::AppendEntriesResp { term: 7, success: false, match_index: 2, seq: 11 });
        roundtrip(&Message::InstallSnapshot {
            term: 9,
            leader: 3,
            last_index: 100,
            last_term: 8,
            data: vec![1, 2, 3],
            voters: vec![1, 2, 3],
            learners: vec![4],
        });
        roundtrip(&Message::InstallSnapshotResp { term: 9, last_index: 100 });
        roundtrip(&Message::SnapMeta {
            term: 9,
            leader: 3,
            xfer_id: 42,
            last_index: 100,
            last_term: 8,
            manifest: vec![7; 64],
            voters: vec![1, 2, 3, 4],
            learners: vec![],
        });
        roundtrip(&Message::SnapChunk {
            term: 9,
            leader: 3,
            xfer_id: 42,
            offset: 65536,
            data: vec![0xab; 1000],
        });
        roundtrip(&Message::SnapAck { term: 9, xfer_id: 42, offset: 66536, done: false });
        roundtrip(&Message::SnapAck { term: 9, xfer_id: 42, offset: u64::MAX, done: true });
        roundtrip(&Message::ReadIndex { term: 4, ctx: 77 });
        roundtrip(&Message::ReadIndexResp { term: 4, ctx: 77, read_index: 1234, ok: true });
        roundtrip(&Message::ReadIndexResp { term: 5, ctx: 0, read_index: 0, ok: false });
        roundtrip(&Message::TimeoutNow { term: 12 });
    }

    fn random_cmd(g: &mut prop::Gen) -> Command {
        match g.usize_in(0..6) {
            0 | 1 => Command::Put { key: g.bytes(0..20), value: g.bytes(0..200) },
            2 | 3 => Command::Delete { key: g.bytes(0..20) },
            4 => Command::Noop,
            _ => Command::ConfChange(match g.usize_in(0..3) {
                0 => ConfChange::AddLearner(g.u64()),
                1 => ConfChange::Promote(g.u64()),
                _ => ConfChange::Remove(g.u64()),
            }),
        }
    }

    fn random_ids(g: &mut prop::Gen) -> Vec<u64> {
        g.vec(0..6, |g| g.u64_in(1..32))
    }

    /// Draw a random instance of *every* message variant — keep the
    /// range in sync with the variant count so new messages can't be
    /// silently skipped.
    fn random_message(g: &mut prop::Gen) -> Message {
        match g.usize_in(0..12) {
            0 => Message::RequestVote {
                term: g.u64(),
                candidate: g.u64_in(0..8),
                last_log_index: g.u64(),
                last_log_term: g.u64(),
                transfer: g.bool(),
            },
            1 => Message::RequestVoteResp { term: g.u64(), granted: g.bool() },
            2 => Message::AppendEntries {
                term: g.u64(),
                leader: g.u64_in(0..8),
                prev_log_index: g.u64(),
                prev_log_term: g.u64(),
                entries: g.vec(0..5, |g| LogEntry {
                    term: g.u64(),
                    index: g.u64(),
                    cmd: random_cmd(g),
                }),
                leader_commit: g.u64(),
                seq: g.u64(),
            },
            3 => Message::AppendEntriesResp {
                term: g.u64(),
                success: g.bool(),
                match_index: g.u64(),
                seq: g.u64(),
            },
            4 => Message::InstallSnapshot {
                term: g.u64(),
                leader: g.u64_in(0..8),
                last_index: g.u64(),
                last_term: g.u64(),
                data: g.bytes(0..500),
                voters: random_ids(g),
                learners: random_ids(g),
            },
            5 => Message::InstallSnapshotResp { term: g.u64(), last_index: g.u64() },
            6 => Message::ReadIndex { term: g.u64(), ctx: g.u64() },
            7 => Message::ReadIndexResp {
                term: g.u64(),
                ctx: g.u64(),
                read_index: g.u64(),
                ok: g.bool(),
            },
            8 => Message::SnapMeta {
                term: g.u64(),
                leader: g.u64_in(0..8),
                xfer_id: g.u64(),
                last_index: g.u64(),
                last_term: g.u64(),
                manifest: g.bytes(0..300),
                voters: random_ids(g),
                learners: random_ids(g),
            },
            9 => Message::SnapChunk {
                term: g.u64(),
                leader: g.u64_in(0..8),
                xfer_id: g.u64(),
                offset: g.u64(),
                data: g.bytes(0..500),
            },
            10 => Message::SnapAck {
                term: g.u64(),
                xfer_id: g.u64(),
                offset: g.u64(),
                done: g.bool(),
            },
            _ => Message::TimeoutNow { term: g.u64() },
        }
    }

    #[test]
    fn random_messages_roundtrip() {
        prop::check("rpc-roundtrip", 400, |g| {
            let m = random_message(g);
            let dec = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            if dec != m {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    /// Mangled frames — truncations and single-bit flips of valid
    /// encodings — must decode to `Err` or to some other message, never
    /// panic or abort (the transport feeds decode() raw network bytes).
    #[test]
    fn mangled_frames_never_panic() {
        prop::check("rpc-mangled", 400, |g| {
            let enc = random_message(g).encode();
            // Truncate at a random boundary (including empty).
            let cut = g.usize_in(0..enc.len() + 1);
            let _ = Message::decode(&enc[..cut]);
            // Flip a single random bit.
            if !enc.is_empty() {
                let mut flipped = enc.clone();
                let byte = g.usize_in(0..flipped.len());
                flipped[byte] ^= 1 << g.usize_in(0..8);
                let _ = Message::decode(&flipped);
            }
            Ok(())
        });
    }

    #[test]
    fn garbage_rejected() {
        assert!(Message::decode(&[99, 1, 2]).is_err());
        assert!(Message::decode(&[]).is_err());
        // Corrupt member-list count on an otherwise valid snapshot
        // frame: bounded failure, not a huge preallocation.
        let mut e = Encoder::new();
        e.u8(4).u64(1).u64(1).u64(10).u64(1).len_bytes(b"");
        e.varint(u32::MAX as u64); // absurd voter count
        assert!(Message::decode(e.as_slice()).is_err());
    }

    #[test]
    fn conf_change_roundtrip() {
        for cc in [ConfChange::AddLearner(9), ConfChange::Promote(9), ConfChange::Remove(1)] {
            assert_eq!(ConfChange::decode_bytes(&cc.encode()).unwrap(), cc);
            assert_eq!(cc.node(), if cc == ConfChange::Remove(1) { 1 } else { 9 });
        }
        assert!(ConfChange::decode_bytes(&[3, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
        assert!(ConfChange::decode_bytes(&[0, 1]).is_err());
    }
}
