//! The Raft node state machine (leader election, log replication,
//! commit, apply, snapshot install) — deterministic and message-
//! driven: `tick()` advances logical time, `handle()` processes one
//! inbound message, and both return the outbound messages to send.
//! The transport/cluster layers own threads and clocks; this module
//! owns correctness.

use super::log::{HardState, RaftLog};
use super::rpc::{Command, ConfChange, LogEntry, LogIndex, Message, Term};
use super::snap::{SnapManifest, SnapPlan, SnapSender};
use crate::util::Rng;
use crate::vlog::VRef;
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub type NodeId = u64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// What a Raft node drives: the storage engine's apply/snapshot hooks.
/// `apply` receives the ValueLog offset of the entry — Nezha's state
/// machines store it; baselines ignore it and re-persist the value.
pub trait StateMachine: Send {
    fn apply(&mut self, entry: &LogEntry, vref: VRef) -> Result<()>;
    /// Serialize current state for follower catch-up.
    fn snapshot_bytes(&mut self) -> Result<Vec<u8>>;
    /// Replace state with a received snapshot.
    fn install_snapshot(
        &mut self,
        data: &[u8],
        last_index: LogIndex,
        last_term: Term,
    ) -> Result<()>;
    /// Conflict resolution truncated (and will rewrite) the log suffix;
    /// epoch files `>= live_epoch` changed in place.  Engines that
    /// cache ValueLog bytes must drop cached state for those epochs —
    /// the rewritten entries were never committed, so applied state is
    /// unaffected.  Default: nothing cached, nothing to do.
    fn on_log_truncated(&mut self, _live_epoch: u32) {}

    // -- streamed snapshot hooks (DESIGN.md §8) ----------------------
    // The defaults keep byte-blob engines (Classic/Dwisckey, test
    // doubles) on the legacy monolithic `InstallSnapshot` path; Nezha
    // overrides all six to ship sealed GC runs as files.

    /// Sender: enumerate a run-shipping snapshot plan covering applied
    /// state at `last_index`/`last_term`.  The engine must keep every
    /// file named in the plan alive (pinned against GC deletion) until
    /// [`Self::snap_stream_end`] releases the plan's id.  `Ok(None)`
    /// means "no streaming support" — raft falls back to
    /// [`Self::snapshot_bytes`].
    fn snap_stream_begin(
        &mut self,
        _last_index: LogIndex,
        _last_term: Term,
    ) -> Result<Option<SnapPlan>> {
        Ok(None)
    }

    /// Sender: the transfer for `plan_id` finished or was abandoned —
    /// release its pinned files.
    fn snap_stream_end(&mut self, _plan_id: u64) {}

    /// Receiver: open (or re-open) a staging area for `manifest` and
    /// return the resume offset — how many bytes of the transfer's
    /// global stream are already staged durably.  Erroring refuses the
    /// stream (the sender falls back to the monolithic path).
    fn snap_sink_begin(&mut self, _manifest: &SnapManifest) -> Result<u64> {
        bail!("engine does not support streamed snapshot install")
    }

    /// Receiver: append chunk bytes at global `offset` (always equal
    /// to the current staged length — the node reorders/dedups).
    fn snap_sink_write(&mut self, _offset: u64, _data: &[u8]) -> Result<()> {
        bail!("engine does not support streamed snapshot install")
    }

    /// Receiver: every byte staged — verify CRCs and atomically cut
    /// over to the shipped state.  On error the staging area is
    /// discarded and the transfer restarts from scratch.
    fn snap_sink_commit(&mut self, _last_index: LogIndex, _last_term: Term) -> Result<()> {
        bail!("engine does not support streamed snapshot install")
    }

    /// Receiver: drop in-memory sink state.  Staged bytes on disk are
    /// kept — they are the resume point if the same transfer is
    /// re-offered (a mismatched manifest wipes them at the next
    /// [`Self::snap_sink_begin`]).
    fn snap_sink_abort(&mut self) {}
}

/// Tunables (times in ticks; the cluster maps ticks to wall time).
#[derive(Clone, Debug)]
pub struct Config {
    pub election_timeout_min: u64,
    pub election_timeout_max: u64,
    pub heartbeat_interval: u64,
    /// Max payload bytes per AppendEntries.
    pub max_batch_bytes: usize,
    /// In-memory log tail kept after apply (for slow followers).
    pub mem_keep_tail: u64,
    /// fsync the log at persistence points (tests: on; benches choose
    /// one policy for all baselines).
    pub fsync: bool,
    /// Lease fast path for linearizable reads: a leader whose last
    /// heartbeat round was quorum-acked within 3/4 of
    /// `election_timeout_min` serves read barriers without a fresh
    /// quorum round (steady state: zero extra RPCs per read).  Off =
    /// every ReadIndex pays a heartbeat quorum round.
    pub lease_reads: bool,
    /// Group-commit latency budget in µs (0 = off).  When set, a
    /// leader's [`Node::replicate`] broadcasts AppendEntries *without*
    /// waiting for the local log sync; the runtime calls
    /// [`Node::flush_group_commit`] once the budget lapses, so one
    /// sync covers every entry appended inside the window.  Commit
    /// still requires a quorum of *durable* copies: the leader's own
    /// entries only join the commit quorum (via `durable_index`) after
    /// the flush — Raft safety unchanged (DESIGN.md §6).
    pub group_commit_us: u64,
    /// Stream snapshots as chunked sealed-run files when the engine
    /// supports it (DESIGN.md §8); off = always the monolithic
    /// `InstallSnapshot` blob.
    pub snap_streaming: bool,
    /// Max payload bytes per `SnapChunk`.
    pub snap_chunk_bytes: usize,
    /// In-flight chunk window per catch-up transfer — bounds how much
    /// snapshot traffic can sit on the wire so catch-up never starves
    /// AppendEntries.
    pub snap_window: usize,
    /// Auto-promotion lag: a leader promotes a learner to voter once
    /// its `match_index` is within this many entries of the leader's
    /// last index (DESIGN.md §9).  0 = auto-promotion off (operators
    /// promote by proposing `ConfChange::Promote` themselves).
    pub promote_lag: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            election_timeout_min: 20,
            election_timeout_max: 40,
            heartbeat_interval: 5,
            max_batch_bytes: 1 << 20,
            mem_keep_tail: 1024,
            fsync: false,
            lease_reads: true,
            group_commit_us: 0,
            snap_streaming: true,
            snap_chunk_bytes: 256 << 10,
            snap_window: 4,
            promote_lag: 64,
        }
    }
}

/// Outbound message with destination.
pub type Outbox = Vec<(NodeId, Message)>;

/// Counters for the bench harness.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeMetrics {
    pub msgs_sent: u64,
    pub elections_started: u64,
    pub snapshots_sent: u64,
    pub snapshots_installed: u64,
    pub entries_applied: u64,
    /// Read barriers resolved off the leader lease (no quorum round).
    pub lease_reads: u64,
    /// Read barriers that paid a heartbeat quorum round.
    pub read_index_rounds: u64,
    /// Log persistence barriers (fsync when [`Config::fsync`], else a
    /// buffered flush).
    pub log_syncs: u64,
    /// Entries whose commit this node observed (leader quorum advance
    /// or follower `leader_commit` catch-up).
    pub entries_committed: u64,
    /// Group-commit flushes that covered at least one entry.
    pub group_commit_batches: u64,
    /// Entries covered by those flushes (sum; mean batch size is
    /// `group_commit_entries / group_commit_batches`).
    pub group_commit_entries: u64,
    /// Largest single group-commit batch.
    pub group_commit_max_batch: u64,
    /// Streamed snapshot chunks put on the wire (sender side).
    pub snap_chunks_sent: u64,
    /// Payload bytes inside those chunks.
    pub snap_bytes_sent: u64,
    /// Streamed snapshot chunks accepted (receiver side).
    pub snap_chunks_recv: u64,
    /// Transfers that re-entered mid-stream (resume offset > 0).
    pub snap_resumes: u64,
    /// Streamed transfers completed (committed at the receiver /
    /// done-acked at the sender).
    pub snap_streams_done: u64,
}

/// Hand-off queue between a replica's consensus loop and its dedicated
/// applier task (DESIGN.md §6): with a lane attached, committed
/// entries are queued here instead of being applied inline, so
/// post-commit value resolution never blocks the consensus state
/// machine.  The queue holds entry *clones*, so the raft log may
/// compact applied-but-unresolved entries out of memory safely.
pub struct ApplyLane {
    q: Mutex<VecDeque<(LogIndex, LogEntry, VRef)>>,
    /// Highest index the applier has fully applied to the engine —
    /// what ReadLane barriers and GC backlog accounting see.
    applied: AtomicU64,
    /// High-water mark of the queue depth (observability).
    depth_max: AtomicU64,
    /// Bumped by [`ApplyLane::begin_install`]; the applier discards
    /// in-flight entries tagged with a stale generation (a snapshot
    /// install already covers them).
    generation: AtomicU64,
    closed: AtomicBool,
    /// With `closed`: drop queued work instead of draining it
    /// (crash-style shutdown).
    discard: AtomicBool,
    waker: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
}

impl ApplyLane {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            q: Mutex::new(VecDeque::new()),
            applied: AtomicU64::new(0),
            depth_max: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            discard: AtomicBool::new(false),
            waker: Mutex::new(None),
        })
    }

    /// Doorbell rung (outside the queue lock) whenever work arrives or
    /// the lane closes — the applier task's reactor wake.
    pub fn set_waker(&self, w: Box<dyn Fn() + Send + Sync>) {
        *self.waker.lock().unwrap() = Some(w);
    }

    fn ring(&self) {
        if let Some(w) = self.waker.lock().unwrap().as_ref() {
            w();
        }
    }

    fn push(&self, idx: LogIndex, entry: LogEntry, vref: VRef) {
        {
            let mut q = self.q.lock().unwrap();
            q.push_back((idx, entry, vref));
            let d = q.len() as u64;
            self.depth_max.fetch_max(d, Ordering::Relaxed);
        }
        self.ring();
    }

    pub fn applied(&self) -> LogIndex {
        self.applied.load(Ordering::Acquire)
    }

    /// Applier side: publish progress after each entry lands in the
    /// engine (and by the install path after a snapshot).
    pub fn set_applied(&self, idx: LogIndex) {
        self.applied.store(idx, Ordering::Release);
    }

    /// Entries queued right now.
    pub fn depth(&self) -> u64 {
        self.q.lock().unwrap().len() as u64
    }

    pub fn depth_max(&self) -> u64 {
        self.depth_max.load(Ordering::Relaxed)
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Snapshot install supersedes everything queued: clear the queue
    /// and invalidate chunks already popped by the applier.  The
    /// caller then installs into the engine and publishes the new
    /// cursor via [`ApplyLane::set_applied`].
    pub fn begin_install(&self) {
        let mut q = self.q.lock().unwrap();
        q.clear();
        self.generation.fetch_add(1, Ordering::AcqRel);
    }

    /// Applier side: pop up to `max` entries, tagged with the
    /// generation they were popped under (re-check it per entry, under
    /// the engine lock, and discard the rest on mismatch).  `None`
    /// means the lane is closed and — unless discarding — drained:
    /// the applier should exit.
    pub fn pop_chunk(&self, max: usize) -> Option<(u64, Vec<(LogIndex, LogEntry, VRef)>)> {
        let mut q = self.q.lock().unwrap();
        if self.discard.load(Ordering::Acquire) {
            q.clear();
            return None;
        }
        if q.is_empty() && self.closed.load(Ordering::Acquire) {
            return None;
        }
        let g = self.generation.load(Ordering::Acquire);
        let n = q.len().min(max);
        Some((g, q.drain(..n).collect()))
    }

    /// Graceful close: the applier drains what is queued, then exits.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.ring();
    }

    /// Crash-style close: queued work is dropped, the applier exits
    /// immediately (the entries are committed and will re-apply from
    /// the log on restart).
    pub fn close_discard(&self) {
        self.discard.store(true, Ordering::Release);
        self.closed.store(true, Ordering::Release);
        self.ring();
    }
}

/// A read barrier parked on the leader until a heartbeat quorum round
/// (issued at `seq`) confirms this node still leads its term.
struct PendingConfirm {
    ctx: u64,
    /// `None`: this node's own read lane asked; `Some(n)`: node `n`
    /// sent a [`Message::ReadIndex`] and gets the resp on completion.
    requester: Option<NodeId>,
    /// Acks count only for heartbeat rounds at or above this.
    seq: u64,
    /// Lease-clock instant the barrier was registered (for pruning).
    issued_at: u64,
}

/// Receiver-side bookkeeping for the in-progress streamed snapshot.
/// Deliberately tiny: the staged bytes live in the engine's staging
/// directory, never in memory (DESIGN.md §8).
struct SnapSink {
    xfer_id: u64,
    /// Next global offset the sink wants (cumulative-ack cursor).
    expected: u64,
    total_len: u64,
    last_index: LogIndex,
    last_term: Term,
    /// Membership carried by the `SnapMeta` offer, adopted at commit
    /// (the snapshot may have compacted away the config entries).
    voters: Vec<NodeId>,
    learners: Vec<NodeId>,
}

/// One version of the membership config, tagged with the log index of
/// the `ConfChange` that created it (the baseline carries the index it
/// was loaded at).  Kept so conflict truncation can roll the active
/// config back to what preceded the cut (DESIGN.md §9).
#[derive(Clone, Debug)]
struct ConfVersion {
    index: LogIndex,
    voters: Vec<NodeId>,
    learners: Vec<NodeId>,
}

/// Durable members sidecar (`<raft dir>/members`): the active config
/// and the log index it reflects, so a restarted node recovers its
/// membership even when the config entries were compacted into a
/// snapshot.
fn save_members(path: &Path, index: LogIndex, voters: &[NodeId], learners: &[NodeId]) -> Result<()> {
    let mut body = crate::util::Encoder::with_capacity(24 + 8 * (voters.len() + learners.len()));
    body.u64(index);
    body.varint(voters.len() as u64);
    for &v in voters {
        body.u64(v);
    }
    body.varint(learners.len() as u64);
    for &l in learners {
        body.u64(l);
    }
    let mut e = crate::util::Encoder::with_capacity(body.len() + 4);
    e.u32(crc32fast::hash(body.as_slice()));
    e.bytes(body.as_slice());
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, e.as_slice())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[allow(clippy::type_complexity)]
fn load_members(path: &Path) -> Result<Option<(LogIndex, Vec<NodeId>, Vec<NodeId>)>> {
    let buf = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut d = crate::util::Decoder::new(&buf);
    let crc = d.u32()?;
    let body = d.bytes(d.remaining())?;
    if crc32fast::hash(body) != crc {
        bail!("members sidecar crc mismatch");
    }
    let mut d = crate::util::Decoder::new(body);
    let index = d.u64()?;
    let nv = d.varint()? as usize;
    let mut voters = Vec::with_capacity(nv.min(1024));
    for _ in 0..nv {
        voters.push(d.u64()?);
    }
    let nl = d.varint()? as usize;
    let mut learners = Vec::with_capacity(nl.min(1024));
    for _ in 0..nl {
        learners.push(d.u64()?);
    }
    Ok(Some((index, voters, learners)))
}

pub struct Node<S: StateMachine> {
    pub id: NodeId,
    /// Replication targets: every other member of the active config
    /// (voters and learners alike).  Derived from `voters`/`learners`.
    peers: Vec<NodeId>,
    /// Voting members of the active config (includes `id` when this
    /// node is a voter).  Effective at *append* of a `ConfChange`
    /// entry — the single-server-change rule (DESIGN.md §9).
    voters: Vec<NodeId>,
    /// Non-voting members: replicated to, never counted in any quorum,
    /// never campaign.
    learners: Vec<NodeId>,
    /// Config versions newest-last (baseline first) for truncation
    /// rollback and for stamping snapshots with the config at their
    /// last index.
    conf_history: Vec<ConfVersion>,
    /// Log index of the in-flight (appended, uncommitted) ConfChange.
    /// A leader refuses a second change until this one commits.
    conf_pending: Option<LogIndex>,
    members_path: std::path::PathBuf,
    /// Set while handling `TimeoutNow`: the resulting vote requests
    /// carry the transfer flag that bypasses vote stickiness.
    transfer_election: bool,
    /// Deferred outbound messages from commit-driven transitions
    /// (e.g. the `TimeoutNow` a self-removed leader sends when its
    /// removal commits); drained by `tick()`/`handle()`.
    stash: Outbox,
    role: Role,
    hard: HardState,
    hard_path: std::path::PathBuf,
    pub log: RaftLog,
    commit_index: LogIndex,
    last_applied: LogIndex,
    /// Highest log index covered by a local persistence barrier.  The
    /// commit quorum counts this instead of `log.last_index()`, which
    /// is what makes group-commit pipelining safe: appended-but-
    /// unsynced leader entries do not count towards commit until
    /// [`Self::flush_group_commit`] syncs them (followers persist
    /// before acking, so their `match_index` is always durable).
    durable_index: LogIndex,
    /// Attached by the cluster runtime: committed entries hand off
    /// here instead of applying inline (see [`ApplyLane`]).
    lane: Option<Arc<ApplyLane>>,
    // Leader volatile state.
    next_index: HashMap<NodeId, LogIndex>,
    match_index: HashMap<NodeId, LogIndex>,
    votes: usize,
    leader_hint: Option<NodeId>,
    // Streamed snapshot state (DESIGN.md §8).
    /// Leader: one in-flight run-shipping transfer per lagging peer.
    snap_xfers: HashMap<NodeId, SnapSender>,
    /// Peers whose engines refused streaming — monolithic path only.
    snap_legacy: HashSet<NodeId>,
    /// Transfer-id source (made unique across leaders via term + id).
    snap_xfer_seq: u64,
    /// Follower: the transfer currently being staged, if any.
    snap_sink: Option<SnapSink>,
    // Timing (logical ticks).
    ticks: u64,
    election_deadline: u64,
    last_heartbeat: u64,
    /// Tick of the last AppendEntries/InstallSnapshot accepted from a
    /// valid leader (0 = never).  Backs vote stickiness: see
    /// [`Self::handle`].
    last_leader_contact: u64,
    // ReadIndex / lease state (leader side).
    /// Heartbeat round counter; every AppendEntries carries it and the
    /// follower echoes it back.
    hb_seq: u64,
    /// Lease-clock instant each recent heartbeat round was broadcast.
    hb_sent_at: HashMap<u64, u64>,
    /// Highest heartbeat round each peer has acked this term.
    peer_ack: HashMap<NodeId, u64>,
    /// Read barriers awaiting a heartbeat quorum round.
    pending_confirm: Vec<PendingConfirm>,
    /// Lease-clock instant the leader lease expires.
    lease_until: u64,
    /// Monotonic clock for lease accounting.  Advances with every tick
    /// AND by [`Self::skip_ticks`] for wall stalls the election logic
    /// forgives, so a lease can never outlive its wall-clock budget on
    /// a stalled thread (ticks under-count wall time; this must not).
    lease_clock: u64,
    /// Index of the no-op this leader appended on winning its
    /// election: read barriers resolve only once `commit_index` has
    /// reached it (Raft §8 — a new leader's commit index is proven
    /// current only after it commits in its own term).
    term_start_index: LogIndex,
    // ReadIndex state (requester side).
    ready_reads: Vec<(u64, LogIndex)>,
    failed_reads: Vec<u64>,
    rng: Rng,
    cfg: Config,
    sm: S,
    pub metrics: NodeMetrics,
}

impl<S: StateMachine> Node<S> {
    pub fn new(
        id: NodeId,
        peers: Vec<NodeId>,
        dir: &Path,
        sm: S,
        cfg: Config,
        seed: u64,
    ) -> Result<Self> {
        let mut voters: Vec<NodeId> = peers.clone();
        voters.push(id);
        voters.sort_unstable();
        voters.dedup();
        Self::with_conf(id, voters, Vec::new(), dir, sm, cfg, seed)
    }

    /// Open a node that joins as a *non-voting learner* of the config
    /// whose voting members are `voters` (this node is not among
    /// them).  The learner persists that baseline immediately so a
    /// crash before its first config entry still restarts it as a
    /// learner, and never as a self-voting one-node cluster.
    pub fn new_learner(
        id: NodeId,
        voters: Vec<NodeId>,
        dir: &Path,
        sm: S,
        cfg: Config,
        seed: u64,
    ) -> Result<Self> {
        let mut voters = voters;
        voters.retain(|&v| v != id);
        voters.sort_unstable();
        voters.dedup();
        let mut node = Self::with_conf(id, voters, vec![id], dir, sm, cfg, seed)?;
        node.persist_members()?;
        Ok(node)
    }

    fn with_conf(
        id: NodeId,
        voters: Vec<NodeId>,
        learners: Vec<NodeId>,
        dir: &Path,
        sm: S,
        cfg: Config,
        seed: u64,
    ) -> Result<Self> {
        let log = RaftLog::open(dir)?;
        let hard_path = dir.join("hardstate");
        let members_path = dir.join("members");
        let hard = HardState::load(&hard_path)?.unwrap_or_default();
        let mut rng = Rng::new(seed ^ id.wrapping_mul(0x9E37_79B9));
        let election_deadline = Self::rand_deadline(&mut rng, &cfg, 0);
        // Whatever the log recovered from disk is durable by
        // definition.
        let durable_index = log.last_index();
        // The durable members sidecar outranks the constructor args: a
        // restarted node keeps the config it last applied, whatever
        // the coordinator believes today.
        let (base_index, voters, learners) = match load_members(&members_path)? {
            Some((i, v, l)) => (i, v, l),
            None => (0, voters, learners),
        };
        let mut node = Self {
            id,
            peers: Vec::new(),
            voters: voters.clone(),
            learners: learners.clone(),
            conf_history: vec![ConfVersion { index: base_index, voters, learners }],
            conf_pending: None,
            members_path,
            transfer_election: false,
            stash: Vec::new(),
            role: Role::Follower,
            hard,
            hard_path,
            log,
            commit_index: 0,
            last_applied: 0,
            durable_index,
            lane: None,
            next_index: HashMap::new(),
            match_index: HashMap::new(),
            votes: 0,
            leader_hint: None,
            snap_xfers: HashMap::new(),
            snap_legacy: HashSet::new(),
            snap_xfer_seq: 0,
            snap_sink: None,
            ticks: 0,
            election_deadline,
            last_heartbeat: 0,
            last_leader_contact: 0,
            hb_seq: 0,
            hb_sent_at: HashMap::new(),
            peer_ack: HashMap::new(),
            pending_confirm: Vec::new(),
            lease_until: 0,
            lease_clock: 0,
            term_start_index: 0,
            ready_reads: Vec::new(),
            failed_reads: Vec::new(),
            rng,
            cfg,
            sm,
            metrics: NodeMetrics::default(),
        };
        node.rebuild_peers();
        // Re-apply config entries past the sidecar's index (the log
        // replay keeps the whole post-snapshot suffix in memory, so a
        // ConfChange appended after the last sidecar write — or after
        // the baseline — is recovered here).
        let from = node.conf_history[0].index.max(node.log.snap_index) + 1;
        for i in from..=node.log.last_index() {
            if let Some(Command::ConfChange(cc)) =
                node.log.entry(i).map(|e| e.cmd.clone())
            {
                node.apply_conf_at_append(i, cc)?;
            }
        }
        // `conf_pending` only gates leaders; a fresh node is a
        // follower (become_leader recomputes it from the log).
        node.conf_pending = None;
        Ok(node)
    }

    fn rand_deadline(rng: &mut Rng, cfg: &Config, now: u64) -> u64 {
        now + rng.range(cfg.election_timeout_min, cfg.election_timeout_max + 1)
    }

    // ---- observers -------------------------------------------------

    pub fn role(&self) -> Role {
        self.role
    }

    pub fn term(&self) -> Term {
        self.hard.term
    }

    pub fn commit_index(&self) -> LogIndex {
        self.commit_index
    }

    /// Highest index whose effects are visible in the engine.  With an
    /// apply lane attached this is the lane's cursor — entries handed
    /// off but not yet resolved by the applier do *not* count, which
    /// is exactly what ReadLane barriers and GC backlog math need.
    pub fn last_applied(&self) -> LogIndex {
        match &self.lane {
            Some(l) => l.applied(),
            None => self.last_applied,
        }
    }

    /// Route committed entries to `lane` instead of applying them
    /// inline.  Attach right after open, before anything commits; the
    /// lane's cursor starts from the inline cursor so recovery replay
    /// done at open stays accounted for.
    pub fn attach_apply_lane(&mut self, lane: Arc<ApplyLane>) {
        lane.set_applied(self.last_applied);
        self.lane = Some(lane);
    }

    pub fn leader_hint(&self) -> Option<NodeId> {
        if self.role == Role::Leader {
            Some(self.id)
        } else {
            self.leader_hint
        }
    }

    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    /// True when a Leader-consistency read may be served from local
    /// applied state right now: this node is leader *and* — when lease
    /// reads are enabled — its lease is live, so a deposed-but-unaware
    /// leader (classic partitioned-leader shape) cannot hand out stale
    /// state.  With `lease_reads` off this degrades to plain
    /// [`Self::is_leader`], the pre-lease behaviour.
    pub fn can_serve_leader_read(&self) -> bool {
        self.role == Role::Leader && (!self.cfg.lease_reads || self.lease_valid())
    }

    pub fn sm(&self) -> &S {
        &self.sm
    }

    pub fn sm_mut(&mut self) -> &mut S {
        &mut self.sm
    }

    /// The index the engine's snapshot-visible state actually covers:
    /// the lane cursor when a lane is attached (handed-off entries are
    /// NOT covered yet), else the inline cursor.
    fn applied_index(&self) -> LogIndex {
        match &self.lane {
            Some(l) => l.applied(),
            None => self.last_applied,
        }
    }

    /// Majority of the *active voter config* — learners and removed
    /// nodes never count (DESIGN.md §9).
    fn quorum(&self) -> usize {
        self.voters.len() / 2 + 1
    }

    fn is_voter(&self) -> bool {
        self.voters.contains(&self.id)
    }

    pub fn voters(&self) -> &[NodeId] {
        &self.voters
    }

    pub fn learners(&self) -> &[NodeId] {
        &self.learners
    }

    // ---- membership (DESIGN.md §9) ---------------------------------

    fn rebuild_peers(&mut self) {
        let id = self.id;
        let mut peers: Vec<NodeId> = self
            .voters
            .iter()
            .chain(self.learners.iter())
            .copied()
            .filter(|&p| p != id)
            .collect();
        peers.sort_unstable();
        peers.dedup();
        self.peers = peers;
    }

    fn persist_members(&mut self) -> Result<()> {
        let v = self.conf_history.last().expect("baseline config");
        save_members(&self.members_path, v.index, &self.voters, &self.learners)
    }

    /// Install `(voters, learners)` as the active config created at
    /// log `index`, refreshing replication bookkeeping and the durable
    /// sidecar.
    fn install_conf(
        &mut self,
        index: LogIndex,
        voters: Vec<NodeId>,
        learners: Vec<NodeId>,
    ) -> Result<()> {
        self.conf_history.push(ConfVersion {
            index,
            voters: voters.clone(),
            learners: learners.clone(),
        });
        // Bound the history: keep the newest version at-or-below the
        // commit index (the rollback floor) plus everything after it.
        let ci = self.commit_index;
        if self.conf_history.len() > 8 {
            if let Some(floor) =
                self.conf_history.iter().rposition(|v| v.index <= ci).filter(|&f| f > 0)
            {
                self.conf_history.drain(..floor);
            }
        }
        self.voters = voters;
        self.learners = learners;
        self.rebuild_peers();
        // Leader bookkeeping: track new peers, drop departed ones (a
        // dropped snapshot transfer must release its engine pin).
        let last = self.log.last_index();
        for p in self.peers.clone() {
            self.next_index.entry(p).or_insert(last + 1);
            self.match_index.entry(p).or_insert(0);
        }
        let peers = self.peers.clone();
        self.next_index.retain(|p, _| peers.contains(p));
        self.match_index.retain(|p, _| peers.contains(p));
        self.peer_ack.retain(|p, _| peers.contains(p));
        self.snap_legacy.retain(|p| peers.contains(p));
        let dropped: Vec<NodeId> =
            self.snap_xfers.keys().copied().filter(|p| !peers.contains(p)).collect();
        for p in dropped {
            if let Some(s) = self.snap_xfers.remove(&p) {
                self.sm.snap_stream_end(s.plan_id());
            }
        }
        self.persist_members()
    }

    /// Apply a ConfChange the moment its entry lands in the log —
    /// append-time activation is what makes overlapping single-server
    /// changes impossible (DESIGN.md §9).
    fn apply_conf_at_append(&mut self, index: LogIndex, cc: ConfChange) -> Result<()> {
        let mut voters = self.voters.clone();
        let mut learners = self.learners.clone();
        match cc {
            ConfChange::AddLearner(n) => {
                if !voters.contains(&n) && !learners.contains(&n) {
                    learners.push(n);
                    learners.sort_unstable();
                }
            }
            ConfChange::Promote(n) => {
                learners.retain(|&l| l != n);
                if !voters.contains(&n) {
                    voters.push(n);
                    voters.sort_unstable();
                }
            }
            ConfChange::Remove(n) => {
                voters.retain(|&v| v != n);
                learners.retain(|&l| l != n);
            }
        }
        self.install_conf(index, voters, learners)?;
        self.conf_pending = Some(index);
        Ok(())
    }

    /// Conflict truncation cut the log at `from`: roll the active
    /// config back to the newest version that precedes the cut.
    fn rollback_conf(&mut self, from: LogIndex) -> Result<()> {
        if self.conf_history.last().map_or(true, |v| v.index < from) {
            return Ok(());
        }
        while self.conf_history.len() > 1
            && self.conf_history.last().map_or(false, |v| v.index >= from)
        {
            self.conf_history.pop();
        }
        let v = self.conf_history.last().expect("baseline config").clone();
        self.voters = v.voters;
        self.learners = v.learners;
        self.rebuild_peers();
        if self.conf_pending.is_some_and(|i| i >= from) {
            self.conf_pending = None;
        }
        self.persist_members()
    }

    /// Membership as of log `index` (best effort: falls back to the
    /// oldest known version when `index` predates the history) — used
    /// to stamp outgoing snapshots.
    fn conf_at(&self, index: LogIndex) -> (Vec<NodeId>, Vec<NodeId>) {
        let v = self
            .conf_history
            .iter()
            .rev()
            .find(|v| v.index <= index)
            .or_else(|| self.conf_history.first())
            .expect("baseline config");
        (v.voters.clone(), v.learners.clone())
    }

    /// Adopt the membership a snapshot carried (both the monolithic
    /// and streamed install paths): the snapshot replaces the log up
    /// to `last_index`, so its config replaces ours.
    fn adopt_snapshot_conf(
        &mut self,
        last_index: LogIndex,
        voters: Vec<NodeId>,
        learners: Vec<NodeId>,
    ) -> Result<()> {
        if voters.is_empty() {
            return Ok(()); // sender predates membership stamping
        }
        if voters == self.voters && learners == self.learners {
            return Ok(());
        }
        self.install_conf(last_index, voters, learners)?;
        self.conf_pending = None;
        Ok(())
    }

    /// Leader: start a membership change.  Refused while another
    /// change is in flight — the single-server-change rule only holds
    /// if changes are serialized through commit.
    pub fn propose_conf(&mut self, cc: ConfChange) -> Result<LogIndex> {
        if self.role != Role::Leader {
            bail!("not leader (hint: {:?})", self.leader_hint());
        }
        if let Some(i) = self.conf_pending {
            bail!("conf change in flight at index {i}");
        }
        match cc {
            ConfChange::AddLearner(n) => {
                if self.voters.contains(&n) || self.learners.contains(&n) {
                    bail!("node {n} is already a member");
                }
            }
            ConfChange::Promote(n) => {
                if self.voters.contains(&n) {
                    bail!("node {n} is already a voter");
                }
                if !self.learners.contains(&n) {
                    bail!("node {n} is not a learner");
                }
            }
            ConfChange::Remove(n) => {
                if !self.voters.contains(&n) && !self.learners.contains(&n) {
                    bail!("node {n} is not a member");
                }
            }
        }
        let index = self.log.last_index() + 1;
        self.log.append(LogEntry {
            term: self.hard.term,
            index,
            cmd: Command::ConfChange(cc),
        })?;
        // A config entry may be appended outside the client write path
        // (auto-promotion), so make it durable here: commit counts the
        // leader's durable_index, and a 2-voter cluster would otherwise
        // wait on an unrelated write to sync it.
        self.persist_log()?;
        self.apply_conf_at_append(index, cc)?;
        Ok(index)
    }

    /// Leader: promote a caught-up learner (called on append/snap-done
    /// acks).  No-op unless `from` is a learner within
    /// [`Config::promote_lag`] of the log head and no change is in
    /// flight.
    fn maybe_promote(&mut self, from: NodeId) -> Result<()> {
        if self.role != Role::Leader
            || self.cfg.promote_lag == 0
            || self.conf_pending.is_some()
            || !self.learners.contains(&from)
        {
            return Ok(());
        }
        let m = self.match_index.get(&from).copied().unwrap_or(0);
        if m.saturating_add(self.cfg.promote_lag) >= self.log.last_index() {
            self.propose_conf(ConfChange::Promote(from))?;
        }
        Ok(())
    }

    /// Commit-index movement hook: clears the in-flight change once it
    /// commits, and finishes a leader's self-removal — hand leadership
    /// to the best-caught-up voter (§4.2.3) and step down.
    fn on_commit_advanced(&mut self) -> Result<()> {
        if self.conf_pending.is_some_and(|i| i <= self.commit_index) {
            self.conf_pending = None;
        }
        if self.role == Role::Leader && !self.is_voter() && self.conf_pending.is_none() {
            let target = self
                .voters
                .iter()
                .copied()
                .max_by_key(|v| self.match_index.get(v).copied().unwrap_or(0));
            if let Some(t) = target {
                self.metrics.msgs_sent += 1;
                self.stash.push((t, Message::TimeoutNow { term: self.hard.term }));
            }
            self.become_follower(self.hard.term, None)?;
            self.leader_hint = None;
        }
        Ok(())
    }

    fn take_stash(&mut self) -> Outbox {
        std::mem::take(&mut self.stash)
    }

    // ---- persistence helpers ---------------------------------------

    fn persist_hard(&mut self) -> Result<()> {
        self.hard.save(&self.hard_path)
    }

    fn persist_log(&mut self) -> Result<()> {
        if self.cfg.fsync {
            self.log.sync()?;
        } else {
            self.log.flush()?;
        }
        self.durable_index = self.log.last_index();
        self.metrics.log_syncs += 1;
        Ok(())
    }

    // ---- time ------------------------------------------------------

    /// Advance one logical tick.
    pub fn tick(&mut self) -> Result<Outbox> {
        self.ticks += 1;
        self.lease_clock += 1;
        let mut out = self.tick_inner()?;
        out.extend(self.take_stash());
        Ok(out)
    }

    fn tick_inner(&mut self) -> Result<Outbox> {
        match self.role {
            Role::Leader => {
                // Abandon read barriers whose quorum round never
                // completed (partitioned majority): the requester's
                // lane times out and retries; local ctxs fail fast.
                if !self.pending_confirm.is_empty() {
                    let horizon = self.cfg.election_timeout_max * 2;
                    let now = self.lease_clock;
                    let failed = &mut self.failed_reads;
                    self.pending_confirm.retain(|pc| {
                        if now.saturating_sub(pc.issued_at) > horizon {
                            if pc.requester.is_none() {
                                failed.push(pc.ctx);
                            }
                            false
                        } else {
                            true
                        }
                    });
                }
                if self.ticks - self.last_heartbeat >= self.cfg.heartbeat_interval {
                    return self.broadcast_append();
                }
                Ok(Vec::new())
            }
            Role::Follower | Role::Candidate => {
                if self.ticks >= self.election_deadline {
                    return self.start_election();
                }
                Ok(Vec::new())
            }
        }
    }

    /// Account for wall time the caller's tick loop *forgave* (a
    /// stalled thread ticks at most a couple of times per loop so a
    /// storage stall doesn't read as a dead leader).  Election logic
    /// must not see these ticks, but the lease clock MUST: a lease
    /// measured against an under-counting clock would stretch its wall
    /// duration past the followers' election timeout and break the
    /// no-other-leader guarantee.
    pub fn skip_ticks(&mut self, skipped: u64) {
        self.lease_clock += skipped;
    }

    fn reset_election_timer(&mut self) {
        self.election_deadline = Self::rand_deadline(&mut self.rng, &self.cfg, self.ticks);
    }

    // ---- elections ---------------------------------------------------

    fn start_election(&mut self) -> Result<Outbox> {
        // Learners (and removed nodes) never campaign: their vote
        // would not count and their term bumps would only disrupt the
        // voters (DESIGN.md §9).
        if !self.is_voter() {
            self.reset_election_timer();
            return Ok(Vec::new());
        }
        self.role = Role::Candidate;
        self.hard.term += 1;
        self.hard.voted_for = Some(self.id);
        self.persist_hard()?;
        self.votes = 1;
        self.reset_election_timer();
        self.metrics.elections_started += 1;
        if self.votes >= self.quorum() {
            // Single-node cluster: win immediately.
            return self.become_leader();
        }
        let msg = Message::RequestVote {
            term: self.hard.term,
            candidate: self.id,
            last_log_index: self.log.last_index(),
            last_log_term: self.log.last_term(),
            transfer: self.transfer_election,
        };
        // Votes come only from voters; learners don't get the RPC.
        let targets: Vec<NodeId> =
            self.voters.iter().copied().filter(|&v| v != self.id).collect();
        self.metrics.msgs_sent += targets.len() as u64;
        Ok(targets.into_iter().map(|p| (p, msg.clone())).collect())
    }

    fn become_follower(&mut self, term: Term, leader: Option<NodeId>) -> Result<()> {
        if self.role == Role::Leader {
            // Deposed: the lease and every parked read barrier die with
            // the leadership.  Remote requesters time out and retry
            // against the new leader; local ctxs fail fast.
            self.lease_until = 0;
            self.peer_ack.clear();
            self.hb_sent_at.clear();
            for pc in self.pending_confirm.drain(..) {
                if pc.requester.is_none() {
                    self.failed_reads.push(pc.ctx);
                }
            }
            // In-flight catch-up transfers die with the leadership;
            // release the engine's run pins.  The new leader re-offers
            // and the receivers resume from their staged bytes.
            let dropped: Vec<SnapSender> =
                self.snap_xfers.drain().map(|(_, s)| s).collect();
            for s in dropped {
                self.sm.snap_stream_end(s.plan_id());
            }
            self.snap_legacy.clear();
        }
        if term > self.hard.term {
            self.hard.term = term;
            self.hard.voted_for = None;
            self.persist_hard()?;
        }
        self.role = Role::Follower;
        if leader.is_some() {
            self.leader_hint = leader;
            self.last_leader_contact = self.ticks;
        }
        self.reset_election_timer();
        Ok(())
    }

    fn become_leader(&mut self) -> Result<Outbox> {
        self.role = Role::Leader;
        self.leader_hint = Some(self.id);
        // A follower-side half-staged transfer is orphaned once we
        // lead; staged bytes stay on disk as a future resume point.
        if self.snap_sink.take().is_some() {
            self.sm.snap_sink_abort();
        }
        self.next_index.clear();
        self.match_index.clear();
        self.peer_ack.clear();
        self.hb_sent_at.clear();
        self.pending_confirm.clear();
        self.lease_until = 0;
        for &p in &self.peers {
            self.next_index.insert(p, self.log.last_index() + 1);
            self.match_index.insert(p, 0);
        }
        // An uncommitted ConfChange inherited in the log suffix is
        // back in flight under this leadership (its config is already
        // active — append-time rule); a second change stays refused
        // until it commits.
        self.conf_pending = self
            .conf_history
            .last()
            .filter(|v| v.index > self.commit_index)
            .map(|v| v.index);
        // Commit barrier for prior-term entries (§5.4.2).  Read
        // barriers resolve only once this no-op commits.
        let idx = self.log.last_index() + 1;
        self.term_start_index = idx;
        self.log.append(LogEntry { term: self.hard.term, index: idx, cmd: Command::Noop })?;
        self.persist_log()?;
        // Single-node cluster: the no-op commits by itself — without
        // this, the §8 read gate would block every barrier until the
        // first client write.
        if self.peers.is_empty() {
            self.advance_commit()?;
        }
        self.broadcast_append()
    }

    // ---- client -----------------------------------------------------

    /// Leader-only: append a command; returns its log index.  The
    /// caller learns commit by watching `last_applied()`.
    pub fn propose(&mut self, cmd: Command) -> Result<LogIndex> {
        if self.role != Role::Leader {
            bail!("not leader (hint: {:?})", self.leader_hint());
        }
        // Config changes must flow through the membership machinery
        // (in-flight gate, append-time activation).
        if let Command::ConfChange(cc) = cmd {
            return self.propose_conf(cc);
        }
        let index = self.log.last_index() + 1;
        self.log.append(LogEntry { term: self.hard.term, index, cmd })?;
        Ok(index)
    }

    /// The ValueLog offset for a proposed index (Nezha engines store
    /// this in the state machine).
    pub fn vref_of(&self, index: LogIndex) -> Option<VRef> {
        self.log.vref_of(index)
    }

    /// Replicate everything pending to all peers (call after a batch
    /// of proposes — the coordinator's group-commit point).
    ///
    /// With [`Config::group_commit_us`] set, the broadcast is
    /// *pipelined ahead of the local sync*: followers start persisting
    /// in parallel with (or before) the leader, and the runtime calls
    /// [`Self::flush_group_commit`] once the budget lapses so one
    /// barrier covers every entry proposed inside the window.
    pub fn replicate(&mut self) -> Result<Outbox> {
        if self.role != Role::Leader {
            return Ok(Vec::new());
        }
        if self.cfg.group_commit_us > 0 {
            return self.broadcast_append();
        }
        self.persist_log()?;
        // Single-node cluster: commit immediately.
        if self.peers.is_empty() {
            self.advance_commit()?;
        }
        self.broadcast_append()
    }

    /// True when this leader holds appended-but-unsynced entries that
    /// a [`Self::flush_group_commit`] would cover — the runtime's cue
    /// to arm a group-commit deadline.
    pub fn has_unsynced(&self) -> bool {
        self.role == Role::Leader && self.log.last_index() > self.durable_index
    }

    /// Group-commit flush point: one persistence barrier covers every
    /// entry appended since the last one, then commit accounting
    /// catches up (the leader's durable ack joins the quorum math —
    /// on a single-node cluster nothing commits before this).
    pub fn flush_group_commit(&mut self) -> Result<()> {
        let last = self.log.last_index();
        if self.role != Role::Leader || last <= self.durable_index {
            return Ok(());
        }
        let batch = last - self.durable_index;
        self.metrics.group_commit_batches += 1;
        self.metrics.group_commit_entries += batch;
        self.metrics.group_commit_max_batch = self.metrics.group_commit_max_batch.max(batch);
        self.persist_log()?;
        self.advance_commit()
    }

    fn broadcast_append(&mut self) -> Result<Outbox> {
        self.last_heartbeat = self.ticks;
        // New heartbeat round: record when it left so a quorum of
        // echoes anchors the lease to this instant.
        self.hb_seq += 1;
        self.hb_sent_at.insert(self.hb_seq, self.lease_clock);
        if self.hb_sent_at.len() > 128 {
            let floor = self.hb_seq.saturating_sub(128);
            self.hb_sent_at.retain(|&s, _| s >= floor);
        }
        let mut out = Vec::new();
        let peers = self.peers.clone();
        for p in peers {
            for m in self.append_for(p)? {
                self.metrics.msgs_sent += 1;
                out.push((p, m));
            }
        }
        Ok(out)
    }

    fn append_for(&mut self, peer: NodeId) -> Result<Vec<Message>> {
        let next = *self.next_index.get(&peer).unwrap_or(&1);
        // Peer too far behind the in-memory log → ship a snapshot.
        let behind_mem = next < self.log.first_in_mem() && next <= self.log.last_index();
        if next <= self.log.snap_index || behind_mem {
            // Streamed run-shipping path first (DESIGN.md §8); falls
            // back to the monolithic blob when the engine has no plan
            // or the peer refused a stream.
            if self.cfg.snap_streaming && !self.snap_legacy.contains(&peer) {
                if let Some(msgs) = self.stream_for(peer)? {
                    return Ok(msgs);
                }
            }
            // Coverage claim is read *before* the snapshot: with an
            // apply lane the applier may land more entries in between,
            // so the snapshot can cover more than it claims — the
            // follower then re-applies a few entries, which is
            // idempotent.  (Claiming more than the engine holds would
            // lose data; this direction is the safe one.)
            let last_index = self.applied_index().max(self.log.snap_index);
            let data = self.sm.snapshot_bytes()?;
            self.metrics.snapshots_sent += 1;
            let last_term = self.log.term_at(last_index).unwrap_or(self.log.snap_term);
            let (voters, learners) = self.conf_at(last_index);
            return Ok(vec![Message::InstallSnapshot {
                term: self.hard.term,
                leader: self.id,
                last_index,
                last_term,
                data,
                voters,
                learners,
            }]);
        }
        let prev = next - 1;
        let Some(prev_term) = self.log.term_at(prev) else {
            // prev fell out of memory between checks — snapshot path
            // next round.
            return Ok(Vec::new());
        };
        let entries = self.log.entries(next, self.log.last_index(), self.cfg.max_batch_bytes);
        Ok(vec![Message::AppendEntries {
            term: self.hard.term,
            leader: self.id,
            prev_log_index: prev,
            prev_log_term: prev_term,
            entries,
            leader_commit: self.commit_index,
            seq: self.hb_seq,
        }])
    }

    /// Drive (or open) the streamed transfer for `peer`.  `Ok(None)`
    /// means the engine offered no plan — use the monolithic path.
    /// `Ok(Some(msgs))` means a stream is active; `msgs` may be empty
    /// between heartbeats (ack-clocked — chunks flow from
    /// [`Self::on_snap_ack`]).
    fn stream_for(&mut self, peer: NodeId) -> Result<Option<Vec<Message>>> {
        let term = self.hard.term;
        let id = self.id;
        if let Some(sender) = self.snap_xfers.get_mut(&peer) {
            let msgs = sender.tick(term, id)?;
            self.count_chunks(&msgs);
            return Ok(Some(msgs));
        }
        let last_index = self.applied_index().max(self.log.snap_index);
        let last_term = self.log.term_at(last_index).unwrap_or(self.log.snap_term);
        // A planning failure (e.g. a run file raced away) is not fatal:
        // fall back to the monolithic path for this attempt.
        let plan = match self.sm.snap_stream_begin(last_index, last_term) {
            Ok(Some(plan)) => plan,
            Ok(None) => return Ok(None),
            Err(e) => {
                eprintln!("raft: snapshot stream plan failed, using monolithic path: {e:#}");
                return Ok(None);
            }
        };
        self.snap_xfer_seq += 1;
        let xfer_id = (term << 24) ^ (id << 16) ^ self.snap_xfer_seq;
        let (voters, learners) = self.conf_at(plan.last_index);
        let sender = SnapSender::new(
            plan,
            xfer_id,
            self.cfg.snap_chunk_bytes,
            self.cfg.snap_window,
            voters,
            learners,
        );
        let meta = sender.meta_msg(term, id);
        self.snap_xfers.insert(peer, sender);
        self.metrics.snapshots_sent += 1;
        Ok(Some(vec![meta]))
    }

    fn count_chunks(&mut self, msgs: &[Message]) {
        for m in msgs {
            if let Message::SnapChunk { data, .. } = m {
                self.metrics.snap_chunks_sent += 1;
                self.metrics.snap_bytes_sent += data.len() as u64;
            }
        }
    }

    // ---- message handling --------------------------------------------

    pub fn handle(&mut self, from: NodeId, msg: Message) -> Result<Outbox> {
        // Vote stickiness (Raft §4.2.3), the lease's safety twin: a
        // higher-term vote request is refused — term untouched — while
        // this node recently heard from a live leader (or IS a leader
        // holding a valid lease).  Without it, one flaky link lets a
        // quorum elect a new leader and commit writes inside the old
        // leader's lease window, making lease reads stale.  Silence
        // for `election_timeout_min` re-enables voting, so a dead
        // leader is still replaced.
        if let Message::RequestVote { term, transfer, .. } = &msg {
            let sticky = match self.role {
                Role::Leader => self.lease_valid(),
                _ => {
                    self.leader_hint.is_some()
                        && self.last_leader_contact > 0
                        && self.ticks.saturating_sub(self.last_leader_contact)
                            < self.cfg.election_timeout_min
                }
            };
            // A transfer election is sanctioned by the old leader
            // (§4.2.3): stickiness must not block it, or a removed
            // leader could never hand off inside the lease window.
            if *term > self.hard.term && sticky && !*transfer {
                self.metrics.msgs_sent += 1;
                return Ok(vec![(
                    from,
                    Message::RequestVoteResp { term: self.hard.term, granted: false },
                )]);
            }
        }
        if msg.term() > self.hard.term {
            let leader = match &msg {
                Message::AppendEntries { leader, .. }
                | Message::InstallSnapshot { leader, .. }
                | Message::SnapMeta { leader, .. }
                | Message::SnapChunk { leader, .. } => Some(*leader),
                _ => None,
            };
            self.become_follower(msg.term(), leader)?;
        }
        let mut out = match msg {
            Message::RequestVote { term, candidate, last_log_index, last_log_term, transfer } => {
                self.on_request_vote(from, term, candidate, last_log_index, last_log_term, transfer)
            }
            Message::RequestVoteResp { term, granted } => self.on_vote_resp(from, term, granted),
            Message::AppendEntries {
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                seq,
            } => self.on_append(
                from,
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                seq,
            ),
            Message::AppendEntriesResp { term, success, match_index, seq } => {
                self.on_append_resp(from, term, success, match_index, seq)
            }
            Message::InstallSnapshot { term, leader, last_index, last_term, data, voters, learners } => {
                self.on_install_snapshot(from, term, leader, last_index, last_term, data, voters, learners)
            }
            Message::InstallSnapshotResp { term, last_index } => {
                self.on_snapshot_resp(from, term, last_index)
            }
            Message::SnapMeta {
                term,
                leader,
                xfer_id,
                last_index,
                last_term,
                manifest,
                voters,
                learners,
            } => self.on_snap_meta(
                from, term, leader, xfer_id, last_index, last_term, manifest, voters, learners,
            ),
            Message::SnapChunk { term, leader, xfer_id, offset, data } => {
                self.on_snap_chunk(from, term, leader, xfer_id, offset, data)
            }
            Message::SnapAck { term, xfer_id, offset, done } => {
                self.on_snap_ack(from, term, xfer_id, offset, done)
            }
            Message::ReadIndex { term, ctx } => self.on_read_index(from, term, ctx),
            Message::ReadIndexResp { term, ctx, read_index, ok } => {
                self.on_read_index_resp(term, ctx, read_index, ok)
            }
            Message::TimeoutNow { term } => self.on_timeout_now(from, term),
        }?;
        // Commit-driven transitions (e.g. the TimeoutNow a removed
        // leader owes its successor) are parked in `stash` because not
        // every commit-advancing path returns an Outbox.
        out.extend(self.take_stash());
        Ok(out)
    }

    /// TimeoutNow (§3.10 leadership transfer): the leader believes we
    /// are the best-caught-up voter and asks us to campaign without
    /// waiting for an election timeout.  The resulting RequestVote
    /// carries `transfer: true` so peers' vote stickiness stands aside.
    fn on_timeout_now(&mut self, _from: NodeId, term: Term) -> Result<Outbox> {
        if term < self.hard.term || !self.is_voter() {
            return Ok(Vec::new());
        }
        self.transfer_election = true;
        let out = self.start_election();
        self.transfer_election = false;
        out
    }

    fn on_request_vote(
        &mut self,
        from: NodeId,
        term: Term,
        candidate: NodeId,
        last_log_index: LogIndex,
        last_log_term: Term,
        _transfer: bool,
    ) -> Result<Outbox> {
        let mut granted = false;
        if term == self.hard.term {
            let can_vote = self.hard.voted_for.is_none() || self.hard.voted_for == Some(candidate);
            // §5.4.1 up-to-date check.
            let up_to_date = last_log_term > self.log.last_term()
                || (last_log_term == self.log.last_term()
                    && last_log_index >= self.log.last_index());
            // Membership check: a node outside our voter set — removed,
            // or a learner with a stale view of itself — must not be
            // able to assemble a quorum from nodes that still list it.
            let is_member = self.voters.contains(&candidate);
            if can_vote && up_to_date && is_member {
                granted = true;
                self.hard.voted_for = Some(candidate);
                self.persist_hard()?;
                self.reset_election_timer();
            }
        }
        self.metrics.msgs_sent += 1;
        Ok(vec![(from, Message::RequestVoteResp { term: self.hard.term, granted })])
    }

    fn on_vote_resp(&mut self, from: NodeId, term: Term, granted: bool) -> Result<Outbox> {
        if self.role != Role::Candidate || term != self.hard.term {
            return Ok(Vec::new());
        }
        // Only voters of the active config count toward the quorum —
        // a grant from a node we no longer list must not tip the tally.
        if granted && self.voters.contains(&from) {
            self.votes += 1;
            if self.votes >= self.quorum() {
                return self.become_leader();
            }
        }
        Ok(Vec::new())
    }

    #[allow(clippy::too_many_arguments)]
    fn on_append(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        prev_log_index: LogIndex,
        prev_log_term: Term,
        entries: Vec<LogEntry>,
        leader_commit: LogIndex,
        seq: u64,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::AppendEntriesResp {
                    term: self.hard.term,
                    success: false,
                    match_index: 0,
                    seq,
                },
            )]);
        }
        // Valid leader for this term.
        self.become_follower(term, Some(leader))?;

        // Consistency check on prev.
        let prev_ok = if prev_log_index == 0 {
            true
        } else if prev_log_index < self.log.snap_index {
            // Leader is behind our snapshot — treat as matching at
            // snapshot point.
            true
        } else {
            self.log.term_at(prev_log_index) == Some(prev_log_term)
        };
        if !prev_ok {
            // Conflict hint: ask the leader to back up to our last
            // index (fast path) or below prev.
            let hint = self.log.last_index().min(prev_log_index.saturating_sub(1));
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::AppendEntriesResp {
                    term: self.hard.term,
                    success: false,
                    match_index: hint,
                    seq,
                },
            )]);
        }

        // Append new entries, truncating conflicts.
        for e in entries {
            if e.index <= self.log.snap_index {
                continue; // covered by snapshot
            }
            let conf = match &e.cmd {
                Command::ConfChange(cc) => Some((e.index, *cc)),
                _ => None,
            };
            match self.log.term_at(e.index) {
                Some(t) if t == e.term => continue, // already have it
                Some(_) => {
                    // Conflict: truncate suffix then append.  The live
                    // epoch file (possibly a reopened frozen one) is
                    // rewritten in place from here on — readahead
                    // caches over it are now stale.
                    self.log.truncate_from(e.index)?;
                    self.durable_index = self.durable_index.min(e.index.saturating_sub(1));
                    self.sm.on_log_truncated(self.log.live_epoch());
                    // Config is effective at *append*, so truncation
                    // must also unwind any config the dropped suffix
                    // carried (§4.1: the replaced entries may include
                    // ConfChanges from a deposed leader).
                    self.rollback_conf(e.index)?;
                    self.log.append(e)?;
                }
                None => {
                    if e.index == self.log.last_index() + 1 {
                        self.log.append(e)?;
                    } else {
                        continue; // gap (stale message) — ignore remainder
                    }
                }
            }
            if let Some((index, cc)) = conf {
                self.apply_conf_at_append(index, cc)?;
            }
        }
        self.persist_log()?;

        let match_index = self.log.last_index();
        let new_commit = leader_commit.min(match_index);
        if new_commit > self.commit_index {
            self.metrics.entries_committed += new_commit - self.commit_index;
            self.commit_index = new_commit;
            self.apply_committed()?;
            self.on_commit_advanced()?;
        }
        self.metrics.msgs_sent += 1;
        Ok(vec![(
            from,
            Message::AppendEntriesResp { term: self.hard.term, success: true, match_index, seq },
        )])
    }

    fn on_append_resp(
        &mut self,
        from: NodeId,
        term: Term,
        success: bool,
        match_index: LogIndex,
        seq: u64,
    ) -> Result<Outbox> {
        if self.role != Role::Leader || term != self.hard.term {
            return Ok(Vec::new());
        }
        // Any term-matching response — even a log-mismatch rejection —
        // proves the peer accepted this node as its term's leader when
        // it echoed round `seq`: record the ack, refresh the lease,
        // and complete read barriers the quorum now confirms.
        let ack = self.peer_ack.entry(from).or_insert(0);
        if seq > *ack {
            *ack = seq;
        }
        self.refresh_lease();
        let mut out = Vec::new();
        if success {
            self.match_index.insert(from, match_index);
            self.next_index.insert(from, match_index + 1);
            self.advance_commit()?;
            if self.role != Role::Leader {
                // Committing that response finished our own removal
                // (`on_commit_advanced` stepped us down) — the stash
                // holds the TimeoutNow; send nothing else.
                return Ok(out);
            }
            self.maybe_promote(from)?;
            out.extend(self.pump_read_confirms());
            // More to send?
            if match_index < self.log.last_index() {
                for m in self.append_for(from)? {
                    self.metrics.msgs_sent += 1;
                    out.push((from, m));
                }
            }
        } else {
            out.extend(self.pump_read_confirms());
            // Back up using the follower's hint.
            let next = self.next_index.entry(from).or_insert(1);
            *next = (match_index + 1).min((*next).saturating_sub(1)).max(1);
            for m in self.append_for(from)? {
                self.metrics.msgs_sent += 1;
                out.push((from, m));
            }
        }
        Ok(out)
    }

    fn advance_commit(&mut self) -> Result<()> {
        // Largest N replicated *durably* on a quorum with term ==
        // current (§5.4.2).  The leader's own vote is `durable_index`,
        // not `last_index()`: with group commit the broadcast runs
        // ahead of the local sync, and unsynced entries must not count
        // (followers' match_index is always durable — they persist
        // before acking).
        // Only voters of the active config count (§4.2.2): learners
        // replicate but never advance commit, and a leader removing
        // itself stops counting its own durable index the moment the
        // Remove is appended.
        let mut candidates: Vec<LogIndex> = self
            .voters
            .iter()
            .filter(|&&v| v != self.id)
            .map(|v| self.match_index.get(v).copied().unwrap_or(0))
            .collect();
        if self.is_voter() {
            candidates.push(self.durable_index);
        }
        candidates.sort_unstable();
        if candidates.len() < self.quorum() {
            return Ok(());
        }
        // The (len - quorum)-th from the end is replicated on >= quorum.
        let n = candidates[candidates.len() - self.quorum()];
        if n > self.commit_index && self.log.term_at(n) == Some(self.hard.term) {
            self.metrics.entries_committed += n - self.commit_index;
            self.commit_index = n;
            self.apply_committed()?;
            self.on_commit_advanced()?;
        }
        Ok(())
    }

    fn apply_committed(&mut self) -> Result<()> {
        while self.last_applied < self.commit_index {
            let idx = self.last_applied + 1;
            let Some(entry) = self.log.entry(idx).cloned() else {
                // Entry not in memory: snapshot already covers it.
                self.last_applied = self.log.snap_index.min(self.commit_index);
                if self.last_applied < idx {
                    bail!("apply gap at {idx}");
                }
                continue;
            };
            let vref = self.log.vref_of(idx).unwrap_or(VRef::new(0, 0));
            // `last_applied` (the field) is the hand-off cursor; the
            // lane publishes the truly-applied cursor.  The lane holds
            // clones, so compact_mem below stays safe.
            match &self.lane {
                Some(lane) => lane.push(idx, entry, vref),
                None => self.sm.apply(&entry, vref)?,
            }
            self.metrics.entries_applied += 1;
            self.last_applied = idx;
        }
        self.log.compact_mem(self.last_applied, self.cfg.mem_keep_tail);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn on_install_snapshot(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        last_index: LogIndex,
        last_term: Term,
        data: Vec<u8>,
        voters: Vec<NodeId>,
        learners: Vec<NodeId>,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            self.metrics.msgs_sent += 1;
            let last_index = self.log.last_index();
            let resp = Message::InstallSnapshotResp { term: self.hard.term, last_index };
            return Ok(vec![(from, resp)]);
        }
        self.become_follower(term, Some(leader))?;
        if last_index > self.log.snap_index && last_index > self.last_applied {
            // Order matters with an apply lane: clear the queue (and
            // invalidate chunks the applier already popped) *before*
            // the engine install, publish the new cursor after — so
            // stale entries can never land on top of snapshot state.
            if let Some(lane) = &self.lane {
                lane.begin_install();
            }
            self.sm.install_snapshot(&data, last_index, last_term)?;
            self.log.reset_to_snapshot(last_index, last_term)?;
            self.commit_index = last_index;
            self.last_applied = last_index;
            self.durable_index = self.log.last_index();
            if let Some(lane) = &self.lane {
                lane.set_applied(last_index);
            }
            // The snapshot replaces the log prefix, including any
            // ConfChange entries it covered — adopt the config the
            // sender stamped on it.
            self.adopt_snapshot_conf(last_index, voters, learners)?;
            self.metrics.snapshots_installed += 1;
        }
        self.metrics.msgs_sent += 1;
        let last_index = self.log.last_index();
        Ok(vec![(from, Message::InstallSnapshotResp { term: self.hard.term, last_index })])
    }

    fn on_snapshot_resp(
        &mut self,
        from: NodeId,
        term: Term,
        last_index: LogIndex,
    ) -> Result<Outbox> {
        if self.role != Role::Leader || term != self.hard.term {
            return Ok(Vec::new());
        }
        self.match_index.insert(from, last_index);
        self.next_index.insert(from, last_index + 1);
        self.maybe_promote(from)?;
        let mut out = Vec::new();
        for m in self.append_for(from)? {
            self.metrics.msgs_sent += 1;
            out.push((from, m));
        }
        Ok(out)
    }

    // ---- streamed snapshot transfer (DESIGN.md §8) -------------------

    /// Receiver: a leader offered (or re-offered) a streamed transfer.
    /// Answer with the resume offset from our staging area, `done` if
    /// our state already covers it, or `u64::MAX` to refuse (engine
    /// has no streaming install — sender falls back to monolithic).
    #[allow(clippy::too_many_arguments)]
    fn on_snap_meta(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        xfer_id: u64,
        last_index: LogIndex,
        last_term: Term,
        manifest: Vec<u8>,
        voters: Vec<NodeId>,
        learners: Vec<NodeId>,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            self.metrics.msgs_sent += 1;
            let resp =
                Message::SnapAck { term: self.hard.term, xfer_id, offset: u64::MAX, done: false };
            return Ok(vec![(from, resp)]);
        }
        self.become_follower(term, Some(leader))?;
        let ack = |offset: u64, done: bool| Message::SnapAck { term, xfer_id, offset, done };
        if last_index <= self.log.snap_index || last_index <= self.last_applied {
            // Already covered — short-circuit to done so the leader
            // moves on to AppendEntries.
            self.metrics.msgs_sent += 1;
            return Ok(vec![(from, ack(u64::MAX, true))]);
        }
        if let Some(sink) = &self.snap_sink {
            if sink.xfer_id == xfer_id {
                // Re-offer of the live transfer (sender stall): re-ack
                // the cursor; if everything is staged, commit now (the
                // original done-ack was lost).
                if sink.expected >= sink.total_len {
                    return self.finish_snap_sink(from);
                }
                let offset = sink.expected;
                self.metrics.msgs_sent += 1;
                return Ok(vec![(from, ack(offset, false))]);
            }
            // A different transfer supersedes the old one (leader
            // change / newer snapshot).
            self.snap_sink = None;
            self.sm.snap_sink_abort();
        }
        let Ok(m) = SnapManifest::decode(&manifest) else {
            self.metrics.msgs_sent += 1;
            return Ok(vec![(from, ack(u64::MAX, false))]);
        };
        match self.sm.snap_sink_begin(&m) {
            Ok(resume) => {
                if resume > 0 {
                    self.metrics.snap_resumes += 1;
                }
                self.snap_sink = Some(SnapSink {
                    xfer_id,
                    expected: resume,
                    total_len: m.total_len,
                    last_index,
                    last_term,
                    voters,
                    learners,
                });
                if resume >= m.total_len {
                    // Fully staged already (or an empty snapshot).
                    return self.finish_snap_sink(from);
                }
                self.metrics.msgs_sent += 1;
                Ok(vec![(from, ack(resume, false))])
            }
            Err(_) => {
                // Engine refused: monolithic fallback.
                self.metrics.msgs_sent += 1;
                Ok(vec![(from, ack(u64::MAX, false))])
            }
        }
    }

    /// Receiver: stage one chunk.  Out-of-order or duplicate chunks
    /// are not written — the cumulative re-ack tells the sender where
    /// to rewind (go-back-N).
    fn on_snap_chunk(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        xfer_id: u64,
        offset: u64,
        data: Vec<u8>,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            return Ok(Vec::new());
        }
        self.become_follower(term, Some(leader))?;
        let Some(sink) = &mut self.snap_sink else {
            // No live transfer (e.g. restarted mid-stream): wait for
            // the sender's stall re-offer of SnapMeta.
            return Ok(Vec::new());
        };
        if sink.xfer_id != xfer_id {
            return Ok(Vec::new());
        }
        if offset != sink.expected {
            // Duplicate (offset < expected) or gap (offset > expected):
            // re-ack the cursor so the sender rewinds.
            let resp = Message::SnapAck { term, xfer_id, offset: sink.expected, done: false };
            self.metrics.msgs_sent += 1;
            return Ok(vec![(from, resp)]);
        }
        match self.sm.snap_sink_write(offset, &data) {
            Ok(()) => {
                let sink = self.snap_sink.as_mut().expect("sink checked above");
                sink.expected += data.len() as u64;
                self.metrics.snap_chunks_recv += 1;
                if sink.expected >= sink.total_len {
                    return self.finish_snap_sink(from);
                }
                let resp =
                    Message::SnapAck { term, xfer_id, offset: sink.expected, done: false };
                self.metrics.msgs_sent += 1;
                Ok(vec![(from, resp)])
            }
            Err(_) => {
                // Staging write failed (disk fault): tear down the
                // in-memory sink but keep staged bytes — the sender's
                // stall re-offer resumes from whatever landed durably.
                self.snap_sink = None;
                self.sm.snap_sink_abort();
                Ok(Vec::new())
            }
        }
    }

    /// Receiver: every byte staged — verify + atomically install, then
    /// done-ack.  A failed commit wipes staging and stays silent; the
    /// sender's stall path restarts the transfer from offset 0.
    fn finish_snap_sink(&mut self, from: NodeId) -> Result<Outbox> {
        let Some(sink) = self.snap_sink.take() else {
            return Ok(Vec::new());
        };
        let SnapSink { xfer_id, total_len, last_index, last_term, voters, learners, .. } = sink;
        if last_index > self.log.snap_index && last_index > self.last_applied {
            // Same ordering as the monolithic install: quiesce the
            // apply lane before the engine cut-over, publish the new
            // cursor after.
            if let Some(lane) = &self.lane {
                lane.begin_install();
            }
            if self.sm.snap_sink_commit(last_index, last_term).is_err() {
                self.sm.snap_sink_abort();
                return Ok(Vec::new());
            }
            self.log.reset_to_snapshot(last_index, last_term)?;
            self.commit_index = last_index;
            self.last_applied = last_index;
            self.durable_index = self.log.last_index();
            if let Some(lane) = &self.lane {
                lane.set_applied(last_index);
            }
            // Adopt the config the sender stamped on the stream — the
            // replaced log prefix may have carried ConfChange entries.
            self.adopt_snapshot_conf(last_index, voters, learners)?;
            self.metrics.snapshots_installed += 1;
            self.metrics.snap_streams_done += 1;
        } else {
            // State moved past the snapshot while it streamed.
            self.sm.snap_sink_abort();
        }
        self.metrics.msgs_sent += 1;
        let resp =
            Message::SnapAck { term: self.hard.term, xfer_id, offset: total_len, done: true };
        Ok(vec![(from, resp)])
    }

    /// Sender: cumulative ack from the receiver — advance the window,
    /// finish the transfer, or fall back to the monolithic path.
    fn on_snap_ack(
        &mut self,
        from: NodeId,
        term: Term,
        xfer_id: u64,
        offset: u64,
        done: bool,
    ) -> Result<Outbox> {
        if self.role != Role::Leader || term != self.hard.term {
            return Ok(Vec::new());
        }
        let Some(sender) = self.snap_xfers.get(&from) else {
            return Ok(Vec::new());
        };
        if sender.xfer_id != xfer_id {
            return Ok(Vec::new());
        }
        if done {
            let sender = self.snap_xfers.remove(&from).expect("sender checked above");
            self.sm.snap_stream_end(sender.plan_id());
            self.metrics.snap_streams_done += 1;
            self.match_index.insert(from, sender.last_index());
            self.next_index.insert(from, sender.last_index() + 1);
            self.maybe_promote(from)?;
            let mut out = Vec::new();
            for m in self.append_for(from)? {
                self.metrics.msgs_sent += 1;
                out.push((from, m));
            }
            return Ok(out);
        }
        if offset == u64::MAX {
            // Refused: this peer's engine wants the monolithic blob.
            let sender = self.snap_xfers.remove(&from).expect("sender checked above");
            self.sm.snap_stream_end(sender.plan_id());
            self.snap_legacy.insert(from);
            let mut out = Vec::new();
            for m in self.append_for(from)? {
                self.metrics.msgs_sent += 1;
                out.push((from, m));
            }
            return Ok(out);
        }
        let sender = self.snap_xfers.get_mut(&from).expect("sender checked above");
        sender.on_ack(offset)?;
        let term = self.hard.term;
        let id = self.id;
        let sender = self.snap_xfers.get_mut(&from).expect("sender checked above");
        let burst = sender.fill_window(term, id)?;
        self.count_chunks(&burst);
        self.metrics.msgs_sent += burst.len() as u64;
        Ok(burst.into_iter().map(|m| (from, m)).collect())
    }

    // ---- linearizable read barriers (ReadIndex + leader lease) -------

    /// Lease length in lease-clock ticks: 3/4 of the *minimum*
    /// election timeout.  A follower that acked a heartbeat sent at
    /// lease-instant `S` cannot vote out this leader before its own
    /// election timer — reset no earlier than `S` — runs at least
    /// `election_timeout_min` of its (never faster than wall) ticks,
    /// so a lease anchored at `S` expires with margin to spare.
    fn lease_len(&self) -> u64 {
        self.cfg.election_timeout_min * 3 / 4
    }

    fn lease_valid(&self) -> bool {
        self.cfg.lease_reads && self.role == Role::Leader && self.lease_clock < self.lease_until
    }

    /// Extend the lease to the newest heartbeat round a quorum has
    /// echoed (self counts for its own latest round).
    fn refresh_lease(&mut self) {
        if !self.cfg.lease_reads {
            return;
        }
        // Only voters anchor the lease: a learner's echo proves
        // nothing about which config-quorum accepts this leadership.
        let mut acked: Vec<u64> = self
            .voters
            .iter()
            .filter(|&&v| v != self.id)
            .filter_map(|v| self.peer_ack.get(v).copied())
            .collect();
        if self.is_voter() {
            acked.push(self.hb_seq);
        }
        let q = self.quorum();
        if acked.len() < q {
            return;
        }
        acked.sort_unstable();
        // q-th largest: the newest round at least q members have seen.
        let quorum_seq = acked[acked.len() - q];
        if let Some(&sent) = self.hb_sent_at.get(&quorum_seq) {
            self.lease_until = self.lease_until.max(sent + self.lease_len());
        }
    }

    /// Complete every parked read barrier whose heartbeat round a
    /// quorum has echoed.  Gated on the §8 no-op commit: the handed-out
    /// index is the *current* commit index, which is at least the
    /// commit point any already-acknowledged write had reached.
    fn pump_read_confirms(&mut self) -> Outbox {
        if self.pending_confirm.is_empty() || self.commit_index < self.term_start_index {
            return Vec::new();
        }
        let q = self.quorum();
        let mut out = Vec::new();
        let mut still_pending = Vec::new();
        for pc in std::mem::take(&mut self.pending_confirm) {
            // Voter acks only — mirrors `refresh_lease`.
            let acks = self.is_voter() as usize
                + self
                    .voters
                    .iter()
                    .filter(|&&v| v != self.id)
                    .filter(|v| self.peer_ack.get(v).is_some_and(|&s| s >= pc.seq))
                    .count();
            if acks >= q {
                match pc.requester {
                    Some(n) => {
                        self.metrics.msgs_sent += 1;
                        out.push((
                            n,
                            Message::ReadIndexResp {
                                term: self.hard.term,
                                ctx: pc.ctx,
                                read_index: self.commit_index,
                                ok: true,
                            },
                        ));
                    }
                    None => self.ready_reads.push((pc.ctx, self.commit_index)),
                }
            } else {
                still_pending.push(pc);
            }
        }
        self.pending_confirm = still_pending;
        out
    }

    /// Begin a linearizable read barrier for an opaque caller token.
    /// On a leader holding a valid lease the barrier resolves
    /// immediately; otherwise a heartbeat quorum round confirms the
    /// leadership first.  On a follower the request is forwarded to
    /// the last known leader.  Outcomes surface through
    /// [`Self::take_read_results`]: serve the read from local state
    /// once `last_applied >= read_index`.
    pub fn request_read(&mut self, ctx: u64) -> Result<Outbox> {
        if self.role == Role::Leader {
            if self.lease_valid() && self.commit_index >= self.term_start_index {
                self.metrics.lease_reads += 1;
                self.ready_reads.push((ctx, self.commit_index));
                return Ok(Vec::new());
            }
            self.metrics.read_index_rounds += 1;
            self.pending_confirm.push(PendingConfirm {
                ctx,
                requester: None,
                seq: self.hb_seq + 1,
                issued_at: self.lease_clock,
            });
            let mut out = self.broadcast_append()?;
            // Single-node cluster: a quorum of one confirms instantly.
            out.extend(self.pump_read_confirms());
            return Ok(out);
        }
        match self.leader_hint {
            Some(l) if l != self.id => {
                self.metrics.msgs_sent += 1;
                Ok(vec![(l, Message::ReadIndex { term: self.hard.term, ctx })])
            }
            _ => {
                // No leader known: fail fast so the caller retries
                // elsewhere (or after the next election).
                self.failed_reads.push(ctx);
                Ok(Vec::new())
            }
        }
    }

    fn on_read_index(&mut self, from: NodeId, _term: Term, ctx: u64) -> Result<Outbox> {
        if self.role != Role::Leader {
            // A higher-term ReadIndex already demoted us in `handle`;
            // either way the requester must re-resolve the leader.
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::ReadIndexResp { term: self.hard.term, ctx, read_index: 0, ok: false },
            )]);
        }
        if self.lease_valid() && self.commit_index >= self.term_start_index {
            self.metrics.lease_reads += 1;
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::ReadIndexResp {
                    term: self.hard.term,
                    ctx,
                    read_index: self.commit_index,
                    ok: true,
                },
            )]);
        }
        self.metrics.read_index_rounds += 1;
        self.pending_confirm.push(PendingConfirm {
            ctx,
            requester: Some(from),
            seq: self.hb_seq + 1,
            issued_at: self.lease_clock,
        });
        self.broadcast_append()
    }

    fn on_read_index_resp(
        &mut self,
        term: Term,
        ctx: u64,
        read_index: LogIndex,
        ok: bool,
    ) -> Result<Outbox> {
        // A resp from a newer term already raised ours in `handle`, so
        // equality means the grant is from our term's leader; anything
        // else is a stale leader's answer and must not be trusted.
        if ok && term == self.hard.term {
            self.ready_reads.push((ctx, read_index));
        } else {
            self.failed_reads.push(ctx);
        }
        Ok(Vec::new())
    }

    /// Drain resolved read barriers: `(ctx, read_index)` pairs ready
    /// to serve once `last_applied >= read_index`, and ctxs whose
    /// barrier failed (no leader, lost leadership, stale grant) that
    /// the caller must retry or surface.
    pub fn take_read_results(&mut self) -> (Vec<(u64, LogIndex)>, Vec<u64>) {
        (std::mem::take(&mut self.ready_reads), std::mem::take(&mut self.failed_reads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    /// Trivial in-memory KV state machine for node tests.
    #[derive(Default)]
    struct MemSm {
        kv: BTreeMap<Vec<u8>, Vec<u8>>,
        applied: Vec<LogIndex>,
    }

    impl StateMachine for MemSm {
        fn apply(&mut self, entry: &LogEntry, _vref: VRef) -> Result<()> {
            self.applied.push(entry.index);
            match &entry.cmd {
                Command::Put { key, value } => {
                    self.kv.insert(key.clone(), value.clone());
                }
                Command::Delete { key } => {
                    self.kv.remove(key);
                }
                Command::Noop | Command::ConfChange(_) => {}
            }
            Ok(())
        }

        fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
            let mut e = crate::util::Encoder::new();
            e.varint(self.kv.len() as u64);
            for (k, v) in &self.kv {
                e.len_bytes(k).len_bytes(v);
            }
            Ok(e.into_vec())
        }

        fn install_snapshot(&mut self, data: &[u8], _li: LogIndex, _lt: Term) -> Result<()> {
            let mut d = crate::util::Decoder::new(data);
            let n = d.varint()? as usize;
            self.kv.clear();
            for _ in 0..n {
                let k = d.len_bytes()?.to_vec();
                let v = d.len_bytes()?.to_vec();
                self.kv.insert(k, v);
            }
            Ok(())
        }
    }

    fn tmpdir(name: &str, id: u64) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("nezha-node-{name}-{}-{id}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Synchronous 3-node test cluster: delivers all messages until
    /// quiescent.
    struct Trio {
        nodes: Vec<Node<MemSm>>,
    }

    impl Trio {
        fn new(name: &str) -> Self {
            Self::with_cfg(name, Config::default())
        }

        fn with_cfg(name: &str, cfg: Config) -> Self {
            let ids = [1u64, 2, 3];
            let nodes = ids
                .iter()
                .map(|&id| {
                    let peers: Vec<u64> = ids.iter().copied().filter(|&p| p != id).collect();
                    Node::new(id, peers, &tmpdir(name, id), MemSm::default(), cfg.clone(), 42)
                        .unwrap()
                })
                .collect();
            Self { nodes }
        }

        fn node(&mut self, id: NodeId) -> &mut Node<MemSm> {
            self.nodes.iter_mut().find(|n| n.id == id).unwrap()
        }

        fn pump(&mut self, mut msgs: Vec<(NodeId, NodeId, Message)>) {
            while let Some((from, to, m)) = msgs.pop() {
                let out = self.node(to).handle(from, m).unwrap();
                for (dst, msg) in out {
                    msgs.push((to, dst, msg));
                }
            }
        }

        fn tick_all(&mut self) {
            let mut msgs = Vec::new();
            for n in &mut self.nodes {
                let id = n.id;
                for (dst, m) in n.tick().unwrap() {
                    msgs.push((id, dst, m));
                }
            }
            self.pump(msgs);
        }

        /// Tick until some node is leader; returns its id.
        fn elect(&mut self) -> NodeId {
            for _ in 0..500 {
                self.tick_all();
                if let Some(l) = self.nodes.iter().find(|n| n.is_leader()) {
                    return l.id;
                }
            }
            panic!("no leader elected");
        }

        fn propose_and_commit(&mut self, leader: NodeId, cmd: Command) -> LogIndex {
            let idx = self.node(leader).propose(cmd).unwrap();
            let out = self.node(leader).replicate().unwrap();
            let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
            self.pump(msgs);
            idx
        }
    }

    #[test]
    fn single_leader_elected() {
        let mut t = Trio::new("elect");
        let leader = t.elect();
        let leaders: Vec<_> = t.nodes.iter().filter(|n| n.is_leader()).collect();
        assert_eq!(leaders.len(), 1);
        assert_eq!(leaders[0].id, leader);
        // Followers learn the hint.
        for n in &t.nodes {
            if !n.is_leader() {
                assert_eq!(n.leader_hint(), Some(leader));
            }
        }
    }

    #[test]
    fn replication_commits_and_applies_everywhere() {
        let mut t = Trio::new("replicate");
        let leader = t.elect();
        for i in 0..20u32 {
            let key = format!("k{i}").into_bytes();
            let value = format!("v{i}").into_bytes();
            t.propose_and_commit(leader, Command::Put { key, value });
        }
        // Followers learn the final commit index from the next
        // heartbeat — pump a few ticks.
        for _ in 0..10 {
            t.tick_all();
        }
        // Everyone applied everything (noop + 20 entries).
        let applied: Vec<_> = t.nodes.iter().map(|n| n.last_applied()).collect();
        assert!(applied.iter().all(|&a| a == applied[0]), "{applied:?}");
        assert!(applied[0] >= 20);
    }

    #[test]
    fn non_leader_rejects_propose() {
        let mut t = Trio::new("reject");
        let leader = t.elect();
        for n in &mut t.nodes {
            if n.id != leader {
                assert!(n.propose(Command::Noop).is_err());
            }
        }
    }

    #[test]
    fn commit_requires_quorum_not_all() {
        // Detach node 3: leader + node 2 still commit.
        let mut t = Trio::new("quorum");
        let leader = t.elect();
        let cmd = Command::Put { key: b"q".to_vec(), value: b"1".to_vec() };
        let idx = t.node(leader).propose(cmd).unwrap();
        let out = t.node(leader).replicate().unwrap();
        // Deliver only to one follower.
        let follower = t.nodes.iter().map(|n| n.id).find(|&id| id != leader).unwrap();
        let msgs: Vec<_> = out
            .into_iter()
            .filter(|(dst, _)| *dst == follower)
            .map(|(dst, m)| (leader, dst, m))
            .collect();
        t.pump(msgs);
        assert!(t.node(leader).commit_index() >= idx);
    }

    #[test]
    fn higher_term_dethrones_leader() {
        let mut t = Trio::new("dethrone");
        let leader = t.elect();
        let term = t.node(leader).term();
        // Let the lease lapse first (ticks with no acks delivered):
        // a live leader inside its lease rightly withholds the vote —
        // see `live_leader_and_fresh_follower_withhold_votes`.
        for _ in 0..Config::default().election_timeout_min * 2 {
            let _ = t.node(leader).tick().unwrap();
        }
        // Candidate must be a real member (membership check denies
        // outsiders) — pick a voter other than the leader.
        let cand = t.nodes.iter().map(|n| n.id).find(|&id| id != leader).unwrap();
        let vote = Message::RequestVote {
            term: term + 10,
            candidate: cand,
            last_log_index: 1 << 30,
            last_log_term: 1 << 30,
            transfer: false,
        };
        let out = t.node(leader).handle(cand, vote).unwrap();
        assert_eq!(t.node(leader).role(), Role::Follower);
        assert_eq!(t.node(leader).term(), term + 10);
        // And it granted the vote (log was up-to-date).
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: true, .. }));
    }

    /// Lease safety: while a leader's lease is valid (and while a
    /// follower has freshly heard from that leader), a higher-term
    /// vote request is refused without even bumping the local term —
    /// otherwise a new leader could commit writes inside the lease
    /// window and lease reads would go stale.
    #[test]
    fn live_leader_and_fresh_follower_withhold_votes() {
        let mut t = Trio::new("sticky");
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        let term = t.node(leader).term();
        let vote = |c: u64| Message::RequestVote {
            term: term + 1,
            candidate: c,
            last_log_index: 1 << 30,
            last_log_term: 1 << 30,
            transfer: false,
        };
        // The leaseholder stays leader at its own term.
        let out = t.node(leader).handle(98, vote(98)).unwrap();
        assert!(t.node(leader).is_leader(), "deposed inside a valid lease");
        assert_eq!(t.node(leader).term(), term);
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: false, .. }));
        // A follower that just heard from this leader withholds too.
        let follower = t.nodes.iter().map(|n| n.id).find(|&id| id != leader).unwrap();
        let out = t.node(follower).handle(98, vote(98)).unwrap();
        assert_eq!(t.node(follower).term(), term);
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: false, .. }));
    }

    #[test]
    fn vote_denied_for_stale_log() {
        let mut t = Trio::new("stalelog");
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"x".to_vec(), value: b"y".to_vec() });
        let term = t.node(leader).term();
        // A candidate with an empty log can't win a vote from the leader.
        let vote = Message::RequestVote {
            term: term + 1,
            candidate: 77,
            last_log_index: 0,
            last_log_term: 0,
            transfer: false,
        };
        let out = t.node(leader).handle(77, vote).unwrap();
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: false, .. }));
    }

    #[test]
    fn snapshot_catches_up_fresh_node() {
        let mut t = Trio::new("snapcatch");
        let leader = t.elect();
        // Small mem tail to force snapshot path.
        t.node(leader).cfg.mem_keep_tail = 2;
        for i in 0..50u32 {
            t.propose_and_commit(
                leader,
                Command::Put { key: format!("k{i:03}").into_bytes(), value: b"v".to_vec() },
            );
        }
        // New empty node 4 joins as the replication target of leader.
        let dir = tmpdir("snapcatch", 4);
        let mut n4 =
            Node::new(4, vec![leader], &dir, MemSm::default(), Config::default(), 7).unwrap();
        // Leader tracks node 4 as far behind.
        t.node(leader).next_index.insert(4, 1);
        t.node(leader).match_index.insert(4, 0);
        // MemSm has no streaming plan, so this exercises the
        // monolithic fallback.
        let m = t.node(leader).append_for(4).unwrap().remove(0);
        assert!(matches!(m, Message::InstallSnapshot { .. }), "expected snapshot, got {m:?}");
        let resp = n4.handle(leader, m).unwrap();
        assert!(n4.last_applied() >= 50);
        assert!(matches!(resp[0].1, Message::InstallSnapshotResp { .. }));
    }

    // ---- streamed snapshot protocol (DESIGN.md §8) -------------------

    use crate::raft::snap::{PlanItem, PlanSource, SnapItem};

    /// MemSm plus the six streaming hooks: the plan is one in-memory
    /// item holding the serialized KV; the sink is a byte buffer with
    /// the engine's staging semantics — staged bytes survive an abort,
    /// and a matching manifest resumes from them (a mismatch wipes).
    #[derive(Default)]
    struct StreamSm {
        inner: MemSm,
        plans_begun: u64,
        ended_plans: Vec<u64>,
        sink_manifest: Option<SnapManifest>,
        staged: Vec<u8>,
    }

    impl StateMachine for StreamSm {
        fn apply(&mut self, entry: &LogEntry, vref: VRef) -> Result<()> {
            self.inner.apply(entry, vref)
        }

        fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
            self.inner.snapshot_bytes()
        }

        fn install_snapshot(&mut self, data: &[u8], li: LogIndex, lt: Term) -> Result<()> {
            self.inner.install_snapshot(data, li, lt)
        }

        fn snap_stream_begin(
            &mut self,
            last_index: LogIndex,
            last_term: Term,
        ) -> Result<Option<SnapPlan>> {
            let blob = self.inner.snapshot_bytes()?;
            self.plans_begun += 1;
            Ok(Some(SnapPlan {
                id: self.plans_begun,
                last_index,
                last_term,
                items: vec![PlanItem {
                    name: "state.blob".to_string(),
                    len: blob.len() as u64,
                    crc: crc32fast::hash(&blob),
                    src: PlanSource::Bytes(blob),
                }],
                shape: Vec::new(),
            }))
        }

        fn snap_stream_end(&mut self, plan_id: u64) {
            self.ended_plans.push(plan_id);
        }

        fn snap_sink_begin(&mut self, manifest: &SnapManifest) -> Result<u64> {
            if self.sink_manifest.as_ref() != Some(manifest) {
                self.staged.clear();
                self.sink_manifest = Some(manifest.clone());
            }
            Ok(self.staged.len() as u64)
        }

        fn snap_sink_write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
            if offset != self.staged.len() as u64 {
                bail!("write at {offset}, staged {}", self.staged.len());
            }
            self.staged.extend_from_slice(data);
            Ok(())
        }

        fn snap_sink_commit(&mut self, last_index: LogIndex, last_term: Term) -> Result<()> {
            let Some(m) = self.sink_manifest.take() else { bail!("no sink manifest") };
            if self.staged.len() as u64 != m.total_len
                || crc32fast::hash(&self.staged) != m.items[0].crc
            {
                self.staged.clear();
                bail!("torn staging");
            }
            let staged = std::mem::take(&mut self.staged);
            self.inner.install_snapshot(&staged, last_index, last_term)
        }

        fn snap_sink_abort(&mut self) {
            // Keep `staged` (and the manifest it belongs to): it is
            // the resume point for a re-offer of the same transfer.
        }
    }

    /// A single-node leader over `StreamSm` with `puts` committed
    /// writes and its in-memory log compacted past index 1, so
    /// catching up a fresh peer must take the snapshot path — with
    /// tiny chunks so the stream spans many windows.
    fn stream_leader(name: &str, puts: u32) -> Node<StreamSm> {
        let mut n =
            Node::new(1, vec![], &tmpdir(name, 1), StreamSm::default(), Config::default(), 7)
                .unwrap();
        n.cfg.mem_keep_tail = 2;
        n.cfg.snap_chunk_bytes = 64;
        n.cfg.snap_window = 2;
        for _ in 0..200 {
            let _ = n.tick().unwrap();
            if n.is_leader() {
                break;
            }
        }
        assert!(n.is_leader());
        for i in 0..puts {
            n.propose(Command::Put {
                key: format!("k{i:03}").into_bytes(),
                value: format!("value-{i:04}").into_bytes(),
            })
            .unwrap();
            n.replicate().unwrap();
        }
        n
    }

    /// End-to-end streamed catch-up: a fresh node 4 joins behind a
    /// compacted leader and is caught up via SnapMeta/SnapChunk/SnapAck
    /// — ack-clocked and windowed — instead of one monolithic blob.
    #[test]
    fn snap_stream_catches_up_fresh_node_in_chunks() {
        let mut leader = stream_leader("streamcatch", 50);
        let mut n4 = Node::new(
            4,
            vec![1],
            &tmpdir("streamcatch", 4),
            StreamSm::default(),
            Config::default(),
            9,
        )
        .unwrap();
        leader.next_index.insert(4, 1);
        leader.match_index.insert(4, 0);

        // FIFO delivery — chunk order is preserved on a healthy link.
        let mut queue: VecDeque<(NodeId, NodeId, Message)> =
            leader.append_for(4).unwrap().into_iter().map(|m| (1, 4, m)).collect();
        assert!(
            matches!(queue[0].2, Message::SnapMeta { .. }),
            "expected a streamed offer, got {:?}",
            queue[0].2
        );
        let mut hops = 0;
        while let Some((from, to, m)) = queue.pop_front() {
            hops += 1;
            assert!(hops < 10_000, "transfer never finished");
            let out =
                if to == 1 { leader.handle(from, m).unwrap() } else { n4.handle(from, m).unwrap() };
            for (dst, msg) in out {
                queue.push_back((to, dst, msg));
            }
        }
        assert_eq!(n4.sm().inner.kv.len(), 50, "follower state installed");
        assert!(n4.last_applied() >= 50);
        // It streamed: several bounded chunks, counted on both ends,
        // and the sender released its plan pin at the end.
        assert!(leader.metrics.snap_chunks_sent >= 4, "{:?}", leader.metrics);
        assert_eq!(leader.metrics.snap_chunks_sent, n4.metrics.snap_chunks_recv);
        assert_eq!(leader.metrics.snap_streams_done, 1);
        assert_eq!(n4.metrics.snap_streams_done, 1);
        assert_eq!(leader.sm().ended_plans, vec![1]);
        // The leader now tracks 4 as caught up (AppendEntries resumed).
        assert!(*leader.match_index.get(&4).unwrap() >= 50);
    }

    /// Receiver-side chunk protocol: gaps and duplicates are never
    /// written — the cumulative re-ack rewinds the sender (go-back-N)
    /// — and the stream installs only once every byte is staged.
    #[test]
    fn snap_chunk_gap_and_duplicate_reack_cursor() {
        let mut donor = MemSm::default();
        for i in 0..8u32 {
            donor.kv.insert(format!("s{i}").into_bytes(), vec![i as u8; 9]);
        }
        let blob = donor.snapshot_bytes().unwrap();
        assert!(blob.len() > 16, "need several chunks");
        let manifest = SnapManifest {
            last_index: 30,
            last_term: 1,
            total_len: blob.len() as u64,
            items: vec![SnapItem {
                name: "state.blob".to_string(),
                len: blob.len() as u64,
                crc: crc32fast::hash(&blob),
            }],
            shape: Vec::new(),
        };
        let mut n =
            Node::new(4, vec![1], &tmpdir("snapgap", 4), StreamSm::default(), Config::default(), 5)
                .unwrap();
        let meta = Message::SnapMeta {
            term: 1,
            leader: 1,
            xfer_id: 7,
            last_index: 30,
            last_term: 1,
            manifest: manifest.encode(),
            voters: vec![1, 4],
            learners: vec![],
        };
        let out = n.handle(1, meta).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 0, done: false, .. }), "{out:?}");
        let chunk = |offset: usize, len: usize| Message::SnapChunk {
            term: 1,
            leader: 1,
            xfer_id: 7,
            offset: offset as u64,
            data: blob[offset..(offset + len).min(blob.len())].to_vec(),
        };
        // A gap (first chunk lost): nothing written, cursor re-acked.
        let out = n.handle(1, chunk(8, 4)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 0, done: false, .. }), "{out:?}");
        assert_eq!(n.metrics.snap_chunks_recv, 0);
        assert!(n.sm().staged.is_empty());
        // The in-order chunk advances the cursor.
        let out = n.handle(1, chunk(0, 8)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 8, done: false, .. }), "{out:?}");
        // A duplicate of it re-acks the cursor without re-writing.
        let out = n.handle(1, chunk(0, 8)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 8, done: false, .. }), "{out:?}");
        assert_eq!(n.sm().staged.len(), 8);
        assert_eq!(n.metrics.snap_chunks_recv, 1);
        // A chunk from an unknown transfer is ignored outright.
        let alien =
            Message::SnapChunk { term: 1, leader: 1, xfer_id: 99, offset: 8, data: vec![1] };
        assert!(n.handle(1, alien).unwrap().is_empty());
        // The rest of the stream lands and commits atomically.
        let mut off = 8;
        let mut last = Vec::new();
        while off < blob.len() {
            last = n.handle(1, chunk(off, 8)).unwrap();
            off += 8;
        }
        assert!(matches!(last[0].1, Message::SnapAck { done: true, .. }), "final ack: {last:?}");
        assert_eq!(n.sm().inner.kv, donor.kv);
        assert_eq!(n.last_applied(), 30);
        assert_eq!(n.metrics.snap_streams_done, 1);
    }

    /// Resume: staged bytes survive a superseded sink; a same-transfer
    /// re-offer re-acks the cursor; and a *new* transfer (leader
    /// change) carrying the same manifest resumes from the staged
    /// offset instead of restarting at 0.
    #[test]
    fn snap_meta_reoffer_resumes_from_staged_offset() {
        let mut donor = MemSm::default();
        for i in 0..8u32 {
            donor.kv.insert(format!("r{i}").into_bytes(), vec![i as u8; 9]);
        }
        let blob = donor.snapshot_bytes().unwrap();
        let manifest = SnapManifest {
            last_index: 30,
            last_term: 1,
            total_len: blob.len() as u64,
            items: vec![SnapItem {
                name: "state.blob".to_string(),
                len: blob.len() as u64,
                crc: crc32fast::hash(&blob),
            }],
            shape: Vec::new(),
        };
        let mut n = Node::new(
            4,
            vec![1],
            &tmpdir("snapresume", 4),
            StreamSm::default(),
            Config::default(),
            5,
        )
        .unwrap();
        let meta = |xfer_id: u64| Message::SnapMeta {
            term: 1,
            leader: 1,
            xfer_id,
            last_index: 30,
            last_term: 1,
            manifest: manifest.encode(),
            voters: vec![1, 4],
            learners: vec![],
        };
        let chunk = |xfer_id: u64, offset: usize, len: usize| Message::SnapChunk {
            term: 1,
            leader: 1,
            xfer_id,
            offset: offset as u64,
            data: blob[offset..(offset + len).min(blob.len())].to_vec(),
        };
        // Stage the first 8 bytes under transfer 7.
        let out = n.handle(1, meta(7)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 0, done: false, .. }), "{out:?}");
        let out = n.handle(1, chunk(7, 0, 8)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 8, done: false, .. }), "{out:?}");
        // A stall re-offer of the live transfer re-acks the cursor —
        // no resume, the sink never went away.
        let out = n.handle(1, meta(7)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 8, done: false, .. }), "{out:?}");
        assert_eq!(n.metrics.snap_resumes, 0);
        // A new sender offers transfer 9 with the same manifest: the
        // old sink is superseded but its staged bytes are the resume
        // point — the ack asks for offset 8, not 0.
        let out = n.handle(1, meta(9)).unwrap();
        assert!(matches!(out[0].1, Message::SnapAck { offset: 8, done: false, .. }), "{out:?}");
        assert_eq!(n.metrics.snap_resumes, 1);
        // Chunks from the dead transfer are ignored; the new one lands.
        assert!(n.handle(1, chunk(7, 8, 8)).unwrap().is_empty());
        let mut off = 8;
        let mut last = Vec::new();
        while off < blob.len() {
            last = n.handle(1, chunk(9, off, 8)).unwrap();
            off += 8;
        }
        assert!(matches!(last[0].1, Message::SnapAck { done: true, .. }), "final ack: {last:?}");
        assert_eq!(n.sm().inner.kv, donor.kv);
        assert_eq!(n.last_applied(), 30);
        assert_eq!(n.metrics.snap_streams_done, 1);
    }

    #[test]
    fn follower_truncates_conflicting_suffix() {
        // Craft a follower with a divergent entry and let an
        // AppendEntries from a newer-term leader fix it.
        let dir = tmpdir("conflict", 1);
        let mut f = Node::new(1, vec![2], &dir, MemSm::default(), Config::default(), 3).unwrap();
        // Local divergent entries at term 1.
        f.hard.term = 1;
        let old = |key: &[u8]| Command::Put { key: key.to_vec(), value: b"old".to_vec() };
        f.log.append(LogEntry { term: 1, index: 1, cmd: old(b"a") }).unwrap();
        f.log.append(LogEntry { term: 1, index: 2, cmd: old(b"b") }).unwrap();
        // Leader at term 2 replicates a different index-2.
        let out = f
            .handle(
                2,
                Message::AppendEntries {
                    term: 2,
                    leader: 2,
                    prev_log_index: 1,
                    prev_log_term: 1,
                    entries: vec![LogEntry {
                        term: 2,
                        index: 2,
                        cmd: Command::Put { key: b"b2".to_vec(), value: b"new".to_vec() },
                    }],
                    leader_commit: 2,
                    seq: 1,
                },
            )
            .unwrap();
        let resp = &out[0].1;
        assert!(
            matches!(resp, Message::AppendEntriesResp { success: true, match_index: 2, .. }),
            "{resp:?}"
        );
        assert_eq!(f.log.entry(2).unwrap().term, 2);
        assert_eq!(f.log.entry(2).unwrap().cmd.key(), b"b2");
        assert_eq!(f.last_applied(), 2);
    }

    #[test]
    fn leader_read_barrier_resolves_off_the_lease() {
        let mut t = Trio::new("leaseread");
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        // The commit round's acks armed the lease: a leader-side read
        // barrier resolves instantly, with zero messages.
        let out = t.node(leader).request_read(1).unwrap();
        assert!(out.is_empty(), "lease read should cost zero RPCs, sent {out:?}");
        let (ready, failed) = t.node(leader).take_read_results();
        assert!(failed.is_empty());
        let commit = t.node(leader).commit_index();
        assert_eq!(ready, vec![(1, commit)]);
        assert!(t.node(leader).metrics.lease_reads >= 1);
    }

    #[test]
    fn follower_read_barrier_round_trips_through_leader() {
        let mut t = Trio::new("followread");
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        let follower = t.nodes.iter().map(|n| n.id).find(|&id| id != leader).unwrap();
        let out = t.node(follower).request_read(9).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, leader);
        assert!(matches!(out[0].1, Message::ReadIndex { ctx: 9, .. }));
        let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (follower, dst, m)).collect();
        t.pump(msgs);
        let commit = t.node(leader).commit_index();
        let (ready, failed) = t.node(follower).take_read_results();
        assert!(failed.is_empty());
        assert_eq!(ready, vec![(9, commit)]);
    }

    #[test]
    fn read_barrier_pays_quorum_round_without_lease() {
        let cfg = Config { lease_reads: false, ..Config::default() };
        let mut t = Trio::with_cfg("noleaseread", cfg);
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        let out = t.node(leader).request_read(3).unwrap();
        assert_eq!(out.len(), 2, "a heartbeat round to both peers");
        // Nothing resolves until the round's echoes return.
        assert!(t.node(leader).take_read_results().0.is_empty());
        let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
        t.pump(msgs);
        let (ready, failed) = t.node(leader).take_read_results();
        assert!(failed.is_empty());
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].0, 3);
        assert_eq!(t.node(leader).metrics.read_index_rounds, 1);
        assert_eq!(t.node(leader).metrics.lease_reads, 0);
    }

    #[test]
    fn deposed_leader_fails_parked_read_barriers() {
        let cfg = Config { lease_reads: false, ..Config::default() };
        let mut t = Trio::with_cfg("deposeread", cfg);
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        // Park a barrier (its heartbeat round is never delivered), then
        // depose the leader with a higher-term vote request.
        let _dropped = t.node(leader).request_read(5).unwrap();
        let term = t.node(leader).term();
        t.node(leader)
            .handle(
                99,
                Message::RequestVote {
                    term: term + 1,
                    candidate: 99,
                    last_log_index: 1 << 30,
                    last_log_term: 1 << 30,
                    transfer: false,
                },
            )
            .unwrap();
        let (ready, failed) = t.node(leader).take_read_results();
        assert!(ready.is_empty(), "a deposed leader must not hand out read indexes");
        assert_eq!(failed, vec![5]);
    }

    #[test]
    fn read_barrier_without_known_leader_fails_fast() {
        let dir = tmpdir("noleader", 1);
        let mut n = Node::new(1, vec![2, 3], &dir, MemSm::default(), Config::default(), 1).unwrap();
        let out = n.request_read(8).unwrap();
        assert!(out.is_empty());
        let (ready, failed) = n.take_read_results();
        assert!(ready.is_empty());
        assert_eq!(failed, vec![8]);
    }

    /// Group commit, the pipelined half: `replicate()` under a budget
    /// broadcasts without a local persistence barrier, commit advances
    /// off the followers' durable acks alone (the leader's unsynced
    /// entries do not count), and the deferred flush covers the whole
    /// batch with one sync.
    #[test]
    fn group_commit_pipelines_broadcast_ahead_of_local_sync() {
        let cfg = Config { group_commit_us: 500, ..Config::default() };
        let mut t = Trio::with_cfg("groupcommit", cfg);
        let leader = t.elect();
        let syncs_before = t.node(leader).metrics.log_syncs;
        let mut last = 0;
        for i in 0..8u32 {
            let cmd = Command::Put { key: format!("g{i}").into_bytes(), value: b"v".to_vec() };
            last = t.node(leader).propose(cmd).unwrap();
        }
        let out = t.node(leader).replicate().unwrap();
        assert_eq!(
            t.node(leader).metrics.log_syncs,
            syncs_before,
            "pipelined replicate must not sync locally"
        );
        assert!(t.node(leader).has_unsynced());
        assert!(t.node(leader).durable_index < last);
        let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
        t.pump(msgs);
        // Both followers persisted and acked: committed without the
        // leader's own durability.
        assert!(t.node(leader).commit_index() >= last, "quorum of durable followers commits");
        assert!(t.node(leader).durable_index < last, "leader still unsynced");
        // The timed-out budget flushes the partial batch in one go.
        t.node(leader).flush_group_commit().unwrap();
        assert!(!t.node(leader).has_unsynced());
        let m = &t.node(leader).metrics;
        assert_eq!(m.log_syncs, syncs_before + 1, "one sync covered the whole batch");
        assert_eq!(m.group_commit_batches, 1);
        assert_eq!(m.group_commit_entries, 8);
        assert_eq!(m.group_commit_max_batch, 8);
        assert!(m.entries_committed >= 8);
    }

    /// On a single-node cluster the quorum IS the leader, so under a
    /// group-commit budget nothing commits until the flush makes the
    /// batch durable — and the flush of a timed-out budget does commit
    /// the partial batch.
    #[test]
    fn group_commit_budget_defers_single_node_commit_until_flush() {
        let dir = tmpdir("gcsolo", 1);
        let cfg = Config { group_commit_us: 1_000, ..Config::default() };
        let mut n = Node::new(1, vec![], &dir, MemSm::default(), cfg, 9).unwrap();
        while !n.is_leader() {
            n.tick().unwrap();
        }
        let cmd = Command::Put { key: b"solo".to_vec(), value: b"v".to_vec() };
        let idx = n.propose(cmd).unwrap();
        let out = n.replicate().unwrap();
        assert!(out.is_empty());
        assert!(n.commit_index() < idx, "commit must wait for the flush");
        assert!(n.has_unsynced());
        n.flush_group_commit().unwrap();
        assert_eq!(n.commit_index(), idx);
        assert_eq!(n.last_applied(), idx);
        // Idempotent when clean.
        n.flush_group_commit().unwrap();
        assert_eq!(n.metrics.group_commit_batches, 1);
    }

    /// Apply-lane hand-off: committed entries queue instead of
    /// applying inline, the public applied cursor lags until the
    /// applier drains the chunk, and close()/pop_chunk() terminate.
    #[test]
    fn apply_lane_decouples_commit_from_apply() {
        let dir = tmpdir("lane", 1);
        let mut n = Node::new(1, vec![], &dir, MemSm::default(), Config::default(), 11).unwrap();
        let lane = ApplyLane::new();
        let rings = Arc::new(AtomicU64::new(0));
        let rings2 = Arc::clone(&rings);
        lane.set_waker(Box::new(move || {
            rings2.fetch_add(1, Ordering::SeqCst);
        }));
        n.attach_apply_lane(Arc::clone(&lane));
        while !n.is_leader() {
            n.tick().unwrap();
        }
        let cmd = Command::Put { key: b"k".to_vec(), value: b"v".to_vec() };
        let idx = n.propose(cmd).unwrap();
        n.replicate().unwrap();
        assert!(n.commit_index() >= idx, "commit does not wait for apply");
        assert!(n.last_applied() < idx, "handed off, not yet applied");
        assert!(n.sm().kv.is_empty(), "engine untouched before the applier runs");
        assert!(rings.load(Ordering::SeqCst) >= 1, "push rings the doorbell");
        assert!(lane.depth_max() >= 1);
        // Drive the applier protocol by hand.
        let (g, chunk) = lane.pop_chunk(16).unwrap();
        assert_eq!(chunk.len(), 2, "noop + put");
        for (i, e, v) in chunk {
            assert_eq!(lane.generation(), g);
            n.sm_mut().apply(&e, v).unwrap();
            lane.set_applied(i);
        }
        assert_eq!(n.last_applied(), idx);
        assert_eq!(n.sm().kv.get(&b"k".to_vec()), Some(&b"v".to_vec()));
        // Graceful close drains-then-ends; pop on empty+closed is None.
        lane.close();
        assert!(lane.pop_chunk(16).is_none());
    }

    #[test]
    fn apply_lane_discard_drops_queued_work() {
        let lane = ApplyLane::new();
        lane.push(1, LogEntry { term: 1, index: 1, cmd: Command::Noop }, VRef::new(0, 0));
        assert_eq!(lane.depth(), 1);
        lane.close_discard();
        assert!(lane.pop_chunk(16).is_none());
        assert_eq!(lane.depth(), 0);
    }

    #[test]
    fn stale_term_append_rejected() {
        let dir = tmpdir("staleappend", 1);
        let mut n = Node::new(1, vec![2], &dir, MemSm::default(), Config::default(), 5).unwrap();
        n.hard.term = 10;
        let out = n
            .handle(
                2,
                Message::AppendEntries {
                    term: 3,
                    leader: 2,
                    prev_log_index: 0,
                    prev_log_term: 0,
                    entries: vec![],
                    leader_commit: 0,
                    seq: 1,
                },
            )
            .unwrap();
        assert!(matches!(out[0].1, Message::AppendEntriesResp { success: false, term: 10, .. }));
        assert_eq!(n.role(), Role::Follower);
    }

    // ---- membership (DESIGN.md §9) -----------------------------------

    /// Like [`Trio`] but with a dynamic roster: nodes can join (as
    /// learners) and leave, and messages to absent nodes are dropped.
    struct Group {
        name: &'static str,
        cfg: Config,
        nodes: Vec<Node<MemSm>>,
    }

    impl Group {
        fn new(name: &'static str, ids: &[u64]) -> Self {
            Self::with_cfg(name, ids, Config::default())
        }

        fn with_cfg(name: &'static str, ids: &[u64], cfg: Config) -> Self {
            let nodes = ids
                .iter()
                .map(|&id| {
                    let peers: Vec<u64> = ids.iter().copied().filter(|&p| p != id).collect();
                    Node::new(id, peers, &tmpdir(name, id), MemSm::default(), cfg.clone(), 42)
                        .unwrap()
                })
                .collect();
            Self { name, cfg, nodes }
        }

        fn add_learner(&mut self, id: u64, voters: Vec<u64>) {
            let n = Node::new_learner(
                id,
                voters,
                &tmpdir(self.name, id),
                MemSm::default(),
                self.cfg.clone(),
                100 + id,
            )
            .unwrap();
            self.nodes.push(n);
        }

        fn has(&self, id: NodeId) -> bool {
            self.nodes.iter().any(|n| n.id == id)
        }

        fn node(&mut self, id: NodeId) -> &mut Node<MemSm> {
            self.nodes.iter_mut().find(|n| n.id == id).unwrap()
        }

        fn stop(&mut self, id: NodeId) {
            self.nodes.retain(|n| n.id != id);
        }

        fn pump(&mut self, mut msgs: Vec<(NodeId, NodeId, Message)>) {
            while let Some((from, to, m)) = msgs.pop() {
                if !self.has(to) {
                    continue; // departed / not yet started
                }
                let out = self.node(to).handle(from, m).unwrap();
                for (dst, msg) in out {
                    msgs.push((to, dst, msg));
                }
            }
        }

        fn tick_all(&mut self) {
            let mut msgs = Vec::new();
            for n in &mut self.nodes {
                let id = n.id;
                for (dst, m) in n.tick().unwrap() {
                    msgs.push((id, dst, m));
                }
            }
            self.pump(msgs);
        }

        /// Heartbeat rounds: enough ticks for every node to converge on
        /// the latest log and config.
        fn settle(&mut self, rounds: usize) {
            for _ in 0..rounds * Config::default().heartbeat_interval as usize {
                self.tick_all();
            }
        }

        fn elect(&mut self) -> NodeId {
            for _ in 0..500 {
                self.tick_all();
                if let Some(l) = self.nodes.iter().find(|n| n.is_leader()) {
                    return l.id;
                }
            }
            panic!("no leader elected");
        }

        fn propose_and_commit(&mut self, leader: NodeId, cmd: Command) -> LogIndex {
            let idx = self.node(leader).propose(cmd).unwrap();
            let out = self.node(leader).replicate().unwrap();
            let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
            self.pump(msgs);
            idx
        }

        /// Propose a conf change and pump it (plus a few heartbeat
        /// rounds) so append-time activation, commit, and any follow-on
        /// auto-promotion all land.
        fn change(&mut self, leader: NodeId, cc: ConfChange) {
            self.node(leader).propose_conf(cc).unwrap();
            let out = self.node(leader).replicate().unwrap();
            let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
            self.pump(msgs);
            self.settle(3);
        }
    }

    /// The acceptance shape at node level: 2 voters grow to 3, then 4
    /// (learner catch-up + auto-promotion), then shrink back to 3 —
    /// with writes committing through every transition.
    #[test]
    fn quorum_matrix_2_to_3_to_4_to_3() {
        let mut g = Group::new("matrix", &[1, 2]);
        let leader = g.elect();
        assert_eq!(g.node(leader).voters(), &[1, 2]);
        g.propose_and_commit(leader, Command::Put { key: b"a".to_vec(), value: b"1".to_vec() });

        // 2 -> 3: add node 3 as a learner; replication catches it up
        // and the leader auto-promotes it.
        g.add_learner(3, vec![1, 2]);
        g.change(leader, ConfChange::AddLearner(3));
        assert_eq!(g.node(leader).voters(), &[1, 2, 3], "learner auto-promoted");
        assert!(g.node(leader).learners().is_empty());
        g.propose_and_commit(leader, Command::Put { key: b"b".to_vec(), value: b"2".to_vec() });

        // 3 -> 4.
        g.add_learner(4, vec![1, 2, 3]);
        g.change(leader, ConfChange::AddLearner(4));
        assert_eq!(g.node(leader).voters(), &[1, 2, 3, 4]);
        g.propose_and_commit(leader, Command::Put { key: b"c".to_vec(), value: b"3".to_vec() });

        // 4 -> 3: remove a follower.
        let victim = *g.node(leader).voters().iter().find(|&&v| v != leader).unwrap();
        g.change(leader, ConfChange::Remove(victim));
        let want: Vec<u64> = [1u64, 2, 3, 4].iter().copied().filter(|&v| v != victim).collect();
        assert_eq!(g.node(leader).voters(), &want[..]);
        g.stop(victim);
        let idx = g
            .propose_and_commit(leader, Command::Put { key: b"d".to_vec(), value: b"4".to_vec() });
        g.settle(3);
        // Every remaining member converges on the full history.
        assert!(g.node(leader).commit_index() >= idx);
        for id in want {
            let n = g.node(id);
            assert!(n.last_applied() >= idx, "node {id} behind");
            assert_eq!(n.sm().kv.get(&b"d".to_vec()), Some(&b"4".to_vec()));
        }
    }

    /// A second change is refused while one is uncommitted, and the
    /// argument checks reject nonsensical changes outright.
    #[test]
    fn one_conf_change_in_flight() {
        let mut g = Group::new("inflight", &[1, 2, 3]);
        let leader = g.elect();
        // Argument validation against the current config.
        assert!(g
            .node(leader)
            .propose_conf(ConfChange::AddLearner(1))
            .unwrap_err()
            .to_string()
            .contains("already a member"));
        assert!(g
            .node(leader)
            .propose_conf(ConfChange::Promote(2))
            .unwrap_err()
            .to_string()
            .contains("already a voter"));
        assert!(g
            .node(leader)
            .propose_conf(ConfChange::Remove(99))
            .unwrap_err()
            .to_string()
            .contains("not a member"));
        // Append (don't commit) one change: the next is refused.
        g.node(leader).propose_conf(ConfChange::AddLearner(4)).unwrap();
        let err = g.node(leader).propose_conf(ConfChange::AddLearner(5)).unwrap_err();
        assert!(err.to_string().contains("conf change in flight"), "{err}");
        // Commit it; the gate lifts.
        let out = g.node(leader).replicate().unwrap();
        let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
        g.pump(msgs);
        g.settle(2);
        g.node(leader).propose_conf(ConfChange::Remove(4)).unwrap();
    }

    /// advance_commit counts only active-config voters: a learner's
    /// ack can never commit an entry, a voter's can.
    #[test]
    fn learner_acks_do_not_advance_commit() {
        let cfg = Config { promote_lag: 0, ..Config::default() };
        let mut g = Group::with_cfg("learnerack", &[1, 2, 3], cfg);
        let leader = g.elect();
        g.add_learner(4, vec![1, 2, 3]);
        g.change(leader, ConfChange::AddLearner(4));
        assert_eq!(g.node(leader).learners(), &[4], "promote_lag=0 keeps it a learner");
        let idx = g
            .node(leader)
            .propose(Command::Put { key: b"k".to_vec(), value: b"v".to_vec() })
            .unwrap();
        let out = g.node(leader).replicate().unwrap();
        // Deliver ONLY the learner's copy (and its ack).
        let to_learner: Vec<_> = out
            .iter()
            .filter(|(dst, _)| *dst == 4)
            .map(|(dst, m)| (leader, *dst, m.clone()))
            .collect();
        g.pump(to_learner);
        assert!(
            g.node(leader).commit_index() < idx,
            "a learner ack must not commit (leader + learner is not a quorum of 3 voters)"
        );
        // One voter ack tips it: leader durable + voter = 2 of 3.
        let to_voter: Vec<_> = out
            .into_iter()
            .filter(|(dst, _)| *dst == 2)
            .map(|(dst, m)| (leader, dst, m))
            .collect();
        g.pump(to_voter);
        assert!(g.node(leader).commit_index() >= idx);
    }

    /// ReadIndex quorum rounds likewise ignore learner echoes.
    #[test]
    fn read_barrier_ignores_learner_acks() {
        let cfg = Config { promote_lag: 0, lease_reads: false, ..Config::default() };
        let mut g = Group::with_cfg("learnerread", &[1, 2, 3], cfg);
        let leader = g.elect();
        g.add_learner(4, vec![1, 2, 3]);
        g.change(leader, ConfChange::AddLearner(4));
        g.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        g.settle(2);
        let out = g.node(leader).request_read(9).unwrap();
        let to_learner: Vec<_> = out
            .iter()
            .filter(|(dst, _)| *dst == 4)
            .map(|(dst, m)| (leader, *dst, m.clone()))
            .collect();
        g.pump(to_learner);
        assert!(
            g.node(leader).take_read_results().0.is_empty(),
            "learner echo must not confirm leadership"
        );
        let to_voter: Vec<_> = out
            .into_iter()
            .filter(|(dst, _)| *dst == 3)
            .map(|(dst, m)| (leader, dst, m))
            .collect();
        g.pump(to_voter);
        let (ready, _) = g.node(leader).take_read_results();
        assert_eq!(ready.len(), 1, "voter echo completes the barrier");
    }

    /// A removed node campaigning on its stale config (which still
    /// lists itself) is denied by members that applied the removal —
    /// even with a perfect log and the transfer flag set.
    #[test]
    fn removed_node_cannot_win_election_with_stale_config() {
        let mut g = Group::new("staleconf", &[1, 2, 3]);
        let leader = g.elect();
        let victim = *g.node(leader).voters().iter().find(|&&v| v != leader).unwrap();
        // Remove it, but never deliver anything to it: its own config
        // still lists all three.
        g.node(leader).propose_conf(ConfChange::Remove(victim)).unwrap();
        let out = g.node(leader).replicate().unwrap();
        let msgs: Vec<_> = out
            .into_iter()
            .filter(|(dst, _)| *dst != victim)
            .map(|(dst, m)| (leader, dst, m))
            .collect();
        g.pump(msgs);
        assert!(!g.node(leader).voters().contains(&victim));
        assert!(g.node(victim).voters().contains(&victim), "victim's view is stale");
        // Best possible campaign from the victim: huge term, perfect
        // log, transfer flag bypassing stickiness.
        let term = g.node(leader).term();
        let vote = Message::RequestVote {
            term: term + 10,
            candidate: victim,
            last_log_index: 1 << 30,
            last_log_term: 1 << 30,
            transfer: true,
        };
        for id in [1u64, 2, 3] {
            if id == victim {
                continue;
            }
            let out = g.node(id).handle(victim, vote.clone()).unwrap();
            assert!(
                matches!(out[0].1, Message::RequestVoteResp { granted: false, .. }),
                "node {id} granted a vote to removed node {victim}"
            );
        }
    }

    /// A leader that removes itself keeps leading (without counting
    /// itself) until the Remove commits, then steps down and hands
    /// leadership over via TimeoutNow — the successor wins inside the
    /// old lease window thanks to the transfer flag.
    #[test]
    fn leader_self_removal_steps_down_and_transfers() {
        let mut g = Group::new("selfremove", &[1, 2, 3]);
        let leader = g.elect();
        g.propose_and_commit(leader, Command::Put { key: b"k".to_vec(), value: b"v".to_vec() });
        g.node(leader).propose_conf(ConfChange::Remove(leader)).unwrap();
        assert!(g.node(leader).is_leader(), "keeps leading until the Remove commits");
        let out = g.node(leader).replicate().unwrap();
        let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
        g.pump(msgs);
        // Commit happened (two remaining voters acked): the old leader
        // stepped down and the TimeoutNow produced a successor without
        // waiting out an election timeout.
        assert!(!g.node(leader).is_leader());
        let new_leader = g.nodes.iter().find(|n| n.is_leader()).expect("transfer elected").id;
        assert_ne!(new_leader, leader);
        assert!(!g.node(new_leader).voters().contains(&leader));
        // The cluster still commits writes.
        let idx = g.propose_and_commit(
            new_leader,
            Command::Put { key: b"k2".to_vec(), value: b"v2".to_vec() },
        );
        assert!(g.node(new_leader).commit_index() >= idx);
    }

    /// Learners never campaign, no matter how long the leader is
    /// silent.
    #[test]
    fn learner_never_campaigns() {
        let dir = tmpdir("learnquiet", 9);
        let mut n =
            Node::new_learner(9, vec![1, 2, 3], &dir, MemSm::default(), Config::default(), 3)
                .unwrap();
        for _ in 0..10 * Config::default().election_timeout_max {
            let out = n.tick().unwrap();
            assert!(out.is_empty(), "learner sent {out:?}");
        }
        assert_eq!(n.role(), Role::Follower);
        assert_eq!(n.term(), 0, "no term bumps from a learner");
    }

    /// The members sidecar outranks constructor args: a crashed
    /// learner restarts as a learner, even if reopened through the
    /// plain constructor.
    #[test]
    fn learner_restart_stays_learner() {
        let dir = tmpdir("learnrestart", 5);
        {
            let n = Node::new_learner(
                5,
                vec![1, 2, 3],
                &dir,
                MemSm::default(),
                Config::default(),
                3,
            )
            .unwrap();
            assert_eq!(n.voters(), &[1, 2, 3]);
            assert_eq!(n.learners(), &[5]);
        }
        // Reopen as if the coordinator passed full-cluster peers.
        let n = Node::new(5, vec![1, 2, 3], &dir, MemSm::default(), Config::default(), 3).unwrap();
        assert_eq!(n.voters(), &[1, 2, 3], "sidecar overrides constructor");
        assert_eq!(n.learners(), &[5]);
    }
}
