//! The Raft node state machine (leader election, log replication,
//! commit, apply, snapshot install) — deterministic and message-
//! driven: `tick()` advances logical time, `handle()` processes one
//! inbound message, and both return the outbound messages to send.
//! The transport/cluster layers own threads and clocks; this module
//! owns correctness.

use super::log::{HardState, RaftLog};
use super::rpc::{Command, LogEntry, LogIndex, Message, Term};
use crate::util::Rng;
use crate::vlog::VRef;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

pub type NodeId = u64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// What a Raft node drives: the storage engine's apply/snapshot hooks.
/// `apply` receives the ValueLog offset of the entry — Nezha's state
/// machines store it; baselines ignore it and re-persist the value.
pub trait StateMachine: Send {
    fn apply(&mut self, entry: &LogEntry, vref: VRef) -> Result<()>;
    /// Serialize current state for follower catch-up.
    fn snapshot_bytes(&mut self) -> Result<Vec<u8>>;
    /// Replace state with a received snapshot.
    fn install_snapshot(&mut self, data: &[u8], last_index: LogIndex, last_term: Term) -> Result<()>;
    /// Conflict resolution truncated (and will rewrite) the log suffix;
    /// epoch files `>= live_epoch` changed in place.  Engines that
    /// cache ValueLog bytes must drop cached state for those epochs —
    /// the rewritten entries were never committed, so applied state is
    /// unaffected.  Default: nothing cached, nothing to do.
    fn on_log_truncated(&mut self, _live_epoch: u32) {}
}

/// Tunables (times in ticks; the cluster maps ticks to wall time).
#[derive(Clone, Debug)]
pub struct Config {
    pub election_timeout_min: u64,
    pub election_timeout_max: u64,
    pub heartbeat_interval: u64,
    /// Max payload bytes per AppendEntries.
    pub max_batch_bytes: usize,
    /// In-memory log tail kept after apply (for slow followers).
    pub mem_keep_tail: u64,
    /// fsync the log at persistence points (tests: on; benches choose
    /// one policy for all baselines).
    pub fsync: bool,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            election_timeout_min: 20,
            election_timeout_max: 40,
            heartbeat_interval: 5,
            max_batch_bytes: 1 << 20,
            mem_keep_tail: 1024,
            fsync: false,
        }
    }
}

/// Outbound message with destination.
pub type Outbox = Vec<(NodeId, Message)>;

/// Counters for the bench harness.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeMetrics {
    pub msgs_sent: u64,
    pub elections_started: u64,
    pub snapshots_sent: u64,
    pub snapshots_installed: u64,
    pub entries_applied: u64,
}

pub struct Node<S: StateMachine> {
    pub id: NodeId,
    peers: Vec<NodeId>,
    role: Role,
    hard: HardState,
    hard_path: std::path::PathBuf,
    pub log: RaftLog,
    commit_index: LogIndex,
    last_applied: LogIndex,
    // Leader volatile state.
    next_index: HashMap<NodeId, LogIndex>,
    match_index: HashMap<NodeId, LogIndex>,
    votes: usize,
    leader_hint: Option<NodeId>,
    // Timing (logical ticks).
    ticks: u64,
    election_deadline: u64,
    last_heartbeat: u64,
    rng: Rng,
    cfg: Config,
    sm: S,
    pub metrics: NodeMetrics,
}

impl<S: StateMachine> Node<S> {
    pub fn new(
        id: NodeId,
        peers: Vec<NodeId>,
        dir: &Path,
        sm: S,
        cfg: Config,
        seed: u64,
    ) -> Result<Self> {
        let log = RaftLog::open(dir)?;
        let hard_path = dir.join("hardstate");
        let hard = HardState::load(&hard_path)?.unwrap_or_default();
        let mut rng = Rng::new(seed ^ id.wrapping_mul(0x9E37_79B9));
        let election_deadline = Self::rand_deadline(&mut rng, &cfg, 0);
        Ok(Self {
            id,
            peers,
            role: Role::Follower,
            hard,
            hard_path,
            log,
            commit_index: 0,
            last_applied: 0,
            next_index: HashMap::new(),
            match_index: HashMap::new(),
            votes: 0,
            leader_hint: None,
            ticks: 0,
            election_deadline,
            last_heartbeat: 0,
            rng,
            cfg,
            sm,
            metrics: NodeMetrics::default(),
        })
    }

    fn rand_deadline(rng: &mut Rng, cfg: &Config, now: u64) -> u64 {
        now + rng.range(cfg.election_timeout_min, cfg.election_timeout_max + 1)
    }

    // ---- observers -------------------------------------------------

    pub fn role(&self) -> Role {
        self.role
    }

    pub fn term(&self) -> Term {
        self.hard.term
    }

    pub fn commit_index(&self) -> LogIndex {
        self.commit_index
    }

    pub fn last_applied(&self) -> LogIndex {
        self.last_applied
    }

    pub fn leader_hint(&self) -> Option<NodeId> {
        if self.role == Role::Leader {
            Some(self.id)
        } else {
            self.leader_hint
        }
    }

    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    pub fn sm(&self) -> &S {
        &self.sm
    }

    pub fn sm_mut(&mut self) -> &mut S {
        &mut self.sm
    }

    fn quorum(&self) -> usize {
        (self.peers.len() + 1) / 2 + 1
    }

    // ---- persistence helpers ---------------------------------------

    fn persist_hard(&mut self) -> Result<()> {
        self.hard.save(&self.hard_path)
    }

    fn persist_log(&mut self) -> Result<()> {
        if self.cfg.fsync {
            self.log.sync()
        } else {
            self.log.flush()
        }
    }

    // ---- time ------------------------------------------------------

    /// Advance one logical tick.
    pub fn tick(&mut self) -> Result<Outbox> {
        self.ticks += 1;
        match self.role {
            Role::Leader => {
                if self.ticks - self.last_heartbeat >= self.cfg.heartbeat_interval {
                    return self.broadcast_append();
                }
                Ok(Vec::new())
            }
            Role::Follower | Role::Candidate => {
                if self.ticks >= self.election_deadline {
                    return self.start_election();
                }
                Ok(Vec::new())
            }
        }
    }

    fn reset_election_timer(&mut self) {
        self.election_deadline = Self::rand_deadline(&mut self.rng, &self.cfg, self.ticks);
    }

    // ---- elections ---------------------------------------------------

    fn start_election(&mut self) -> Result<Outbox> {
        self.role = Role::Candidate;
        self.hard.term += 1;
        self.hard.voted_for = Some(self.id);
        self.persist_hard()?;
        self.votes = 1;
        self.reset_election_timer();
        self.metrics.elections_started += 1;
        if self.votes >= self.quorum() {
            // Single-node cluster: win immediately.
            return self.become_leader();
        }
        let msg = Message::RequestVote {
            term: self.hard.term,
            candidate: self.id,
            last_log_index: self.log.last_index(),
            last_log_term: self.log.last_term(),
        };
        Ok(self.to_all(msg))
    }

    fn to_all(&mut self, msg: Message) -> Outbox {
        self.metrics.msgs_sent += self.peers.len() as u64;
        self.peers.iter().map(|&p| (p, msg.clone())).collect()
    }

    fn become_follower(&mut self, term: Term, leader: Option<NodeId>) -> Result<()> {
        if term > self.hard.term {
            self.hard.term = term;
            self.hard.voted_for = None;
            self.persist_hard()?;
        }
        self.role = Role::Follower;
        if leader.is_some() {
            self.leader_hint = leader;
        }
        self.reset_election_timer();
        Ok(())
    }

    fn become_leader(&mut self) -> Result<Outbox> {
        self.role = Role::Leader;
        self.leader_hint = Some(self.id);
        self.next_index.clear();
        self.match_index.clear();
        for &p in &self.peers {
            self.next_index.insert(p, self.log.last_index() + 1);
            self.match_index.insert(p, 0);
        }
        // Commit barrier for prior-term entries (§5.4.2).
        let idx = self.log.last_index() + 1;
        self.log.append(LogEntry { term: self.hard.term, index: idx, cmd: Command::Noop })?;
        self.persist_log()?;
        self.broadcast_append()
    }

    // ---- client -----------------------------------------------------

    /// Leader-only: append a command; returns its log index.  The
    /// caller learns commit by watching `last_applied()`.
    pub fn propose(&mut self, cmd: Command) -> Result<LogIndex> {
        if self.role != Role::Leader {
            bail!("not leader (hint: {:?})", self.leader_hint());
        }
        let index = self.log.last_index() + 1;
        self.log.append(LogEntry { term: self.hard.term, index, cmd })?;
        Ok(index)
    }

    /// The ValueLog offset for a proposed index (Nezha engines store
    /// this in the state machine).
    pub fn vref_of(&self, index: LogIndex) -> Option<VRef> {
        self.log.vref_of(index)
    }

    /// Replicate everything pending to all peers (call after a batch
    /// of proposes — the coordinator's group-commit point).
    pub fn replicate(&mut self) -> Result<Outbox> {
        if self.role != Role::Leader {
            return Ok(Vec::new());
        }
        self.persist_log()?;
        // Single-node cluster: commit immediately.
        if self.peers.is_empty() {
            self.advance_commit()?;
        }
        self.broadcast_append()
    }

    fn broadcast_append(&mut self) -> Result<Outbox> {
        self.last_heartbeat = self.ticks;
        let mut out = Vec::new();
        let peers = self.peers.clone();
        for p in peers {
            if let Some(m) = self.append_for(p)? {
                self.metrics.msgs_sent += 1;
                out.push((p, m));
            }
        }
        Ok(out)
    }

    fn append_for(&mut self, peer: NodeId) -> Result<Option<Message>> {
        let next = *self.next_index.get(&peer).unwrap_or(&1);
        // Peer too far behind the in-memory log → ship a snapshot.
        if next <= self.log.snap_index || (next < self.log.first_in_mem() && next <= self.log.last_index())
        {
            let data = self.sm.snapshot_bytes()?;
            self.metrics.snapshots_sent += 1;
            // Snapshot covers the applied prefix.
            let last_index = self.last_applied.max(self.log.snap_index);
            let last_term = self.log.term_at(last_index).unwrap_or(self.log.snap_term);
            return Ok(Some(Message::InstallSnapshot {
                term: self.hard.term,
                leader: self.id,
                last_index,
                last_term,
                data,
            }));
        }
        let prev = next - 1;
        let Some(prev_term) = self.log.term_at(prev) else {
            // prev fell out of memory between checks — snapshot path
            // next round.
            return Ok(None);
        };
        let entries = self.log.entries(next, self.log.last_index(), self.cfg.max_batch_bytes);
        Ok(Some(Message::AppendEntries {
            term: self.hard.term,
            leader: self.id,
            prev_log_index: prev,
            prev_log_term: prev_term,
            entries,
            leader_commit: self.commit_index,
        }))
    }

    // ---- message handling --------------------------------------------

    pub fn handle(&mut self, from: NodeId, msg: Message) -> Result<Outbox> {
        if msg.term() > self.hard.term {
            let leader = match &msg {
                Message::AppendEntries { leader, .. } | Message::InstallSnapshot { leader, .. } => {
                    Some(*leader)
                }
                _ => None,
            };
            self.become_follower(msg.term(), leader)?;
        }
        match msg {
            Message::RequestVote { term, candidate, last_log_index, last_log_term } => {
                self.on_request_vote(from, term, candidate, last_log_index, last_log_term)
            }
            Message::RequestVoteResp { term, granted } => self.on_vote_resp(term, granted),
            Message::AppendEntries { term, leader, prev_log_index, prev_log_term, entries, leader_commit } => {
                self.on_append(from, term, leader, prev_log_index, prev_log_term, entries, leader_commit)
            }
            Message::AppendEntriesResp { term, success, match_index } => {
                self.on_append_resp(from, term, success, match_index)
            }
            Message::InstallSnapshot { term, leader, last_index, last_term, data } => {
                self.on_install_snapshot(from, term, leader, last_index, last_term, data)
            }
            Message::InstallSnapshotResp { term, last_index } => {
                self.on_snapshot_resp(from, term, last_index)
            }
        }
    }

    fn on_request_vote(
        &mut self,
        from: NodeId,
        term: Term,
        candidate: NodeId,
        last_log_index: LogIndex,
        last_log_term: Term,
    ) -> Result<Outbox> {
        let mut granted = false;
        if term == self.hard.term {
            let can_vote =
                self.hard.voted_for.is_none() || self.hard.voted_for == Some(candidate);
            // §5.4.1 up-to-date check.
            let up_to_date = last_log_term > self.log.last_term()
                || (last_log_term == self.log.last_term()
                    && last_log_index >= self.log.last_index());
            if can_vote && up_to_date {
                granted = true;
                self.hard.voted_for = Some(candidate);
                self.persist_hard()?;
                self.reset_election_timer();
            }
        }
        self.metrics.msgs_sent += 1;
        Ok(vec![(from, Message::RequestVoteResp { term: self.hard.term, granted })])
    }

    fn on_vote_resp(&mut self, term: Term, granted: bool) -> Result<Outbox> {
        if self.role != Role::Candidate || term != self.hard.term {
            return Ok(Vec::new());
        }
        if granted {
            self.votes += 1;
            if self.votes >= self.quorum() {
                return self.become_leader();
            }
        }
        Ok(Vec::new())
    }

    #[allow(clippy::too_many_arguments)]
    fn on_append(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        prev_log_index: LogIndex,
        prev_log_term: Term,
        entries: Vec<LogEntry>,
        leader_commit: LogIndex,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::AppendEntriesResp { term: self.hard.term, success: false, match_index: 0 },
            )]);
        }
        // Valid leader for this term.
        self.become_follower(term, Some(leader))?;

        // Consistency check on prev.
        let prev_ok = if prev_log_index == 0 {
            true
        } else if prev_log_index < self.log.snap_index {
            // Leader is behind our snapshot — treat as matching at
            // snapshot point.
            true
        } else {
            self.log.term_at(prev_log_index) == Some(prev_log_term)
        };
        if !prev_ok {
            // Conflict hint: ask the leader to back up to our last
            // index (fast path) or below prev.
            let hint = self.log.last_index().min(prev_log_index.saturating_sub(1));
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::AppendEntriesResp {
                    term: self.hard.term,
                    success: false,
                    match_index: hint,
                },
            )]);
        }

        // Append new entries, truncating conflicts.
        for e in entries {
            if e.index <= self.log.snap_index {
                continue; // covered by snapshot
            }
            match self.log.term_at(e.index) {
                Some(t) if t == e.term => continue, // already have it
                Some(_) => {
                    // Conflict: truncate suffix then append.  The live
                    // epoch file (possibly a reopened frozen one) is
                    // rewritten in place from here on — readahead
                    // caches over it are now stale.
                    self.log.truncate_from(e.index)?;
                    self.sm.on_log_truncated(self.log.live_epoch());
                    self.log.append(e)?;
                }
                None => {
                    if e.index == self.log.last_index() + 1 {
                        self.log.append(e)?;
                    }
                    // else: gap (stale message) — ignore remainder
                }
            }
        }
        self.persist_log()?;

        let match_index = self.log.last_index();
        if leader_commit > self.commit_index {
            self.commit_index = leader_commit.min(match_index);
            self.apply_committed()?;
        }
        self.metrics.msgs_sent += 1;
        Ok(vec![(
            from,
            Message::AppendEntriesResp { term: self.hard.term, success: true, match_index },
        )])
    }

    fn on_append_resp(
        &mut self,
        from: NodeId,
        term: Term,
        success: bool,
        match_index: LogIndex,
    ) -> Result<Outbox> {
        if self.role != Role::Leader || term != self.hard.term {
            return Ok(Vec::new());
        }
        if success {
            self.match_index.insert(from, match_index);
            self.next_index.insert(from, match_index + 1);
            self.advance_commit()?;
            // More to send?
            if match_index < self.log.last_index() {
                if let Some(m) = self.append_for(from)? {
                    self.metrics.msgs_sent += 1;
                    return Ok(vec![(from, m)]);
                }
            }
        } else {
            // Back up using the follower's hint.
            let next = self.next_index.entry(from).or_insert(1);
            *next = (match_index + 1).min((*next).saturating_sub(1)).max(1);
            if let Some(m) = self.append_for(from)? {
                self.metrics.msgs_sent += 1;
                return Ok(vec![(from, m)]);
            }
        }
        Ok(Vec::new())
    }

    fn advance_commit(&mut self) -> Result<()> {
        // Largest N replicated on a quorum with term == current (§5.4.2).
        let mut candidates: Vec<LogIndex> = self
            .match_index
            .values()
            .copied()
            .chain(std::iter::once(self.log.last_index()))
            .collect();
        candidates.sort_unstable();
        // The (len - quorum)-th from the end is replicated on >= quorum.
        let n = candidates[candidates.len().saturating_sub(self.quorum())];
        if n > self.commit_index && self.log.term_at(n) == Some(self.hard.term) {
            self.commit_index = n;
            self.apply_committed()?;
        }
        Ok(())
    }

    fn apply_committed(&mut self) -> Result<()> {
        while self.last_applied < self.commit_index {
            let idx = self.last_applied + 1;
            let Some(entry) = self.log.entry(idx).cloned() else {
                // Entry not in memory: snapshot already covers it.
                self.last_applied = self.log.snap_index.min(self.commit_index);
                if self.last_applied < idx {
                    bail!("apply gap at {idx}");
                }
                continue;
            };
            let vref = self.log.vref_of(idx).unwrap_or(VRef::new(0, 0));
            self.sm.apply(&entry, vref)?;
            self.metrics.entries_applied += 1;
            self.last_applied = idx;
        }
        self.log.compact_mem(self.last_applied, self.cfg.mem_keep_tail);
        Ok(())
    }

    fn on_install_snapshot(
        &mut self,
        from: NodeId,
        term: Term,
        leader: NodeId,
        last_index: LogIndex,
        last_term: Term,
        data: Vec<u8>,
    ) -> Result<Outbox> {
        if term < self.hard.term {
            self.metrics.msgs_sent += 1;
            return Ok(vec![(
                from,
                Message::InstallSnapshotResp { term: self.hard.term, last_index: self.log.last_index() },
            )]);
        }
        self.become_follower(term, Some(leader))?;
        if last_index > self.log.snap_index && last_index > self.last_applied {
            self.sm.install_snapshot(&data, last_index, last_term)?;
            self.log.reset_to_snapshot(last_index, last_term)?;
            self.commit_index = last_index;
            self.last_applied = last_index;
            self.metrics.snapshots_installed += 1;
        }
        self.metrics.msgs_sent += 1;
        Ok(vec![(
            from,
            Message::InstallSnapshotResp { term: self.hard.term, last_index: self.log.last_index() },
        )])
    }

    fn on_snapshot_resp(&mut self, from: NodeId, term: Term, last_index: LogIndex) -> Result<Outbox> {
        if self.role != Role::Leader || term != self.hard.term {
            return Ok(Vec::new());
        }
        self.match_index.insert(from, last_index);
        self.next_index.insert(from, last_index + 1);
        if let Some(m) = self.append_for(from)? {
            self.metrics.msgs_sent += 1;
            return Ok(vec![(from, m)]);
        }
        Ok(Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    /// Trivial in-memory KV state machine for node tests.
    #[derive(Default)]
    struct MemSm {
        kv: BTreeMap<Vec<u8>, Vec<u8>>,
        applied: Vec<LogIndex>,
    }

    impl StateMachine for MemSm {
        fn apply(&mut self, entry: &LogEntry, _vref: VRef) -> Result<()> {
            self.applied.push(entry.index);
            match &entry.cmd {
                Command::Put { key, value } => {
                    self.kv.insert(key.clone(), value.clone());
                }
                Command::Delete { key } => {
                    self.kv.remove(key);
                }
                Command::Noop => {}
            }
            Ok(())
        }

        fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
            let mut e = crate::util::Encoder::new();
            e.varint(self.kv.len() as u64);
            for (k, v) in &self.kv {
                e.len_bytes(k).len_bytes(v);
            }
            Ok(e.into_vec())
        }

        fn install_snapshot(&mut self, data: &[u8], _li: LogIndex, _lt: Term) -> Result<()> {
            let mut d = crate::util::Decoder::new(data);
            let n = d.varint()? as usize;
            self.kv.clear();
            for _ in 0..n {
                let k = d.len_bytes()?.to_vec();
                let v = d.len_bytes()?.to_vec();
                self.kv.insert(k, v);
            }
            Ok(())
        }
    }

    fn tmpdir(name: &str, id: u64) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("nezha-node-{name}-{}-{id}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Synchronous 3-node test cluster: delivers all messages until
    /// quiescent.
    struct Trio {
        nodes: Vec<Node<MemSm>>,
    }

    impl Trio {
        fn new(name: &str) -> Self {
            let ids = [1u64, 2, 3];
            let nodes = ids
                .iter()
                .map(|&id| {
                    let peers: Vec<u64> = ids.iter().copied().filter(|&p| p != id).collect();
                    Node::new(
                        id,
                        peers,
                        &tmpdir(name, id),
                        MemSm::default(),
                        Config::default(),
                        42,
                    )
                    .unwrap()
                })
                .collect();
            Self { nodes }
        }

        fn node(&mut self, id: NodeId) -> &mut Node<MemSm> {
            self.nodes.iter_mut().find(|n| n.id == id).unwrap()
        }

        fn pump(&mut self, mut msgs: Vec<(NodeId, NodeId, Message)>) {
            while let Some((from, to, m)) = msgs.pop() {
                let out = self.node(to).handle(from, m).unwrap();
                for (dst, msg) in out {
                    msgs.push((to, dst, msg));
                }
            }
        }

        fn tick_all(&mut self) {
            let mut msgs = Vec::new();
            for n in &mut self.nodes {
                let id = n.id;
                for (dst, m) in n.tick().unwrap() {
                    msgs.push((id, dst, m));
                }
            }
            self.pump(msgs);
        }

        /// Tick until some node is leader; returns its id.
        fn elect(&mut self) -> NodeId {
            for _ in 0..500 {
                self.tick_all();
                if let Some(l) = self.nodes.iter().find(|n| n.is_leader()) {
                    return l.id;
                }
            }
            panic!("no leader elected");
        }

        fn propose_and_commit(&mut self, leader: NodeId, cmd: Command) -> LogIndex {
            let idx = self.node(leader).propose(cmd).unwrap();
            let out = self.node(leader).replicate().unwrap();
            let msgs: Vec<_> = out.into_iter().map(|(dst, m)| (leader, dst, m)).collect();
            self.pump(msgs);
            idx
        }
    }

    #[test]
    fn single_leader_elected() {
        let mut t = Trio::new("elect");
        let leader = t.elect();
        let leaders: Vec<_> = t.nodes.iter().filter(|n| n.is_leader()).collect();
        assert_eq!(leaders.len(), 1);
        assert_eq!(leaders[0].id, leader);
        // Followers learn the hint.
        for n in &t.nodes {
            if !n.is_leader() {
                assert_eq!(n.leader_hint(), Some(leader));
            }
        }
    }

    #[test]
    fn replication_commits_and_applies_everywhere() {
        let mut t = Trio::new("replicate");
        let leader = t.elect();
        for i in 0..20u32 {
            t.propose_and_commit(
                leader,
                Command::Put { key: format!("k{i}").into_bytes(), value: format!("v{i}").into_bytes() },
            );
        }
        // Followers learn the final commit index from the next
        // heartbeat — pump a few ticks.
        for _ in 0..10 {
            t.tick_all();
        }
        // Everyone applied everything (noop + 20 entries).
        let applied: Vec<_> = t.nodes.iter().map(|n| n.last_applied()).collect();
        assert!(applied.iter().all(|&a| a == applied[0]), "{applied:?}");
        assert!(applied[0] >= 20);
    }

    #[test]
    fn non_leader_rejects_propose() {
        let mut t = Trio::new("reject");
        let leader = t.elect();
        for n in &mut t.nodes {
            if n.id != leader {
                assert!(n.propose(Command::Noop).is_err());
            }
        }
    }

    #[test]
    fn commit_requires_quorum_not_all() {
        // Detach node 3: leader + node 2 still commit.
        let mut t = Trio::new("quorum");
        let leader = t.elect();
        let idx = t.node(leader).propose(Command::Put { key: b"q".to_vec(), value: b"1".to_vec() }).unwrap();
        let out = t.node(leader).replicate().unwrap();
        // Deliver only to one follower.
        let follower = t.nodes.iter().map(|n| n.id).find(|&id| id != leader).unwrap();
        let msgs: Vec<_> = out
            .into_iter()
            .filter(|(dst, _)| *dst == follower)
            .map(|(dst, m)| (leader, dst, m))
            .collect();
        t.pump(msgs);
        assert!(t.node(leader).commit_index() >= idx);
    }

    #[test]
    fn higher_term_dethrones_leader() {
        let mut t = Trio::new("dethrone");
        let leader = t.elect();
        let term = t.node(leader).term();
        let out = t
            .node(leader)
            .handle(99, Message::RequestVote { term: term + 10, candidate: 99, last_log_index: 1 << 30, last_log_term: 1 << 30 })
            .unwrap();
        assert_eq!(t.node(leader).role(), Role::Follower);
        assert_eq!(t.node(leader).term(), term + 10);
        // And it granted the vote (log was up-to-date).
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: true, .. }));
    }

    #[test]
    fn vote_denied_for_stale_log() {
        let mut t = Trio::new("stalelog");
        let leader = t.elect();
        t.propose_and_commit(leader, Command::Put { key: b"x".to_vec(), value: b"y".to_vec() });
        let term = t.node(leader).term();
        // A candidate with an empty log can't win a vote from the leader.
        let out = t
            .node(leader)
            .handle(77, Message::RequestVote { term: term + 1, candidate: 77, last_log_index: 0, last_log_term: 0 })
            .unwrap();
        assert!(matches!(out[0].1, Message::RequestVoteResp { granted: false, .. }));
    }

    #[test]
    fn snapshot_catches_up_fresh_node() {
        let mut t = Trio::new("snapcatch");
        let leader = t.elect();
        // Small mem tail to force snapshot path.
        t.node(leader).cfg.mem_keep_tail = 2;
        for i in 0..50u32 {
            t.propose_and_commit(
                leader,
                Command::Put { key: format!("k{i:03}").into_bytes(), value: b"v".to_vec() },
            );
        }
        // New empty node 4 joins as the replication target of leader.
        let dir = tmpdir("snapcatch", 4);
        let mut n4 = Node::new(4, vec![leader], &dir, MemSm::default(), Config::default(), 7).unwrap();
        // Leader tracks node 4 as far behind.
        t.node(leader).next_index.insert(4, 1);
        t.node(leader).match_index.insert(4, 0);
        let m = t.node(leader).append_for(4).unwrap().unwrap();
        assert!(matches!(m, Message::InstallSnapshot { .. }), "expected snapshot, got {m:?}");
        let resp = n4.handle(leader, m).unwrap();
        assert!(n4.last_applied() >= 50);
        assert!(matches!(resp[0].1, Message::InstallSnapshotResp { .. }));
    }

    #[test]
    fn follower_truncates_conflicting_suffix() {
        // Craft a follower with a divergent entry and let an
        // AppendEntries from a newer-term leader fix it.
        let dir = tmpdir("conflict", 1);
        let mut f = Node::new(1, vec![2], &dir, MemSm::default(), Config::default(), 3).unwrap();
        // Local divergent entries at term 1.
        f.hard.term = 1;
        f.log.append(LogEntry { term: 1, index: 1, cmd: Command::Put { key: b"a".to_vec(), value: b"old".to_vec() } }).unwrap();
        f.log.append(LogEntry { term: 1, index: 2, cmd: Command::Put { key: b"b".to_vec(), value: b"old".to_vec() } }).unwrap();
        // Leader at term 2 replicates a different index-2.
        let out = f
            .handle(
                2,
                Message::AppendEntries {
                    term: 2,
                    leader: 2,
                    prev_log_index: 1,
                    prev_log_term: 1,
                    entries: vec![LogEntry { term: 2, index: 2, cmd: Command::Put { key: b"b2".to_vec(), value: b"new".to_vec() } }],
                    leader_commit: 2,
                },
            )
            .unwrap();
        assert!(matches!(out[0].1, Message::AppendEntriesResp { success: true, match_index: 2, .. }));
        assert_eq!(f.log.entry(2).unwrap().term, 2);
        assert_eq!(f.log.entry(2).unwrap().cmd.key(), b"b2");
        assert_eq!(f.last_applied(), 2);
    }

    #[test]
    fn stale_term_append_rejected() {
        let dir = tmpdir("staleappend", 1);
        let mut n = Node::new(1, vec![2], &dir, MemSm::default(), Config::default(), 5).unwrap();
        n.hard.term = 10;
        let out = n
            .handle(
                2,
                Message::AppendEntries {
                    term: 3,
                    leader: 2,
                    prev_log_index: 0,
                    prev_log_term: 0,
                    entries: vec![],
                    leader_commit: 0,
                },
            )
            .unwrap();
        assert!(matches!(out[0].1, Message::AppendEntriesResp { success: false, term: 10, .. }));
        assert_eq!(n.role(), Role::Follower);
    }
}
