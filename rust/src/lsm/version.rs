//! Level structure + MANIFEST.
//!
//! L0 holds whole-memtable flushes (files may overlap; newest first).
//! L1..Ln hold non-overlapping sorted runs.  The MANIFEST is rewritten
//! atomically (tmp + rename) on every version change — simple and
//! crash-safe at our scale; RocksDB's log-structured manifest is an
//! optimization we don't need.

use crate::util::{Decoder, Encoder};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub const MAX_LEVELS: usize = 7;

/// Metadata for one live SSTable file.
#[derive(Clone, Debug)]
pub struct FileMeta {
    pub id: u64,
    pub size: u64,
    pub entries: u64,
    pub first_key: Vec<u8>,
    pub last_key: Vec<u8>,
}

/// The level structure. `levels[0]` is newest-first; deeper levels are
/// key-ordered and non-overlapping.
#[derive(Clone, Debug, Default)]
pub struct Version {
    pub levels: Vec<Vec<FileMeta>>,
    pub next_file_id: u64,
}

impl Version {
    pub fn new() -> Self {
        Self { levels: vec![Vec::new(); MAX_LEVELS], next_file_id: 1 }
    }

    pub fn alloc_file_id(&mut self) -> u64 {
        let id = self.next_file_id;
        self.next_file_id += 1;
        id
    }

    pub fn live_files(&self) -> impl Iterator<Item = &FileMeta> {
        self.levels.iter().flatten()
    }

    pub fn total_bytes(&self, level: usize) -> u64 {
        self.levels[level].iter().map(|f| f.size).sum()
    }

    pub fn file_count(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Insert a flushed file at L0 (newest first).
    pub fn add_l0(&mut self, meta: FileMeta) {
        self.levels[0].insert(0, meta);
    }

    /// Replace `removed` file ids at `level` and `level+1` with `added`
    /// files at `level+1`, keeping deeper levels key-sorted.
    pub fn apply_compaction(&mut self, level: usize, removed: &[u64], added: Vec<FileMeta>) {
        for l in [level, level + 1] {
            self.levels[l].retain(|f| !removed.contains(&f.id));
        }
        self.levels[level + 1].extend(added);
        self.levels[level + 1].sort_by(|a, b| a.first_key.cmp(&b.first_key));
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.u64(self.next_file_id);
        e.varint(self.levels.len() as u64);
        for level in &self.levels {
            e.varint(level.len() as u64);
            for f in level {
                e.u64(f.id)
                    .u64(f.size)
                    .u64(f.entries)
                    .len_bytes(&f.first_key)
                    .len_bytes(&f.last_key);
            }
        }
        let body = e.into_vec();
        let mut framed = Encoder::with_capacity(body.len() + 8);
        framed.u32(body.len() as u32).u32(crc32fast::hash(&body)).bytes(&body);
        framed.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Decoder::new(buf);
        let len = d.u32()? as usize;
        let crc = d.u32()?;
        let body = d.bytes(len)?;
        anyhow::ensure!(crc32fast::hash(body) == crc, "manifest crc mismatch");
        let mut d = Decoder::new(body);
        let next_file_id = d.u64()?;
        let nlevels = d.varint()? as usize;
        anyhow::ensure!(nlevels <= 16, "manifest: absurd level count");
        let mut levels = Vec::with_capacity(nlevels);
        for _ in 0..nlevels {
            let n = d.varint()? as usize;
            let mut files = Vec::with_capacity(n);
            for _ in 0..n {
                files.push(FileMeta {
                    id: d.u64()?,
                    size: d.u64()?,
                    entries: d.u64()?,
                    first_key: d.len_bytes()?.to_vec(),
                    last_key: d.len_bytes()?.to_vec(),
                });
            }
            levels.push(files);
        }
        while levels.len() < MAX_LEVELS {
            levels.push(Vec::new());
        }
        Ok(Self { levels, next_file_id })
    }

    /// Atomic rewrite: write tmp, fsync, rename.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let tmp = dir.join("MANIFEST.tmp");
        let fin = dir.join("MANIFEST");
        std::fs::write(&tmp, self.encode()).context("manifest write")?;
        let f = std::fs::File::open(&tmp)?;
        f.sync_data()?;
        std::fs::rename(&tmp, &fin)?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let p = dir.join("MANIFEST");
        match std::fs::read(&p) {
            Ok(buf) => Ok(Some(Self::decode(&buf)?)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// SSTable file naming.
pub fn table_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("{id:010}.sst"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: u64, first: &str, last: &str) -> FileMeta {
        FileMeta {
            id,
            size: 1000,
            entries: 10,
            first_key: first.as_bytes().to_vec(),
            last_key: last.as_bytes().to_vec(),
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut v = Version::new();
        v.add_l0(meta(1, "a", "m"));
        v.add_l0(meta(2, "b", "z"));
        v.levels[1].push(meta(3, "a", "k"));
        v.next_file_id = 42;
        let v2 = Version::decode(&v.encode()).unwrap();
        assert_eq!(v2.next_file_id, 42);
        assert_eq!(v2.levels[0].len(), 2);
        assert_eq!(v2.levels[0][0].id, 2); // newest first preserved
        assert_eq!(v2.levels[1][0].last_key, b"k".to_vec());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nezha-ver-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Version::load(&dir).unwrap().is_none());
        let mut v = Version::new();
        v.add_l0(meta(7, "x", "y"));
        v.save(&dir).unwrap();
        let v2 = Version::load(&dir).unwrap().unwrap();
        assert_eq!(v2.levels[0][0].id, 7);
    }

    #[test]
    fn corrupt_manifest_rejected() {
        let mut v = Version::new();
        v.add_l0(meta(1, "a", "b"));
        let mut buf = v.encode();
        let l = buf.len();
        buf[l - 1] ^= 0xff;
        assert!(Version::decode(&buf).is_err());
    }

    #[test]
    fn apply_compaction_moves_files_down() {
        let mut v = Version::new();
        v.add_l0(meta(1, "a", "m"));
        v.add_l0(meta(2, "c", "z"));
        v.levels[1].push(meta(3, "a", "j"));
        v.apply_compaction(0, &[1, 2, 3], vec![meta(4, "m", "z"), meta(5, "a", "l")]);
        assert!(v.levels[0].is_empty());
        let ids: Vec<u64> = v.levels[1].iter().map(|f| f.id).collect();
        assert_eq!(ids, vec![5, 4]); // key-sorted: "a" before "m"
    }

    #[test]
    fn l0_is_newest_first() {
        let mut v = Version::new();
        v.add_l0(meta(1, "a", "b"));
        v.add_l0(meta(2, "a", "b"));
        v.add_l0(meta(3, "a", "b"));
        let ids: Vec<u64> = v.levels[0].iter().map(|f| f.id).collect();
        assert_eq!(ids, vec![3, 2, 1]);
    }
}
