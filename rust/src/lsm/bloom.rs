//! Bloom filter for SSTables (and for the Final Compacted Storage's
//! negative-lookup fast path).  Double hashing `h1 + i*h2` — the same
//! probe construction the L1 Pallas kernel emits, so the GC path can
//! build filter bits either in Rust or from the XLA artifact.

use crate::util::{Decoder, Encoder};
use crate::vlog::hash::hash_pair;
use anyhow::Result;

/// Probes per key — mirrored in `python/compile/model.py::BLOOM_K`.
pub const BLOOM_K: usize = 4;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bloom {
    bits: Vec<u64>,
    mask: u32, // number of bits - 1 (power of two)
}

impl Bloom {
    /// Size the filter for `n` keys at ~10 bits/key, rounded up to a
    /// power of two (>= 64 bits).
    pub fn with_capacity(n: usize) -> Self {
        let want = (n.max(8) * 10).next_power_of_two().max(64);
        Self {
            bits: vec![0u64; want / 64],
            mask: (want - 1) as u32,
        }
    }

    #[inline]
    fn positions(&self, key: &[u8]) -> [u32; BLOOM_K] {
        let (h1, h2) = hash_pair(key);
        let mut out = [0u32; BLOOM_K];
        for (i, o) in out.iter_mut().enumerate() {
            *o = h1.wrapping_add((i as u32).wrapping_mul(h2)) & self.mask;
        }
        out
    }

    pub fn insert(&mut self, key: &[u8]) {
        for pos in self.positions(key) {
            self.bits[(pos / 64) as usize] |= 1u64 << (pos % 64);
        }
    }

    /// Insert from precomputed bit positions (the XLA `index_build`
    /// output path).  Positions must already be masked to this filter's
    /// size — callers pass the same mask to the planner.
    pub fn insert_positions(&mut self, pos: &[u32]) {
        for &p in pos {
            let p = p & self.mask;
            self.bits[(p / 64) as usize] |= 1u64 << (p % 64);
        }
    }

    pub fn may_contain(&self, key: &[u8]) -> bool {
        self.positions(key)
            .iter()
            .all(|&pos| self.bits[(pos / 64) as usize] & (1u64 << (pos % 64)) != 0)
    }

    pub fn mask(&self) -> u32 {
        self.mask
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.u32(self.mask);
        e.varint(self.bits.len() as u64);
        for w in &self.bits {
            e.u64(*w);
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self> {
        let mask = d.u32()?;
        let n = d.varint()? as usize;
        anyhow::ensure!(
            n as u64 * 64 == mask as u64 + 1,
            "bloom: inconsistent size"
        );
        let mut bits = Vec::with_capacity(n);
        for _ in 0..n {
            bits.push(d.u64()?);
        }
        Ok(Self { bits, mask })
    }

    pub fn byte_size(&self) -> usize {
        self.bits.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn no_false_negatives() {
        let mut b = Bloom::with_capacity(1000);
        for i in 0..1000u32 {
            b.insert(format!("key{i}").as_bytes());
        }
        for i in 0..1000u32 {
            assert!(b.may_contain(format!("key{i}").as_bytes()));
        }
    }

    #[test]
    fn false_positive_rate_reasonable() {
        let mut b = Bloom::with_capacity(10_000);
        for i in 0..10_000u32 {
            b.insert(format!("key{i}").as_bytes());
        }
        let fp = (0..10_000u32)
            .filter(|i| b.may_contain(format!("absent{i}").as_bytes()))
            .count();
        // ~10 bits/key with k=4 gives ~2%; allow slack.
        assert!(fp < 600, "fp={fp}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut b = Bloom::with_capacity(100);
        for i in 0..100u32 {
            b.insert(&i.to_le_bytes());
        }
        let mut e = Encoder::new();
        b.encode(&mut e);
        let mut d = Decoder::new(e.as_slice());
        let b2 = Bloom::decode(&mut d).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn insert_positions_matches_insert() {
        // The precomputed-positions path (XLA planner) must set the
        // exact bits the direct path sets.
        prop::check("bloom-positions", 200, |g| {
            let key = g.bytes(0..32);
            let mut a = Bloom::with_capacity(512);
            let mut b = Bloom::with_capacity(512);
            a.insert(&key);
            let (h1, h2) = hash_pair(&key);
            let pos: Vec<u32> = (0..BLOOM_K as u32)
                .map(|i| h1.wrapping_add(i.wrapping_mul(h2)) & b.mask())
                .collect();
            b.insert_positions(&pos);
            if a != b {
                return Err(format!("mismatch for key {key:?}"));
            }
            Ok(())
        });
    }
}
