//! Write-ahead log: CRC-framed records, append + replay.
//!
//! Record frame: `[len u32][crc32 u32][payload len bytes]`.
//! Payload: one batch = repeated `(op u8, key len_bytes, [value
//! len_bytes])` — op 0 = put, 1 = delete.
//!
//! Replay stops at the first torn/corrupt frame (standard
//! crash-consistency semantics: a torn tail means those writes never
//! acked).

use crate::util::{Decoder, Encoder};
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::Value;

pub struct Wal {
    path: PathBuf,
    file: BufWriter<File>,
    bytes_written: u64,
}

const OP_PUT: u8 = 0;
const OP_DELETE: u8 = 1;

impl Wal {
    pub fn create(path: &Path) -> Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("wal create {path:?}"))?;
        Ok(Self {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            bytes_written: 0,
        })
    }

    /// Append one batch of ops as a single frame. Returns frame size.
    pub fn append_batch(&mut self, ops: &[(&[u8], &Value)]) -> Result<u64> {
        let mut payload = Encoder::new();
        for (k, v) in ops {
            match v {
                Value::Put(val) => {
                    payload.u8(OP_PUT).len_bytes(k).len_bytes(val);
                }
                Value::Delete => {
                    payload.u8(OP_DELETE).len_bytes(k);
                }
            }
        }
        let body = payload.as_slice();
        let mut frame = Encoder::with_capacity(body.len() + 8);
        frame.u32(body.len() as u32);
        frame.u32(crc32fast::hash(body));
        frame.bytes(body);
        self.file.write_all(frame.as_slice())?;
        self.bytes_written += frame.len() as u64;
        Ok(frame.len() as u64)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }

    pub fn sync(&mut self) -> Result<()> {
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        Ok(())
    }

    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Replay every intact frame, invoking `f(key, value)` in log order.
    /// Returns the number of ops replayed.
    pub fn replay(path: &Path, mut f: impl FnMut(&[u8], Value)) -> Result<usize> {
        let mut buf = Vec::new();
        match File::open(path) {
            Ok(mut file) => {
                file.read_to_end(&mut buf)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e.into()),
        }
        let mut ops = 0usize;
        let mut pos = 0usize;
        while pos + 8 <= buf.len() {
            let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
            let start = pos + 8;
            if start + len > buf.len() {
                break; // torn tail
            }
            let body = &buf[start..start + len];
            if crc32fast::hash(body) != crc {
                break; // corrupt frame: stop, like a torn write
            }
            let mut d = Decoder::new(body);
            while !d.is_empty() {
                let op = d.u8()?;
                let key = d.len_bytes()?.to_vec();
                match op {
                    OP_PUT => {
                        let val = d.len_bytes()?.to_vec();
                        f(&key, Value::Put(val));
                    }
                    OP_DELETE => f(&key, Value::Delete),
                    other => anyhow::bail!("wal: unknown op {other}"),
                }
                ops += 1;
            }
            pos = start + len;
        }
        Ok(ops)
    }

    /// Delete the log file (after a successful memtable flush).
    pub fn remove(path: &Path) -> Result<()> {
        match std::fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-wal-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_then_replay() {
        let dir = tmpdir("roundtrip");
        let p = dir.join("wal");
        let mut w = Wal::create(&p).unwrap();
        w.append_batch(&[(b"a", &Value::Put(b"1".to_vec()))]).unwrap();
        w.append_batch(&[
            (b"b", &Value::Put(b"2".to_vec())),
            (b"a", &Value::Delete),
        ])
        .unwrap();
        w.flush().unwrap();
        let mut got = Vec::new();
        let n = Wal::replay(&p, |k, v| got.push((k.to_vec(), v))).unwrap();
        assert_eq!(n, 3);
        assert_eq!(got[0], (b"a".to_vec(), Value::Put(b"1".to_vec())));
        assert_eq!(got[2], (b"a".to_vec(), Value::Delete));
    }

    #[test]
    fn replay_missing_file_is_empty() {
        let dir = tmpdir("missing");
        let n = Wal::replay(&dir.join("nope"), |_, _| panic!()).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn torn_tail_is_ignored() {
        let dir = tmpdir("torn");
        let p = dir.join("wal");
        let mut w = Wal::create(&p).unwrap();
        w.append_batch(&[(b"a", &Value::Put(b"1".to_vec()))]).unwrap();
        w.flush().unwrap();
        // Append garbage simulating a torn write.
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[9, 0, 0, 0, 1, 2, 3]).unwrap();
        }
        let mut got = 0;
        let n = Wal::replay(&p, |_, _| got += 1).unwrap();
        assert_eq!(n, 1);
        assert_eq!(got, 1);
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let dir = tmpdir("crc");
        let p = dir.join("wal");
        let mut w = Wal::create(&p).unwrap();
        w.append_batch(&[(b"a", &Value::Put(b"1".to_vec()))]).unwrap();
        w.append_batch(&[(b"b", &Value::Put(b"2".to_vec()))]).unwrap();
        w.flush().unwrap();
        // Flip a byte in the second frame's payload.
        let mut bytes = std::fs::read(&p).unwrap();
        let l = bytes.len();
        bytes[l - 1] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let mut keys = Vec::new();
        Wal::replay(&p, |k, _| keys.push(k.to_vec())).unwrap();
        assert_eq!(keys, vec![b"a".to_vec()]);
    }

    #[test]
    fn bytes_written_counts_frames() {
        let dir = tmpdir("bytes");
        let mut w = Wal::create(&dir.join("wal")).unwrap();
        let n = w.append_batch(&[(b"k", &Value::Put(vec![0u8; 100]))]).unwrap();
        assert!(n > 100);
        assert_eq!(w.bytes_written(), n);
    }
}
