//! Immutable sorted string table.
//!
//! Layout (all little-endian, varint = LEB128):
//!
//! ```text
//! [data block 0][data block 1]...[index block][bloom block][footer]
//! data block : repeated (op u8, key len_bytes, [value len_bytes])
//! index block: varint count, then per block:
//!              (first_key len_bytes, last_key len_bytes,
//!               offset varint, len varint, entries varint)
//! bloom block: Bloom::encode
//! footer     : index_off u64, index_len u64, bloom_off u64,
//!              bloom_len u64, entry_count u64, crc32(index||bloom) u32,
//!              magic u64
//! ```
//!
//! Readers keep the decoded index + bloom resident (tiny) and read data
//! blocks on demand via `pread`, fronted by the Db-level block cache.

use super::bloom::Bloom;
use super::Value;
use crate::util::{Decoder, Encoder};
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: u64 = 0x4E5A_5353_5442_0001; // "NZSSTB" v1
const OP_PUT: u8 = 0;
const OP_DELETE: u8 = 1;

/// Target uncompressed data-block size.
pub const BLOCK_TARGET: usize = 16 * 1024;

/// One index entry describing a data block.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub first_key: Vec<u8>,
    pub last_key: Vec<u8>,
    pub offset: u64,
    pub len: u64,
    pub entries: u64,
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Streaming SSTable writer. Keys MUST arrive in strictly increasing
/// order (the merge iterators guarantee this).
pub struct TableWriter {
    path: PathBuf,
    file: BufWriter<File>,
    block: Encoder,
    block_first: Option<Vec<u8>>,
    block_entries: u64,
    metas: Vec<BlockMeta>,
    last_key: Option<Vec<u8>>,
    offset: u64,
    keys: Vec<Vec<u8>>, // for bloom build at finish
    entry_count: u64,
}

impl TableWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let file = OpenOptions::new()
            .create_new(true)
            .write(true)
            .open(path)
            .with_context(|| format!("sstable create {path:?}"))?;
        Ok(Self {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            block: Encoder::with_capacity(BLOCK_TARGET + 512),
            block_first: None,
            block_entries: 0,
            metas: Vec::new(),
            last_key: None,
            offset: 0,
            keys: Vec::new(),
            entry_count: 0,
        })
    }

    pub fn add(&mut self, key: &[u8], value: &Value) -> Result<()> {
        if let Some(last) = &self.last_key {
            if key <= last.as_slice() {
                bail!("sstable: keys out of order ({last:?} then {key:?})");
            }
        }
        if self.block_first.is_none() {
            self.block_first = Some(key.to_vec());
        }
        match value {
            Value::Put(v) => {
                self.block.u8(OP_PUT).len_bytes(key).len_bytes(v);
            }
            Value::Delete => {
                self.block.u8(OP_DELETE).len_bytes(key);
            }
        }
        self.block_entries += 1;
        self.entry_count += 1;
        self.keys.push(key.to_vec());
        self.last_key = Some(key.to_vec());
        if self.block.len() >= BLOCK_TARGET {
            self.finish_block()?;
        }
        Ok(())
    }

    fn finish_block(&mut self) -> Result<()> {
        if self.block.is_empty() {
            return Ok(());
        }
        let data = std::mem::replace(
            &mut self.block,
            Encoder::with_capacity(BLOCK_TARGET + 512),
        )
        .into_vec();
        self.file.write_all(&data)?;
        self.metas.push(BlockMeta {
            first_key: self.block_first.take().unwrap(),
            last_key: self.last_key.clone().unwrap(),
            offset: self.offset,
            len: data.len() as u64,
            entries: self.block_entries,
        });
        self.offset += data.len() as u64;
        self.block_entries = 0;
        Ok(())
    }

    /// Finish the table; returns (file size, entry count).
    pub fn finish(mut self) -> Result<(u64, u64)> {
        self.finish_block()?;
        // Index block.
        let mut index = Encoder::new();
        index.varint(self.metas.len() as u64);
        for m in &self.metas {
            index
                .len_bytes(&m.first_key)
                .len_bytes(&m.last_key)
                .varint(m.offset)
                .varint(m.len)
                .varint(m.entries);
        }
        // Bloom block.
        let mut bloom = Bloom::with_capacity(self.keys.len());
        for k in &self.keys {
            bloom.insert(k);
        }
        let mut bloom_enc = Encoder::new();
        bloom.encode(&mut bloom_enc);

        let index_off = self.offset;
        let index_len = index.len() as u64;
        let bloom_off = index_off + index_len;
        let bloom_len = bloom_enc.len() as u64;

        let mut crc = crc32fast::Hasher::new();
        crc.update(index.as_slice());
        crc.update(bloom_enc.as_slice());

        self.file.write_all(index.as_slice())?;
        self.file.write_all(bloom_enc.as_slice())?;
        let mut footer = Encoder::with_capacity(52);
        footer
            .u64(index_off)
            .u64(index_len)
            .u64(bloom_off)
            .u64(bloom_len)
            .u64(self.entry_count)
            .u32(crc.finalize())
            .u64(MAGIC);
        self.file.write_all(footer.as_slice())?;
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        let size = bloom_off + bloom_len + footer.len() as u64;
        Ok((size, self.entry_count))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn entry_count(&self) -> u64 {
        self.entry_count
    }

    pub fn approx_bytes(&self) -> u64 {
        self.offset + self.block.len() as u64
    }
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Open SSTable: resident index + bloom, on-demand block reads.
pub struct Table {
    pub id: u64,
    path: PathBuf,
    file: File,
    pub metas: Vec<BlockMeta>,
    bloom: Bloom,
    pub entry_count: u64,
    pub file_size: u64,
}

impl Table {
    pub fn open(id: u64, path: &Path) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("sstable open {path:?}"))?;
        let file_size = file.metadata()?.len();
        const FOOTER: u64 = 8 * 5 + 4 + 8;
        if file_size < FOOTER {
            bail!("sstable too small: {path:?}");
        }
        file.seek(SeekFrom::End(-(FOOTER as i64)))?;
        let mut fb = vec![0u8; FOOTER as usize];
        file.read_exact(&mut fb)?;
        let mut d = Decoder::new(&fb);
        let index_off = d.u64()?;
        let index_len = d.u64()?;
        let _bloom_off = d.u64()?;
        let bloom_len = d.u64()?;
        let entry_count = d.u64()?;
        let crc_want = d.u32()?;
        let magic = d.u64()?;
        if magic != MAGIC {
            bail!("sstable bad magic: {path:?}");
        }
        let mut meta_buf = vec![0u8; (index_len + bloom_len) as usize];
        file.seek(SeekFrom::Start(index_off))?;
        file.read_exact(&mut meta_buf)?;
        if crc32fast::hash(&meta_buf) != crc_want {
            bail!("sstable meta crc mismatch: {path:?}");
        }
        let mut d = Decoder::new(&meta_buf[..index_len as usize]);
        let n = d.varint()? as usize;
        let mut metas = Vec::with_capacity(n);
        for _ in 0..n {
            metas.push(BlockMeta {
                first_key: d.len_bytes()?.to_vec(),
                last_key: d.len_bytes()?.to_vec(),
                offset: d.varint()?,
                len: d.varint()?,
                entries: d.varint()?,
            });
        }
        let mut d = Decoder::new(&meta_buf[index_len as usize..]);
        let bloom = Bloom::decode(&mut d)?;
        Ok(Self { id, path: path.to_path_buf(), file, metas, bloom, entry_count, file_size })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn first_key(&self) -> Option<&[u8]> {
        self.metas.first().map(|m| m.first_key.as_slice())
    }

    pub fn last_key(&self) -> Option<&[u8]> {
        self.metas.last().map(|m| m.last_key.as_slice())
    }

    pub fn may_contain(&self, key: &[u8]) -> bool {
        self.bloom.may_contain(key)
    }

    pub fn overlaps(&self, start: &[u8], end: &[u8]) -> bool {
        match (self.first_key(), self.last_key()) {
            (Some(f), Some(l)) => f < end && start <= l,
            _ => false,
        }
    }

    /// Index of the block that could contain `key`, if any.
    fn block_for(&self, key: &[u8]) -> Option<usize> {
        // First block whose last_key >= key.
        let i = self.metas.partition_point(|m| m.last_key.as_slice() < key);
        if i < self.metas.len() && self.metas[i].first_key.as_slice() <= key {
            Some(i)
        } else if i < self.metas.len() {
            // key falls in a gap before block i — not present, but for
            // range scans we still start here.
            None
        } else {
            None
        }
    }

    /// Raw block bytes (cache-fill path).
    pub fn read_block(&self, idx: usize) -> Result<Arc<Vec<u8>>> {
        let m = &self.metas[idx];
        let mut buf = vec![0u8; m.len as usize];
        read_at(&self.file, m.offset, &mut buf)?;
        Ok(Arc::new(buf))
    }

    /// Decode every (key, value) in a block.
    pub fn decode_block(data: &[u8]) -> Result<Vec<(Vec<u8>, Value)>> {
        let mut d = Decoder::new(data);
        let mut out = Vec::new();
        while !d.is_empty() {
            let op = d.u8()?;
            let key = d.len_bytes()?.to_vec();
            let val = match op {
                OP_PUT => Value::Put(d.len_bytes()?.to_vec()),
                OP_DELETE => Value::Delete,
                other => bail!("sstable: unknown op {other}"),
            };
            out.push((key, val));
        }
        Ok(out)
    }

    /// Point lookup without cache (Db layers the cache on top).
    pub fn get(&self, key: &[u8], cache: Option<&super::db::BlockCache>) -> Result<Option<Value>> {
        if !self.bloom.may_contain(key) {
            return Ok(None);
        }
        let Some(bi) = self.block_for(key) else {
            return Ok(None);
        };
        let data = self.block_data(bi, cache)?;
        let entries = Self::decode_block(&data)?;
        match entries.binary_search_by(|(k, _)| k.as_slice().cmp(key)) {
            Ok(i) => Ok(Some(entries[i].1.clone())),
            Err(_) => Ok(None),
        }
    }

    pub fn block_data(
        &self,
        idx: usize,
        cache: Option<&super::db::BlockCache>,
    ) -> Result<Arc<Vec<u8>>> {
        if let Some(c) = cache {
            return c.get_or_load(self.id, idx as u64, || self.read_block(idx));
        }
        self.read_block(idx)
    }

    /// Iterate the whole table in order.
    pub fn iter(&self) -> TableIter<'_> {
        TableIter { table: self, block: 0, entries: Vec::new(), pos: 0 }
    }

    /// Iterate entries with key in `[start, end)`.  An empty `end`
    /// means unbounded (iterate to the last key).
    pub fn range(&self, start: &[u8], end: &[u8]) -> Result<Vec<(Vec<u8>, Value)>> {
        use crate::util::key_before_end;
        let mut out = Vec::new();
        let begin = self.metas.partition_point(|m| m.last_key.as_slice() < start);
        for bi in begin..self.metas.len() {
            if !key_before_end(&self.metas[bi].first_key, end) {
                break;
            }
            let data = self.read_block(bi)?;
            for (k, v) in Self::decode_block(&data)? {
                if !key_before_end(&k, end) {
                    return Ok(out);
                }
                if k.as_slice() >= start {
                    out.push((k, v));
                }
            }
        }
        Ok(out)
    }
}

/// pread wrapper (no seek state mutation, thread-safe reads).
pub fn read_at(file: &File, offset: u64, buf: &mut [u8]) -> Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, offset)?;
    Ok(())
}

/// Full-table forward iterator (used by compaction merges).
pub struct TableIter<'a> {
    table: &'a Table,
    block: usize,
    entries: Vec<(Vec<u8>, Value)>,
    pos: usize,
}

impl Iterator for TableIter<'_> {
    type Item = (Vec<u8>, Value);

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            if self.pos < self.entries.len() {
                let item =
                    std::mem::replace(&mut self.entries[self.pos], (Vec::new(), Value::Delete));
                self.pos += 1;
                return Some(item);
            }
            if self.block >= self.table.metas.len() {
                return None;
            }
            let data = self.table.read_block(self.block).ok()?;
            self.entries = Table::decode_block(&data).ok()?;
            self.pos = 0;
            self.block += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-sst-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn build(path: &Path, n: u32, vlen: usize) -> Table {
        let mut w = TableWriter::create(path).unwrap();
        for i in 0..n {
            let k = format!("key{i:08}");
            w.add(k.as_bytes(), &Value::Put(vec![(i % 251) as u8; vlen])).unwrap();
        }
        w.finish().unwrap();
        Table::open(1, path).unwrap()
    }

    #[test]
    fn write_read_roundtrip() {
        let dir = tmpdir("rt");
        let t = build(&dir.join("t.sst"), 1000, 100);
        assert_eq!(t.entry_count, 1000);
        for i in [0u32, 1, 500, 999] {
            let k = format!("key{i:08}");
            let v = t.get(k.as_bytes(), None).unwrap().unwrap();
            assert_eq!(v, Value::Put(vec![(i % 251) as u8; 100]));
        }
        assert_eq!(t.get(b"nope", None).unwrap(), None);
        assert_eq!(t.get(b"key00000500x", None).unwrap(), None);
    }

    #[test]
    fn multi_block_tables_index_correctly() {
        let dir = tmpdir("mb");
        // 1000 * 2KB values -> many blocks
        let t = build(&dir.join("t.sst"), 1000, 2048);
        assert!(t.metas.len() > 10, "blocks={}", t.metas.len());
        for i in (0..1000).step_by(97) {
            let k = format!("key{i:08}");
            assert!(t.get(k.as_bytes(), None).unwrap().is_some(), "{k}");
        }
    }

    #[test]
    fn out_of_order_keys_rejected() {
        let dir = tmpdir("ooo");
        let mut w = TableWriter::create(&dir.join("t.sst")).unwrap();
        w.add(b"b", &Value::Put(vec![])).unwrap();
        assert!(w.add(b"a", &Value::Put(vec![])).is_err());
        assert!(w.add(b"b", &Value::Put(vec![])).is_err()); // dup also rejected
    }

    #[test]
    fn iter_returns_all_sorted() {
        let dir = tmpdir("iter");
        let t = build(&dir.join("t.sst"), 500, 64);
        let items: Vec<_> = t.iter().collect();
        assert_eq!(items.len(), 500);
        for w in items.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn range_scan_bounds() {
        let dir = tmpdir("range");
        let t = build(&dir.join("t.sst"), 100, 16);
        let got = t.range(b"key00000010", b"key00000020").unwrap();
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].0, b"key00000010".to_vec());
        assert_eq!(got[9].0, b"key00000019".to_vec());
        // Empty range
        assert!(t.range(b"x", b"z").unwrap().is_empty());
        // Range covering everything
        assert_eq!(t.range(b"a", b"z").unwrap().len(), 100);
    }

    #[test]
    fn tombstones_roundtrip() {
        let dir = tmpdir("tomb");
        let p = dir.join("t.sst");
        let mut w = TableWriter::create(&p).unwrap();
        w.add(b"a", &Value::Put(b"1".to_vec())).unwrap();
        w.add(b"b", &Value::Delete).unwrap();
        w.finish().unwrap();
        let t = Table::open(1, &p).unwrap();
        assert_eq!(t.get(b"b", None).unwrap(), Some(Value::Delete));
    }

    #[test]
    fn corrupt_meta_detected() {
        let dir = tmpdir("corrupt");
        let p = dir.join("t.sst");
        build(&p, 100, 32);
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip a bit in the index region (just before footer).
        let l = bytes.len();
        bytes[l - 60] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Table::open(1, &p).is_err());
    }

    #[test]
    fn overlap_checks() {
        let dir = tmpdir("ov");
        let t = build(&dir.join("t.sst"), 10, 8); // key00000000..key00000009
        assert!(t.overlaps(b"key00000005", b"key00000100"));
        assert!(!t.overlaps(b"key00000100", b"key00000200"));
        assert!(t.overlaps(b"a", b"z"));
    }
}
