//! Leveled compaction: picker + merge.
//!
//! Triggers (checked after every flush):
//! * L0: file-count trigger (`l0_compaction_trigger`) — merge all L0
//!   files plus overlapping L1 files into L1.
//! * L1..Ln: size trigger (`level_base_bytes * 10^(i-1)`) — pick the
//!   oldest-ranged file and merge it with its overlap in the next
//!   level.
//!
//! The merge keeps newest-wins semantics (L(i) shadows L(i+1); within
//! L0, newer files shadow older).  Tombstones are dropped only when the
//! output level is the deepest populated level, otherwise preserved.

use super::sstable::{Table, TableWriter};
use super::version::{table_path, FileMeta, Version, MAX_LEVELS};
use super::Value;
use anyhow::Result;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// A picked compaction job.
#[derive(Debug)]
pub struct Job {
    pub level: usize,
    /// File ids consumed from `level` and `level + 1`.
    pub inputs: Vec<u64>,
}

/// Decide whether any level needs compaction.
pub fn pick(version: &Version, l0_trigger: usize, level_base_bytes: u64) -> Option<Job> {
    if version.levels[0].len() >= l0_trigger {
        let mut inputs: Vec<u64> = version.levels[0].iter().map(|f| f.id).collect();
        // All overlapping L1 files join the merge.
        let (lo, hi) = key_span(&version.levels[0]);
        for f in &version.levels[1] {
            if overlaps(f, &lo, &hi) {
                inputs.push(f.id);
            }
        }
        return Some(Job { level: 0, inputs });
    }
    for level in 1..MAX_LEVELS - 1 {
        let limit = level_base_bytes.saturating_mul(10u64.pow(level as u32 - 1));
        if version.total_bytes(level) > limit && !version.levels[level].is_empty() {
            let victim = &version.levels[level][0];
            let mut inputs = vec![victim.id];
            for f in &version.levels[level + 1] {
                if overlaps(f, &victim.first_key, &victim.last_key) {
                    inputs.push(f.id);
                }
            }
            return Some(Job { level, inputs });
        }
    }
    None
}

fn key_span(files: &[FileMeta]) -> (Vec<u8>, Vec<u8>) {
    let mut lo = files[0].first_key.clone();
    let mut hi = files[0].last_key.clone();
    for f in files {
        if f.first_key < lo {
            lo = f.first_key.clone();
        }
        if f.last_key > hi {
            hi = f.last_key.clone();
        }
    }
    (lo, hi)
}

fn overlaps(f: &FileMeta, lo: &[u8], hi: &[u8]) -> bool {
    f.first_key.as_slice() <= hi && lo <= f.last_key.as_slice()
}

/// Execute a compaction job: merge inputs, write output tables to
/// `dir`, update `version`, and return (new metas, bytes written).
/// `tables` maps file id -> open Table. Output files are split at
/// `output_split_bytes`.
pub fn run(
    dir: &Path,
    version: &mut Version,
    tables: &HashMap<u64, Arc<Table>>,
    job: &Job,
    output_split_bytes: u64,
) -> Result<(Vec<FileMeta>, u64)> {
    // Precedence order: within L0 the Version keeps newest first; files
    // at `level` shadow files at `level + 1`.  Build oldest→newest and
    // let BTreeMap overwrite.
    let mut ordered: Vec<u64> = Vec::new();
    // level+1 files first (oldest precedence)…
    for f in &version.levels[job.level + 1] {
        if job.inputs.contains(&f.id) {
            ordered.push(f.id);
        }
    }
    // …then `level` files, oldest L0 last-in-version-vec first.
    for f in version.levels[job.level].iter().rev() {
        if job.inputs.contains(&f.id) {
            ordered.push(f.id);
        }
    }

    let mut merged: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    for id in &ordered {
        let t = tables
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("compaction: table {id} not open"))?;
        for (k, v) in t.iter() {
            merged.insert(k, v);
        }
    }

    // Tombstone elision: if no deeper level holds data, deletes can die.
    let deepest_populated = (0..MAX_LEVELS)
        .rev()
        .find(|&l| !version.levels[l].is_empty())
        .unwrap_or(0);
    let drop_tombstones = job.level + 1 >= deepest_populated;

    let mut metas = Vec::new();
    let mut bytes_written = 0u64;
    let mut writer: Option<TableWriter> = None;
    let mut writer_id = 0u64;
    for (k, v) in &merged {
        if drop_tombstones && matches!(v, Value::Delete) {
            continue;
        }
        if writer.is_none() {
            writer_id = version.alloc_file_id();
            writer = Some(TableWriter::create(&table_path(dir, writer_id))?);
        }
        let w = writer.as_mut().unwrap();
        w.add(k, v)?;
        if w.approx_bytes() >= output_split_bytes {
            let (size, entries) = finish(writer.take().unwrap())?;
            bytes_written += size;
            metas.push(open_meta(dir, writer_id, size, entries)?);
        }
    }
    if let Some(w) = writer {
        if w.entry_count() > 0 {
            let id = writer_id;
            let (size, entries) = finish(w)?;
            bytes_written += size;
            metas.push(open_meta(dir, id, size, entries)?);
        } else {
            // Empty output (everything elided): remove the placeholder.
            let _ = std::fs::remove_file(table_path(dir, writer_id));
        }
    }

    version.apply_compaction(job.level, &job.inputs, metas.clone());
    Ok((metas, bytes_written))
}

fn finish(w: TableWriter) -> Result<(u64, u64)> {
    w.finish()
}

fn open_meta(dir: &Path, id: u64, size: u64, entries: u64) -> Result<FileMeta> {
    let t = Table::open(id, &table_path(dir, id))?;
    Ok(FileMeta {
        id,
        size,
        entries,
        first_key: t.first_key().unwrap_or_default().to_vec(),
        last_key: t.last_key().unwrap_or_default().to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: u64, first: &str, last: &str, size: u64) -> FileMeta {
        FileMeta {
            id,
            size,
            entries: 1,
            first_key: first.as_bytes().to_vec(),
            last_key: last.as_bytes().to_vec(),
        }
    }

    #[test]
    fn no_compaction_when_below_triggers() {
        let mut v = Version::new();
        v.add_l0(meta(1, "a", "b", 100));
        assert!(pick(&v, 4, 1 << 20).is_none());
    }

    #[test]
    fn l0_trigger_includes_overlapping_l1() {
        let mut v = Version::new();
        for i in 1..=4 {
            v.add_l0(meta(i, "c", "m", 100));
        }
        v.levels[1].push(meta(10, "a", "d", 100)); // overlaps
        v.levels[1].push(meta(11, "x", "z", 100)); // no overlap
        let job = pick(&v, 4, 1 << 20).unwrap();
        assert_eq!(job.level, 0);
        assert!(job.inputs.contains(&10));
        assert!(!job.inputs.contains(&11));
        assert_eq!(job.inputs.len(), 5);
    }

    #[test]
    fn size_trigger_fires_on_l1() {
        let mut v = Version::new();
        v.levels[1].push(meta(1, "a", "m", 2 << 20));
        v.levels[2].push(meta(2, "a", "c", 100));
        let job = pick(&v, 100, 1 << 20).unwrap();
        assert_eq!(job.level, 1);
        assert!(job.inputs.contains(&1));
        assert!(job.inputs.contains(&2));
    }
}
