//! In-memory sorted write buffer.  A `BTreeMap` keyed by user key and
//! holding the *latest* write wins — exactly the visibility the engine
//! needs because writes arrive in Raft apply order (single writer).

use super::Value;
use std::collections::BTreeMap;
use std::ops::Bound;

#[derive(Default, Debug)]
pub struct MemTable {
    map: BTreeMap<Vec<u8>, Value>,
    /// Approximate heap footprint (keys + values + per-entry overhead),
    /// used for the flush trigger.
    approx_bytes: usize,
}

const ENTRY_OVERHEAD: usize = 48; // BTreeMap node + Vec headers, rough

impl MemTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: &[u8], value: Value) {
        let add = key.len() + value.encoded_len() + ENTRY_OVERHEAD;
        if let Some(old) = self.map.insert(key.to_vec(), value) {
            let sub = key.len() + old.encoded_len() + ENTRY_OVERHEAD;
            self.approx_bytes = self.approx_bytes.saturating_sub(sub);
        }
        self.approx_bytes += add;
    }

    pub fn get(&self, key: &[u8]) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    /// Ordered iteration over the whole table (for flush).
    pub fn iter(&self) -> impl Iterator<Item = (&[u8], &Value)> {
        self.map.iter().map(|(k, v)| (k.as_slice(), v))
    }

    /// Ordered iteration over `[start, end)`.  An empty `end` means
    /// unbounded (iterate to the last key).
    pub fn range<'a>(
        &'a self,
        start: &[u8],
        end: &[u8],
    ) -> impl Iterator<Item = (&'a [u8], &'a Value)> {
        let upper = if end.is_empty() { Bound::Unbounded } else { Bound::Excluded(end) };
        self.map
            .range::<[u8], _>((Bound::Included(start), upper))
            .map(|(k, v)| (k.as_slice(), v))
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.approx_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latest_write_wins() {
        let mut m = MemTable::new();
        m.insert(b"k", Value::Put(b"v1".to_vec()));
        m.insert(b"k", Value::Put(b"v2".to_vec()));
        assert_eq!(m.get(b"k"), Some(&Value::Put(b"v2".to_vec())));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn tombstone_replaces_put() {
        let mut m = MemTable::new();
        m.insert(b"k", Value::Put(b"v".to_vec()));
        m.insert(b"k", Value::Delete);
        assert_eq!(m.get(b"k"), Some(&Value::Delete));
    }

    #[test]
    fn size_accounting_tracks_overwrites() {
        let mut m = MemTable::new();
        m.insert(b"k", Value::Put(vec![0u8; 1000]));
        let s1 = m.approx_bytes();
        m.insert(b"k", Value::Put(vec![0u8; 10]));
        assert!(m.approx_bytes() < s1);
        m.clear();
        assert_eq!(m.approx_bytes(), 0);
    }

    #[test]
    fn range_is_sorted_and_bounded() {
        let mut m = MemTable::new();
        for k in ["a", "c", "e", "g"] {
            m.insert(k.as_bytes(), Value::Put(k.as_bytes().to_vec()));
        }
        let got: Vec<_> = m.range(b"b", b"f").map(|(k, _)| k.to_vec()).collect();
        assert_eq!(got, vec![b"c".to_vec(), b"e".to_vec()]);
    }

    #[test]
    fn iter_is_globally_sorted() {
        let mut m = MemTable::new();
        for k in ["z", "a", "m", "b"] {
            m.insert(k.as_bytes(), Value::Put(vec![]));
        }
        let keys: Vec<_> = m.iter().map(|(k, _)| k.to_vec()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
